"""Unified telemetry: span tracer, metrics registry, structured run log.

One zero-dependency layer (stdlib + numpy) behind a single knob —
``telemetry=off|metrics|trace`` — that every runtime component reports
through (contracts: DESIGN.md §Observability & telemetry):

  * :class:`Tracer` — thread-aware span context managers
    (``tel.span("prefill_flush", phase=3)``) recording Chrome-trace-event
    JSON loadable in Perfetto / ``chrome://tracing``.  Spans carry the
    recording thread's id, so producer-thread engine spans and
    learner-thread update spans land on separate tracks and their overlap
    (the async pipeline's whole point) is visible, not averaged away.
  * :class:`MetricsRegistry` — typed counters / gauges / histograms with
    percentiles.  The single sink unifying ``ContinuousEngine.stats`` /
    ``_phase_waits`` / ``_phase_lats``, the trainer's per-phase metric
    dicts, the PR-9 resilience counters and the Sparse-RL mismatch
    diagnostics (per-phase xi histogram, veto rate, mean_rho /
    staleness_kl, pool-occupancy timeline).
  * :class:`RunLog` — leveled, step/phase-stamped JSONL event log
    (``reports/run_log.jsonl``) replacing ad-hoc ``print()`` diagnostics,
    with human-readable console rendering at the default level so CLI
    output stays useful.

The ``off`` mode is pinned bitwise-identical to an uninstrumented build:
every instrumentation site goes through :meth:`Telemetry.timed` /
:meth:`Telemetry.span`, which in ``off`` mode return a shared no-op
context manager and never touch a clock, and telemetry only ever observes
host-side values — it never feeds anything back into a compiled program.
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

__all__ = [
    "Telemetry", "Tracer", "MetricsRegistry", "RunLog",
    "Counter", "Gauge", "Histogram", "TELEMETRY_MODES",
]

TELEMETRY_MODES = ("off", "metrics", "trace")


# ---------------------------------------------------------------------------
# span tracer (Chrome trace-event JSON)
# ---------------------------------------------------------------------------
class _NullCtx:
    """Shared no-op context manager: the entire hot-path cost of
    ``telemetry=off`` is one attribute load and returning this singleton."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class _Span:
    """One open span: records a Chrome ``ph:"X"`` complete event on exit.

    Exceptions close the span (``__exit__`` always records, stamping
    ``error`` into the event args) and propagate — tracing never swallows
    a failure."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_tid", "_annot")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._annot = None
        if tracer._jax_annotations:
            from jax.profiler import TraceAnnotation
            self._annot = TraceAnnotation(name)

    def __enter__(self):
        if self._annot is not None:
            self._annot.__enter__()
        self._tid = threading.get_ident()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.perf_counter_ns() - self._t0
        if self._annot is not None:
            self._annot.__exit__(exc_type, exc, tb)
        if exc_type is not None:
            self.args = dict(self.args, error=exc_type.__name__)
        self._tracer._record(self.name, self._t0, dur, self._tid, self.args)
        return False


class Tracer:
    """Collects Chrome trace events; thread-safe; bounded.

    Timestamps are ``perf_counter_ns`` relative to tracer construction,
    emitted in microseconds (the trace-event unit).  ``max_events`` bounds
    memory on long runs — overflow drops the newest events and counts them
    (``dropped_events``), never silently."""

    def __init__(self, *, jax_annotations: bool = False,
                 max_events: int = 500_000):
        self._epoch_ns = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._jax_annotations = jax_annotations
        self._max_events = max_events
        self.dropped_events = 0
        self._pid = os.getpid()

    # -- recording ------------------------------------------------------
    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def _record(self, name, t0_ns, dur_ns, tid, args) -> None:
        ev = {"name": name, "ph": "X", "pid": self._pid, "tid": tid,
              "ts": (t0_ns - self._epoch_ns) / 1e3, "dur": dur_ns / 1e3}
        if args:
            ev["args"] = args
        self._append(ev)

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker (weight swaps, fault firings)."""
        ev = {"name": name, "ph": "i", "s": "t", "pid": self._pid,
              "tid": threading.get_ident(),
              "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3}
        if args:
            ev["args"] = args
        self._append(ev)

    def counter(self, name: str, value: float) -> None:
        """A Chrome counter sample — renders as a timeline track
        (pool-occupancy over the phase)."""
        self._append({"name": name, "ph": "C", "pid": self._pid,
                      "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
                      "args": {"value": value}})

    def _append(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) >= self._max_events:
                self.dropped_events += 1
                return
            self._events.append(ev)

    # -- export ---------------------------------------------------------
    def to_chrome(self, other_data: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, Any]:
        """The Chrome trace-event container object (JSON Object Format):
        ``traceEvents`` plus free-form ``otherData`` — Perfetto ignores the
        extra keys, `tools/trace_report.py` reads the embedded metrics
        snapshot from them."""
        with self._lock:
            events = list(self._events)
        out: Dict[str, Any] = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": dict(other_data or {}),
        }
        if self.dropped_events:
            out["otherData"]["dropped_events"] = self.dropped_events
        return out


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
class Counter:
    """Monotone accumulator (admissions, restarts, skipped updates)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value}


class Gauge:
    """Last-write-wins point value (pool peak fraction, weight version)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value}


class Histogram:
    """Sampled distribution with exact percentiles up to ``max_samples``.

    Keeps the raw observations (so ``percentile`` agrees bit-for-bit with
    ``np.percentile`` — the testable contract) plus running count / sum
    over ALL observations.  Past ``max_samples`` it degrades to a
    deterministic reservoir (seeded per-histogram RNG: two runs observing
    the same sequence snapshot identically) — percentiles become estimates
    but never cost unbounded memory on million-token runs."""

    def __init__(self, name: str, max_samples: int = 65536):
        self.name = name
        self._max = max_samples
        self._samples: List[float] = []
        self.count = 0
        self.sum = 0.0
        self._lock = threading.Lock()
        self._rng = np.random.default_rng(abs(hash(name)) % (2 ** 32))

    def observe(self, v: float) -> None:
        self.observe_many((v,))

    def observe_many(self, vs: Sequence[float]) -> None:
        arr = np.asarray(vs, np.float64).ravel()
        if arr.size == 0:
            return
        with self._lock:
            self.sum += float(arr.sum())
            for v in arr:
                self.count += 1
                if len(self._samples) < self._max:
                    self._samples.append(float(v))
                else:                      # reservoir: keep each with k/n
                    j = int(self._rng.integers(0, self.count))
                    if j < self._max:
                        self._samples[j] = float(v)

    def percentile(self, q) -> Any:
        with self._lock:
            if not self._samples:
                return float("nan") if np.isscalar(q) else \
                    np.full(len(q), np.nan)
            return np.percentile(np.asarray(self._samples), q)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            if not self._samples:
                return {"count": 0, "sum": 0.0}
            s = np.asarray(self._samples)
        p50, p90, p99 = np.percentile(s, [50, 90, 99])
        return {"count": self.count, "sum": self.sum,
                "mean": self.sum / self.count,
                "min": float(s.min()), "max": float(s.max()),
                "p50": float(p50), "p90": float(p90), "p99": float(p99)}


class MetricsRegistry:
    """Get-or-create registry of typed metrics, thread-safe.

    Names are dotted (``engine.admissions``, ``mismatch.log_xi``); a name
    registered as one type and fetched as another is a loud ``TypeError``
    — the registry is the schema."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, klass, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = klass(name, **kw)
            elif not isinstance(m, klass):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {klass.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """``{name: {field: value}}`` — the JSON-ready registry state that
        trace export embeds and `tools/trace_report.py` summarizes."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.snapshot() for name, m in items}


# ---------------------------------------------------------------------------
# structured run log
# ---------------------------------------------------------------------------
_LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}


class RunLog:
    """Leveled, step/phase-stamped structured event log.

    Each event is one JSONL record (``ts`` wall-clock, ``level``,
    ``event``, optional ``step``/``phase``/``msg`` plus free-form fields)
    appended to ``path`` when configured, and — at or above
    ``console_level`` — rendered human-readably to the console, so the
    CLIs keep their `[step N] ...` output while every diagnostic also
    lands machine-parseable in ``reports/run_log.jsonl``."""

    def __init__(self, path: Optional[str] = None,
                 console_level: Optional[str] = "info",
                 stream=None):
        self.path = path
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            self._fh = open(path, "a", encoding="utf-8")
        self._console = (_LEVELS[console_level]
                         if console_level is not None else None)
        self._stream = stream if stream is not None else sys.stdout
        self._lock = threading.Lock()

    def event(self, event: str, *, level: str = "info",
              step: Optional[int] = None, phase: Optional[int] = None,
              msg: Optional[str] = None, **fields) -> None:
        lv = _LEVELS[level]
        rec: Dict[str, Any] = {"ts": round(time.time(), 6), "level": level,
                               "event": event}
        if step is not None:
            rec["step"] = int(step)
        if phase is not None:
            rec["phase"] = int(phase)
        if msg is not None:
            rec["msg"] = msg
        for k, v in fields.items():
            rec[k] = _jsonable(v)
        with self._lock:
            if self._fh is not None:
                self._fh.write(json.dumps(rec) + "\n")
                self._fh.flush()
            if self._console is not None and lv >= self._console:
                print(self._render(rec), file=self._stream, flush=True)

    @staticmethod
    def _render(rec: Dict[str, Any]) -> str:
        head = ""
        if "step" in rec:
            head = f"[step {rec['step']}] "
        elif "phase" in rec:
            head = f"[phase {rec['phase']}] "
        if rec["level"] in ("warn", "error"):
            head += f"{rec['level'].upper()} "
        if "msg" in rec:
            return head + rec["msg"]
        body = " ".join(f"{k}={_fmt(v)}" for k, v in rec.items()
                        if k not in ("ts", "level", "event", "step", "phase"))
        return f"{head}{rec['event']}" + (f": {body}" if body else "")

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)

def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


# ---------------------------------------------------------------------------
# the facade: one handle, one knob
# ---------------------------------------------------------------------------
class _TimedSpan:
    """Span + duration-histogram observation in one context manager: trace
    mode gets the Chrome event, metrics mode gets ``<name>_s`` observed in
    the registry (the phase-breakdown source when spans are off)."""

    __slots__ = ("_tel", "_name", "_args", "_span", "_t0")

    def __init__(self, tel: "Telemetry", name: str, args: Dict[str, Any]):
        self._tel = tel
        self._name = name
        self._args = args

    def __enter__(self):
        self._span = None
        if self._tel.tracer is not None:
            self._span = self._tel.tracer.span(self._name, **self._args)
            self._span.__enter__()
        else:
            self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._span is not None:
            dt = (time.perf_counter_ns() - self._span._t0) / 1e9
            self._span.__exit__(exc_type, exc, tb)
        else:
            dt = time.perf_counter() - self._t0
        self._tel.metrics.histogram(self._name + "_s").observe(dt)
        return False


class Telemetry:
    """The one handle components hold; behaviour keyed on ``mode``:

      * ``off``     — ``span``/``timed``/``instant``/``counter_sample``
        return/do nothing (shared no-op singleton, no clock reads); the
        run log still works (console rendering replaces the old prints).
      * ``metrics`` — registry on: ``timed`` observes duration histograms,
        observe/count/gauge record; spans stay off (≤ 3 % phase wall-clock
        — the bench-gated bound).
      * ``trace``   — everything: spans + instants + counter timelines on
        the tracer, plus the full registry.

    ``jax_annotations=True`` additionally wraps every traced span in
    ``jax.profiler.TraceAnnotation`` so device profiles collected with the
    JAX profiler line up with these host spans."""

    def __init__(self, mode: str = "off", *,
                 run_log_path: Optional[str] = None,
                 console_level: Optional[str] = "info",
                 jax_annotations: bool = False,
                 log_stream=None):
        if mode not in TELEMETRY_MODES:
            raise ValueError(f"telemetry mode {mode!r} not in "
                             f"{TELEMETRY_MODES}")
        self.mode = mode
        self.metrics_on = mode in ("metrics", "trace")
        self.trace_on = mode == "trace"
        self.tracer = Tracer(jax_annotations=jax_annotations) \
            if self.trace_on else None
        self.metrics = MetricsRegistry() if self.metrics_on else None
        self.log = RunLog(run_log_path, console_level=console_level,
                          stream=log_stream)

    # -- spans ----------------------------------------------------------
    def span(self, name: str, **args):
        """Trace-only span (no registry side effects)."""
        if self.tracer is None:
            return _NULL_CTX
        return self.tracer.span(name, **args)

    def timed(self, name: str, **args):
        """Span + ``<name>_s`` duration histogram (the instrumentation
        idiom for hot-path sections that feed the phase breakdown)."""
        if not self.metrics_on:
            return _NULL_CTX
        return _TimedSpan(self, name, args)

    def instant(self, name: str, **args) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, **args)

    def counter_sample(self, name: str, value: float) -> None:
        """One point on a counter timeline (trace) + histogram observation
        (metrics) — the pool-occupancy-over-time idiom."""
        if not self.metrics_on:
            return
        if self.tracer is not None:
            self.tracer.counter(name, value)
        self.metrics.histogram(name).observe(value)

    # -- registry shortcuts (no-ops when metrics are off) ---------------
    def count(self, name: str, n: float = 1.0) -> None:
        if self.metrics is not None:
            self.metrics.counter(name).inc(n)

    def gauge(self, name: str, v: float) -> None:
        if self.metrics is not None:
            self.metrics.gauge(name).set(v)

    def observe(self, name: str, v) -> None:
        if self.metrics is not None:
            if np.ndim(v):
                self.metrics.histogram(name).observe_many(v)
            else:
                self.metrics.histogram(name).observe(v)

    # -- export ---------------------------------------------------------
    def export_trace(self, path: str) -> Optional[str]:
        """Write the Chrome trace JSON (with the metrics snapshot embedded
        under ``otherData.metrics``).  No-op unless mode is ``trace``."""
        if self.tracer is None:
            return None
        other: Dict[str, Any] = {}
        if self.metrics is not None:
            other["metrics"] = self.metrics.snapshot()
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.tracer.to_chrome(other), fh)
        return path

    def close(self) -> None:
        self.log.close()


# the module-level default every component falls back to: off-mode with
# console-only logging — holding it is free and unconditional, so call
# sites never branch on "is telemetry configured"
NULL = Telemetry("off")
