"""Shared model building blocks: RMSNorm, RoPE, inits, embeddings.

All modules are functional: parameters are plain nested dicts of jnp arrays.
Every init function returns ``(params, axes)`` where ``axes`` mirrors the
param tree with tuples of *logical* axis names (consumed by
``repro.distributed.sharding``).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, dtype_of


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def _normal(rng, shape, dtype, std):
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def dense_init(rng, in_dim: int, out_dim: int, axes: Tuple[str, ...],
               dtype, *, bias: bool = False, std: Optional[float] = None,
               quant: str = "none"):
    """A linear layer W:(in,out) (+ optional b:(out,)).

    quant="int8": symmetric per-output-channel quantization — storage is
    int8 q:(in,out) + f32 scale:(out,).  Halves the weight-read bytes on the
    serving path (the dominant HBM term of batch<=1 decode)."""
    std = std if std is not None else 1.0 / math.sqrt(in_dim)
    if quant == "int8":
        w = _normal(rng, (in_dim, out_dim), jnp.float32, std)
        q, scale = quantize_int8(w)
        p = {"q": q, "scale": scale}
        a = {"q": axes, "scale": (axes[-1],)}
    else:
        p = {"w": _normal(rng, (in_dim, out_dim), dtype, std)}
        a = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        a["b"] = (axes[-1],)
    return p, a


def quantize_int8(w: jnp.ndarray):
    """Symmetric per-output-channel int8 quantization of (in, out)."""
    amax = jnp.max(jnp.abs(w), axis=0)                   # (out,)
    scale = (amax / 127.0 + 1e-12).astype(jnp.float32)
    q = jnp.clip(jnp.round(w / scale[None, :]), -127, 127).astype(jnp.int8)
    return q, scale


def apply_dense(p, x, compute_dtype):
    if "q" in p:
        w = p["q"].astype(compute_dtype) * p["scale"].astype(compute_dtype)
    else:
        w = p["w"].astype(compute_dtype)
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype), w)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def norm_init(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("embed",)}


def rms_norm(p, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_init(rng, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    p = {"tok": _normal(rng, (cfg.vocab_size, cfg.d_model), dtype, 0.02)}
    a = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["head"] = _normal(jax.random.fold_in(rng, 1),
                            (cfg.d_model, cfg.vocab_size), dtype,
                            1.0 / math.sqrt(cfg.d_model))
        a["head"] = ("embed", "vocab")
    return p, a


def embed_tokens(p, tokens, compute_dtype):
    return p["tok"].astype(compute_dtype)[tokens]


def unembed(p, x, cfg: ModelConfig):
    """Final logits in float32 (sampling / log-prob numerics)."""
    w = p["head"] if "head" in p else p["tok"].T
    logits = jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    if cfg.logits_softcap > 0:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return logits


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_init(rng, cfg: ModelConfig, d_ff: int):
    dtype = dtype_of(cfg.param_dtype)
    r = jax.random.split(rng, 3)
    q = cfg.weight_quant
    if cfg.mlp_style == "swiglu":
        p, a = {}, {}
        p["gate"], a["gate"] = dense_init(r[0], cfg.d_model, d_ff, ("embed", "ffn"), dtype, quant=q)
        p["up"], a["up"] = dense_init(r[1], cfg.d_model, d_ff, ("embed", "ffn"), dtype, quant=q)
        p["down"], a["down"] = dense_init(r[2], d_ff, cfg.d_model, ("ffn", "embed"), dtype, quant=q)
        return p, a
    p, a = {}, {}
    p["up"], a["up"] = dense_init(r[0], cfg.d_model, d_ff, ("embed", "ffn"), dtype, quant=q)
    p["down"], a["down"] = dense_init(r[1], d_ff, cfg.d_model, ("ffn", "embed"), dtype, quant=q)
    return p, a


def apply_mlp(p, x, cfg: ModelConfig):
    cdt = dtype_of(cfg.compute_dtype) if x.dtype != jnp.float32 else x.dtype
    if cfg.mlp_style == "swiglu":
        h = jax.nn.silu(apply_dense(p["gate"], x, cdt)) * apply_dense(p["up"], x, cdt)
    else:
        h = jax.nn.gelu(apply_dense(p["up"], x, cdt))
    from repro.distributed.sharding import lsc

    h = lsc(h, *((None,) * (h.ndim - 1)), "ffn")
    return apply_dense(p["down"], h, cdt)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------
def log_softmax_gather(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Per-token log-probs of `tokens` under `logits` (float32, stable)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tok_logit = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
    return tok_logit - lse


def entropy_from_logits(logits: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)
