"""GQA attention: full-sequence (train / prefill) and cached decode step."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SparseRLConfig, dtype_of
from repro.distributed.sharding import lsc
from repro.kvcache import KVCache, append, attend, enforce_budget, update_scores
from repro.kvcache.paged import PagedKVCache, paged_append, paged_attend
from repro.models.common import apply_dense, apply_rope, dense_init


def attn_init(rng, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    r = jax.random.split(rng, 4)
    hd, Hq, Hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    p, a = {}, {}
    q = cfg.weight_quant
    p["wq"], a["wq"] = dense_init(r[0], cfg.d_model, Hq * hd, ("embed", "heads"),
                                  dtype, bias=cfg.qkv_bias, quant=q)
    p["wk"], a["wk"] = dense_init(r[1], cfg.d_model, Hkv * hd, ("embed", "kv_heads"),
                                  dtype, bias=cfg.qkv_bias, quant=q)
    p["wv"], a["wv"] = dense_init(r[2], cfg.d_model, Hkv * hd, ("embed", "kv_heads"),
                                  dtype, bias=cfg.qkv_bias, quant=q)
    p["wo"], a["wo"] = dense_init(r[3], Hq * hd, cfg.d_model, ("heads", "embed"),
                                  dtype, quant=q)
    return p, a


def _project_qkv(p, x, cfg: ModelConfig, positions):
    """x: (B, S, D) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd); RoPE applied."""
    cdt = x.dtype
    B, S, _ = x.shape
    q = apply_dense(p["wq"], x, cdt).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = apply_dense(p["wk"], x, cdt).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = apply_dense(p["wv"], x, cdt).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = lsc(q, "batch", "seq", "heads", None)
    k = lsc(k, "batch", "seq", "kv_heads", None)
    v = lsc(v, "batch", "seq", "kv_heads", None)
    return q, k, v


FLASH_SEQ_THRESHOLD = 2048  # use blocked attention at/above this length


def full_attention(p, x, cfg: ModelConfig, *, positions=None,
                   valid_mask: Optional[jnp.ndarray] = None,
                   causal: bool = True,
                   return_kv: bool = False,
                   use_flash: Optional[bool] = None):
    """Teacher-forced attention.  valid_mask: (B, S) True for real tokens.

    Returns ``out`` or ``(out, (k, v))`` with k/v in (B, Hkv, S, hd) layout
    (cache layout) when ``return_kv``.  ``use_flash`` selects the blocked
    online-softmax path (O(block^2) memory — mandatory for long prefill /
    re-scoring); defaults to S >= FLASH_SEQ_THRESHOLD.
    """
    B, S, D = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = _project_qkv(p, x, cfg, positions)
    if use_flash is None:
        use_flash = S >= FLASH_SEQ_THRESHOLD
    if use_flash:
        from repro.models.flash import flash_attention

        out = flash_attention(q, k, v, q_positions=positions,
                              kv_positions=positions, kv_valid=valid_mask,
                              causal=causal)
    else:
        G = cfg.num_heads // cfg.num_kv_heads
        qg = q.reshape(B, S, cfg.num_kv_heads, G, cfg.head_dim)
        scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((B, 1, 1, S, S), bool)
        if causal:
            cm = positions[:, :, None] >= positions[:, None, :]   # q >= k
            mask = mask & cm[:, None, None, :, :]
        if valid_mask is not None:
            mask = mask & valid_mask[:, None, None, None, :]
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
        out = out.reshape(B, S, cfg.num_heads, cfg.head_dim)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = lsc(out, "batch", "seq", "heads")
    y = apply_dense(p["wo"], out, x.dtype)
    if return_kv:
        kc = jnp.swapaxes(k, 1, 2)   # (B, Hkv, S, hd)
        vc = jnp.swapaxes(v, 1, 2)
        return y, (kc, vc)
    return y


def obs_window_scores(p, x, cfg: ModelConfig, positions, valid_mask,
                      window: int) -> jnp.ndarray:
    """SnapKV selection signal: attention of the last `window` (valid) query
    positions over all keys, pooled over the window and the GQA group.
    Returns (B, Hkv, S).  Cheap: only W x S logits, no S x S matrix."""
    B, S, D = x.shape
    # chunked prefill can present a bucket narrower than the window; the
    # selection signal then pools over every available query column
    window = min(window, S)
    q, k, _ = _project_qkv(p, x, cfg, positions)
    # last `window` valid positions are ... the last `window` columns when the
    # prompt is left-padded (our convention).
    qw = q[:, -window:]                                        # (B, W, Hq, hd)
    G = cfg.num_heads // cfg.num_kv_heads
    qg = qw.reshape(B, window, cfg.num_kv_heads, G, cfg.head_dim)
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
    logits = jnp.einsum("bwhgd,bkhd->bhgwk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    kmask = valid_mask[:, None, None, None, :]
    wpos = positions[:, -window:]
    cm = wpos[:, :, None] >= positions[:, None, :]             # (B, W, S)
    logits = jnp.where(kmask & cm[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(kmask & cm[:, None, None, :, :], probs, 0.0)
    return probs.sum(axis=(2, 3))                              # (B, Hkv, S)


def decode_attention(p, x_tok, cfg: ModelConfig, cache: KVCache,
                     scfg: SparseRLConfig, cur_pos: jnp.ndarray,
                     ) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode.  x_tok: (B, D) hidden; cur_pos: (B,) absolute pos.

    Contiguous cache: evict-if-full -> append -> attend (incl. new token) ->
    score update -> budget enforcement (a no-op except for the "per_head" /
    "adaptive" registry policies, whose budgets are applied by slot
    invalidation — DESIGN.md §Sampler policy registry).  The per_head policy
    attends through the fused budget-attention kernel (`ops.budget_attention`
    — one pass produces both the output and the pooled per-slot mass its
    score update consumes).  Paged cache (block-table pool, dense only — no
    eviction, no score update): append through the block table -> attend the
    materialized page chains (identical math; DESIGN.md §Paged cache &
    prefix sharing).
    """
    B, D = x_tok.shape
    x = x_tok[:, None, :]
    q, k, v = _project_qkv(p, x, cfg, cur_pos[:, None])
    q1 = q[:, 0]                                                # (B, Hq, hd)
    k1 = jnp.swapaxes(k, 1, 2)[:, :, 0]                          # (B, Hkv, hd)
    v1 = jnp.swapaxes(v, 1, 2)[:, :, 0]
    if isinstance(cache, PagedKVCache):
        cache = paged_append(cache, k1, v1, cur_pos)
        out = paged_attend(q1, cache)
    else:
        cache = append(cache, k1, v1, cur_pos, scfg)
        if scfg.compression == "per_head":
            from repro.kernels import ops

            out, probs_pooled = ops.budget_attention(
                q1, cache.k, cache.v, cache.pos)
        else:
            out, probs_pooled = attend(q1, cache)
        cache = update_scores(cache, probs_pooled, scfg)
        cache = enforce_budget(cache, scfg, cur_pos)
    out = out.reshape(B, cfg.num_heads * cfg.head_dim)
    y = apply_dense(p["wo"], out, x_tok.dtype)
    return y, cache


def cross_attention_init(rng, cfg: ModelConfig):
    """Enc-dec cross attention (whisper): separate qkv over encoder states."""
    return attn_init(rng, cfg)


def cross_attention(p, x, enc_kv, cfg: ModelConfig, enc_mask=None):
    """x: (B, S, D) decoder hiddens; enc_kv: (k, v) each (B, Henc_kv, T, hd)
    precomputed from encoder output (no RoPE across modalities)."""
    B, S, D = x.shape
    k, v = enc_kv
    T = k.shape[2]
    q = apply_dense(p["wq"], x, x.dtype).reshape(B, S, cfg.num_heads, cfg.head_dim)
    G = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(B, S, cfg.num_kv_heads, G, cfg.head_dim)
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
    logits = jnp.einsum("bqhgd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if enc_mask is not None:
        logits = jnp.where(enc_mask[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqk,bhkd->bqhgd", probs, v)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return apply_dense(p["wo"], out, x.dtype)


def project_enc_kv(p, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output: (B, Hkv, T, hd)."""
    B, T, D = enc_out.shape
    k = apply_dense(p["wk"], enc_out, enc_out.dtype).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = apply_dense(p["wv"], enc_out, enc_out.dtype).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    return jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
