"""Decoder-only transformer (dense / MoE / VLM) with scan-over-layers.

Layer parameters are stacked with a leading ``L`` dim and consumed by
``jax.lax.scan`` so HLO size (and compile time) is O(1) in depth; each layer
body is optionally rematerialized (``cfg.remat == 'block'``).

Public entry points (used by rollout / trainer / launch):
  init_params / param_axes
  forward         : teacher-forced logits over a full sequence
  prefill         : forward + build per-layer (possibly compressed) KV caches
  decode_step     : one-token step against the cache stack
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MOE, ModelConfig, SparseRLConfig, dtype_of
from repro.distributed.sharding import lsc
from repro.kvcache import KVCache, compress_prefill
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import (
    apply_mlp,
    embed_init,
    embed_tokens,
    mlp_init,
    norm_init,
    rms_norm,
    unembed,
)


class DecodeState(NamedTuple):
    caches: KVCache          # stacked: every leaf has leading layer dim L
    pos: jnp.ndarray         # (B,) next absolute position per row


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _layer_init(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 4)
    p, a = {}, {}
    p["ln1"], a["ln1"] = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    p["attn"], a["attn"] = attn.attn_init(r[0], cfg)
    p["ln2"], a["ln2"] = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    if cfg.family == MOE:
        p["moe"], a["moe"] = moe_mod.moe_init(r[1], cfg)
    else:
        p["mlp"], a["mlp"] = mlp_init(r[1], cfg, cfg.d_ff)
    return p, a


def init_params(cfg: ModelConfig, rng):
    r_emb, r_layers, r_final = jax.random.split(rng, 3)
    emb, emb_a = embed_init(r_emb, cfg)
    layer_rngs = jax.random.split(r_layers, cfg.num_layers)
    stacked = jax.vmap(lambda r: _layer_init(r, cfg)[0])(layer_rngs)
    fn, fn_a = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    return {"embed": emb, "layers": stacked, "final_norm": fn}


def param_axes(cfg: ModelConfig):
    """Logical axis names mirroring init_params' tree (no allocation)."""
    emb_a = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb_a["head"] = ("embed", "vocab")
    layer_a = _layer_axes(cfg)
    stacked_a = jax.tree.map(lambda t: ("layers",) + t, layer_a,
                             is_leaf=lambda x: isinstance(x, tuple) and all(
                                 isinstance(e, (str, type(None))) for e in x))
    fn_a = {"scale": ("embed",)}
    return {"embed": emb_a, "layers": stacked_a, "final_norm": fn_a}


def _dense_axes(cfg: ModelConfig, axes):
    if cfg.weight_quant == "int8":
        return {"q": axes, "scale": (axes[-1],)}
    return {"w": axes}


def _layer_axes(cfg: ModelConfig):
    a = {}
    a["ln1"] = {"scale": ("embed",)}
    a["ln2"] = {"scale": ("embed",)}
    attn_a = {
        "wq": _dense_axes(cfg, ("embed", "heads")),
        "wk": _dense_axes(cfg, ("embed", "kv_heads")),
        "wv": _dense_axes(cfg, ("embed", "kv_heads")),
        "wo": _dense_axes(cfg, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        for n in ("wq", "wk", "wv"):
            attn_a[n]["b"] = (("heads",) if n == "wq" else ("kv_heads",))
    a["attn"] = attn_a
    if cfg.family == MOE:
        a["moe"] = {
            "router": ("embed", None),
            "gate": ("experts", "embed", "moe_ffn"),
            "up": ("experts", "embed", "moe_ffn"),
            "down": ("experts", "moe_ffn", "embed"),
        }
    else:
        mlp_a = {"up": _dense_axes(cfg, ("embed", "ffn")),
                 "down": _dense_axes(cfg, ("ffn", "embed"))}
        if cfg.mlp_style == "swiglu":
            mlp_a["gate"] = _dense_axes(cfg, ("embed", "ffn"))
        a["mlp"] = mlp_a
    return a


# ---------------------------------------------------------------------------
# Forward (teacher-forced)
# ---------------------------------------------------------------------------
def _embed_inputs(params, cfg: ModelConfig, tokens, prefix_embeds):
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cdt), x], axis=1)
    return lsc(x, "batch", "seq", "embed")


def _extend_for_prefix(x, valid_mask, positions, n_prefix: int):
    """Prepend the (always-valid) patch prefix to caller-supplied masks and
    positions that cover only the token part."""
    B = x.shape[0]
    if valid_mask is not None and valid_mask.shape[1] + n_prefix == x.shape[1]:
        valid_mask = jnp.concatenate(
            [jnp.ones((B, n_prefix), bool), valid_mask], axis=1)
    if positions is not None and positions.shape[1] + n_prefix == x.shape[1]:
        positions = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(n_prefix)[None], (B, n_prefix)),
             positions + n_prefix], axis=1)
    return valid_mask, positions


def _block(cfg: ModelConfig, p, x, positions, valid_mask, use_flash):
    from repro.distributed.sharding import layer_param_lsc

    p = layer_param_lsc(p, _layer_axes(cfg))
    h = rms_norm(p["ln1"], x, cfg.rms_eps)
    h = attn.full_attention(p["attn"], h, cfg, positions=positions,
                            valid_mask=valid_mask, use_flash=use_flash)
    x = x + h
    h = rms_norm(p["ln2"], x, cfg.rms_eps)
    if cfg.family == MOE:
        h, aux = moe_mod.apply_moe(p["moe"], h, cfg)
    else:
        h, aux = apply_mlp(p["mlp"], h, cfg), jnp.float32(0)
    x = lsc(x + h, "batch", "seq", "embed")
    return x, aux


def forward(params, cfg: ModelConfig, tokens, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            valid_mask: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            use_flash: Optional[bool] = None,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B, S, V) float32, aux_loss scalar).

    For VLM, ``prefix_embeds`` (B, P, D) are prepended; logits cover the full
    (P + S) sequence; labels should mask the prefix.
    """
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    B, S, _ = x.shape
    if prefix_embeds is not None:
        valid_mask, positions = _extend_for_prefix(
            x, valid_mask, positions, prefix_embeds.shape[1])
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if valid_mask is None:
        valid_mask = jnp.ones((B, S), bool)

    def body(carry, lp):
        xc, aux = carry
        xn, a = _block(cfg, lp, xc, positions, valid_mask, use_flash)
        return (xn, aux + a), None

    body_fn = body
    if cfg.remat == "block":
        body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    k = cfg.remat_chunk
    if cfg.scan_layers and k > 1 and cfg.num_layers % k == 0:
        # 2-level remat: outer scan over L/k chunks saves only chunk
        # boundaries; the whole inner k-layer scan recomputes in backward.
        chunked = jax.tree.map(
            lambda t: t.reshape(cfg.num_layers // k, k, *t.shape[1:]),
            params["layers"])

        def chunk_body(carry, chunk_params):
            return jax.lax.scan(body, carry, chunk_params)

        chunk_fn = jax.checkpoint(
            chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), _ = jax.lax.scan(chunk_fn, (x, jnp.float32(0)), chunked)
    elif cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)), params["layers"])
    else:
        aux = jnp.float32(0)
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            (x, aux), _ = body_fn((x, aux), lp)
    x = rms_norm(params["final_norm"], x, cfg.rms_eps)
    logits = unembed(params["embed"], x, cfg)
    return lsc(logits, "batch", "seq", "vocab"), aux


# ---------------------------------------------------------------------------
# Prefill: forward + build per-layer caches (dense or compressed)
# ---------------------------------------------------------------------------
def prefill(params, cfg: ModelConfig, tokens, *, scfg: SparseRLConfig,
            slots: int,
            prefix_embeds: Optional[jnp.ndarray] = None,
            valid_mask: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            use_flash: Optional[bool] = None,
            ) -> Tuple[jnp.ndarray, DecodeState]:
    """Returns (last-token logits (B, V), DecodeState with L-stacked caches).

    With a compressing ``scfg``, each layer's prompt KVs are reduced to
    ``slots`` via the SnapKV-style observation-window selection; the same
    scores seed the h2o/rkv importance accumulators.
    """
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    B, S, _ = x.shape
    if prefix_embeds is not None:
        valid_mask, positions = _extend_for_prefix(
            x, valid_mask, positions, prefix_embeds.shape[1])
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if valid_mask is None:
        valid_mask = jnp.ones((B, S), bool)

    def body(carry, lp):
        xc = carry
        h = rms_norm(lp["ln1"], xc, cfg.rms_eps)
        hattn, (kc, vc) = attn.full_attention(
            lp["attn"], h, cfg, positions=positions, valid_mask=valid_mask,
            return_kv=True, use_flash=use_flash)
        obs = attn.obs_window_scores(lp["attn"], h, cfg, positions, valid_mask,
                                     window=max(scfg.obs_window, 1))
        xc = xc + hattn
        h = rms_norm(lp["ln2"], xc, cfg.rms_eps)
        if cfg.family == MOE:
            h, _ = moe_mod.apply_moe(lp["moe"], h, cfg)
        else:
            h = apply_mlp(lp["mlp"], h, cfg)
        xc = lsc(xc + h, "batch", "seq", "embed")
        cache = compress_prefill(kc, vc, valid_mask, obs, slots, scfg, positions)
        return xc, cache

    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, params["layers"])
    else:
        caches = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            x, c = body(x, lp)
            caches.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    x = rms_norm(params["final_norm"], x, cfg.rms_eps)
    logits_last = unembed(params["embed"], x[:, -1], cfg)
    next_pos = jnp.max(jnp.where(valid_mask, positions, -1), axis=-1) + 1
    return logits_last, DecodeState(caches=caches, pos=next_pos.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def decode_step(params, cfg: ModelConfig, state: DecodeState, tokens,
                scfg: SparseRLConfig) -> Tuple[jnp.ndarray, DecodeState]:
    """tokens: (B,) int32 — the tokens sampled at the previous step.
    Returns (logits (B, V) float32, new state)."""
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt)              # (B, D)
    x = lsc(x, "batch", "embed")

    def body(xc, layer):
        lp, cache = layer
        h = rms_norm(lp["ln1"], xc[:, None, :], cfg.rms_eps)[:, 0]
        hattn, cache = attn.decode_attention(lp["attn"], h, cfg, cache, scfg,
                                             state.pos)
        xc = xc + hattn
        h = rms_norm(lp["ln2"], xc[:, None, :], cfg.rms_eps)
        if cfg.family == MOE:
            h, _ = moe_mod.apply_moe(lp["moe"], h, cfg)
        else:
            h = apply_mlp(lp["mlp"], h, cfg)
        xc = xc + h[:, 0]
        return xc, cache

    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, (params["layers"], state.caches))
    else:
        caches = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            c = jax.tree.map(lambda t: t[i], state.caches)
            x, cn = body(x, (lp, c))
            caches.append(cn)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    x = rms_norm(params["final_norm"], x[:, None, :], cfg.rms_eps)[:, 0]
    logits = unembed(params["embed"], x, cfg)
    return logits, DecodeState(caches=caches, pos=state.pos + 1)
