from repro.models.lm import ModelFns, get_model

__all__ = ["get_model", "ModelFns"]
