"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block inserted
every ``hybrid_attn_every`` layers (weights shared across occurrences, each
occurrence with its own KV cache — Sparse-RL's budget cache applies to these
attention caches; the Mamba2 state stays O(1)).

Simplification vs the released Zamba2 (documented in DESIGN.md): the shared
block consumes the hidden stream directly (no concat-with-embedding or
per-occurrence LoRA).  Layout: n_super super-blocks of (K mamba layers + the
shared attn block), plus L - n_super*K trailing mamba layers.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SparseRLConfig, dtype_of
from repro.distributed.sharding import lsc
from repro.kvcache import KVCache, compress_prefill
from repro.models import attention as attn
from repro.models import mamba2 as mb
from repro.models.common import (
    apply_mlp,
    embed_init,
    embed_tokens,
    mlp_init,
    norm_init,
    rms_norm,
    unembed,
)


class HybridState(NamedTuple):
    conv_super: jnp.ndarray   # (n_super, K, B, W-1, ch)
    h_super: jnp.ndarray      # (n_super, K, B, H, P, N)
    conv_rest: jnp.ndarray    # (r, B, W-1, ch)
    h_rest: jnp.ndarray       # (r, B, H, P, N)
    caches: KVCache           # stacked (n_super, ...)
    pos: jnp.ndarray          # (B,)


def _split(cfg: ModelConfig) -> Tuple[int, int, int]:
    K = cfg.hybrid_attn_every
    n_super = cfg.num_layers // K
    rest = cfg.num_layers - n_super * K
    return n_super, K, rest


def init_params(cfg: ModelConfig, rng):
    r = jax.random.split(rng, 5)
    emb, _ = embed_init(r[0], cfg)
    n_super, K, rest = _split(cfg)
    rngs = jax.random.split(r[1], n_super * K)
    m_super = jax.vmap(lambda k: mb._ssm_layer_init(k, cfg)[0])(rngs)
    m_super = jax.tree.map(lambda t: t.reshape(n_super, K, *t.shape[1:]), m_super)
    if rest:
        rngs_r = jax.random.split(r[2], rest)
        m_rest = jax.vmap(lambda k: mb._ssm_layer_init(k, cfg)[0])(rngs_r)
    else:
        m_rest = None
    # shared attention block (single copy)
    sa = {}
    sa["ln1"], _ = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    sa["attn"], _ = attn.attn_init(r[3], cfg)
    sa["ln2"], _ = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    sa["mlp"], _ = mlp_init(r[4], cfg, cfg.d_ff)
    fn, _ = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    p = {"embed": emb, "mamba_super": m_super, "shared_attn": sa,
         "final_norm": fn}
    if m_rest is not None:
        p["mamba_rest"] = m_rest
    return p


def param_axes(cfg: ModelConfig):
    n_super, K, rest = _split(cfg)
    m_axes = mb.ssm_layer_axes(cfg)
    is_ax = lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
    sup = jax.tree.map(lambda t: ("layers", "layers") + t, m_axes, is_leaf=is_ax)
    emb_a = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb_a["head"] = ("embed", "vocab")
    attn_a = {
        "wq": {"w": ("embed", "heads")},
        "wk": {"w": ("embed", "kv_heads")},
        "wv": {"w": ("embed", "kv_heads")},
        "wo": {"w": ("heads", "embed")},
    }
    if cfg.qkv_bias:
        for n in ("wq", "wk", "wv"):
            attn_a[n]["b"] = (attn_a[n]["w"][-1],)
    mlp_a = {"up": {"w": ("embed", "ffn")}, "down": {"w": ("ffn", "embed")}}
    if cfg.mlp_style == "swiglu":
        mlp_a["gate"] = {"w": ("embed", "ffn")}
    sa = {"ln1": {"scale": ("embed",)}, "attn": attn_a,
          "ln2": {"scale": ("embed",)}, "mlp": mlp_a}
    axes = {"embed": emb_a, "mamba_super": sup, "shared_attn": sa,
            "final_norm": {"scale": ("embed",)}}
    if rest:
        axes["mamba_rest"] = jax.tree.map(lambda t: ("layers",) + t, m_axes,
                                          is_leaf=is_ax)
    return axes


def _attn_block(cfg, sa, x, positions, valid_mask, use_flash):
    h = rms_norm(sa["ln1"], x, cfg.rms_eps)
    h = attn.full_attention(sa["attn"], h, cfg, positions=positions,
                            valid_mask=valid_mask, use_flash=use_flash)
    x = x + h
    h = rms_norm(sa["ln2"], x, cfg.rms_eps)
    return lsc(x + apply_mlp(sa["mlp"], h, cfg), "batch", "seq", "embed")


def forward(params, cfg: ModelConfig, tokens, *, valid_mask=None,
            positions=None, prefix_embeds=None, use_flash=None):
    del prefix_embeds
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = embed_tokens(params["embed"], tokens, cdt)
    n_super, K, rest = _split(cfg)

    def mamba_layer(xc, lp):
        h = rms_norm(lp["norm"], xc, cfg.rms_eps)
        y, _, _ = mb._ssm_block(lp, cfg, h, valid=valid_mask)
        return xc + y, None

    def super_body(xc, mp):
        xc, _ = jax.lax.scan(mamba_layer, xc, mp)
        xc = _attn_block(cfg, params["shared_attn"], xc, positions, valid_mask,
                         use_flash)
        return xc, None

    body = jax.checkpoint(super_body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat == "block" else super_body
    x, _ = jax.lax.scan(body, x, params["mamba_super"])
    if rest:
        x, _ = jax.lax.scan(mamba_layer, x, params["mamba_rest"])
    x = rms_norm(params["final_norm"], x, cfg.rms_eps)
    return unembed(params["embed"], x, cfg), jnp.float32(0)


def prefill(params, cfg: ModelConfig, tokens, *, scfg: SparseRLConfig,
            slots: int, valid_mask=None, positions=None, prefix_embeds=None,
            use_flash=None):
    del prefix_embeds
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if valid_mask is None:
        valid_mask = jnp.ones((B, S), bool)
    x = embed_tokens(params["embed"], tokens, cdt)
    n_super, K, rest = _split(cfg)
    sa = params["shared_attn"]

    def mamba_layer(xc, lp):
        h = rms_norm(lp["norm"], xc, cfg.rms_eps)
        y, h_fin, tail = mb._ssm_block(lp, cfg, h, valid=valid_mask)
        return xc + y, (h_fin, tail)

    def super_body(xc, mp):
        xc, (hs, tails) = jax.lax.scan(mamba_layer, xc, mp)
        h = rms_norm(sa["ln1"], xc, cfg.rms_eps)
        hattn, (kc, vc) = attn.full_attention(
            sa["attn"], h, cfg, positions=positions, valid_mask=valid_mask,
            return_kv=True, use_flash=use_flash)
        obs = attn.obs_window_scores(sa["attn"], h, cfg, positions, valid_mask,
                                     window=max(scfg.obs_window, 1))
        xc = xc + hattn
        h2 = rms_norm(sa["ln2"], xc, cfg.rms_eps)
        xc = xc + apply_mlp(sa["mlp"], h2, cfg)
        cache = compress_prefill(kc, vc, valid_mask, obs, slots, scfg, positions)
        return xc, (hs, tails, cache)

    x, (h_sup, tail_sup, caches) = jax.lax.scan(super_body, x, params["mamba_super"])
    if rest:
        x, (h_rest, tail_rest) = jax.lax.scan(mamba_layer, x, params["mamba_rest"])
    else:
        W, ch = cfg.ssm_conv_width, cfg.d_inner + 2 * cfg.ssm_state
        h_rest = jnp.zeros((0, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        tail_rest = jnp.zeros((0, B, W - 1, ch), cdt)
    x = rms_norm(params["final_norm"], x, cfg.rms_eps)
    logits_last = unembed(params["embed"], x[:, -1], cfg)
    next_pos = jnp.max(jnp.where(valid_mask, positions, -1), axis=-1) + 1
    state = HybridState(conv_super=tail_sup, h_super=h_sup,
                        conv_rest=tail_rest, h_rest=h_rest,
                        caches=caches, pos=next_pos.astype(jnp.int32))
    return logits_last, state


def decode_step(params, cfg: ModelConfig, state: HybridState, tokens,
                scfg: SparseRLConfig):
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt)
    n_super, K, rest = _split(cfg)
    sa = params["shared_attn"]

    def mamba_step(xc, layer):
        lp, tail, h0 = layer
        hin = rms_norm(lp["norm"], xc[:, None, :], cfg.rms_eps)
        z, xh, Bc, Cc, dtv, tail_new = mb._project(lp, cfg, hin, tail)
        A = -jnp.exp(lp["A_log"])
        dA = jnp.exp(dtv[:, 0] * A[None, :])
        xt = xh[:, 0].astype(jnp.float32) * dtv[:, 0, :, None]
        h_new = h0 * dA[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt, Bc[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), h_new)
        y = y + lp["D"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(xc.shape[0], cfg.d_inner).astype(xc.dtype)
        y = rms_norm(lp["gnorm"], y * jax.nn.silu(z[:, 0]), cfg.rms_eps)
        y = jnp.einsum("bk,kd->bd", y, lp["out_proj"]["w"].astype(xc.dtype))
        return xc + y, (tail_new, h_new)

    def super_step(xc, layer):
        mp, tails, hs, cache = layer
        xc, (tails_n, hs_n) = jax.lax.scan(mamba_step, xc, (mp, tails, hs))
        h = rms_norm(sa["ln1"], xc[:, None, :], cfg.rms_eps)[:, 0]
        hattn, cache = attn.decode_attention(sa["attn"], h, cfg, cache, scfg,
                                             state.pos)
        xc = xc + hattn
        h2 = rms_norm(sa["ln2"], xc[:, None, :], cfg.rms_eps)
        xc = xc + apply_mlp(sa["mlp"], h2, cfg)[:, 0]
        return xc, (tails_n, hs_n, cache)

    x, (tail_sup, h_sup, caches) = jax.lax.scan(
        super_step, x,
        (params["mamba_super"], state.conv_super, state.h_super, state.caches))
    if rest:
        x, (tail_rest, h_rest) = jax.lax.scan(
            mamba_step, x, (params["mamba_rest"], state.conv_rest, state.h_rest))
    else:
        tail_rest, h_rest = state.conv_rest, state.h_rest
    x = rms_norm(params["final_norm"], x[:, None, :], cfg.rms_eps)[:, 0]
    logits = unembed(params["embed"], x, cfg)
    return logits, HybridState(conv_super=tail_sup, h_super=h_sup,
                               conv_rest=tail_rest, h_rest=h_rest,
                               caches=caches, pos=state.pos + 1)
