"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB: callers (and ``input_specs``) provide
precomputed frame embeddings (B, F, d_model).  The encoder is a non-causal
transformer over frames; the decoder is a causal LM with cached self-attention
(Sparse-RL budget cache applies) plus cross-attention to the fixed encoder
states (cross K/V computed once at prefill).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SparseRLConfig, dtype_of
from repro.distributed.sharding import lsc
from repro.kvcache import KVCache, compress_prefill
from repro.models import attention as attn
from repro.models.common import (
    apply_mlp,
    embed_init,
    embed_tokens,
    mlp_init,
    norm_init,
    rms_norm,
    unembed,
)


class EncDecState(NamedTuple):
    caches: KVCache        # decoder self-attn caches, stacked (L, ...)
    cross_k: jnp.ndarray   # (L, B, Hkv, F, hd)
    cross_v: jnp.ndarray
    enc_mask: jnp.ndarray  # (B, F)
    pos: jnp.ndarray       # (B,)


def _enc_layer_init(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 2)
    p = {}
    p["ln1"], _ = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    p["attn"], _ = attn.attn_init(r[0], cfg)
    p["ln2"], _ = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    p["mlp"], _ = mlp_init(r[1], cfg, cfg.d_ff)
    return p


def _dec_layer_init(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 3)
    p = _enc_layer_init(r[0], cfg)
    p["lnx"], _ = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    p["xattn"], _ = attn.attn_init(r[1], cfg)
    return p


def init_params(cfg: ModelConfig, rng):
    r = jax.random.split(rng, 4)
    emb, _ = embed_init(r[0], cfg)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(
        jax.random.split(r[1], cfg.encoder_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(
        jax.random.split(r[2], cfg.num_layers))
    fn, _ = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    efn, _ = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    return {"embed": emb, "enc_layers": enc, "dec_layers": dec,
            "enc_norm": efn, "final_norm": fn}


def param_axes(cfg: ModelConfig):
    attn_a = {
        "wq": {"w": ("embed", "heads")},
        "wk": {"w": ("embed", "kv_heads")},
        "wv": {"w": ("embed", "kv_heads")},
        "wo": {"w": ("heads", "embed")},
    }
    if cfg.qkv_bias:
        for n in ("wq", "wk", "wv"):
            attn_a[n]["b"] = (attn_a[n]["w"][-1],)
    mlp_a = {"up": {"w": ("embed", "ffn")}, "down": {"w": ("ffn", "embed")}}
    if cfg.mlp_style == "swiglu":
        mlp_a["gate"] = {"w": ("embed", "ffn")}
    enc_a = {"ln1": {"scale": ("embed",)}, "attn": attn_a,
             "ln2": {"scale": ("embed",)}, "mlp": mlp_a}
    dec_a = dict(enc_a)
    dec_a["lnx"] = {"scale": ("embed",)}
    dec_a["xattn"] = attn_a
    is_ax = lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
    stack = lambda t: jax.tree.map(lambda a: ("layers",) + a, t, is_leaf=is_ax)
    emb_a = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb_a["head"] = ("embed", "vocab")
    return {"embed": emb_a, "enc_layers": stack(enc_a),
            "dec_layers": stack(dec_a),
            "enc_norm": {"scale": ("embed",)},
            "final_norm": {"scale": ("embed",)}}


def encode(params, cfg: ModelConfig, frames, enc_mask=None, use_flash=None):
    """frames: (B, F, d_model) stub embeddings -> encoder states."""
    B, F, _ = frames.shape
    x = frames.astype(dtype_of(cfg.compute_dtype))
    x = lsc(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
    if enc_mask is None:
        enc_mask = jnp.ones((B, F), bool)

    def body(xc, lp):
        h = rms_norm(lp["ln1"], xc, cfg.rms_eps)
        h = attn.full_attention(lp["attn"], h, cfg, positions=positions,
                                valid_mask=enc_mask, causal=False,
                                use_flash=use_flash)
        xc = xc + h
        h = rms_norm(lp["ln2"], xc, cfg.rms_eps)
        return lsc(xc + apply_mlp(lp["mlp"], h, cfg), "batch", "seq", "embed"), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(params["enc_norm"], x, cfg.rms_eps)


def forward(params, cfg: ModelConfig, tokens, *, frames=None, enc_mask=None,
            valid_mask=None, positions=None, prefix_embeds=None, use_flash=None):
    """Teacher-forced decode logits.  frames (or prefix_embeds) required."""
    if frames is None:
        frames = prefix_embeds
    assert frames is not None, "audio forward needs frame embeddings"
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if enc_mask is None:
        enc_mask = jnp.ones(frames.shape[:2], bool)
    enc_out = encode(params, cfg, frames, enc_mask, use_flash)
    x = embed_tokens(params["embed"], tokens, dtype_of(cfg.compute_dtype))

    def body(xc, lp):
        h = rms_norm(lp["ln1"], xc, cfg.rms_eps)
        h = attn.full_attention(lp["attn"], h, cfg, positions=positions,
                                valid_mask=valid_mask, use_flash=use_flash)
        xc = xc + h
        h = rms_norm(lp["lnx"], xc, cfg.rms_eps)
        enc_kv = attn.project_enc_kv(lp["xattn"], enc_out, cfg)
        xc = xc + attn.cross_attention(lp["xattn"], h, enc_kv, cfg, enc_mask)
        h = rms_norm(lp["ln2"], xc, cfg.rms_eps)
        return xc + apply_mlp(lp["mlp"], h, cfg), None

    body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat == "block" else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = rms_norm(params["final_norm"], x, cfg.rms_eps)
    return unembed(params["embed"], x, cfg), jnp.float32(0)


def prefill(params, cfg: ModelConfig, tokens, *, scfg: SparseRLConfig,
            slots: int, frames=None, enc_mask=None, valid_mask=None,
            positions=None, prefix_embeds=None, use_flash=None):
    if frames is None:
        frames = prefix_embeds
    assert frames is not None
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if valid_mask is None:
        valid_mask = jnp.ones((B, S), bool)
    if enc_mask is None:
        enc_mask = jnp.ones(frames.shape[:2], bool)
    enc_out = encode(params, cfg, frames, enc_mask, use_flash)
    x = embed_tokens(params["embed"], tokens, dtype_of(cfg.compute_dtype))

    def body(xc, lp):
        h = rms_norm(lp["ln1"], xc, cfg.rms_eps)
        hattn, (kc, vc) = attn.full_attention(
            lp["attn"], h, cfg, positions=positions, valid_mask=valid_mask,
            return_kv=True, use_flash=use_flash)
        obs = attn.obs_window_scores(lp["attn"], h, cfg, positions, valid_mask,
                                     window=max(scfg.obs_window, 1))
        xc = xc + hattn
        h = rms_norm(lp["lnx"], xc, cfg.rms_eps)
        ck, cv = attn.project_enc_kv(lp["xattn"], enc_out, cfg)
        xc = xc + attn.cross_attention(lp["xattn"], h, (ck, cv), cfg, enc_mask)
        h = rms_norm(lp["ln2"], xc, cfg.rms_eps)
        xc = xc + apply_mlp(lp["mlp"], h, cfg)
        cache = compress_prefill(kc, vc, valid_mask, obs, slots, scfg, positions)
        return xc, (cache, ck, cv)

    x, (caches, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(params["final_norm"], x, cfg.rms_eps)
    logits_last = unembed(params["embed"], x[:, -1], cfg)
    next_pos = jnp.max(jnp.where(valid_mask, positions, -1), axis=-1) + 1
    return logits_last, EncDecState(caches=caches, cross_k=cks, cross_v=cvs,
                                    enc_mask=enc_mask,
                                    pos=next_pos.astype(jnp.int32))


def decode_step(params, cfg: ModelConfig, state: EncDecState, tokens,
                scfg: SparseRLConfig):
    x = embed_tokens(params["embed"], tokens, dtype_of(cfg.compute_dtype))

    def body(xc, layer):
        lp, cache, ck, cv = layer
        h = rms_norm(lp["ln1"], xc[:, None, :], cfg.rms_eps)[:, 0]
        hattn, cache = attn.decode_attention(lp["attn"], h, cfg, cache, scfg,
                                             state.pos)
        xc = xc + hattn
        h = rms_norm(lp["lnx"], xc[:, None, :], cfg.rms_eps)
        xc = xc + attn.cross_attention(lp["xattn"], h, (ck, cv), cfg,
                                       state.enc_mask)[:, 0]
        h = rms_norm(lp["ln2"], xc[:, None, :], cfg.rms_eps)
        xc = xc + apply_mlp(lp["mlp"], h, cfg)[:, 0]
        return xc, cache

    x, caches = jax.lax.scan(
        body, x, (params["dec_layers"], state.caches, state.cross_k, state.cross_v))
    x = rms_norm(params["final_norm"], x[:, None, :], cfg.rms_eps)[:, 0]
    logits = unembed(params["embed"], x, cfg)
    return logits, state._replace(caches=caches, pos=state.pos + 1)
