"""Blocked (flash-style) causal attention in pure jnp — bounded memory.

Used for long-sequence prefill and dense re-scoring where materializing the
S x S score matrix is infeasible.  Online-softmax over KV blocks, scanned
over Q blocks, so live memory is O(block_q * block_k) per head.  The Pallas
TPU kernel (`repro.kernels.flash_attention`) implements the same contract for
the hardware target; this is its oracle and the CPU execution path.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30


@partial(jax.jit, static_argnames=("block_q", "block_k", "causal"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *,
                    q_positions: Optional[jnp.ndarray] = None,
                    kv_positions: Optional[jnp.ndarray] = None,
                    kv_valid: Optional[jnp.ndarray] = None,
                    block_q: int = 512, block_k: int = 512,
                    causal: bool = True) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D); GQA via Hq % Hkv == 0.

    q_positions/kv_positions: (B, Sq)/(B, Sk) absolute positions for the
    causal mask (defaults to arange).  kv_valid: (B, Sk) padding mask.
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(D))
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
    if kv_valid is None:
        kv_valid = jnp.ones((B, Sk), bool)

    # pad to block multiples
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, ((0, 0), (0, pq)), constant_values=-1)
    kpos = jnp.pad(kv_positions, ((0, 0), (0, pk)), constant_values=-1)
    kval = jnp.pad(kv_valid, ((0, 0), (0, pk)), constant_values=False)
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k

    qb = qp.reshape(B, nq, block_q, Hkv, G, D)
    kb = kp.reshape(B, nk, block_k, Hkv, D)
    vb = vp.reshape(B, nk, block_k, Hkv, D)
    qposb = qpos.reshape(B, nq, block_q)
    kposb = kpos.reshape(B, nk, block_k)
    kvalb = kval.reshape(B, nk, block_k)

    def q_block(carry, qi):
        qblk = qb[:, qi]                                        # (B,bq,Hkv,G,D)
        qpb = qposb[:, qi]                                      # (B,bq)

        def kv_block(acc, ki):
            m, l, o = acc
            kblk, vblk = kb[:, ki], vb[:, ki]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            msk = kvalb[:, ki][:, None, None, None, :]
            if causal:
                cm = qpb[:, :, None] >= kposb[:, ki][:, None, :]
                msk = msk & cm[:, None, None, :, :]
            s = jnp.where(msk, s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk, p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            o_new = o * corr[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hkv, G, block_q), NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block_q), jnp.float32)
        o0 = jnp.zeros((B, Hkv, G, block_q, D), jnp.float32)
        if causal:
            # skip kv blocks strictly after this q block (standard flash trick);
            # positions are monotone so block-level bounds are exact.
            hi = nk  # conservative when positions are custom; XLA hoists the
            (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0), jnp.arange(hi))
        else:
            (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0), jnp.arange(nk))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(out, 3, 1)                           # (B,bq,Hkv,G,D)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, 0, jnp.arange(nq))          # (nq,B,bq,Hkv,G,D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * block_q, Hq, D)
    return out[:, :Sq]


def reference_attention(q, k, v, *, q_positions=None, kv_positions=None,
                        kv_valid=None, causal=True):
    """O(S^2)-memory oracle for tests."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
    if kv_valid is None:
        kv_valid = jnp.ones((B, Sk), bool)
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(D))
    msk = kv_valid[:, None, None, None, :]
    if causal:
        cm = q_positions[:, :, None] >= kv_positions[:, None, :]
        msk = msk & cm[:, None, None, :, :]
    s = jnp.where(msk, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(msk, p, 0.0)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)
