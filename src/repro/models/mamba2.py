"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD forward: within-chunk quadratic attention-like term + cross-chunk
recurrent state passing (scanned).  Decode is the O(1) recurrence
``h <- h * exp(dt*A) + dt * B (x)``; no KV cache exists, which is exactly why
Sparse-RL's KV compression is *inapplicable* to this family (DESIGN.md
§Arch-applicability).

Shapes: x heads H = d_inner / P (head dim P); B/C shared across heads
(single group), state size N = cfg.ssm_state.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, dtype_of
from repro.distributed.sharding import lsc
from repro.models.common import (
    dense_init,
    embed_init,
    embed_tokens,
    norm_init,
    rms_norm,
    unembed,
)


class SSMState(NamedTuple):
    conv: jnp.ndarray   # (L, B, W-1, d_conv_ch)   rolling pre-conv window
    h: jnp.ndarray      # (L, B, H, P, N)          recurrent state
    pos: jnp.ndarray    # (B,) next absolute position


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _ssm_layer_init(rng, cfg: ModelConfig):
    dt = dtype_of(cfg.param_dtype)
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N
    r = jax.random.split(rng, 4)
    p, a = {}, {}
    p["norm"], a["norm"] = norm_init(cfg.d_model, dt)
    proj_out = 2 * di + 2 * N + H      # z, x, B, C, dt
    p["in_proj"], a["in_proj"] = dense_init(r[0], cfg.d_model, proj_out,
                                            ("embed", "ssm_inner"), dt)
    p["conv_w"] = (jax.random.normal(r[1], (cfg.ssm_conv_width, conv_ch), jnp.float32)
                   * 0.1).astype(dt)
    a["conv_w"] = (None, "ssm_inner")
    p["conv_b"] = jnp.zeros((conv_ch,), dt)
    a["conv_b"] = ("ssm_inner",)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32))
    a["A_log"] = ("ssm_heads",)
    p["D"] = jnp.ones((H,), jnp.float32)
    a["D"] = ("ssm_heads",)
    p["dt_bias"] = jnp.zeros((H,), jnp.float32)
    a["dt_bias"] = ("ssm_heads",)
    p["gnorm"] = {"scale": jnp.ones((di,), dt)}
    a["gnorm"] = {"scale": ("ssm_inner",)}
    p["out_proj"], a["out_proj"] = dense_init(r[2], di, cfg.d_model,
                                              ("ssm_inner", "embed"), dt)
    return p, a


def init_params(cfg: ModelConfig, rng):
    r_emb, r_layers = jax.random.split(rng)
    emb, _ = embed_init(r_emb, cfg)
    rngs = jax.random.split(r_layers, cfg.num_layers)
    layers = jax.vmap(lambda r: _ssm_layer_init(r, cfg)[0])(rngs)
    fn, _ = norm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    return {"embed": emb, "layers": layers, "final_norm": fn}


def ssm_layer_axes(cfg: ModelConfig):
    return {
        "norm": {"scale": ("embed",)},
        "in_proj": {"w": ("embed", "ssm_inner")},
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "gnorm": {"scale": ("ssm_inner",)},
        "out_proj": {"w": ("ssm_inner", "embed")},
    }


def param_axes(cfg: ModelConfig):
    layer_a = ssm_layer_axes(cfg)
    emb_a = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb_a["head"] = ("embed", "vocab")
    stacked = jax.tree.map(lambda t: ("layers",) + t, layer_a,
                           is_leaf=lambda x: isinstance(x, tuple) and all(
                               isinstance(e, (str, type(None))) for e in x))
    return {"embed": emb_a, "layers": stacked,
            "final_norm": {"scale": ("embed",)}}


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------
def _project(p, cfg: ModelConfig, x, conv_tail: Optional[jnp.ndarray] = None,
             valid: Optional[jnp.ndarray] = None):
    """x: (B, S, D) -> (z, xh, Bc, Cc, dt, conv_in).

    conv_tail: (B, W-1, conv_ch) carried context for the causal depthwise conv
    (decode / chunked prefill); zeros if None.  ``valid`` zeroes the conv
    input at padded positions so left padding is exactly equivalent to the
    zero-initialized conv tail (no leakage into the first real tokens).
    """
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"]["w"].astype(x.dtype))
    z, xr, Bc, Cc, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N],
                                  axis=-1)
    conv_in = jnp.concatenate([xr, Bc, Cc], axis=-1)            # (B,S,conv_ch)
    if valid is not None:
        conv_in = conv_in * valid[..., None].astype(conv_in.dtype)
    B_, S, _ = conv_in.shape
    if conv_tail is None:
        conv_tail = jnp.zeros((B_, W - 1, conv_in.shape[-1]), conv_in.dtype)
    padded = jnp.concatenate([conv_tail, conv_in], axis=1)      # (B, S+W-1, ch)
    # causal depthwise conv as a sum of W shifted slices (cheap, fusible)
    conv = sum(padded[:, i:i + S] * p["conv_w"][i].astype(x.dtype)
               for i in range(W))
    conv = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))
    xr, Bc, Cc = jnp.split(conv, [di, di + N], axis=-1)
    xh = xr.reshape(B_, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    new_tail = padded[:, -(W - 1):] if W > 1 else jnp.zeros((B_, 0, conv_in.shape[-1]), conv_in.dtype)
    return z, xh, Bc, Cc, dt, new_tail


# ---------------------------------------------------------------------------
# Chunked SSD forward (train / prefill)
# ---------------------------------------------------------------------------
def _ssd(cfg: ModelConfig, xh, Bc, Cc, dt, A, h0=None, valid=None):
    """xh: (B,S,H,P); Bc/Cc: (B,S,N); dt: (B,S,H); A: (H,) negative.

    Returns (y (B,S,H,P), h_final (B,H,P,N)).  ``valid`` (B,S) zeroes updates
    at padded positions.
    """
    B_, S, H, P = xh.shape
    N = Bc.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    pad = (-S) % Q
    if pad:
        # padded tail positions get dt=0 => exact no-op updates
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nC = S_pad // Q
    if valid is not None:
        valid = jnp.pad(valid, ((0, 0), (0, pad))) if pad else valid
        dt = dt * valid[..., None].astype(dt.dtype)
    dA = dt * A[None, None, :]                                   # (B,S,H) <= 0
    xt = (xh.astype(jnp.float32) * dt[..., None])                # dt-weighted input

    cs = lambda t: t.reshape(B_, nC, Q, *t.shape[2:])
    xq, Bq, Cq, dAq = cs(xt), cs(Bc.astype(jnp.float32)), cs(Cc.astype(jnp.float32)), cs(dA)
    cum = jnp.cumsum(dAq, axis=2)                                # (B,nC,Q,H)

    # within-chunk (quadratic in Q): y_i += sum_{j<=i} exp(cum_i - cum_j) (C_i.B_j) x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,nC,Qi,Qj,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask BEFORE exp: non-causal entries have seg > 0 and can overflow to
    # inf, which turns the where() backward into inf * 0 = NaN
    decay = jnp.exp(jnp.where(causal, seg, -1e30))
    cb = jnp.einsum("bcin,bcjn->bcij", Cq, Bq)                   # (B,nC,Qi,Qj)
    att = cb[..., None] * decay                                  # (B,nC,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xq)

    # chunk-final states: h_c = sum_j exp(cum_last - cum_j) B_j x_j
    last = cum[:, :, -1:, :]                                     # (B,nC,1,H)
    w = jnp.exp(last - cum)                                      # (B,nC,Q,H)
    hc = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", w, Bq, xq)         # (B,nC,H,P,N)
    chunk_decay = jnp.exp(last[:, :, 0, :])                      # (B,nC,H)

    def scan_fn(h, inp):
        hc_c, dec_c = inp
        h_new = h * dec_c[..., None, None] + hc_c
        return h_new, h

    h_init = (jnp.zeros((B_, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_fin, h_prev = jax.lax.scan(scan_fn, h_init,
                                 (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                          # (B,nC,H,P,N) state entering chunk

    # cross-chunk: y_i += exp(cum_i) C_i . h_prev
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp", jnp.exp(cum), Cq, h_prev)
    y = (y_intra + y_inter).reshape(B_, S_pad, H, P)[:, :S]
    return y, h_fin


def _ssm_block(p, cfg: ModelConfig, x, h0=None, conv_tail=None, valid=None):
    """One mamba2 block on (B,S,D). Returns (y, h_final, conv_tail')."""
    z, xh, Bc, Cc, dt, tail = _project(p, cfg, x, conv_tail, valid=valid)
    if valid is not None:
        xh = xh * valid[..., None, None].astype(xh.dtype)
    A = -jnp.exp(p["A_log"])
    y, h_fin = _ssd(cfg, xh, Bc, Cc, dt, A, h0=h0, valid=valid)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], cfg.d_inner).astype(x.dtype)
    y = rms_norm(p["gnorm"], y * jax.nn.silu(z), cfg.rms_eps)
    y = lsc(y, "batch", "seq", "ssm_inner")
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"]["w"].astype(x.dtype)), h_fin, tail


# ---------------------------------------------------------------------------
# Public API (same contract as transformer.py)
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens, *, valid_mask=None,
            positions=None, prefix_embeds=None, use_flash=None):
    del positions, prefix_embeds, use_flash
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt)
    x = lsc(x, "batch", "seq", "embed")

    def body(carry, lp):
        xc = carry
        h = rms_norm(lp["norm"], xc, cfg.rms_eps)
        y, _, _ = _ssm_block(lp, cfg, h, valid=valid_mask)
        return xc + y, None

    body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat == "block" else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = rms_norm(params["final_norm"], x, cfg.rms_eps)
    return unembed(params["embed"], x, cfg), jnp.float32(0)


def prefill(params, cfg: ModelConfig, tokens, *, scfg=None, slots=0,
            valid_mask=None, positions=None, prefix_embeds=None, use_flash=None):
    """SSM prefill: run the chunked forward, carry out (h, conv_tail)."""
    del scfg, slots, positions, prefix_embeds, use_flash
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    if valid_mask is None:
        valid_mask = jnp.ones((B, S), bool)
    x = embed_tokens(params["embed"], tokens, cdt)

    def body(carry, lp):
        xc = carry
        h = rms_norm(lp["norm"], xc, cfg.rms_eps)
        y, h_fin, tail = _ssm_block(lp, cfg, h, valid=valid_mask)
        return xc + y, (h_fin, tail)

    x, (hs, tails) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(params["final_norm"], x, cfg.rms_eps)
    logits_last = unembed(params["embed"], x[:, -1], cfg)
    next_pos = jnp.sum(valid_mask, axis=-1).astype(jnp.int32)
    state = SSMState(conv=tails, h=hs, pos=next_pos)
    return logits_last, state


def decode_step(params, cfg: ModelConfig, state: SSMState, tokens, scfg=None):
    del scfg
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt)               # (B, D)

    def body(xc, layer):
        lp, conv_tail, h0 = layer
        hin = rms_norm(lp["norm"], xc[:, None, :], cfg.rms_eps)
        z, xh, Bc, Cc, dt, tail = _project(lp, cfg, hin, conv_tail)
        A = -jnp.exp(lp["A_log"])
        dA = jnp.exp(dt[:, 0] * A[None, :])                      # (B,H)
        xt = xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None]
        h_new = h0 * dA[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt, Bc[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), h_new)
        y = y + lp["D"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(xc.shape[0], cfg.d_inner).astype(xc.dtype)
        y = rms_norm(lp["gnorm"], y * jax.nn.silu(z[:, 0]), cfg.rms_eps)
        y = jnp.einsum("bk,kd->bd", y, lp["out_proj"]["w"].astype(xc.dtype))
        return xc + y, (tail, h_new)

    x, (tails, hs) = jax.lax.scan(body, x,
                                  (params["layers"], state.conv, state.h))
    x = rms_norm(params["final_norm"], x[:, None, :], cfg.rms_eps)[:, 0]
    logits = unembed(params["embed"], x, cfg)
    return logits, SSMState(conv=tails, h=hs, pos=state.pos + 1)
