"""Top-k MoE FFN with static-capacity gather/scatter dispatch.

Routing is sort-free: per-(token, k) expert assignments get a within-expert
rank via a one-hot cumsum; tokens beyond the per-expert capacity are dropped
(Switch-style).  The dispatch buffer is laid out (E, C, D) with the expert
dim sharded over the ``model`` mesh axis — expert parallelism: GSPMD lowers
the scatter/gather into all-to-all style collectives.

FLOPs are proportional to *active* experts (capacity-bounded), so the
roofline's MODEL_FLOPS = 6 * N_active * D comparison stays honest — unlike
the dense "compute every expert" fallback.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, dtype_of
from repro.distributed.sharding import lsc


def moe_init(rng, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    r = jax.random.split(rng, 4)
    std = 1.0 / jnp.sqrt(D)
    p = {
        "router": (jax.random.normal(r[0], (D, E), jnp.float32) * 0.02).astype(jnp.float32),
        "gate": (jax.random.normal(r[1], (E, D, F), jnp.float32) * std).astype(dtype),
        "up": (jax.random.normal(r[2], (E, D, F), jnp.float32) * std).astype(dtype),
        "down": (jax.random.normal(r[3], (E, F, D), jnp.float32) / jnp.sqrt(F)).astype(dtype),
    }
    a = {
        "router": ("embed", None),
        "gate": ("experts", "embed", "moe_ffn"),
        "up": ("experts", "embed", "moe_ffn"),
        "down": ("experts", "moe_ffn", "embed"),
    }
    return p, a


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.experts_per_token
              / max(cfg.num_experts, 1))
    return max(cap, 4)


def apply_moe(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss).  aux_loss is the Switch load-balance
    term (scalar, fp32)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = _capacity(cfg, T)
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)             # (T, K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    # load-balance auxiliary loss (Switch eq. 4 generalized to top-k)
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32).sum(1), axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    flat_ids = expert_ids.reshape(T * K)
    oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)           # (T*K, E)
    rank = (jnp.cumsum(oh, axis=0) - oh)                        # pre-count
    rank = jnp.take_along_axis(rank, flat_ids[:, None], axis=1)[:, 0]
    keep = rank < C
    dest = flat_ids * C + jnp.minimum(rank, C - 1)              # (T*K,)

    cdt = x.dtype
    src = jnp.repeat(xf, K, axis=0)                             # (T*K, D) token per slot
    buf = jnp.zeros((E * C, D), cdt)
    buf = buf.at[dest].add(jnp.where(keep[:, None], src, 0).astype(cdt))
    buf = buf.reshape(E, C, D)
    buf = lsc(buf, "experts", None, "embed")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(cdt)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(cdt))
    h = lsc(h, "experts", None, "moe_ffn")
    out = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(cdt))
    out = lsc(out, "experts", None, "embed")

    y_slots = out.reshape(E * C, D)[dest]                       # (T*K, D)
    w = (gate_vals.reshape(T * K) * keep).astype(cdt)
    y = (y_slots * w[:, None]).reshape(T, K, D).sum(axis=1)
    return y.reshape(B, S, D), aux
