"""Uniform model API across all families.

``get_model(cfg)`` returns a :class:`ModelFns` namespace with a single batch
convention consumed by the rollout engine, trainer and launcher:

  batch = {
    "tokens":        (B, S) int32            (always)
    "prefix_embeds": (B, P, d_model)         (vlm: stub patch embeddings)
    "frames":        (B, F, d_model)         (audio: stub frame embeddings)
    "valid_mask":    (B, S) bool             (optional; False = padding)
    "positions":     (B, S) int32            (optional)
    "enc_mask":      (B, F) bool             (audio, optional)
  }
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable


from repro.configs.base import AUDIO, DENSE, HYBRID, MOE, SSM, VLM, ModelConfig


@dataclass(frozen=True)
class ModelFns:
    init_params: Callable
    param_axes: Callable
    forward: Callable      # (params, cfg, batch, use_flash=None) -> (logits, aux)
    prefill: Callable      # (params, cfg, batch, scfg, slots) -> (last_logits, state)
    decode_step: Callable  # (params, cfg, state, tokens, scfg) -> (logits, state)
    has_kv_cache: bool     # False for pure SSM (Sparse-RL inapplicable)


def _opt(batch, key):
    return batch.get(key) if isinstance(batch, dict) else None


def get_model(cfg: ModelConfig) -> ModelFns:
    if cfg.family in (DENSE, MOE, VLM):
        from repro.models import transformer as T

        def fwd(params, cfg, batch, use_flash=None):
            return T.forward(params, cfg, batch["tokens"],
                             prefix_embeds=_opt(batch, "prefix_embeds"),
                             valid_mask=_opt(batch, "valid_mask"),
                             positions=_opt(batch, "positions"),
                             use_flash=use_flash)

        def pf(params, cfg, batch, scfg, slots, use_flash=None):
            return T.prefill(params, cfg, batch["tokens"], scfg=scfg, slots=slots,
                             prefix_embeds=_opt(batch, "prefix_embeds"),
                             valid_mask=_opt(batch, "valid_mask"),
                             positions=_opt(batch, "positions"),
                             use_flash=use_flash)

        return ModelFns(T.init_params, T.param_axes, fwd, pf, T.decode_step, True)

    if cfg.family == SSM:
        from repro.models import mamba2 as M

        def fwd(params, cfg, batch, use_flash=None):
            return M.forward(params, cfg, batch["tokens"],
                             valid_mask=_opt(batch, "valid_mask"))

        def pf(params, cfg, batch, scfg, slots, use_flash=None):
            return M.prefill(params, cfg, batch["tokens"],
                             valid_mask=_opt(batch, "valid_mask"))

        return ModelFns(M.init_params, M.param_axes, fwd, pf, M.decode_step, False)

    if cfg.family == HYBRID:
        from repro.models import hybrid as H

        def fwd(params, cfg, batch, use_flash=None):
            return H.forward(params, cfg, batch["tokens"],
                             valid_mask=_opt(batch, "valid_mask"),
                             positions=_opt(batch, "positions"),
                             use_flash=use_flash)

        def pf(params, cfg, batch, scfg, slots, use_flash=None):
            return H.prefill(params, cfg, batch["tokens"], scfg=scfg, slots=slots,
                             valid_mask=_opt(batch, "valid_mask"),
                             positions=_opt(batch, "positions"),
                             use_flash=use_flash)

        return ModelFns(H.init_params, H.param_axes, fwd, pf, H.decode_step, True)

    if cfg.family == AUDIO:
        from repro.models import encdec as E

        def fwd(params, cfg, batch, use_flash=None):
            return E.forward(params, cfg, batch["tokens"],
                             frames=batch["frames"],
                             enc_mask=_opt(batch, "enc_mask"),
                             valid_mask=_opt(batch, "valid_mask"),
                             positions=_opt(batch, "positions"),
                             use_flash=use_flash)

        def pf(params, cfg, batch, scfg, slots, use_flash=None):
            return E.prefill(params, cfg, batch["tokens"], scfg=scfg, slots=slots,
                             frames=batch["frames"],
                             enc_mask=_opt(batch, "enc_mask"),
                             valid_mask=_opt(batch, "valid_mask"),
                             positions=_opt(batch, "positions"),
                             use_flash=use_flash)

        return ModelFns(E.init_params, E.param_axes, fwd, pf, E.decode_step, True)

    raise ValueError(cfg.family)
