"""Rule-based binary verifier (paper §5.1: reward 1 iff correct, else 0).

Host-side (numpy) — rewards are computed between the rollout and update
phases, exactly where RL frameworks run their rule-based checkers.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.data.tokenizer import TOKENIZER, CharTokenizer


def parse_answer(text: str) -> str:
    """First integer (optional minus) in the completion."""
    s = text.strip()
    out, started = [], False
    for ch in s:
        if ch == "-" and not started:
            out.append(ch)
            started = True
        elif ch.isdigit():
            out.append(ch)
            started = True
        elif started:
            break
    ans = "".join(out)
    return ans if ans not in ("", "-") else ""


def binary_rewards(resp_tokens: np.ndarray, answers: Sequence[str],
                   tok: CharTokenizer = TOKENIZER) -> np.ndarray:
    """resp_tokens: (B, T) sampled ids; answers: gold strings. -> (B,) f32."""
    resp_tokens = np.asarray(resp_tokens)
    out = np.zeros((resp_tokens.shape[0],), np.float32)
    for i in range(resp_tokens.shape[0]):
        text = tok.decode(resp_tokens[i])
        out[i] = 1.0 if parse_answer(text) == str(answers[i]) else 0.0
    return out


def decode_responses(resp_tokens: np.ndarray,
                     tok: CharTokenizer = TOKENIZER) -> List[str]:
    return [tok.decode(row) for row in np.asarray(resp_tokens)]
