from repro.rewards.verifier import binary_rewards, decode_responses, parse_answer

__all__ = ["binary_rewards", "decode_responses", "parse_answer"]
