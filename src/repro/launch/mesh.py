"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS for 512 host devices before calling.

Topology (TPU v5e-class): one pod = 16 x 16 = 256 chips; multi-pod = 2 pods
= 512 chips with the ``pod`` axis crossing the DCI.  Axis roles:
  pod   — outer data parallelism (gradient all-reduce over DCI) or pipeline
          stages (config option)
  data  — FSDP / batch sharding (ICI)
  model — tensor / expert parallelism (ICI)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Generic helper for tests / elastic re-mesh (e.g. a pod-loss restart
    onto (15, 16) is a different data-axis size with identical rules)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
