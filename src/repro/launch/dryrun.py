import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes, with ShapeDtypeStruct stand-ins (no allocation).

Per cell this produces (written to ``reports/dryrun/<cell>.json``):
  * proof of compilation (the deliverable: sharding is coherent),
  * compiled.memory_analysis()  — per-device bytes (fits-in-HBM evidence),
  * compiled.cost_analysis()    — per-device HLO flops/bytes (NOTE: XLA
    counts while-loop bodies ONCE; see launch/costs.py for the trip-adjusted
    analytic model this feeds),
  * the collective-op inventory parsed from the compiled HLO (types, shapes,
    bytes, loop trip-adjusted).

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--also-single-pod]
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SparseRLConfig, TrainConfig, get_config, get_shapes
from repro.configs.base import HYBRID, SSM, ModelConfig, ShapeSpec
from repro.distributed.sharding import named_sharding, param_rules, use_mesh_rules
from repro.launch import specs as S
from repro.launch import steps as ST
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh

SDS = jax.ShapeDtypeStruct

def train_micro(shape: ShapeSpec, mesh) -> int:
    """Grad-accumulation depth: per-microbatch global batch = total DP size
    (one sequence per data shard per microbatch)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    return max(1, shape.global_batch // dp)


def _attach(sds_tree, axes_tree, mesh, rules=None):
    """Attach NamedShardings to an SDS tree via logical axes."""
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def one(sds, ax):
        sh = named_sharding(mesh, sds.shape, ax, rules)
        return SDS(sds.shape, sds.dtype, sharding=sh)

    return jax.tree.map(one, sds_tree, axes_tree, is_leaf=is_ax)


def _is_attention_family(cfg: ModelConfig) -> bool:
    return cfg.family not in (SSM, HYBRID)


def cell_plan(cfg: ModelConfig, shape: ShapeSpec, scfg: SparseRLConfig,
              mesh=None) -> Dict:
    """What gets lowered for this cell (documented in EXPERIMENTS.md)."""
    if shape.kind == "train":
        nm = train_micro(shape, mesh) if mesh is not None else 16
        return dict(kind="train", num_micro=nm)
    if shape.kind == "prefill":
        return dict(kind="prefill", sparse_cache=_is_attention_family(cfg))
    # decode
    sparse_cache = False
    note = "dense cache (memory-wall baseline)"
    if shape.sparse_cache_only and _is_attention_family(cfg):
        sparse_cache = True
        note = ("sparse budget cache — a dense 500k cache is the memory wall "
                "the paper removes; SSM/hybrid run natively")
    return dict(kind="decode", sparse_cache=sparse_cache, note=note)


def build_cell(arch: str, shape: ShapeSpec, mesh, *,
               scfg: Optional[SparseRLConfig] = None,
               rules: Optional[dict] = None,
               num_micro: Optional[int] = None,
               strategy: str = "baseline",
               grad_dtype=None,
               cfg_override=None):
    """Lower + compile one cell.  Returns (compiled, lowered, info dict).

    ``strategy`` selects a named logical-rule mapping (launch/strategies.py)
    for §Perf hillclimbs — the mesh itself never changes; ``cfg_override``
    swaps in a numerics variant of the arch config (e.g. int8 weights).
    """
    cfg = cfg_override or get_config(arch)
    scfg = scfg or SparseRLConfig()
    if strategy != "baseline":
        from repro.launch import strategies as STR

        rules = dict(STR.rules_for(strategy) or {}, **(rules or {}))
        p_rules = STR.param_rules_for(strategy)
        strat = STR.STRATEGIES.get(strategy)
        if num_micro is None and shape.kind == "train" and strat is not None \
                and strat.tp_eff == 1:
            # every mesh axis is data-parallel: per-micro batch = chip count
            chips = int(__import__("numpy").prod(mesh.devices.shape))
            num_micro = max(1, shape.global_batch // chips)
    else:
        p_rules = param_rules(rules)
    plan = cell_plan(cfg, shape, scfg, mesh)
    if num_micro is not None and plan["kind"] == "train":
        plan["num_micro"] = num_micro
    plan["strategy"] = strategy
    m_axes_mod = __import__("repro.models", fromlist=["get_model"])
    mfns = m_axes_mod.get_model(cfg)

    p_sds = S.param_specs(cfg)
    p_axes = mfns.param_axes(cfg)
    with use_mesh_rules(mesh, rules, prules=p_rules):
        p_sds_sh = _attach(p_sds, p_axes, mesh, p_rules)
        if plan["kind"] == "train":
            nm = plan["num_micro"]
            batch = S.train_batch_specs(cfg, shape, nm)
            baxes = S.train_batch_axes(cfg, nm)
            batch_sh = _attach(batch, baxes, mesh, rules)
            opt_sds = ST.init_opt_specs(p_sds, cfg)
            opt_sh = _attach(opt_sds, ST.opt_axes(p_axes), mesh, p_rules)
            tcfg = TrainConfig()
            import jax.numpy as _jnp
            fn = ST.make_train_step(
                cfg, scfg, tcfg, num_micro=nm, use_flash=False,
                grad_dtype=grad_dtype or _jnp.float32, grad_rules=p_rules)
            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(
                p_sds_sh, opt_sh, batch_sh)
        elif plan["kind"] == "prefill":
            batch = S.prefill_batch_specs(cfg, shape)
            batch_sh = _attach(batch, S.prefill_batch_axes(cfg), mesh, rules)
            fn = ST.make_prefill_step(cfg, scfg,
                                      sparse_cache=plan["sparse_cache"],
                                      ctx_len=shape.seq_len, use_flash=True)
            lowered = jax.jit(fn).lower(p_sds_sh, batch_sh)
        else:  # decode
            st_sds, st_axes, tok_sds = S.decode_state_specs(
                cfg, shape, scfg, sparse_cache=plan["sparse_cache"])
            st_sh = _attach(st_sds, st_axes, mesh, rules)
            tok_sh = SDS(tok_sds.shape, tok_sds.dtype,
                         sharding=named_sharding(mesh, tok_sds.shape,
                                                 ("batch",), rules))
            rng_sds = SDS((2,), jnp.uint32)
            fn = ST.make_decode_step(cfg, scfg)
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(
                p_sds_sh, st_sh, tok_sh, rng_sds)
        compiled = lowered.compile()
    return compiled, lowered, dict(plan=plan, arch=arch, shape=shape.name)


def summarize(compiled, lowered, info) -> Dict:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):        # older jax: per-device dicts
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    colls = collective_stats(txt)
    out = dict(
        arch=info["arch"], shape=info["shape"], plan=info["plan"],
        memory=dict(
            argument_bytes=getattr(ma, "argument_size_in_bytes", None),
            output_bytes=getattr(ma, "output_size_in_bytes", None),
            temp_bytes=getattr(ma, "temp_size_in_bytes", None),
            alias_bytes=getattr(ma, "alias_size_in_bytes", None),
        ),
        cost=dict(
            flops=ca.get("flops"),
            bytes_accessed=ca.get("bytes accessed"),
            note="XLA counts while bodies once; see analytic model",
        ),
        collectives=colls,
    )
    return out


def run_cells(cells, mesh, tag: str, out_dir: str = "reports/dryrun",
              strategy: str = "baseline"):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch, shape in cells:
        t0 = time.time()
        name = f"{arch}__{shape.name}__{tag}"
        if strategy != "baseline":
            name += f"__{strategy}"
        try:
            compiled, lowered, info = build_cell(arch, shape, mesh,
                                                 strategy=strategy)
            row = summarize(compiled, lowered, info)
            row.update(status="ok", compile_s=round(time.time() - t0, 1))
            del compiled, lowered
        except Exception as e:  # noqa: BLE001 — report, continue
            row = dict(arch=arch, shape=shape.name, status="FAIL",
                       error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-2000:],
                       compile_s=round(time.time() - t0, 1))
        results.append(row)
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(row, f, indent=1, default=str)
        mem = row.get("memory", {}).get("temp_bytes")
        print(f"[{tag}] {arch:20s} {shape.name:12s} {row['status']:4s} "
              f"compile={row['compile_s']}s temp={mem}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--also-single-pod", action="store_true")
    ap.add_argument("--strategy", default="baseline",
                    help="sharding strategy (launch/strategies.py): "
                         "baseline | zero3 | zero3_ep")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    meshes = []
    if args.multi_pod:
        meshes.append(("pod2x16x16", make_production_mesh(multi_pod=True)))
    if args.also_single_pod or not args.multi_pod:
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))

    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in get_shapes(a)]
    else:
        assert args.arch, "--arch or --all"
        shapes = get_shapes(args.arch)
        if args.shape:
            shapes = [s for s in shapes if s.name == args.shape]
        cells = [(args.arch, s) for s in shapes]

    all_ok = True
    for tag, mesh in meshes:
        res = run_cells(cells, mesh, tag, args.out, strategy=args.strategy)
        bad = [r for r in res if r["status"] != "ok"]
        all_ok &= not bad
        print(f"== {tag}: {len(res) - len(bad)}/{len(res)} cells compiled ==")
    raise SystemExit(0 if all_ok else 1)


if __name__ == "__main__":
    main()
