"""Analytic roofline cost model per (architecture x shape x mesh) cell.

WHY ANALYTIC: XLA's ``compiled.cost_analysis()`` counts while-loop bodies
ONCE (verified in EXPERIMENTS.md §Methodology: a 10-trip scan of a 16.8
MFLOP matmul reports 16.8 MFLOPs, the unrolled equivalent 168 MFLOPs).  With
scan-over-layers + grad-accumulation scans + flash-attention block scans,
raw cost_analysis would undercount by 2-4 orders of magnitude.  The roofline
below is therefore computed from first principles of the model math —
validated against cost_analysis on unrolled micro-configs (where HLO
counting is exact) in tests/test_costs.py — while the compiled artifact
supplies the memory analysis and the collective-op schedule.

Hardware constants (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.

Conventions:
  * FLOPs / bytes are GLOBAL per step; roofline terms divide by chip count
    (matching "HLO_FLOPs / (chips x peak)" in the spec, since per-device HLO
    numbers x chips == global).
  * collective bytes use the ring convention: per-chip payload for an
    all-gather / reduce-scatter of a tensor of size X over a group of g is
    X * (g-1) / g; all-reduce is 2x that.  DCI (pod axis) and ICI (data /
    model axes) are reported separately; the collective term uses the SLOWER
    path when both are exercised.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.base import (
    AUDIO,
    HYBRID,
    MOE,
    SSM,
    ModelConfig,
    ShapeSpec,
    SparseRLConfig,
)

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (one axis direction)
DCI_BW = 25e9             # inter-pod (conservative: half ICI)

BF16 = 2
F32 = 4


@dataclass
class MeshShape:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data


@dataclass
class Strategy:
    """A sharding/numerics strategy on the FIXED production mesh, expressed
    as rule/numerics choices (the hillclimb search space).

    tp_eff=1 ("zero3") folds the model axis into data parallelism via rule
    overrides — same physical mesh, different logical mapping.
    """
    name: str = "baseline"
    tp_eff: Optional[int] = None      # None -> mesh.model
    weight_bits: int = 16             # 16 | 8 | 4 (quantized weight reads)
    grad_accum_bits: int = 32         # 32 | 16
    chunked_loss: bool = False        # vocab-chunked logsumexp (no SxV logits)
    remat_chunk: int = 0              # 0 = per-layer remat; k = 2-level, save
                                      # every k-th boundary only

    def eff(self, mesh: MeshShape) -> "MeshShape":
        if self.tp_eff is None or self.tp_eff == mesh.model:
            return mesh
        assert mesh.model % self.tp_eff == 0
        return MeshShape(pod=mesh.pod,
                         data=mesh.data * (mesh.model // self.tp_eff),
                         model=self.tp_eff)


BASELINE = Strategy()


def _ring(full_bytes: float, g: int) -> float:
    return full_bytes * (g - 1) / g if g > 1 else 0.0


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == HYBRID:
        return cfg.num_layers // cfg.hybrid_attn_every
    if cfg.family == SSM:
        return 0
    return cfg.num_layers


def _param_bytes(cfg: ModelConfig) -> float:
    return cfg.n_params() * (BF16 if cfg.param_dtype == "bfloat16" else F32)


@dataclass
class CellCost:
    flops: float              # global per step
    hbm_bytes: float          # global per step
    coll_ici_bytes: float     # per-chip payload over ICI
    coll_dci_bytes: float     # per-chip payload over DCI (pod axis)
    model_flops: float        # 6*N_active*D (train) / 2*N_active*D (inference)
    detail: Dict[str, float]

    def terms(self, mesh: MeshShape) -> Dict[str, float]:
        t_comp = self.flops / (mesh.chips * PEAK_FLOPS)
        t_mem = self.hbm_bytes / (mesh.chips * HBM_BW)
        t_ici = self.coll_ici_bytes / ICI_BW
        t_dci = self.coll_dci_bytes / DCI_BW
        t_coll = max(t_ici, t_dci)
        dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
        return dict(
            compute_s=t_comp, memory_s=t_mem, collective_s=t_coll,
            collective_ici_s=t_ici, collective_dci_s=t_dci,
            bottleneck=dom[1],
            step_s=max(t_comp, t_mem, t_coll),
            roofline_frac=(self.model_flops / (mesh.chips * PEAK_FLOPS))
            / max(t_comp, t_mem, t_coll, 1e-30),
            useful_ratio=self.model_flops / max(self.flops, 1e-30),
        )


def _attention_flops(cfg: ModelConfig, B: float, S: float, causal=True) -> float:
    """QK^T + PV for one forward pass over S tokens (per attn layer set)."""
    L = _attn_layers(cfg)
    eff = 0.5 if causal else 1.0
    return 4.0 * L * B * S * S * cfg.num_heads * cfg.head_dim * eff


def _ssm_flops(cfg: ModelConfig, B: float, S: float) -> float:
    """SSD: within-chunk quadratic + state terms (per ssm layer set)."""
    if cfg.family not in (SSM, HYBRID):
        return 0.0
    L = cfg.num_layers if cfg.family == SSM else cfg.num_layers
    Q = cfg.ssm_chunk
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    per_tok = 2 * Q * H * P * 0.5 + 4 * H * P * N   # intra-chunk + state in/out
    return 2.0 * L * B * S * per_tok


def train_cost(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshShape,
               scfg: SparseRLConfig, num_micro: int,
               strat: Strategy = BASELINE) -> CellCost:
    mesh = strat.eff(mesh)
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == AUDIO:
        S = 2596 + 1500  # decoder tokens + encoder frames (both computed)
    num_micro = max(num_micro, 1)
    D_tokens = B * S
    N = cfg.n_params()
    N_act = cfg.n_active_params()

    # fwd 2ND + bwd 4ND + remat refwd 2ND  (matmul part, active params)
    lin = 8.0 * N_act * D_tokens
    attn = _attention_flops(cfg, B, S) * 4.0       # fwd+bwd+remat
    ssm = _ssm_flops(cfg, B, S) * 4.0
    opt = 10.0 * N                                 # adamw elementwise
    flops = lin + attn + ssm + opt
    if strat.remat_chunk > 1:
        # 2-level remat: one extra fwd recompute within each chunk
        flops += 2.0 * N_act * D_tokens + _attention_flops(cfg, B, S)

    P_b = _param_bytes(cfg) * strat.weight_bits / 16.0
    acc = BF16 if cfg.accum_dtype == "bfloat16" else F32
    gacc = strat.grad_accum_bits / 8.0
    act_bytes = cfg.num_layers * B * S * cfg.d_model * BF16
    logit_bytes = (2 * B * S * cfg.vocab_size * F32 if not strat.chunked_loss
                   else 2 * B * S * 4096 * F32)
    hbm = (
        num_micro * 3 * P_b             # params read fwd + bwd + remat refwd
        + 2 * N * gacc                  # grad accumulator read+write
        + P_b * 2 + 4 * N * acc         # optimizer: rw params, rw m and v
        + 6 * act_bytes                 # layer-boundary saves + reread + bwd
        + logit_bytes                   # logits + grad (or chunked)
    )

    # collectives ---------------------------------------------------------
    # FSDP param all-gather per microbatch (fwd + bwd remat gather), sharded
    # over dp; reduce-scatter of grads once per step; TP activation
    # all-reduces 2/layer/micro (fwd) + 2 (bwd) + 2 (remat).
    dp, tp, pod = mesh.dp, mesh.model, mesh.pod
    ag_params = num_micro * 2 * _ring(P_b / tp, dp)       # per chip over ICI
    rs_grads = _ring(N * gacc / tp, dp)
    # per-chip activation slab PER MICROBATCH: (B / num_micro / dp) sequences
    act_full = (B / num_micro / dp) * S * cfg.d_model * BF16
    ar_tp = (6 * cfg.num_layers * num_micro) * 2 * _ring(act_full, tp) \
        if tp > 1 else 0.0
    ici = ag_params + rs_grads + ar_tp
    # pod axis: the dp group spans pods; attribute the pod hop of the grad
    # reduce-scatter + param gathers to DCI
    dci = (_ring(N * F32 / (tp * mesh.data), pod)
           + num_micro * 2 * _ring(P_b / (tp * mesh.data), pod)) if pod > 1 else 0.0

    moe_a2a = 0.0
    if cfg.family == MOE:
        # dispatch + return (fwd, bwd, remat) per layer per micro:
        # k-way routed per-chip token slab crossing the EP axis
        tok_b = (B / num_micro / dp) * S * cfg.d_model * BF16
        moe_a2a = cfg.num_layers * num_micro * 4 * _ring(
            tok_b * cfg.experts_per_token, tp)
        ici += moe_a2a

    return CellCost(
        flops=flops, hbm_bytes=hbm, coll_ici_bytes=ici, coll_dci_bytes=dci,
        model_flops=6.0 * N_act * D_tokens,
        detail=dict(linear=lin, attention=attn, ssm=ssm, optimizer=opt,
                    ag_params=ag_params, rs_grads=rs_grads, ar_tp=ar_tp,
                    moe_a2a=moe_a2a, act_bytes=act_bytes))


def prefill_cost(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshShape,
                 scfg: SparseRLConfig) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    N_act = cfg.n_active_params()
    lin = 2.0 * N_act * B * S
    attn = _attention_flops(cfg, B, S)
    ssm = _ssm_flops(cfg, B, S)
    flops = lin + attn + ssm

    P_b = _param_bytes(cfg)
    act_bytes = cfg.num_layers * B * S * cfg.d_model * BF16
    kv_bytes = (_attn_layers(cfg) * B * cfg.num_kv_heads * S * cfg.head_dim
                * 2 * BF16)
    hbm = P_b + 4 * act_bytes + kv_bytes

    dp, tp, pod = mesh.dp, mesh.model, mesh.pod
    ag_params = _ring(P_b / tp, dp)
    act_full = (B / dp if B >= dp else B) * S * cfg.d_model * BF16
    ar_tp = 2 * cfg.num_layers * 2 * _ring(act_full, tp) if tp > 1 else 0.0
    ici = ag_params + ar_tp
    dci = _ring(P_b / (tp * mesh.data), pod) if pod > 1 else 0.0
    if cfg.family == MOE:
        tok_b = (B / dp if B >= dp else B) * S * cfg.d_model * BF16
        ici += cfg.num_layers * 2 * _ring(tok_b * cfg.experts_per_token, tp)

    return CellCost(flops=flops, hbm_bytes=hbm, coll_ici_bytes=ici,
                    coll_dci_bytes=dci, model_flops=2.0 * N_act * B * S,
                    detail=dict(linear=lin, attention=attn, ssm=ssm,
                                kv_bytes=kv_bytes))


def decode_cost(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshShape,
                scfg: SparseRLConfig, sparse_cache: bool,
                strat: Strategy = BASELINE) -> CellCost:
    mesh = strat.eff(mesh)
    B = shape.global_batch
    ctx = scfg.cache_slots if sparse_cache else shape.seq_len
    N_act = cfg.n_active_params()
    lin = 2.0 * N_act * B
    attn = 4.0 * _attn_layers(cfg) * B * ctx * cfg.num_heads * cfg.head_dim
    ssm = 0.0
    if cfg.family in (SSM, HYBRID):
        H, P, Nst = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        ssm = 2.0 * cfg.num_layers * B * (4 * H * P * Nst)
    flops = lin + attn + ssm

    P_b = _param_bytes(cfg) * strat.weight_bits / 16.0
    cache_bytes = (_attn_layers(cfg) * B * cfg.num_kv_heads * ctx
                   * cfg.head_dim * 2 * BF16)
    hbm = P_b + 2 * cache_bytes + 2 * B * cfg.vocab_size * F32

    dp, tp, pod = mesh.dp, mesh.model, mesh.pod
    # decode: params resident (no FSDP gather on the serving path — weights
    # stay sharded TP and activations all-reduce per layer)
    act_full = (B / dp if B >= dp else B) * cfg.d_model * BF16
    ar_tp = 2 * cfg.num_layers * 2 * _ring(act_full, tp) if tp > 1 else 0.0
    # dense long caches shard slots over model -> attention partial softmax
    # all-reduce of (B, Hq, out) per layer
    ici = ar_tp
    dci = 0.0
    if cfg.family == MOE:
        ici += cfg.num_layers * 2 * _ring(
            (B / dp if B >= dp else B) * cfg.d_model * BF16
            * cfg.experts_per_token, tp)

    return CellCost(flops=flops, hbm_bytes=hbm, coll_ici_bytes=ici,
                    coll_dci_bytes=dci, model_flops=2.0 * N_act * B,
                    detail=dict(linear=lin, attention=attn, ssm=ssm,
                                cache_bytes=cache_bytes))


def cell_cost(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshShape,
              scfg: Optional[SparseRLConfig] = None,
              num_micro: Optional[int] = None, sparse_cache: bool = False,
              strat: Strategy = BASELINE) -> CellCost:
    scfg = scfg or SparseRLConfig()
    if shape.kind == "train":
        if num_micro is None:
            num_micro = max(1, shape.global_batch // strat.eff(mesh).dp)
        return train_cost(cfg, shape, mesh, scfg, num_micro, strat)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape, mesh, scfg)
    return decode_cost(cfg, shape, mesh, scfg, sparse_cache, strat)
