"""pjit-able step functions for every cell kind.

  train_step   — Sparse-RL update (Eq. 7 loss -> grads -> AdamW), with
                 gradient accumulation over a leading microbatch dim
                 (``lax.scan``: live activations = one microbatch).
  prefill_step — rollout-phase prefill: forward + build the (compressed)
                 KV cache stack.
  decode_step  — one serve-step: decode one token against the cache and
                 sample (the rollout inner loop body).

These are pure functions of (params, opt_state, batch/state) so the dry-run
can ``jax.jit(...).lower(*ShapeDtypeStructs).compile()`` them directly, and
``train.py`` / ``serve.py`` run them for real.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SparseRLConfig, TrainConfig, dtype_of
from repro.core import sparse_rl_loss
from repro.models import get_model
from repro.optim import adamw
from repro.rollout import sample_token
from repro.rollout.engine import rescore_parts


def _extra(batch: Dict) -> Dict:
    return {k: batch[k] for k in ("prefix_embeds", "frames", "enc_mask")
            if k in batch}


def make_loss_fn(cfg: ModelConfig, scfg: SparseRLConfig, *,
                 use_flash: bool = False):
    m = get_model(cfg)

    def loss_fn(params, mb):
        logp_theta = rescore_parts(
            params, cfg, m, mb["prompt_tokens"], mb["prompt_mask"],
            mb["resp_tokens"], mb["resp_mask"], extra_batch=_extra(mb),
            use_flash=use_flash)
        out = sparse_rl_loss(logp_theta, mb["logp_old"], mb["logp_sparse"],
                             mb["advantages"], mb["resp_mask"], scfg)
        return out.loss, out.metrics

    return loss_fn


def make_train_step(cfg: ModelConfig, scfg: SparseRLConfig, tcfg: TrainConfig,
                    *, num_micro: int = 1, use_flash: bool = False,
                    grad_dtype=jnp.float32, grad_rules: dict = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  When num_micro > 1 every batch leaf has a leading microbatch
    dim and gradients accumulate in a scan.  Gradients / accumulators carry
    explicit sharding constraints matching the parameter layout (2-D
    FSDP x TP) — without them SPMD replicates the accumulator, which at 405B
    scale is the difference between 6 GB and 700 GB per device."""
    loss_fn = make_loss_fn(cfg, scfg, use_flash=use_flash)
    grad_fn = jax.grad(loss_fn, has_aux=True)
    from repro.distributed.sharding import param_rules, tree_lsc
    from repro.models import get_model as _gm
    p_axes = _gm(cfg).param_axes(cfg)
    p_rules = grad_rules if grad_rules is not None else param_rules()

    def train_step(params, opt_state, batch):
        if num_micro > 1:
            def micro(acc, mb):
                g, metrics = grad_fn(params, mb)
                g = tree_lsc(g, p_axes, p_rules)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), acc, g)
                acc = tree_lsc(acc, p_axes, p_rules)
                return acc, metrics

            zeros = tree_lsc(jax.tree.map(
                lambda p: jnp.zeros(p.shape, grad_dtype), params), p_axes,
                p_rules)
            grads, metrics = jax.lax.scan(micro, zeros, batch)
            grads = jax.tree.map(lambda g: g / num_micro, grads)
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        else:
            grads, metrics = grad_fn(params, batch)
            grads = tree_lsc(grads, p_axes, p_rules)
        lr = adamw.warmup_cosine(opt_state.step, base_lr=scfg.learning_rate,
                                 warmup=tcfg.warmup_steps,
                                 total=tcfg.total_steps)
        params, opt_state, om = adamw.update(
            params, grads, opt_state, lr=lr, b1=tcfg.adam_b1, b2=tcfg.adam_b2,
            eps=tcfg.adam_eps, weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip)
        return params, opt_state, dict(metrics, **om)

    return train_step


def make_prefill_step(cfg: ModelConfig, scfg: SparseRLConfig, *,
                      sparse_cache: bool, ctx_len: int,
                      use_flash: Optional[bool] = None):
    m = get_model(cfg)
    slots = scfg.cache_slots if sparse_cache else ctx_len + 8

    def prefill_step(params, batch):
        return m.prefill(params, cfg, batch, scfg, slots, use_flash=use_flash)

    return prefill_step


def make_decode_step(cfg: ModelConfig, scfg: SparseRLConfig):
    m = get_model(cfg)

    def decode_step(params, state, tokens, rng):
        logits, state = m.decode_step(params, cfg, state, tokens, scfg)
        tok, logp = sample_token(rng, logits, scfg.temperature, scfg.top_p)
        return tok, logp, state

    return decode_step


def init_opt_specs(param_sds, cfg: ModelConfig):
    """SDS tree for the AdamW state matching param specs."""
    accum = dtype_of(cfg.accum_dtype)
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, accum)
    return adamw.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(zeros, param_sds),
        nu=jax.tree.map(zeros, param_sds))


def opt_axes(params_axes):
    """Optimizer-state logical axes mirror the parameter axes."""
    return adamw.AdamWState(step=(), mu=params_axes, nu=params_axes)
