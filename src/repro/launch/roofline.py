"""Roofline report: analytic three-term model per cell, merged with the
dry-run's compiled evidence (memory analysis + HLO collective inventory).

  PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16]
writes reports/roofline.md + reports/roofline.json.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.configs import ARCH_IDS, SparseRLConfig, get_config, get_shapes
from repro.launch.costs import MeshShape, cell_cost

HW_NOTE = ("TPU v5e-class: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link "
           "ICI per chip")

# Per-device peaks backing the *kernel-level* roofline sanity bound the
# autotuner applies to sweep winners (kernels/autotune.py; methodology in
# PERFORMANCE.md).  Values are (peak FLOP/s, peak HBM bytes/s).  The
# ``interpret`` row covers every non-TPU host: deliberately generous
# optimistic peaks, so the bound stays a true lower bound — interpret-mode
# timings sit orders of magnitude above it, and any measurement that lands
# *below* it is a benchmarking bug (caching, a dropped block_until_ready),
# not a fast kernel.
KERNEL_PEAKS = {
    "tpu_v4": (275e12, 1228e9),
    "tpu_v5e": (197e12, 819e9),
    "tpu_v5p": (459e12, 2765e9),
    "interpret": (1e12, 400e9),
}


def kernel_bound_s(flops: float, hbm_bytes: float, device_kind: str) -> float:
    """Analytic lower bound on one kernel launch: the slower of the compute
    term (flops / peak FLOP/s) and the memory term (bytes / peak HBM B/s).
    No launch-overhead term — omitting it keeps this a strict lower bound,
    which is what the autotuner's too-fast-winner rejection needs."""
    peak_f, peak_b = KERNEL_PEAKS.get(device_kind, KERNEL_PEAKS["interpret"])
    return max(flops / peak_f, hbm_bytes / peak_b)


def _mesh_of(tag: str) -> MeshShape:
    return MeshShape(pod=2, data=16, model=16) if tag == "pod2x16x16" \
        else MeshShape(pod=1, data=16, model=16)


def _plan_flags(cfg, shape):
    from repro.launch.dryrun import cell_plan

    plan = cell_plan(cfg, shape, SparseRLConfig())
    return plan


def build_rows(mesh_tag: str, dryrun_dir: str = "reports/dryrun"
               ) -> List[Dict]:
    mesh = _mesh_of(mesh_tag)
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in get_shapes(arch):
            plan = _plan_flags(cfg, shape)
            num_micro = plan.get("num_micro", 16)
            if shape.kind == "train":
                num_micro = max(1, shape.global_batch // mesh.dp)
            cost = cell_cost(cfg, shape, mesh, num_micro=num_micro,
                             sparse_cache=plan.get("sparse_cache", False))
            terms = cost.terms(mesh)
            row = dict(
                arch=arch, shape=shape.name, kind=shape.kind,
                mesh=mesh_tag, chips=mesh.chips,
                flops_g=cost.flops, hbm_bytes_g=cost.hbm_bytes,
                coll_ici_chip=cost.coll_ici_bytes,
                coll_dci_chip=cost.coll_dci_bytes,
                model_flops=cost.model_flops,
                **{k: v for k, v in terms.items()},
            )
            # merge dry-run evidence
            p = os.path.join(dryrun_dir, f"{arch}__{shape.name}__{mesh_tag}.json")
            if os.path.exists(p):
                with open(p) as f:
                    d = json.load(f)
                row["compiled"] = d.get("status")
                mem = d.get("memory") or {}
                row["hbm_per_dev_gb"] = round(
                    ((mem.get("argument_bytes") or 0)
                     + (mem.get("temp_bytes") or 0)) / 1e9, 2)
                row["hlo_collective_bytes"] = (d.get("collectives") or {}).get(
                    "total_bytes")
                row["hlo_flops_per_dev"] = (d.get("cost") or {}).get("flops")
            rows.append(row)
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck "
           "| roofline frac | useful ratio | HBM/dev GB | compiled |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['bottleneck']}** | {r['roofline_frac']:.2%} "
            f"| {r['useful_ratio']:.2f} | {r.get('hbm_per_dev_gb', '-')} "
            f"| {r.get('compiled', '-')} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16",
                    choices=["pod16x16", "pod2x16x16"])
    ap.add_argument("--out", default="reports")
    args = ap.parse_args()
    rows = build_rows(args.mesh)
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"roofline_{args.mesh}.json"), "w") as f:
        json.dump(rows, f, indent=1)
    md = [f"# Roofline — {args.mesh} ({HW_NOTE})", "", to_markdown(rows)]
    with open(os.path.join(args.out, f"roofline_{args.mesh}.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    print(to_markdown(rows))


if __name__ == "__main__":
    main()
