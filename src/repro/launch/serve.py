"""Batched serving driver: prefill + sparse-cache decode.

Demonstrates the deployment-side claim (paper §5.4): a Sparse-RL-trained
model served WITH the same KV compression it was trained under.  Loads a
checkpoint if given, otherwise serves a fresh init (useful for throughput
measurement).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
      --batch 16 --max-new 32 --compression rkv
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--compression", default="rkv")
    ap.add_argument("--kv-budget", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from dataclasses import replace

    from repro.checkpoint import restore
    from repro.configs import SparseRLConfig, get_config
    from repro.data import TOKENIZER, make_problems, encode_prompts
    from repro.models import get_model
    from repro.rewards import binary_rewards, decode_responses
    from repro.rollout import generate

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    scfg = SparseRLConfig(compression=args.compression)
    if args.smoke:
        scfg = replace(scfg, kv_budget=args.kv_budget or 24, kv_buffer=8,
                       obs_window=4, num_sinks=2)
    elif args.kv_budget:
        scfg = replace(scfg, kv_budget=args.kv_budget)

    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        tree = {"params": params}
        restored, step, _ = restore(args.ckpt_dir, tree)
        params = restored["params"]
        print(f"restored checkpoint step {step}")

    problems = make_problems(args.batch, args.seed, "easy")
    ids, mask, answers = encode_prompts(problems, 24)
    batch = {"tokens": jnp.asarray(ids), "valid_mask": jnp.asarray(mask)}

    gen = jax.jit(lambda p, b, r: generate(
        p, cfg, m, b, scfg, r, max_new_tokens=args.max_new,
        eos_id=TOKENIZER.eos_id))
    # warmup (compile)
    ro = gen(params, batch, jax.random.PRNGKey(1))
    jax.block_until_ready(ro.resp_tokens)
    t0 = time.time()
    ro = gen(params, batch, jax.random.PRNGKey(2))
    jax.block_until_ready(ro.resp_tokens)
    dt = time.time() - t0
    toks = int(np.asarray(jax.device_get(ro.lengths)).sum())
    rewards = binary_rewards(np.asarray(jax.device_get(ro.resp_tokens)), answers)

    slots = scfg.cache_slots if scfg.compression != "none" else ids.shape[1] + args.max_new
    print(f"served batch={args.batch} new_tokens={toks} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s) | cache slots/seq/layer: {slots} "
          f"| accuracy: {rewards.mean():.3f}")
    for i, (p, r) in enumerate(zip(problems[:4], decode_responses(
            np.asarray(jax.device_get(ro.resp_tokens))))):
        print(f"  [{i}] {p.prompt!r} -> {r!r} (gold {p.answer})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
