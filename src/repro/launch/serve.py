"""Serving driver with request-arrival simulation over the continuous-
batching engine (deployment half of the paper, §5.4: a Sparse-RL-trained
model served WITH the KV compression it was trained under).

Simulates an open-loop arrival process (Poisson at ``--rate`` req/s, or a
burst of everything at t=0), drives either the continuous-batching scheduler
(`repro.rollout.continuous`) or the lockstep baseline over the same
workload, and reports throughput, per-request latency percentiles
(p50/p90/p99), queue wait, and goodput — tokens/s from requests that met
``--slo-ms``.  Response-length mix comes from per-request new-token caps
(``--resp-dist mixed`` draws a long-tailed spread; real EOS also finishes a
request early).  Loads a checkpoint if given, otherwise serves a fresh init
(useful for pure scheduler measurement).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
      --engine both --num-requests 24 --batch 4 --max-new 64 \
      --compression rkv --rate 50 --slo-ms 2000
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


def _report(log, name, completions, wall_s, slo_ms=None):
    """Serving summary as structured run-log events (DESIGN.md
    §Observability & telemetry): the console rendering keeps the familiar
    ``[name] ...`` lines, while --run-log captures the same numbers as
    machine-parseable JSONL."""
    toks = sum(len(c.tokens) for c in completions)
    lat = [c.latency for c in completions]
    wait = [c.queue_wait for c in completions]
    p50, p90, p99 = (_pct(lat, q) for q in (50, 90, 99))
    wait_p50 = _pct(wait, 50)
    log.event("serve_throughput", engine=name, requests=len(completions),
              tokens=toks, wall_s=wall_s, tok_per_s=toks / wall_s,
              req_per_s=len(completions) / wall_s,
              msg=f"[{name}] {len(completions)} requests, {toks} tokens "
                  f"in {wall_s:.2f}s -> {toks / wall_s:.1f} tok/s, "
                  f"{len(completions) / wall_s:.1f} req/s")
    log.event("serve_latency", engine=name, p50_s=p50, p90_s=p90, p99_s=p99,
              queue_wait_p50_s=wait_p50,
              msg=f"[{name}] latency p50/p90/p99: {p50*1e3:.0f}/"
                  f"{p90*1e3:.0f}/{p99*1e3:.0f} ms | "
                  f"queue wait p50: {wait_p50*1e3:.0f} ms")
    if slo_ms is not None:
        ok = [c for c in completions if c.latency * 1e3 <= slo_ms]
        good = sum(len(c.tokens) for c in ok)
        log.event("serve_goodput", engine=name, slo_ms=slo_ms,
                  goodput_tok_per_s=good / wall_s, in_slo=len(ok),
                  requests=len(completions),
                  msg=f"[{name}] goodput (<= {slo_ms:.0f} ms): "
                      f"{good / wall_s:.1f} tok/s "
                      f"({len(ok)}/{len(completions)} requests in SLO)")
    reasons = {}
    for c in completions:
        reasons[c.finish_reason] = reasons.get(c.finish_reason, 0) + 1
    log.event("serve_finish_reasons", engine=name, reasons=reasons,
              msg=f"[{name}] finish reasons: {reasons}")


def mix_prompt_lengths(prompts, seed, plen_dist="mixed"):
    """Spread PROMPT lengths: each prompt keeps its full, half or quarter
    tokens (drawn per prompt, min 3) — the ONE definition of the "mixed"
    prompt-length workload, shared by the serving and rollout benchmarks so
    their ``plen_dist`` row labels always mean the same distribution."""
    if plen_dist == "fixed":
        return list(prompts)
    if plen_dist != "mixed":
        raise ValueError(f"unknown plen_dist {plen_dist!r}")
    rng = np.random.default_rng(seed + 2)
    fracs = rng.choice([1.0, 0.5, 0.25], size=len(prompts), p=[0.3, 0.4, 0.3])
    return [p[:max(3, int(round(len(p) * f)))]
            for p, f in zip(prompts, fracs)]


def make_workload(n, prompt_len, max_new, rate, resp_dist, seed, level="easy",
                  group_size=1, plen_dist="fixed"):
    """n*group_size Requests over the synthetic math task: Poisson arrivals
    at ``rate`` req/s (rate 0 = burst at t=0) and fixed or long-tailed-mixed
    response caps.  ``group_size`` > 1 repeats each of the n prompts G times
    under distinct uids — the GRPO group-sampling shape, where the paged
    backend's prefix cache prefills each prompt once (hit rate (G-1)/G).

    ``plen_dist="mixed"`` additionally spreads PROMPT lengths (each prompt
    keeps its full, half or quarter tokens, drawn per prompt) — the regime
    the chunked-prefill length buckets exist for: short prompts stop paying
    for engine-wide padding at admission (DESIGN.md §Chunked prefill &
    fill-aware decode).  Truncation is per prompt, so group members still
    share their (shortened) prompt."""
    from repro.data import encode_prompts, make_problems
    from repro.rollout import Request

    problems = make_problems(n, seed, level)
    ids, mask, answers = encode_prompts(problems, prompt_len)
    prompts = mix_prompt_lengths([ids[i][mask[i]] for i in range(n)],
                                 seed, plen_dist)
    total = n * group_size
    rng = np.random.default_rng(seed + 1)
    if rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=total))
    else:
        arrivals = np.zeros(total)
    if resp_dist == "mixed":
        lo = max(2, max_new // 16)
        spread = [lo, max(lo, max_new // 4), max(lo, max_new // 2), max_new]
        caps = rng.choice(spread, size=total, p=[0.4, 0.3, 0.2, 0.1])
    else:
        caps = np.full(total, max_new)
    reqs = [Request(uid=u, prompt=prompts[u // group_size],
                    max_new_tokens=int(caps[u]),
                    arrival_time=float(arrivals[u])) for u in range(total)]
    problems = [problems[u // group_size] for u in range(total)]
    answers = np.asarray([answers[u // group_size] for u in range(total)])
    return reqs, problems, answers


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="both",
                    choices=["continuous", "lockstep", "both"])
    ap.add_argument("--num-requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode batch size (row slots)")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--sampler-policy", default=None,
                    help="registry sampler policy (rollout.policies): dense, "
                         "rkv, snapkv, h2o, streaming, per_head, adaptive, "
                         "quant-int8, quant-fp8.  Supersedes the legacy "
                         "--compression/--kv-quant pair (DESIGN.md "
                         "§Sampler policy registry)")
    ap.add_argument("--compression", default=None,
                    help="DEPRECATED alias: use --sampler-policy (maps "
                         "through the registry bitwise-identically)")
    ap.add_argument("--kv-budget", type=int, default=None)
    ap.add_argument("--cache-backend", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="paged = block-table pool with prefix sharing "
                         "(DESIGN.md §Paged cache & prefix sharing)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged backend: tokens per pool page")
    ap.add_argument("--kv-quant", default=None,
                    choices=["none", "int8", "fp8"],
                    help="DEPRECATED alias: use --sampler-policy quant-int8/"
                         "quant-fp8.  Paged backend: quantized KV pool "
                         "storage with per-(page, kv-head) scales (DESIGN.md "
                         "§Quantized paged pool)")
    ap.add_argument("--group-size", type=int, default=1,
                    help="repeat each prompt G times (GRPO group sampling; "
                         "total requests = num-requests * G)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, req/s (0 = burst at t=0)")
    ap.add_argument("--resp-dist", default="mixed",
                    choices=["mixed", "fixed"],
                    help="per-request response-cap distribution")
    ap.add_argument("--plen-dist", default="fixed",
                    choices=["fixed", "mixed"],
                    help="prompt-length distribution (mixed = per-prompt "
                         "full/half/quarter truncation; exercises the "
                         "chunked-prefill length buckets)")
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt-token budget per admission sweep "
                         "(Sarathi-style chunked prefill; default auto)")
    ap.add_argument("--overlap-harvest", action="store_true",
                    help="async double-buffered harvest: chunk t+1 "
                         "dispatched before chunk t is fetched (wins when "
                         "host bookkeeping rivals chunk compute; costs a "
                         "chunk-sized bubble per finished request)")
    ap.add_argument("--slo-ms", type=float, default=None)
    ap.add_argument("--warmup", action="store_true",
                    help="run the workload once first so reported numbers "
                         "exclude XLA compilation")
    ap.add_argument("--telemetry", default="off",
                    choices=["off", "metrics", "trace"],
                    help="observability knob (DESIGN.md "
                         "§Observability & telemetry): "
                         "metrics = registry; trace = spans + "
                         "registry exported as Chrome trace JSON")
    ap.add_argument("--trace-out", default=None,
                    help="Chrome trace-event JSON output path (telemetry="
                         "trace; default reports/trace_serve.json)")
    ap.add_argument("--run-log", default=None,
                    help="structured JSONL run-log path (default "
                         "reports/run_log.jsonl when telemetry is on)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from dataclasses import replace

    from repro.checkpoint import restore
    from repro.configs import SparseRLConfig, get_config
    from repro.data import TOKENIZER
    from repro.models import get_model
    from repro.rewards import binary_rewards, decode_responses
    from repro.rollout import ContinuousEngine, LockstepServer, rollout_slots
    from repro.rollout.policies import resolve_cli_policy
    from repro.telemetry import Telemetry

    tel = Telemetry(args.telemetry,
                    run_log_path=(args.run_log
                                  or ("reports/run_log.jsonl"
                                      if args.telemetry != "off" else None)))
    log = tel.log

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    policy = resolve_cli_policy(args.sampler_policy, args.compression,
                                args.kv_quant, default_compression="rkv")
    scfg = policy.apply(SparseRLConfig())
    if args.smoke:
        scfg = replace(scfg, kv_budget=args.kv_budget or 24, kv_buffer=8,
                       obs_window=4, num_sinks=2)
    elif args.kv_budget:
        scfg = replace(scfg, kv_budget=args.kv_budget)

    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        restored, step, _ = restore(args.ckpt_dir, {"params": params})
        params = restored["params"]
        log.event("checkpoint_restored", step=int(step),
                  ckpt_dir=args.ckpt_dir,
                  msg=f"restored checkpoint step {step}")

    reqs, problems, answers = make_workload(
        args.num_requests, args.prompt_len, args.max_new, args.rate,
        args.resp_dist, args.seed, group_size=args.group_size,
        plen_dist=args.plen_dist)
    slots = rollout_slots(scfg, args.prompt_len, args.max_new)
    log.event(
        "serve_config", arch=args.arch, smoke=args.smoke, policy=policy.name,
        cache_slots=int(slots), backend=args.cache_backend,
        requests=len(reqs), group_size=args.group_size, rate=args.rate,
        resp_dist=args.resp_dist,
        msg=f"arch={args.arch}{' (smoke)' if args.smoke else ''} "
            f"policy={policy.name} cache slots/seq/layer: {slots} | "
            f"backend={args.cache_backend} | "
            f"{len(reqs)} requests"
            f"{f' ({args.num_requests} prompts x G={args.group_size})' if args.group_size > 1 else ''}, "
            f"rate={args.rate if args.rate > 0 else 'burst'} req/s, "
            f"resp-dist={args.resp_dist}")

    results = {}
    if args.engine in ("continuous", "both"):
        eng = ContinuousEngine(
            params, cfg, m, scfg, batch_size=args.batch,
            prompt_len=args.prompt_len, max_new_tokens=args.max_new,
            eos_id=TOKENIZER.eos_id, decode_chunk=args.decode_chunk,
            seed=args.seed, cache_backend=args.cache_backend,
            block_size=args.block_size, kv_quant=policy.kv_quant,
            prefill_chunk=args.prefill_chunk,
            overlap_harvest=args.overlap_harvest,
            telemetry=tel)
        if args.warmup:
            eng.run(reqs)
            eng.reset_clock()
            if eng.prefix is not None:
                # report COLD sharing numbers (one prefill per prompt,
                # (G-1)/G hit rate) — a warm cache would show 100%
                eng.prefix.clear()
        t0 = time.perf_counter()
        with tel.span("serve_run", engine="continuous"):
            completions = eng.run(reqs)
        wall = time.perf_counter() - t0
        _report(log, "continuous", completions, wall, args.slo_ms)
        st = eng.stats
        used = st["decode_steps"] * args.batch - st["wasted_row_steps"]
        log.event(
            "serve_engine_stats", engine="continuous",
            decode_steps=st["decode_steps"], chunks=st["chunks"],
            row_step_util=used / max(st["decode_steps"] * args.batch, 1),
            msg=f"[continuous] decode steps: {st['decode_steps']:.0f} "
                f"({st['chunks']:.0f} chunks), row-step utilization: "
                f"{used / max(st['decode_steps'] * args.batch, 1):.0%}")
        log.event(
            "serve_prefill_stats", engine="continuous",
            prefills=st["prefills"],
            prefill_dispatches=st["prefill_dispatches"],
            prefill_tokens=st["prefill_tokens"], prefill_s=st["prefill_s"],
            msg=f"[continuous] prefill: {st['prefills']:.0f} prompts in "
                f"{st['prefill_dispatches']:.0f} batched dispatches, "
                f"{st['prefill_tokens']:.0f} padded tokens "
                f"({st['prefill_s']*1e3:.0f} ms host-side dispatch)")
        if args.cache_backend == "paged":
            extra = ""
            if eng.allocator is not None:
                extra = (f" | pool pages in use (peak): "
                         f"{st['blocks_in_use_peak']:.0f}/"
                         f"{eng.pool_blocks - 1}")
            log.event(
                "serve_prefix_stats", engine="continuous",
                prefills=st["prefills"], admissions=st["admissions"],
                hit_rate=eng.prefix_hit_rate,
                blocks_in_use_peak=st.get("blocks_in_use_peak"),
                msg=f"[continuous] prefix sharing: "
                    f"{st['prefills']:.0f} prefills for "
                    f"{st['admissions']:.0f} admissions, hit rate "
                    f"{eng.prefix_hit_rate:.0%}{extra}")
            ps = eng.kv_pool_stats()
            log.event(
                "serve_kv_pool", engine="continuous",
                kv_quant=policy.kv_quant, **ps,
                msg=f"[continuous] kv pool ({policy.kv_quant}): "
                    f"{ps['kv_pool_bytes_per_layer'] / 2**20:.2f} MiB/layer, "
                    f"{ps['kv_bytes_per_token']:.1f} B/token, "
                    f"{ps['kv_capacity_ratio']:.2f}x fp capacity")
        results["continuous"] = completions
    if args.engine in ("lockstep", "both"):
        srv = LockstepServer(
            params, cfg, m, scfg, batch_size=args.batch,
            prompt_len=args.prompt_len, max_new_tokens=args.max_new,
            eos_id=TOKENIZER.eos_id, seed=args.seed)
        if args.warmup:
            srv.run(reqs)
        t0 = time.perf_counter()
        with tel.span("serve_run", engine="lockstep"):
            completions = srv.run(reqs)
        wall = time.perf_counter() - t0
        _report(log, "lockstep", completions, wall, args.slo_ms)
        results["lockstep"] = completions

    if len(results) == 2:
        same = all(np.array_equal(a.tokens, b.tokens) for a, b in
                   zip(results["continuous"], results["lockstep"]))
        log.event("serve_engine_parity", token_identical=bool(same),
                  msg=f"token-identical across engines: {same}")

    completions = next(iter(results.values()))
    resp = [c.tokens for c in completions]
    longest = max(len(r) for r in resp)
    mat = np.zeros((len(resp), longest), np.int32)
    for i, r in enumerate(resp):
        mat[i, :len(r)] = r
    acc = binary_rewards(mat, answers).mean()
    log.event("serve_accuracy", accuracy=float(acc),
              msg=f"accuracy: {acc:.3f}")
    for i, r in enumerate(decode_responses(mat[:4])):
        print(f"  [{i}] {problems[i].prompt!r} -> {r!r} "
              f"(gold {answers[i]})")
    if args.telemetry == "trace":
        out = args.trace_out or "reports/trace_serve.json"
        tel.export_trace(out)
        print(f"[telemetry] chrome trace -> {out} "
              f"(tools/trace_report.py or ui.perfetto.dev)")
    tel.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
