"""Named sharding strategies on the FIXED production mesh.

A strategy is (logical-rule overrides, numerics flags).  The mesh shape never
changes — the physical (16,16)/(2,16,16) topology is the contract — only the
logical mapping does (e.g. ``zero3`` folds the model axis into data
parallelism, which GSPMD realizes as pure ZeRO-3).

Used by the §Perf hillclimb: the analytic cost model predicts, the dry-run
recompile (rules=...) verifies collective bytes / memory.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.launch.costs import Strategy

# rules overrides per strategy name (merged over DEFAULT_RULES; the param
# attacher additionally applies PARAM_EXTRA_RULES on top)
_NO_TP = {
    "heads": None, "kv_heads": None, "ffn": None, "moe_ffn": None,
    "vocab": None, "ssm_inner": None, "ssm_heads": None,
    "batch": ("pod", "data", "model"),
    "embed": None,  # activations stay replicated; params get fsdp below
}

RULES: Dict[str, Optional[dict]] = {
    "baseline": None,
    # pure ZeRO-3: every axis is data parallelism; params/grads/opt-state
    # sharded over all 256 chips, gathered per layer
    "zero3": dict(_NO_TP, experts=None),
    # ZeRO-3 for the dense trunk + expert parallelism: expert weights stay
    # RESIDENT sharded on the model axis (no per-layer gather of the 61 GB
    # expert bank); tokens all-to-all across the EP groups
    "zero3_ep": dict(_NO_TP, experts=("model",)),
}

# param-fsdp override per strategy ("embed" param dim placement)
PARAM_FSDP: Dict[str, Tuple[str, ...]] = {
    "baseline": ("pod", "data"),
    "zero3": ("pod", "data", "model"),
    "zero3_ep": ("pod", "data"),
}

STRATEGIES: Dict[str, Strategy] = {
    "baseline": Strategy("baseline"),
    "zero3": Strategy("zero3", tp_eff=1),
    "zero3_bf16g": Strategy("zero3_bf16g", tp_eff=1, grad_accum_bits=16),
    "zero3_ep": Strategy("zero3_ep", tp_eff=1),
    "int8": Strategy("int8", weight_bits=8),
    "int4": Strategy("int4", weight_bits=4),
}


def rules_for(name: str) -> Optional[dict]:
    return RULES.get(name)


def param_rules_for(name: str) -> dict:
    from repro.distributed.sharding import DEFAULT_RULES

    base = dict(DEFAULT_RULES)
    over = RULES.get(name)
    if over:
        base.update(over)
    base["embed"] = PARAM_FSDP.get(name, ("pod", "data"))
    return base
