"""Compiled-HLO introspection: collective-op inventory with while-loop
trip-count adjustment.

``compiled.cost_analysis()`` counts while bodies once (verified — see
EXPERIMENTS.md §Methodology), so collective bytes inside a scanned layer
stack would be undercounted by ~num_layers.  This parser:

  1. splits the HLO text into named computations,
  2. finds collective ops (all-reduce / all-gather / reduce-scatter /
     all-to-all / collective-permute) and their output shapes,
  3. extracts each while loop's trip count from its condition computation
     (scan lowers to a counter compared against a constant),
  4. multiplies nested collective bytes by the enclosing trip counts.

Byte accounting uses the op's OUTPUT shape — the per-device payload that
crosses links once per ring step; we report raw payload bytes and leave the
(|axis|-1)/|axis| ring factor to the roofline layer.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+\[[\d,]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL_RE = re.compile(
    r"(?:while\(|condition=|body=|calls=|to_apply=|branch_computations=)"
    r"[^,)\n]*%?([\w\.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _HEADER_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?\s*:\s*"?(\d+)')


def _trip_count_of_condition(lines: List[str]) -> Optional[int]:
    """Fallback when backend_config lacks known_trip_count: scan conditions
    compare an s32 counter to a constant."""
    consts = {}
    for ln in lines:
        m = re.match(r"\s*%?([\w\.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in lines:
        if "compare(" in ln and "direction=LT" in ln:
            for name, val in consts.items():
                if name in ln:
                    return val
    if consts:
        return max(consts.values())
    return None


def _find_whiles(lines: List[str]):
    """yield (body_name, condition_name, trip_or_None) per while op."""
    for ln in lines:
        if " while(" in ln:
            body = re.search(r"body=%?([\w\.\-]+)", ln)
            cond = re.search(r"condition=%?([\w\.\-]+)", ln)
            trip = _TRIP_RE.search(ln)
            if body and cond:
                yield (body.group(1), cond.group(1),
                       int(trip.group(1)) if trip else None)


def _find_calls(lines: List[str]):
    for ln in lines:
        for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", ln):
            yield m.group(1)


def collective_stats(hlo: str) -> Dict:
    comps = split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w\.\-]+)", line)
        if m:
            entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: treat the whole text as one computation
        comps = {"__all__": hlo.splitlines()}
        entry = "__all__"

    totals = defaultdict(float)
    counts = defaultdict(int)
    seen_trip = {}

    def comp_collectives(name: str, multiplier: float, depth: int = 0):
        if depth > 12 or name not in comps:
            return
        lines = comps[name]
        for ln in lines:
            m = _COLL_RE.search(ln)
            if m:
                kind = m.group(3)
                # skip -done halves (bytes counted at -start)
                if "-done(" in ln:
                    continue
                b = _shape_bytes(m.group(1) or m.group(2))
                totals[kind] += b * multiplier
                counts[kind] += 1
        for body, cond, trip in _find_whiles(lines):
            if trip is None:
                trip = seen_trip.get(cond)
                if trip is None:
                    trip = _trip_count_of_condition(comps.get(cond, [])) or 1
                    seen_trip[cond] = trip
            comp_collectives(body, multiplier * trip, depth + 1)
        for callee in _find_calls(lines):
            if callee != name:
                comp_collectives(callee, multiplier, depth + 1)

    comp_collectives(entry, 1.0)
    return dict(
        bytes_by_kind={k: int(v) for k, v in totals.items()},
        op_counts=dict(counts),
        total_bytes=int(sum(totals.values())),
    )
