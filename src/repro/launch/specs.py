"""ShapeDtypeStruct input specs for every (architecture x shape) cell.

Nothing here allocates: params come from ``jax.eval_shape(init_params)``,
batches/states are hand-constructed SDS trees (weak-type-correct, shardable).
The same specs serve the dry-run lowering and the roofline accounting.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AUDIO,
    HYBRID,
    SSM,
    VLM,
    ModelConfig,
    ShapeSpec,
    SparseRLConfig,
    dtype_of,
)
from repro.models import get_model

SDS = jax.ShapeDtypeStruct

# prompt length carved out of each train sequence (rest is response)
TRAIN_PROMPT_LEN = 512
# whisper train split of the 4k budget: the arch's real encoder length
# (1500 frames) + the remaining budget as decoder tokens
AUDIO_TRAIN_FRAMES = 1500
AUDIO_TRAIN_DECODER = 4096 - AUDIO_TRAIN_FRAMES
# vlm patch prefix
VLM_PATCHES = 256


def param_specs(cfg: ModelConfig):
    m = get_model(cfg)
    return jax.eval_shape(lambda: m.init_params(cfg, jax.random.PRNGKey(0)))


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec,
                      num_micro: int = 1) -> Dict:
    """RL update-phase batch: rollout tensors ready for the Eq. 7 loss.

    Leaves get a leading microbatch dim when num_micro > 1 (grad-accum scan).
    """
    B = shape.global_batch // num_micro
    assert B * num_micro == shape.global_batch, (shape.global_batch, num_micro)
    if cfg.family == AUDIO:
        S = AUDIO_TRAIN_DECODER
        P = 128
    elif cfg.family == VLM:
        S = shape.seq_len - VLM_PATCHES
        P = TRAIN_PROMPT_LEN
    else:
        S = shape.seq_len
        P = TRAIN_PROMPT_LEN
    T = S - P
    lead = (num_micro, B) if num_micro > 1 else (B,)
    cdt = dtype_of(cfg.compute_dtype)
    batch = {
        "prompt_tokens": SDS(lead + (P,), jnp.int32),
        "prompt_mask": SDS(lead + (P,), jnp.bool_),
        "resp_tokens": SDS(lead + (T,), jnp.int32),
        "resp_mask": SDS(lead + (T,), jnp.bool_),
        "logp_sparse": SDS(lead + (T,), jnp.float32),
        "logp_old": SDS(lead + (T,), jnp.float32),
        "advantages": SDS(lead, jnp.float32),
    }
    if cfg.family == VLM:
        batch["prefix_embeds"] = SDS(lead + (VLM_PATCHES, cfg.d_model), cdt)
    if cfg.family == AUDIO:
        batch["frames"] = SDS(lead + (AUDIO_TRAIN_FRAMES, cfg.d_model), cdt)
    return batch


def train_batch_axes(cfg: ModelConfig, num_micro: int = 1) -> Dict:
    lead = (None, "batch") if num_micro > 1 else ("batch",)
    ax = {
        "prompt_tokens": lead + (None,),
        "prompt_mask": lead + (None,),
        "resp_tokens": lead + (None,),
        "resp_mask": lead + (None,),
        "logp_sparse": lead + (None,),
        "logp_old": lead + (None,),
        "advantages": lead,
    }
    if cfg.family == VLM:
        ax["prefix_embeds"] = lead + (None, "embed")
    if cfg.family == AUDIO:
        ax["frames"] = lead + (None, "embed")
    return ax


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    cdt = dtype_of(cfg.compute_dtype)
    if cfg.family == AUDIO:
        # seq_len lands on the decoder self-attention context; the encoder
        # is the arch's fixed 1500 frames (stub embeddings)
        batch = {"tokens": SDS((B, S), jnp.int32),
                 "valid_mask": SDS((B, S), jnp.bool_),
                 "frames": SDS((B, cfg.encoder_frames, cfg.d_model), cdt)}
        return batch
    batch = {"tokens": SDS((B, S), jnp.int32),
             "valid_mask": SDS((B, S), jnp.bool_)}
    if cfg.family == VLM:
        batch["prefix_embeds"] = SDS((B, VLM_PATCHES, cfg.d_model), cdt)
    return batch


def prefill_batch_axes(cfg: ModelConfig) -> Dict:
    ax = {"tokens": ("batch", None), "valid_mask": ("batch", None)}
    if cfg.family == VLM:
        ax["prefix_embeds"] = ("batch", None, "embed")
    if cfg.family == AUDIO:
        ax["frames"] = ("batch", None, "embed")
    return ax


# ---------------------------------------------------------------------------
# Decode state specs (per family) — built analytically, no tracing
# ---------------------------------------------------------------------------
def decode_state_specs(cfg: ModelConfig, shape: ShapeSpec,
                       scfg: SparseRLConfig, *, sparse_cache: bool):
    """(state_sds, state_axes, token_sds) for a decode cell with a context of
    ``shape.seq_len`` tokens already in cache."""
    from repro.kvcache.cache import KVCache
    from repro.models.encdec import EncDecState
    from repro.models.hybrid import HybridState
    from repro.models.mamba2 import SSMState
    from repro.models.transformer import DecodeState

    B = shape.global_batch
    ctx = shape.seq_len
    cdt = dtype_of(cfg.compute_dtype)
    slots = scfg.cache_slots if sparse_cache else ctx + 8
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def cache_sds(n_layers):
        # every leaf (incl. the per-row fill counter) carries the stacked
        # layer dim — prefill builds caches as scan ys
        return KVCache(
            k=SDS((n_layers, B, cfg.num_kv_heads, slots, cfg.head_dim), cdt),
            v=SDS((n_layers, B, cfg.num_kv_heads, slots, cfg.head_dim), cdt),
            pos=SDS((n_layers, B, cfg.num_kv_heads, slots), jnp.int32),
            score=SDS((n_layers, B, cfg.num_kv_heads, slots), jnp.float32),
            fill=SDS((n_layers, B), jnp.int32),
        )

    def cache_axes(stacked: bool = True):
        lead = ("layers",) if stacked else ()
        return KVCache(
            k=lead + ("batch", "kv_heads", "cache_slots", None),
            v=lead + ("batch", "kv_heads", "cache_slots", None),
            pos=lead + ("batch", "kv_heads", "cache_slots"),
            score=lead + ("batch", "kv_heads", "cache_slots"),
            fill=lead + ("batch",),
        )

    tok = SDS((B,), jnp.int32)
    if cfg.family in ("dense", "moe", "vlm"):
        st = DecodeState(caches=cache_sds(cfg.num_layers), pos=SDS((B,), jnp.int32))
        ax = DecodeState(caches=cache_axes(), pos=("batch",))
        return st, ax, tok
    if cfg.family == SSM:
        W, ch = cfg.ssm_conv_width, cfg.d_inner + 2 * cfg.ssm_state
        st = SSMState(
            conv=SDS((cfg.num_layers, B, W - 1, ch), cdt),
            h=SDS((cfg.num_layers, B, cfg.ssm_heads, cfg.ssm_head_dim,
                   cfg.ssm_state), jnp.float32),
            pos=SDS((B,), jnp.int32))
        ax = SSMState(conv=("layers", "batch", None, "ssm_inner"),
                      h=("layers", "batch", "ssm_heads", None, None),
                      pos=("batch",))
        return st, ax, tok
    if cfg.family == HYBRID:
        n_super = cfg.num_layers // cfg.hybrid_attn_every
        K = cfg.hybrid_attn_every
        rest = cfg.num_layers - n_super * K
        W, ch = cfg.ssm_conv_width, cfg.d_inner + 2 * cfg.ssm_state
        st = HybridState(
            conv_super=SDS((n_super, K, B, W - 1, ch), cdt),
            h_super=SDS((n_super, K, B, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32),
            conv_rest=SDS((rest, B, W - 1, ch), cdt),
            h_rest=SDS((rest, B, cfg.ssm_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32),
            caches=jax.tree.map(
                lambda s: SDS((n_super,) + s.shape[1:], s.dtype),
                cache_sds(n_super)),
            pos=SDS((B,), jnp.int32))
        ax = HybridState(
            conv_super=("layers", None, "batch", None, "ssm_inner"),
            h_super=("layers", None, "batch", "ssm_heads", None, None),
            conv_rest=("layers", "batch", None, "ssm_inner"),
            h_rest=("layers", "batch", "ssm_heads", None, None),
            caches=cache_axes(),
            pos=("batch",))
        return st, ax, tok
    if cfg.family == AUDIO:
        F = cfg.encoder_frames
        st = EncDecState(
            caches=cache_sds(cfg.num_layers),
            cross_k=SDS((cfg.num_layers, B, cfg.num_kv_heads, F, cfg.head_dim), cdt),
            cross_v=SDS((cfg.num_layers, B, cfg.num_kv_heads, F, cfg.head_dim), cdt),
            enc_mask=SDS((B, F), jnp.bool_),
            pos=SDS((B,), jnp.int32))
        ax = EncDecState(
            caches=cache_axes(),
            cross_k=("layers", "batch", "kv_heads", None, None),
            cross_v=("layers", "batch", "kv_heads", None, None),
            enc_mask=("batch", None),
            pos=("batch",))
        return st, ax, tok
    raise ValueError(cfg.family)
