"""Training launcher.

Two modes:
  * real run (default): drives the RL Trainer (rollout -> verify -> rescore
    -> Sparse-RL update) at a size the current host can execute.  On TPU
    pods this is the production entry point; on this CPU container the
    reduced (smoke) configs run end-to-end.
  * --dry-run: delegates to repro.launch.dryrun (lower + compile only).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --smoke \
      --steps 100 --compression rkv
  PYTHONPATH=src python -m repro.launch.train --arch llama3-405b --dry-run
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-qwen2.5-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--sampler-policy", default=None,
                    help="registry sampler policy (rollout.policies): dense, "
                         "rkv, snapkv, h2o, streaming, per_head, adaptive, "
                         "quant-int8, quant-fp8.  Resolves compression + "
                         "kv-quant in one shot; supersedes the legacy "
                         "--compression/--kv-quant pair (DESIGN.md "
                         "§Sampler policy registry)")
    ap.add_argument("--compression", default=None,
                    choices=["rkv", "snapkv", "h2o", "streaming", "per_head",
                             "adaptive", "none"],
                    help="DEPRECATED alias: use --sampler-policy.  Maps "
                         "through the registry bitwise-identically "
                         "(none -> dense)")
    ap.add_argument("--no-reject", action="store_true")
    ap.add_argument("--no-reweight", action="store_true")
    ap.add_argument("--kv-budget", type=int, default=None)
    ap.add_argument("--group-size", type=int, default=8)
    ap.add_argument("--rollout-backend", default="lockstep",
                    choices=["lockstep", "continuous"],
                    help="rollout phase driver: fixed-length lockstep scan, "
                         "or the continuous-batching engine with group "
                         "admission and EOS early-exit — see DESIGN.md "
                         "§Training on the continuous engine")
    ap.add_argument("--cache-backend", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="continuous backend only: paged = prompt pages "
                         "prefilled once per group, refcount-shared")
    ap.add_argument("--kv-quant", default=None,
                    choices=["none", "int8", "fp8"],
                    help="DEPRECATED alias: use --sampler-policy quant-int8/"
                         "quant-fp8.  Paged backend only: quantized KV pool "
                         "storage; the quantized engine is the behavior "
                         "policy (logp_sparse) and the dense rescore "
                         "supplies pi_old, so the sparse-RL correction "
                         "absorbs the mismatch (DESIGN.md "
                         "§Quantized paged pool)")
    ap.add_argument("--decode-batch", type=int, default=0,
                    help="continuous backend: engine row slots "
                         "(0 = half the phase's requests)")
    ap.add_argument("--decode-chunk", type=int, default=4,
                    help="continuous backend: decode steps per host harvest")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous backend: prompt-token budget per "
                         "admission sweep (Sarathi-style chunked prefill; "
                         "default auto)")
    ap.add_argument("--overlap-harvest", action="store_true",
                    help="continuous backend: async double-buffered harvest "
                         "(dispatch chunk t+1 before fetching chunk t)")
    ap.add_argument("--group-slack", type=int, default=0,
                    help="over-provision each group by k rollouts; keep G "
                         "(continuous: first G to finish, stragglers "
                         "cancelled mid-flight)")
    ap.add_argument("--pipeline", default="sync",
                    choices=["sync", "async"],
                    help="async = overlapped actor-learner pipeline "
                         "(requires --rollout-backend continuous): a "
                         "producer thread streams finished rollout groups "
                         "into a bounded staging queue while the learner "
                         "updates — see DESIGN.md "
                         "§Async pipeline & staleness correction")
    ap.add_argument("--max-lag", type=int, default=1,
                    help="async pipeline: max learner steps the rollout "
                         "weights may trail (0 = serialized handoff, "
                         "bit-identical to --pipeline sync)")
    ap.add_argument("--stage-groups", type=int, default=0,
                    help="async pipeline: staging-queue capacity in groups "
                         "(0 = auto: two phases' worth)")
    ap.add_argument("--watchdog-timeout", type=float, default=60.0,
                    help="async pipeline: producer heartbeat staleness "
                         "bound in seconds before a watchdog restart "
                         "(DESIGN.md §Fault tolerance & degraded modes)")
    ap.add_argument("--max-producer-restarts", type=int, default=2,
                    help="async pipeline: watchdog restart budget before "
                         "escalating")
    ap.add_argument("--storm-threshold", type=float, default=0.9,
                    help="rejection-storm degraded mode: phase veto rate "
                         "above which vetoed groups re-roll through the "
                         "dense fallback policy (1.0 disables)")
    ap.add_argument("--anomaly-max-skips", type=int, default=3,
                    help="consecutive non-finite updates tolerated "
                         "(skipped, params untouched) before raising")
    ap.add_argument("--fault-plan", default=None,
                    help="arm deterministic fault injection, e.g. "
                         "'producer_crash@phase=3 nan_grads@step=7' "
                         "(recovery drills; unarmed = bitwise no-op)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for injected-fault payloads")
    ap.add_argument("--telemetry", default="off",
                    choices=["off", "metrics", "trace"],
                    help="observability knob (DESIGN.md "
                         "§Observability & telemetry): "
                         "off = bitwise no-op; metrics = "
                         "registry only (<= 3%% phase overhead); trace = "
                         "spans + registry, exported as Chrome trace JSON "
                         "(--trace-out, viewable in Perfetto)")
    ap.add_argument("--trace-out", default=None,
                    help="Chrome trace-event JSON output path (telemetry="
                         "trace; default reports/trace_train.json)")
    ap.add_argument("--run-log", default=None,
                    help="structured JSONL run-log path (default "
                         "reports/run_log.jsonl when telemetry is on; "
                         "console rendering always stays on)")
    ap.add_argument("--jax-annotations", action="store_true",
                    help="telemetry=trace: wrap host spans in jax.profiler."
                         "TraceAnnotation so device profiles line up")
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/srl_train")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun

        sys.argv = ["dryrun", "--arch", args.arch, "--multi-pod",
                    "--also-single-pod"]
        return dryrun.main()

    from dataclasses import replace

    from repro.configs import SparseRLConfig, TrainConfig, get_config
    from repro.rollout.policies import resolve_cli_policy
    from repro.runtime import FaultPlan, Trainer, TrainerOptions

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    smoke_scale = args.smoke or cfg.n_params() < 5e7
    policy = resolve_cli_policy(args.sampler_policy, args.compression,
                                args.kv_quant, default_compression="rkv")
    scfg = policy.apply(SparseRLConfig(
        reject=not args.no_reject,
        reweight=not args.no_reweight,
        group_size=args.group_size,
    ))
    if smoke_scale:
        scfg = replace(scfg, kv_budget=args.kv_budget or 24, kv_buffer=8,
                       obs_window=4, num_sinks=2, max_new_tokens=20,
                       learning_rate=args.lr or 3e-4)
    elif args.kv_budget:
        scfg = replace(scfg, kv_budget=args.kv_budget)
    if args.lr:
        scfg = replace(scfg, learning_rate=args.lr)
    tcfg = TrainConfig(total_steps=args.steps, seed=args.seed,
                       checkpoint_dir=args.ckpt_dir,
                       update_batch=64 if smoke_scale else 256,
                       warmup_steps=max(args.steps // 20, 2),
                       checkpoint_every=max(args.steps // 4, 10))
    opts = TrainerOptions(num_prompts=16 if smoke_scale else 128,
                          prompt_len=24, max_new_tokens=scfg.max_new_tokens,
                          rollout_backend=args.rollout_backend,
                          cache_backend=args.cache_backend,
                          kv_quant=policy.kv_quant,
                          decode_batch=args.decode_batch,
                          decode_chunk=args.decode_chunk,
                          prefill_chunk=args.prefill_chunk,
                          overlap_harvest=args.overlap_harvest,
                          group_slack=args.group_slack,
                          pipeline=args.pipeline, max_lag=args.max_lag,
                          stage_groups=args.stage_groups,
                          watchdog_timeout=args.watchdog_timeout,
                          max_producer_restarts=args.max_producer_restarts,
                          storm_threshold=args.storm_threshold,
                          anomaly_max_skips=args.anomaly_max_skips,
                          faults=(FaultPlan.parse(args.fault_plan,
                                                  seed=args.fault_seed)
                                  if args.fault_plan else None),
                          telemetry=args.telemetry,
                          run_log=(args.run_log
                                   or ("reports/run_log.jsonl"
                                       if args.telemetry != "off" else None)),
                          jax_annotations=args.jax_annotations)
    tr = Trainer(cfg, scfg, tcfg, opts)
    hist = tr.train(args.steps - tr.step, log_every=10)
    tr.save_checkpoint()
    if hist:
        last = {k: v for k, v in sorted(hist[-1].items())
                if isinstance(v, float)}
        tr.tel.log.event(
            "train_summary", step=tr.step, steps_run=len(hist),
            msg=(f"done: {len(hist)} step(s), "
                 f"reward={last.get('reward', float('nan')):.4f} "
                 f"loss={last.get('loss', float('nan')):.4f}"),
            **last)
    if args.telemetry == "trace":
        out = args.trace_out or "reports/trace_train.json"
        tr.tel.export_trace(out)
        print(f"[telemetry] chrome trace -> {out} "
              f"(tools/trace_report.py or ui.perfetto.dev)")
    tr.tel.close()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(hist, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
