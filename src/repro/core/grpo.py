"""GRPO (Shao et al., 2024) — group-relative advantages + clipped surrogate.

Paper Appendix D.  The critic-free advantage (Eq. 10) normalizes each
response's reward within its G-sample group; the surrogate (Eq. 11) is the
PPO clipped objective with importance weight w = pi_theta / pi_old.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def group_advantages(rewards: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """rewards: (num_prompts, G) -> advantages (num_prompts, G), Eq. 10.

    Groups with zero reward variance (all-correct / all-wrong) get zero
    advantage — no learning signal, standard GRPO behaviour.
    """
    mean = jnp.mean(rewards, axis=-1, keepdims=True)
    std = jnp.std(rewards, axis=-1, keepdims=True)
    return (rewards - mean) / (std + eps)


def ppo_clip_term(w: jnp.ndarray, adv: jnp.ndarray, clip_eps: float
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """min(w*A, clip(w)*A) and an is-clipped indicator (for the clip-ratio
    telemetry, paper App. C)."""
    clipped_w = jnp.clip(w, 1.0 - clip_eps, 1.0 + clip_eps)
    obj = jnp.minimum(w * adv, clipped_w * adv)
    is_clipped = (w * adv) > (clipped_w * adv)
    return obj, is_clipped


def k3_kl(logp_ref: jnp.ndarray, logp_theta: jnp.ndarray) -> jnp.ndarray:
    """Schulman's k3 estimator of KL(pi_theta || pi_ref), per token.
    Non-negative, low-variance; the GRPO KL regularizer."""
    log_ratio = logp_ref - logp_theta
    return jnp.exp(log_ratio) - log_ratio - 1.0


def masked_mean(x: jnp.ndarray, mask: jnp.ndarray,
                axis=None, eps: float = 1e-9) -> jnp.ndarray:
    m = mask.astype(x.dtype)
    return jnp.sum(x * m, axis=axis) / (jnp.sum(m, axis=axis) + eps)


def grpo_loss(logp_theta: jnp.ndarray, logp_old: jnp.ndarray,
              advantages: jnp.ndarray, token_mask: jnp.ndarray,
              *, clip_eps: float = 0.2,
              logp_ref: Optional[jnp.ndarray] = None,
              kl_coef: float = 0.0) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Plain GRPO objective (dense rollouts), Eq. 11.

    logp_*: (B, T) per-response-token log-probs; advantages: (B,);
    token_mask: (B, T) True for real response tokens.
    """
    w = jnp.exp(jnp.clip(logp_theta - jax.lax.stop_gradient(logp_old),
                         -20.0, 20.0))
    obj, clipped = ppo_clip_term(w, advantages[:, None], clip_eps)
    per_seq = masked_mean(obj, token_mask, axis=-1)             # 1/|o_i| sum_t
    loss = -jnp.mean(per_seq)
    metrics = {
        "clip_ratio": masked_mean(clipped.astype(jnp.float32), token_mask),
        "mean_ratio": masked_mean(w, token_mask),
    }
    if logp_ref is not None and kl_coef > 0:
        kl = masked_mean(k3_kl(jax.lax.stop_gradient(logp_ref), logp_theta),
                         token_mask)
        loss = loss + kl_coef * kl
        metrics["ref_kl"] = kl
    return loss, metrics
