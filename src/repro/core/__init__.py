from repro.core.grpo import (
    group_advantages,
    grpo_loss,
    k3_kl,
    masked_mean,
    ppo_clip_term,
)
from repro.core.sparse_rl import (
    SparseRLOut,
    mismatch_metrics,
    rejection_mask,
    resolved_policy,
    sparse_rl_loss,
    sparsity_consistency_ratio,
)

__all__ = [
    "group_advantages",
    "grpo_loss",
    "k3_kl",
    "masked_mean",
    "ppo_clip_term",
    "sparse_rl_loss",
    "sparsity_consistency_ratio",
    "rejection_mask",
    "resolved_policy",
    "mismatch_metrics",
    "SparseRLOut",
]
