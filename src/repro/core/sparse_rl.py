"""Sparse-RL (the paper's contribution): stable RL training under sparse
(KV-compressed) rollouts.

Three policies coexist (paper §3):
  pi_old    — dense old policy: full-context log-probs of the sampler weights
  pi_sparse — sparse sampler: what the budget-cache rollout actually sampled
  pi_theta  — the learner being optimized

Corrections (paper §4):
  * sparsity consistency ratio   xi_t = pi_old / pi_sparse           (Eq. 5)
  * Sparsity-Aware Rejection     M_RS(o) = 0 iff any xi_t < eps      (Eq. 6)
  * Importance-based Reweighting xi_t OUTSIDE the PPO clip           (Eq. 7)

All ratio math is done in log space; xi is capped (``xi_clip_max``) for
variance control — a numerical-safety deviation from the paper documented in
DESIGN.md (the paper's Eq. 7 uses raw xi; with eps-rejection active the cap
binds only in the far tail).

Async actor-learner extension (beyond-paper; DESIGN.md §Async pipeline &
staleness correction): when rollouts are produced by a pipelined actor whose
weights lag the learner, a FOURTH policy appears —

  pi_behave — dense policy of the *sampler-version* weights (the snapshot
              the token was actually drawn under)

and pi_old splits into (pi_behave, pi_prox): ``logp_old`` keeps its role as
the PPO proximal anchor (dense log-probs under the learner weights at
update time — identical to today's sync trainer), while ``logp_behave``
carries the dense sampler-version log-probs.  The staleness mismatch is
absorbed exactly like the sparsity mismatch: a per-token importance ratio
rho_t = pi_prox / pi_behave, capped at ``staleness_clip``, composed
multiplicatively with xi outside the PPO clip.  At lag 0 the two policies
coincide bitwise, log rho_t = 0 exactly, rho_t = exp(0) = 1.0, and the loss
degenerates to the sync objective (multiplication by the float 1.0 is exact
in IEEE arithmetic) — the equivalence the async e2e test pins.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SparseRLConfig
from repro.core.grpo import k3_kl, masked_mean, ppo_clip_term


def sparsity_consistency_ratio(logp_old: jnp.ndarray, logp_sparse: jnp.ndarray,
                               xi_clip_max: float = 10.0) -> jnp.ndarray:
    """xi_t = pi_old / pi_sparse, Eq. 5.  (B, T) float32."""
    log_xi = logp_old.astype(jnp.float32) - logp_sparse.astype(jnp.float32)
    return jnp.exp(jnp.minimum(log_xi, jnp.log(xi_clip_max)))


def rejection_mask(logp_old: jnp.ndarray, logp_sparse: jnp.ndarray,
                   token_mask: jnp.ndarray, eps: float) -> jnp.ndarray:
    """M_RS per sequence, Eq. 6: veto iff ANY valid token has xi < eps.

    Computed in log space: xi < eps  <=>  logp_old - logp_sparse < log(eps).
    Returns (B,) float32 in {0, 1}.
    """
    log_xi = logp_old.astype(jnp.float32) - logp_sparse.astype(jnp.float32)
    anomalous = (log_xi < jnp.log(eps)) & token_mask
    return 1.0 - jnp.any(anomalous, axis=-1).astype(jnp.float32)


def resolved_policy(scfg: SparseRLConfig, kv_quant: str = "none"):
    """The registry entry behind a resolved config (rollout.policies).

    The loss itself is policy-agnostic — pi_sparse is whatever the sampler
    recorded — but callers (trainer telemetry, the matrix harness) need the
    policy's *identity class*: ``resolved_policy(...).is_dense`` says whether
    logp_sparse is structurally equal to logp_old, i.e. xi == 1, the
    rejection mask never fires and mismatch_kl is numerical noise.  Lazy
    import keeps the core loss layer free of a rollout dependency at import
    time.
    """
    from repro.rollout.policies import policy_for_scfg

    return policy_for_scfg(scfg, kv_quant)


class SparseRLOut(NamedTuple):
    loss: jnp.ndarray
    metrics: Dict[str, jnp.ndarray]


def sparse_rl_loss(logp_theta: jnp.ndarray,
                   logp_old: jnp.ndarray,
                   logp_sparse: jnp.ndarray,
                   advantages: jnp.ndarray,
                   token_mask: jnp.ndarray,
                   scfg: SparseRLConfig,
                   *,
                   logp_ref: Optional[jnp.ndarray] = None,
                   logp_behave: Optional[jnp.ndarray] = None) -> SparseRLOut:
    """The Sparse-RL objective, Eq. 7 (negated for minimization).

      J = E[ 1/G sum_i M_RS(o_i) 1/|o_i| sum_t rho_{i,t} xi_{i,t}
             * min(w_{i,t} A_i, clip(w_{i,t}, 1±eps) A_i) ]  - kl_coef * KL

    logp_theta   (B, T): learner log-probs (differentiated)
    logp_old     (B, T): dense proximal log-probs (stop-grad): the learner
                         weights at update time — in the sync trainer this
                         IS the dense old policy of the sampler
    logp_sparse  (B, T): sparse sampler log-probs recorded at rollout time
    advantages   (B,)  : group-normalized rewards
    token_mask   (B, T): True for response tokens up to (incl.) EOS
    logp_behave  (B, T): optional — dense log-probs under each token's
                         *sampler-version* weights (async pipeline).  When
                         given, xi and the rejection mask pair it with
                         logp_sparse (the exact dense-vs-sparse contrast of
                         the weights that sampled the token), and the
                         staleness ratio rho_t = min(pi_old/pi_behave,
                         staleness_clip) composes with xi outside the clip.
                         None (sync) == rho_t = 1 identically.

    Ablations: scfg.reject / scfg.reweight toggle the two corrections
    (both False == the paper's "naive sparse" baseline);
    scfg.sequence_level enables the GSPO-style beyond-paper variant.
    """
    logp_old = jax.lax.stop_gradient(logp_old)
    logp_sparse = jax.lax.stop_gradient(logp_sparse)
    if logp_behave is None:
        lb = logp_old
        rho = None
    else:
        lb = jax.lax.stop_gradient(logp_behave)
        # staleness importance ratio, capped like xi (variance control);
        # at lag 0 logp_old == lb bitwise -> rho == exp(0) == 1.0 exactly
        log_rho = (logp_old.astype(jnp.float32) - lb.astype(jnp.float32))
        rho = jnp.exp(jnp.minimum(log_rho, jnp.log(scfg.staleness_clip)))

    xi = sparsity_consistency_ratio(lb, logp_sparse, scfg.xi_clip_max)
    m_rs = rejection_mask(lb, logp_sparse, token_mask, scfg.rejection_eps)

    if not scfg.reject:
        m_rs = jnp.ones_like(m_rs)
    xi_w = xi if scfg.reweight else jnp.ones_like(xi)

    if scfg.sequence_level:
        # GSPO-style: length-normalized sequence ratio inside the clip.
        log_w_seq = masked_mean(logp_theta - logp_old, token_mask, axis=-1)
        w = jnp.exp(jnp.clip(log_w_seq, -20.0, 20.0))[:, None]
        xi_seq = jnp.exp(jnp.minimum(
            masked_mean(jnp.log(xi + 1e-30), token_mask, axis=-1),
            jnp.log(scfg.xi_clip_max)))[:, None]
        xi_w = jnp.broadcast_to(xi_seq, xi.shape) if scfg.reweight else jnp.ones_like(xi)
    else:
        # clamp the log-ratio: an unbounded w=exp(500) meeting a xi=0 token
        # yields 0 * inf = NaN; +/-20 is far outside the clip range anyway
        w = jnp.exp(jnp.clip(logp_theta - logp_old, -20.0, 20.0))

    obj, clipped = ppo_clip_term(w, advantages[:, None], scfg.clip_eps)
    per_tok = xi_w * obj if rho is None else rho * xi_w * obj
    per_seq = masked_mean(per_tok, token_mask, axis=-1)          # 1/|o_i|
    loss = -jnp.mean(m_rs * per_seq)

    # mismatch KL (paper Fig. 3): KL(pi_sparse || pi_dense) estimated on the
    # sampled tokens: E_sparse[log pi_sparse - log pi_dense], paired with
    # the dense policy of the weights that actually sampled (lb)
    mismatch_kl = masked_mean(logp_sparse - lb, token_mask)
    metrics = {
        "rejection_rate": 1.0 - jnp.mean(m_rs),
        "clip_ratio": masked_mean(clipped.astype(jnp.float32), token_mask),
        "mean_xi": masked_mean(xi, token_mask),
        # masked positions fill with +inf, not 0: a 0 fill clamps the metric
        # at 0 whenever every valid log-ratio is positive
        "min_log_xi": jnp.min(jnp.where(token_mask, lb - logp_sparse,
                                        jnp.inf)),
        "mismatch_kl": mismatch_kl,
        "mean_ratio": masked_mean(w * jnp.ones_like(xi), token_mask),
        "accepted_frac_tokens": masked_mean(
            jnp.broadcast_to(m_rs[:, None], token_mask.shape), token_mask),
    }
    if rho is not None:
        # staleness telemetry: how far the learner drifted from each
        # token's sampler snapshot (KL estimate on sampled tokens) and the
        # mean applied correction
        metrics["mean_rho"] = masked_mean(rho, token_mask)
        metrics["staleness_kl"] = masked_mean(lb - logp_old, token_mask)
    if logp_ref is not None and scfg.kl_coef > 0:
        kl = masked_mean(k3_kl(jax.lax.stop_gradient(logp_ref), logp_theta),
                         token_mask)
        loss = loss + scfg.kl_coef * kl
        metrics["ref_kl"] = kl
    return SparseRLOut(loss=loss, metrics=metrics)


def mismatch_metrics(logp_old, logp_sparse, token_mask,
                     row_mask=None, xi_clip_max: float = 10.0
                     ) -> Dict[str, float]:
    """Host-side dense-vs-sparse mismatch telemetry over selected rows.

    The jitted loss aggregates ``min_log_xi``/``mismatch_kl``/``mean_xi``
    over its whole minibatch — correct when every row came from the sparse
    sampler, but poisoned under the rejection-storm degraded mode
    (DESIGN.md §Fault tolerance & degraded modes): rerolled dense-fallback
    rows carry ``logp_sparse == logp_old`` bitwise (xi == 1 exactly, the
    identity-class contract), so mixing them in dilutes the mismatch the
    metrics exist to watch.  This helper recomputes the three metrics over
    ``row_mask`` (the genuinely-sparse rows) only; with no sparse row left
    it returns ``min_log_xi = +inf`` / ``mismatch_kl = 0`` — "no sparse
    evidence this phase", not "zero mismatch".
    """
    lo = np.asarray(jax.device_get(logp_old), np.float32)
    ls = np.asarray(jax.device_get(logp_sparse), np.float32)
    mask = np.asarray(jax.device_get(token_mask), bool)
    if row_mask is not None:
        rows = np.asarray(row_mask, bool)
        lo, ls, mask = lo[rows], ls[rows], mask[rows]
    if not mask.any():
        return {"min_log_xi": float("inf"), "mismatch_kl": 0.0,
                "mean_xi": 1.0}
    log_xi = (lo - ls)[mask]
    return {"min_log_xi": float(log_xi.min()),
            "mismatch_kl": float((ls - lo)[mask].mean()),
            "mean_xi": float(np.exp(
                np.minimum(log_xi, np.log(xi_clip_max))).mean())}
