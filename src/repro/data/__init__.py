from repro.data.synthetic_math import PromptLoader, encode_prompts, make_problems
from repro.data.tokenizer import TOKENIZER, CharTokenizer

__all__ = ["TOKENIZER", "CharTokenizer", "PromptLoader", "encode_prompts",
           "make_problems"]
