"""Character-level tokenizer for the synthetic math task.

The real paper trains on GSM8K/MATH text with the backbone's BPE tokenizer;
offline we embed a small char vocabulary into the first ``len(VOCAB)`` ids of
whatever vocab_size the architecture declares (the remaining ids are simply
never produced — harmless for RL mechanics, and keeps every assigned arch
config's true vocab_size intact for the dry-run/roofline).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

PAD, BOS, EOS = 0, 1, 2
_SPECIALS = ["<pad>", "<bos>", "<eos>"]
_CHARS = list("0123456789+-*/=?().,: QA#")
VOCAB = _SPECIALS + _CHARS


class CharTokenizer:
    pad_id, bos_id, eos_id = PAD, BOS, EOS

    def __init__(self):
        self._c2i = {c: i + len(_SPECIALS) for i, c in enumerate(_CHARS)}
        self._i2c = {i + len(_SPECIALS): c for i, c in enumerate(_CHARS)}

    @property
    def vocab_size(self) -> int:
        return len(VOCAB)

    def encode(self, s: str, *, bos: bool = False, eos: bool = False) -> List[int]:
        ids = [self._c2i[c] for c in s if c in self._c2i]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        out = []
        for i in ids:
            i = int(i)
            if i == EOS:
                break
            if i in self._i2c:
                out.append(self._i2c[i])
        return "".join(out)

    def pad_batch(self, seqs: List[List[int]], length: int,
                  left: bool = True) -> np.ndarray:
        """Left-pad (default) to fixed length; returns (B, length) int32."""
        out = np.full((len(seqs), length), PAD, np.int32)
        for r, s in enumerate(seqs):
            s = s[-length:] if left else s[:length]
            if left:
                out[r, length - len(s):] = s
            else:
                out[r, :len(s)] = s
        return out


TOKENIZER = CharTokenizer()
