"""Synthetic verifiable math-reasoning task ("GSM-lite").

Stands in for SimpleRL-Zoo (GSM8K + MATH): multi-step integer arithmetic with
an exactly-verifiable answer and the paper's strict binary reward.  Three
difficulty tiers mirror the paper's Easy/Medium/Hard splits:

  easy   : a ⊕ b, single-digit operands, answer in [0, 18]
  medium : a ⊕ b ⊕ c with +/-
  hard   : (a ⊕ b) ⊕ c including *, multi-digit intermediates

Prompts look like ``Q:(3+5)*2=?A:`` and a correct completion is the decimal
answer followed by EOS.  Deterministic per (seed, index) — reproducible
epochs across restarts and elastic re-sharding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.data.tokenizer import TOKENIZER, CharTokenizer


@dataclass(frozen=True)
class Problem:
    prompt: str
    answer: str


def _gen_one(rng: np.random.Generator, level: str) -> Problem:
    if level == "trivial":
        # single-digit sum <= 9: one-token answer (smoke-model curriculum)
        a = int(rng.integers(0, 10))
        b = int(rng.integers(0, 10 - a))
        return Problem(prompt=f"Q:{a}+{b}=?A:", answer=str(a + b))
    if level == "easy":
        a, b = rng.integers(0, 10, 2)
        op = rng.choice(["+", "-"])
        expr = f"{a}{op}{b}"
    elif level == "medium":
        a, b, c = rng.integers(0, 10, 3)
        o1, o2 = rng.choice(["+", "-"], 2)
        expr = f"{a}{o1}{b}{o2}{c}"
    else:  # hard
        a, b, c = rng.integers(1, 10, 3)
        o1 = rng.choice(["+", "-", "*"])
        o2 = rng.choice(["+", "-", "*"])
        expr = f"({a}{o1}{b}){o2}{c}"
    ans = eval(expr)  # noqa: S307 — generator-controlled arithmetic only
    return Problem(prompt=f"Q:{expr}=?A:", answer=str(ans))


def make_problems(n: int, seed: int, level: str = "easy") -> List[Problem]:
    rng = np.random.default_rng(seed)
    return [_gen_one(rng, level) for _ in range(n)]


def encode_prompts(problems: List[Problem], prompt_len: int,
                   tok: CharTokenizer = TOKENIZER
                   ) -> Tuple[np.ndarray, np.ndarray, List[str]]:
    """Left-padded prompt ids + mask + the gold answers (host strings)."""
    seqs = [tok.encode(p.prompt, bos=True) for p in problems]
    ids = tok.pad_batch(seqs, prompt_len, left=True)
    mask = ids != tok.pad_id
    # BOS occupies a real slot; count it valid
    return ids, mask, [p.answer for p in problems]


class PromptLoader:
    """Deterministic, host-shardable prompt stream.

    Every (epoch, step) batch is a pure function of (seed, level, sizes) so a
    restarted or re-sharded job regenerates identical data — checkpoint
    carries only the step counter.
    """

    def __init__(self, *, batch_prompts: int, prompt_len: int, seed: int = 0,
                 level: str = "easy", num_problems: int = 8000,
                 host_index: int = 0, host_count: int = 1):
        self.batch = batch_prompts
        self.prompt_len = prompt_len
        self.seed = seed
        self.level = level
        self.problems = make_problems(num_problems, seed, level)
        self.host_index, self.host_count = host_index, host_count

    def get(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, len(self.problems), self.batch * self.host_count)
        idx = idx[self.host_index::self.host_count][:self.batch]
        probs = [self.problems[i] for i in idx]
        ids, mask, answers = encode_prompts(probs, self.prompt_len)
        return ids, mask, answers
