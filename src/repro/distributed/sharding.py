"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Models annotate activations with *logical* axis names via :func:`lsc`
(logical sharding constraint) and parameters carry logical axis tuples
produced next to ``init_params``.  At launch time a :class:`ShardingRules`
context binds logical names to physical mesh axes; outside any context (unit
tests, CPU smoke runs) every annotation is a no-op.

The binding is divisibility-aware: if a tensor dim is not divisible by the
product of its mapped mesh axes, the mapping for that dim silently falls back
to replication (e.g. 40 attention heads on a 16-way ``model`` axis).  This is
what lets one rule set serve all ten architectures.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

# Default logical->physical rules for the production (data, model) /
# (pod, data, model) meshes.  ``batch`` spans every data-parallel axis.
DEFAULT_RULES: Dict[str, AxisVal] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,              # replicated by default
    "seq_shard": ("data",),   # context parallelism for long prefill
    "embed": None,            # residual stream replicated across model axis
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "moe_ffn": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "cache_slots": None,
    # parameters: 2-D sharded — TP dim via the axes above, FSDP dim below
    "fsdp": ("data",),        # parameter dim sharded over the data axis (ZeRO-3)
    "fsdp_pod": ("pod", "data"),  # optional: FSDP across pods too
}


class ShardingRules(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, AxisVal] = dict(DEFAULT_RULES)
        self.prules: Optional[Dict[str, AxisVal]] = None  # param placement
        self.enabled = False


_CTX = ShardingRules()


class use_mesh_rules:
    """Context manager binding a mesh + logical rules for model tracing.

    ``prules`` (parameter-placement rules) let model bodies constrain their
    per-iteration layer-param slices (see `layer_param_lsc`) — without this,
    GSPMD hoists FSDP all-gathers outside scan-over-layers and gathers the
    whole stacked parameter bank at once.
    """

    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, AxisVal]] = None,
                 prules: Optional[Dict[str, AxisVal]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        self.prules = prules
        self._saved = None

    def __enter__(self):
        self._saved = (_CTX.mesh, _CTX.rules, _CTX.prules, _CTX.enabled)
        _CTX.mesh, _CTX.rules, _CTX.prules, _CTX.enabled = (
            self.mesh, self.rules, self.prules, True)
        return self

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules, _CTX.prules, _CTX.enabled = self._saved
        return False


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh if _CTX.enabled else None


def _axis_size(mesh: Mesh, axes: AxisVal) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return n


def _resolve(mesh: Mesh, rules: Dict[str, AxisVal], shape: Sequence[int],
             logical: Sequence[Optional[str]], *, hint: bool = False) -> P:
    """Map logical dim names to a PartitionSpec, dropping non-divisible or
    unknown axes and axes absent from the mesh.

    hint=False (input/parameter placements): unresolved dims are REPLICATED.
    hint=True  (with_sharding_constraint on activations): unresolved dims are
    UNCONSTRAINED — a None there would mean "force-replicate", which makes
    GSPMD all-gather e.g. the batch dim of every annotated activation (a
    ~100x collective-volume bug caught by the HLO inventory, see
    EXPERIMENTS.md §Perf iteration A2)."""
    unre = P.UNCONSTRAINED if hint else None
    spec = []
    used: set = set()
    for dim, name in zip(shape, logical):
        axes = rules.get(name) if name else None
        if axes is None:
            spec.append(unre)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
        if not axes:
            spec.append(unre)
            continue
        size = _axis_size(mesh, axes)
        if size <= 1 or dim % size != 0:
            # divisibility fallback: try a prefix of the axis tuple
            ok = None
            for k in range(len(axes) - 1, 0, -1):
                sub = axes[:k]
                if dim % _axis_size(mesh, sub) == 0 and _axis_size(mesh, sub) > 1:
                    ok = sub
                    break
            if ok is None:
                spec.append(unre)
                continue
            axes = ok
        used.update(axes)
        spec.append(axes if len(axes) > 1 else axes[0])
    if not hint:
        while spec and spec[-1] is None:
            spec.pop()
    return P(*spec)


def logical_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None,
                 rules: Optional[Dict[str, AxisVal]] = None) -> P:
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    assert mesh is not None
    return _resolve(mesh, rules, shape, logical)


def lsc(x, *logical: Optional[str]):
    """Logical sharding constraint.  No-op outside a `use_mesh_rules` context.
    Unnamed / unresolved dims are left UNCONSTRAINED (propagation decides)."""
    if not _CTX.enabled or _CTX.mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"lsc: {len(logical)} names for rank-{x.ndim} tensor")
    spec = _resolve(_CTX.mesh, _CTX.rules, x.shape, logical, hint=True)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


# Parameter-placement rules: identical to activation rules EXCEPT the
# residual/feature dim ("embed") is FSDP-sharded across every data-parallel
# axis (ZeRO-3).  Activations keep "embed" replicated across `model`, so one
# logical vocabulary serves both trees; `used`-axis tracking stops a dim from
# double-sharding when the same names appear in one shape.
PARAM_EXTRA_RULES: Dict[str, AxisVal] = {"embed": ("pod", "data")}


def param_rules(rules: Optional[Dict[str, AxisVal]] = None) -> Dict[str, AxisVal]:
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    merged.update(PARAM_EXTRA_RULES)
    return merged


def tree_lsc(tree, axes_tree, rules: Optional[Dict[str, AxisVal]] = None):
    """Apply sharding constraints across a pytree using a parallel tree of
    logical-axis tuples (e.g. constrain gradient accumulators to the
    parameter layout).  ``rules`` overrides the context rules (pass
    ``param_rules()`` for parameter-like trees)."""
    if not _CTX.enabled or _CTX.mesh is None:
        return tree
    mesh = _CTX.mesh
    use = rules or _CTX.rules

    def one(x, a):
        spec = _resolve(mesh, use, x.shape, a, hint=True)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree.map(one, tree, axes_tree)


def layer_param_lsc(lp, layer_axes):
    """Constrain a scanned layer's param slice to its sharded placement
    (parameter rules if bound, else context rules).  Keeps the FSDP
    all-gather INSIDE the scan body — per-layer, not whole-stack."""
    if not _CTX.enabled or _CTX.mesh is None:
        return lp
    rules = _CTX.prules or _CTX.rules
    return tree_lsc(lp, layer_axes, rules)


def named_sharding(mesh: Mesh, shape: Sequence[int],
                   logical: Sequence[Optional[str]],
                   rules: Optional[Dict[str, AxisVal]] = None) -> NamedSharding:
    return NamedSharding(mesh, _resolve(mesh, rules or dict(DEFAULT_RULES), shape, logical))


def tree_shardings(mesh: Mesh, shapes_tree, axes_tree,
                   rules: Optional[Dict[str, AxisVal]] = None):
    """Build a NamedSharding pytree from a ShapeDtypeStruct tree + a logical
    axes tree (same structure, leaves = tuple of names)."""

    def one(sds, names):
        return named_sharding(mesh, sds.shape, names, rules)

    return jax.tree.map(one, shapes_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
