from repro.distributed.sharding import (
    DEFAULT_RULES,
    logical_spec,
    lsc,
    named_sharding,
    tree_shardings,
    use_mesh_rules,
)

__all__ = ["lsc", "use_mesh_rules", "named_sharding", "tree_shardings",
           "logical_spec", "DEFAULT_RULES"]
