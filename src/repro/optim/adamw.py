"""AdamW with configurable accumulator dtype + global-norm clipping +
warmup-cosine schedule.  Pure-pytree (no optax offline); optimizer state
mirrors the parameter tree so the same sharding rules apply (FSDP-sharded
moments; bf16 accumulators for the 405B-class configs).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray   # () int32
    mu: dict            # first moment, accum dtype
    nu: dict            # second moment, accum dtype


def init(params, accum_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, accum_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[dict, jnp.ndarray]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def warmup_cosine(step, *, base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, base_lr * cos)


def update(params, grads, state: AdamWState, *, lr,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.0,
           grad_clip: Optional[float] = None):
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if grad_clip is not None and grad_clip > 0:
        grads, gn = clip_by_global_norm(grads, grad_clip)
        metrics["grad_norm"] = gn
    else:
        metrics["grad_norm"] = global_norm(grads)
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay > 0:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics
