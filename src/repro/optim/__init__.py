from repro.optim.adamw import (
    AdamWState,
    clip_by_global_norm,
    global_norm,
    init,
    update,
    warmup_cosine,
)

__all__ = ["AdamWState", "init", "update", "warmup_cosine", "global_norm",
           "clip_by_global_norm"]
