"""Configuration system for the Sparse-RL framework.

One frozen dataclass (:class:`ModelConfig`) describes every supported model
family (dense / moe / ssm / hybrid / vlm / audio).  Architecture files under
``repro/configs/`` instantiate the exact published configs; every config also
knows how to produce a *reduced* variant for CPU smoke tests via
:meth:`ModelConfig.smoke`.

Shapes (the assigned input-shape set) are described by :class:`ShapeSpec` and
bound per-architecture by the registry.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"  # encoder-decoder with conv/frame frontend stub

FAMILIES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    Only the fields relevant to ``family`` are consumed by the model builder;
    the rest keep their defaults.
    """

    name: str
    family: str

    # Transformer core ------------------------------------------------------
    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    num_kv_heads: int = 12            # GQA: kv heads <= q heads
    d_ff: int = 3072
    vocab_size: int = 32000
    head_dim: Optional[int] = None    # default: d_model // num_heads
    qkv_bias: bool = False            # qwen1.5/2.5 style
    mlp_style: str = "swiglu"         # swiglu (3 mats) | gelu (2 mats, whisper)
    rope_theta: float = 1e6
    rms_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE --------------------------------------------------------------------
    num_experts: int = 0              # 0 => dense FFN
    experts_per_token: int = 0        # top-k
    moe_d_ff: Optional[int] = None    # per-expert hidden (defaults to d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01     # load-balance auxiliary loss

    # SSM (Mamba2 / SSD) ------------------------------------------------------
    ssm_state: int = 0                # state dim N (0 => no ssm blocks)
    ssm_head_dim: int = 64            # P (headdim)
    ssm_expand: int = 2               # d_inner = expand * d_model
    ssm_chunk: int = 64               # SSD chunk length
    ssm_conv_width: int = 4

    # Hybrid (zamba2-style): every `hybrid_attn_every` blocks insert a shared
    # attention block (weights shared across occurrences).
    hybrid_attn_every: int = 6

    # Enc-dec (whisper-style) -------------------------------------------------
    encoder_layers: int = 0           # 0 => decoder-only
    encoder_frames: int = 1500        # max encoder positions (frame embeddings)

    # VLM ---------------------------------------------------------------------
    num_patches: int = 0              # prefix patch embeddings (stub frontend)

    # Numerics ----------------------------------------------------------------
    param_dtype: str = "float32"      # storage dtype of parameters
    compute_dtype: str = "bfloat16"   # activations / matmuls
    accum_dtype: str = "float32"      # optimizer accumulators
    weight_quant: str = "none"        # none | int8 — dense-matmul weights
                                      # stored int8 + per-channel f32 scale
                                      # (serving path; halves the HBM read)
    logits_softcap: float = 0.0

    # Distribution defaults ----------------------------------------------------
    remat: str = "block"              # none | block (remat each layer)
    remat_chunk: int = 0              # >1: 2-level remat — save only every
                                      # k-th layer boundary (memory ~ L/k + k
                                      # slabs instead of L, one extra fwd)
    scan_layers: bool = True          # lax.scan over stacked layer params

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.family == MOE and self.moe_d_ff is None:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived -------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head), used for
        MODEL_FLOPS = 6*N*D roofline checks."""
        c = self
        emb = c.vocab_size * c.d_model
        head = 0 if c.tie_embeddings else c.vocab_size * c.d_model
        per_attn = (
            c.d_model * c.num_heads * c.head_dim          # Wq
            + 2 * c.d_model * c.num_kv_heads * c.head_dim  # Wk, Wv
            + c.num_heads * c.head_dim * c.d_model         # Wo
        )
        if c.qkv_bias:
            per_attn += (c.num_heads + 2 * c.num_kv_heads) * c.head_dim
        ffn_mats = 3 if c.mlp_style == "swiglu" else 2
        per_dense_ffn = ffn_mats * c.d_model * c.d_ff     # gate/up/down (SwiGLU) or up/down (GELU)
        per_moe_ffn = c.num_experts * 3 * c.d_model * (c.moe_d_ff or c.d_ff) + c.d_model * c.num_experts
        norms = 2 * c.d_model

        if c.family in (DENSE, VLM):
            blocks = c.num_layers * (per_attn + per_dense_ffn + norms)
        elif c.family == MOE:
            blocks = c.num_layers * (per_attn + per_moe_ffn + norms)
        elif c.family == SSM:
            per_ssm = (
                c.d_model * 2 * c.d_inner                  # in_proj (x, z)
                + c.d_inner * c.d_model                    # out_proj
                + c.d_inner * 2 * c.ssm_state              # B, C proj
                + c.d_inner                                # dt
                + c.ssm_heads                              # A_log
                + c.ssm_conv_width * (c.d_inner + 2 * c.ssm_state)
            )
            blocks = c.num_layers * (per_ssm + c.d_model)
        elif c.family == HYBRID:
            per_ssm = (
                c.d_model * 2 * c.d_inner + c.d_inner * c.d_model
                + c.d_inner * 2 * c.ssm_state + c.d_inner + c.ssm_heads
                + c.ssm_conv_width * (c.d_inner + 2 * c.ssm_state)
            )
            n_attn = max(1, c.num_layers // c.hybrid_attn_every)
            # shared attention block counted ONCE (weights shared)
            blocks = c.num_layers * (per_ssm + c.d_model) + (per_attn + per_dense_ffn + norms)
            del n_attn
        elif c.family == AUDIO:
            dec = c.num_layers * (2 * per_attn + per_dense_ffn + 3 * c.d_model)
            enc = c.encoder_layers * (per_attn + per_dense_ffn + norms)
            blocks = dec + enc
        else:  # pragma: no cover
            raise AssertionError(c.family)
        return int(emb + head + blocks)

    def n_active_params(self) -> int:
        """Active params per token (differs from n_params for MoE)."""
        c = self
        if c.family != MOE:
            return self.n_params()
        emb = c.vocab_size * c.d_model
        head = 0 if c.tie_embeddings else c.vocab_size * c.d_model
        per_attn = (
            c.d_model * c.num_heads * c.head_dim
            + 2 * c.d_model * c.num_kv_heads * c.head_dim
            + c.num_heads * c.head_dim * c.d_model
        )
        active_ffn = c.experts_per_token * 3 * c.d_model * (c.moe_d_ff or c.d_ff)
        return int(emb + head + c.num_layers * (per_attn + active_ffn + 2 * c.d_model))

    # -- reduced variant for CPU smoke tests ---------------------------------
    def smoke(self) -> "ModelConfig":
        """A tiny same-family config: small layers/width, few experts, tiny
        vocab.  Used by per-arch smoke tests and examples."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads < self.num_heads else 4,
            d_ff=128,
            vocab_size=512,
            head_dim=16,
            param_dtype="float32",
            compute_dtype="float32",
            rope_theta=1e4,
        )
        if self.family == MOE:
            # capacity_factor = num_experts makes the smoke config dropless
            # (capacity >= T*k even under total routing imbalance), so decode
            # matches teacher-forcing exactly in tests.  Full configs keep the
            # realistic 1.25 — capacity drops there are a *real* source of
            # sampler/learner mismatch that Sparse-RL's xi correction absorbs
            # (DESIGN.md §Arch-applicability).
            kw.update(num_experts=4, experts_per_token=2, moe_d_ff=64,
                      capacity_factor=4.0)
        if self.family in (SSM, HYBRID):
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16, hybrid_attn_every=2)
        if self.family == AUDIO:
            kw.update(encoder_layers=2, encoder_frames=32)
        if self.family == VLM:
            kw.update(num_patches=8)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned set)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    # decode cells carry the *context length* in seq_len (KV cache of seq_len,
    # one new token generated).
    sparse_cache_only: bool = False  # long_500k on attention archs: dense cache infeasible


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode", sparse_cache_only=True)

LM_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


# ---------------------------------------------------------------------------
# Sparse-RL / rollout configuration (paper §5.1 + Appendix A)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SparseRLConfig:
    """Hyper-parameters of the paper's method."""

    # KV compression (Appendix A)
    kv_budget: int = 512          # B_budget
    kv_buffer: int = 128          # B_buffer (extra slots before eviction kicks in)
    obs_window: int = 8           # alpha: most recent tokens always retained
    rkv_lambda: float = 0.1       # R-KV importance/redundancy trade-off
    num_sinks: int = 4            # StreamingLLM attention sinks
    compression: str = "rkv"      # rkv | snapkv | h2o | streaming | per_head
                                  # | adaptive | none(dense) — resolve through
                                  # rollout.policies (registry owns geometry)

    # Per-head budget policy ("per_head"; RL-guided head-importance line of
    # work): the leading ceil(frac * Hkv) kv heads — the "reasoning" heads —
    # keep dense caches; the rest are hard-capped at kv_budget.
    reasoning_head_frac: float = 0.5

    # Step-scheduled adaptive budget ("adaptive"; Sparrow-style): effective
    # budget decays linearly from cache_slots to min_frac * cache_slots over
    # the first decay_tokens decode positions, then stays flat.
    adaptive_min_frac: float = 0.25
    adaptive_decay_tokens: int = 256

    # GRPO (§5.1)
    group_size: int = 8           # G rollouts per prompt
    temperature: float = 1.0
    top_p: float = 1.0
    max_new_tokens: int = 4096
    clip_eps: float = 0.2         # PPO clip epsilon
    kl_coef: float = 1e-4         # KL loss coefficient
    learning_rate: float = 1e-6

    # Sparse-RL corrections (§4)
    rejection_eps: float = 1e-4   # epsilon threshold on xi_t
    reweight: bool = True         # Importance-based Reweighting (xi factor)
    reject: bool = True           # Sparsity-Aware Rejection Sampling
    xi_clip_max: float = 10.0     # numerical safety cap on xi (beyond-paper)
    sequence_level: bool = False  # GSPO-style variant (beyond-paper)
    # Async actor-learner staleness correction (beyond-paper; DESIGN.md
    # §Async pipeline & staleness correction): cap on the per-token
    # behavior-policy ratio rho_t = pi_prox / pi_behave — the same
    # variance-control role xi_clip_max plays for the sparsity ratio.
    staleness_clip: float = 2.0

    @property
    def cache_slots(self) -> int:
        return self.kv_budget + self.kv_buffer

    def naive(self) -> "SparseRLConfig":
        """Naive sparse rollout baseline: compression, no corrections."""
        return replace(self, reweight=False, reject=False)

    def dense(self) -> "SparseRLConfig":
        return replace(self, compression="none", reweight=False, reject=False)


# ---------------------------------------------------------------------------
# Training-run configuration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TrainConfig:
    rollout_batch: int = 1024       # prompts*G per rollout phase (paper: 1024)
    update_batch: int = 256         # sequences per gradient step (paper: 256)
    microbatch: int = 0             # 0 => no grad accumulation
    total_steps: int = 400
    warmup_steps: int = 10
    grad_clip: float = 1.0
    weight_decay: float = 0.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    seed: int = 0
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/srl_ckpt"
    keep_checkpoints: int = 3


def dtype_of(name: str):
    import jax.numpy as jnp

    return {
        "float32": jnp.float32,
        "bfloat16": jnp.bfloat16,
        "float16": jnp.float16,
    }[name]
