"""Mamba2-370M — attention-free SSD. [arXiv:2405.21060; unverified]

Sparse-RL's KV compression is inapplicable (no KV cache; recurrent state is
already O(1)) — see DESIGN.md §Arch-applicability.  The arch runs the dense
GRPO path.
"""
from repro.configs.base import ModelConfig, SSM

CONFIG = ModelConfig(
    name="mamba2-370m", family=SSM,
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
    tie_embeddings=True,
)
