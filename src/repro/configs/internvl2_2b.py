"""InternVL2-2B — InternViT frontend (stub) + InternLM2 backbone. [arXiv:2404.16821; hf]

The ViT is a STUB: input_specs() provides precomputed patch embeddings that
are prepended to the token embeddings (256 patches for train_4k).
"""
from repro.configs.base import ModelConfig, VLM

CONFIG = ModelConfig(
    name="internvl2-2b", family=VLM,
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, num_patches=256,
    rope_theta=1e6,
)
