"""DBRX-132B — fine-grained MoE 16 experts top-4, GQA kv=8. [hf:databricks/dbrx-base; unverified]"""
from repro.configs.base import ModelConfig, MOE

CONFIG = ModelConfig(
    name="dbrx-132b", family=MOE,
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, moe_d_ff=10752, vocab_size=100352,
    num_experts=16, experts_per_token=4,
    rope_theta=5e5, param_dtype="bfloat16",
)
