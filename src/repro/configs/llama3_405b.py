"""Llama-3 405B — dense, GQA kv=8, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="llama3-405b", family=DENSE,
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256,
    rope_theta=5e5, param_dtype="bfloat16", accum_dtype="bfloat16",
)
