"""The paper's own backbone scale (Qwen2.5-7B-like) for faithful-repro runs.

[hf:Qwen/Qwen2.5-7B; hf] 28L d_model=3584 28H kv=4 d_ff=18944 vocab=152064.
"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="paper-qwen2.5-7b", family=DENSE,
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, qkv_bias=True,
    rope_theta=1e6, param_dtype="bfloat16",
)
