"""Architecture registry: ``--arch <id>`` resolution.

Maps each assigned architecture id to its exact published :class:`ModelConfig`
and its input-shape set (all LM archs share the 4 assigned shapes; per-family
adaptations are documented in DESIGN.md and encoded in ``input_specs``).
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.configs.base import LM_SHAPES, ModelConfig, ShapeSpec

_ARCH_MODULES = {
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "llama3-405b": "repro.configs.llama3_405b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "yi-34b": "repro.configs.yi_34b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "whisper-small": "repro.configs.whisper_small",
    # the paper's own backbone (not part of the 40-cell grid)
    "paper-qwen2.5-7b": "repro.configs.paper_qwen",
}

ARCH_IDS = tuple(k for k in _ARCH_MODULES if k != "paper-qwen2.5-7b")


def get_config(arch: str) -> ModelConfig:
    import importlib

    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_shapes(arch: str) -> Tuple[ShapeSpec, ...]:
    """All 10 assigned archs use the 4 LM shapes (40 cells)."""
    get_config(arch)  # validate id
    return LM_SHAPES


def all_cells():
    """Yield every (arch_id, ShapeSpec) baseline cell — 40 total."""
    for arch in ARCH_IDS:
        for shape in get_shapes(arch):
            yield arch, shape


def describe() -> Dict[str, dict]:
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        out[arch] = dict(
            family=cfg.family,
            params_B=round(cfg.n_params() / 1e9, 2),
            active_params_B=round(cfg.n_active_params() / 1e9, 2),
            layers=cfg.num_layers,
            d_model=cfg.d_model,
        )
    return out
