from repro.configs.base import (
    AUDIO,
    DENSE,
    HYBRID,
    LM_SHAPES,
    MOE,
    SSM,
    VLM,
    ModelConfig,
    ShapeSpec,
    SparseRLConfig,
    TrainConfig,
    dtype_of,
)
from repro.configs.registry import ARCH_IDS, all_cells, get_config, get_shapes

__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "SparseRLConfig",
    "TrainConfig",
    "LM_SHAPES",
    "ARCH_IDS",
    "get_config",
    "get_shapes",
    "all_cells",
    "dtype_of",
    "DENSE",
    "MOE",
    "SSM",
    "HYBRID",
    "VLM",
    "AUDIO",
]
