"""Qwen3-30B-A3B — MoE 128 experts top-8, GQA kv=4. [hf:Qwen/Qwen3-30B-A3B; hf]

d_ff=768 is the per-expert (moe) intermediate size; head_dim is 128
(explicit in the HF config, not d_model/num_heads).
"""
from repro.configs.base import ModelConfig, MOE

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family=MOE,
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, moe_d_ff=768, vocab_size=151936,
    num_experts=128, experts_per_token=8,
    rope_theta=1e6, param_dtype="bfloat16",
)
