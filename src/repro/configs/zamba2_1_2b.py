"""Zamba2-1.2B — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, HYBRID

CONFIG = ModelConfig(
    name="zamba2-1.2b", family=HYBRID,
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
    hybrid_attn_every=6,
)
