"""Qwen1.5-32B — dense, MHA (kv=40), QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="qwen1.5-32b", family=DENSE,
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064, qkv_bias=True,
    rope_theta=1e6, param_dtype="bfloat16",
)
