"""Qwen2.5-14B — dense, GQA kv=8, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="qwen2.5-14b", family=DENSE,
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=13824, vocab_size=152064, qkv_bias=True,
    rope_theta=1e6, param_dtype="bfloat16",
)
