"""Whisper-small — enc-dec, conv frontend STUB. [arXiv:2212.04356; unverified]

input_specs() provides precomputed frame embeddings (post-conv).  Decoder is
the LM backbone: self-attention with KV cache (Sparse-RL applies) + fixed
cross-attention to encoder states.  GELU MLP (2 matrices).
"""
from repro.configs.base import ModelConfig, AUDIO

CONFIG = ModelConfig(
    name="whisper-small", family=AUDIO,
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, mlp_style="gelu",
    encoder_layers=12, encoder_frames=1500,
)
