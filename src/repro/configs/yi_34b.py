"""Yi-34B — llama-arch dense, GQA kv=8. [arXiv:2403.04652; hf]"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="yi-34b", family=DENSE,
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    rope_theta=5e6, param_dtype="bfloat16",
)
