from repro.kvcache.attend import attend
from repro.kvcache.cache import (
    KVCache,
    append,
    compress_prefill,
    dense_prefill,
    eviction_scores,
    init_cache,
    reset_rows,
    update_scores,
    write_rows,
)

__all__ = [
    "KVCache",
    "init_cache",
    "append",
    "attend",
    "update_scores",
    "eviction_scores",
    "compress_prefill",
    "dense_prefill",
    "reset_rows",
    "write_rows",
]
