"""Decode-step attention over the slotted cache (pure-jnp reference path).

The Pallas TPU kernel (`repro.kernels.budget_attention`) implements the same
contract; `use_kernel=True` on the ops wrapper switches paths.  This function
is also the oracle the kernel is tested against.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kvcache.cache import KVCache


def attend_arrays(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Raw-array decode attention: q (B, Hq, Dh) over k/v (B, Hkv, S, Dh)
    with pos (B, Hkv, S) (-1 = empty, masked).

    Shared by both cache backends — the contiguous slotted cache attends its
    slot arrays directly, the paged backend attends its *materialized* page
    chains (`kvcache.paged.paged_attend`); running the identical math on
    bitwise-identical arrays is what makes the two backends token-identical.
    """
    B, Hq, Dh = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg, kf) * scale
    valid = (pos >= 0)[:, :, None, :]                          # (B,Hkv,1,S)
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(valid, probs, 0.0)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, vf)
    return out.reshape(B, Hq, Dh).astype(q.dtype), probs.sum(axis=2)


def attend(q: jnp.ndarray, cache: KVCache) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q: (B, Hq, Dh) roped single-token queries.

    Returns (out (B, Hq, Dh), probs_pooled (B, Hkv, S)) where probs_pooled is
    the attention mass each slot received, summed over the q-heads of its GQA
    group — the eviction-policy update signal.
    """
    return attend_arrays(q, cache.k, cache.v, cache.pos)
