"""Paged KV cache: block-table pool with refcounted prefix sharing.

The contiguous slotted cache (`kvcache/cache.py`) gives every row its own
``slots`` block of HBM, so G group rollouts of one prompt (GRPO sampling)
store the shared prompt G times and prefill it G times.  This module keeps
K/V in a global *block pool* instead — fixed-size pages indexed per row by a
block table — so rows can map the same physical prompt blocks (vLLM-style
paging, adapted to XLA's static shapes):

  k_pool, v_pool : (N, Hkv, bs, Dh)  N pages of bs tokens each (one pool per
                                     layer; callers stack a leading L dim)
  pos_pool       : (N, bs) int32     absolute position per pooled token
                                     (POS_EMPTY = padding, masks attention)
  block_tables   : (B, nb) int32     row -> page chain, -1 = unmapped
  fill           : (B,) int32        logical tokens written per row

Device-side invariants (DESIGN.md §Paged cache & prefix sharing):

* **Append-only, dense.**  The pool backend never evicts — it is the dense
  (``compression="none"``) serving path.  Logical token ``t`` of a row lives
  at page ``block_tables[b, t // bs]``, offset ``t % bs``; slot order IS
  temporal order, exactly like the contiguous dense cache.
* **Exclusive write pages.**  A row only ever appends into pages it owns
  (refcount 1).  Shared prompt pages are read-only; the partially-filled
  prompt tail page is materialized as a private copy at admission
  (copy-on-write) *before* the first divergent append can land in it.
* **Page 0 is the garbage sink.**  The allocator never hands out page 0;
  unmapped table entries (-1) clamp to it, so retired rows decoding pad
  tokens (static shapes keep every row stepping) scribble into page 0
  instead of someone else's data.
* **Token identity by materialization.**  `materialize` gathers a row's
  pages back into the contiguous ``(B, Hkv, S, Dh)`` layout — bitwise equal
  to what the contiguous cache would hold (zeros beyond ``fill``, POS_EMPTY
  on padding) — and `paged_attend` runs the *same* attention math on it, so
  paged decode is token-identical to the contiguous path (the equivalence
  tests enforce this; the Pallas `kernels/paged_decode.py` gather kernel is
  the TPU fast path with its own allclose oracle).
* **Quantized storage (``quant="int8"|"fp8"``).**  The pool optionally
  holds K/V quantized with one float32 scale per (page, kv head) —
  ``k_scale``/``v_scale`` (N, Hkv) — cutting pool bytes per resident token
  ~2x (int8 vs bf16) to ~4x (int8 vs f32).  Writes quantize
  (`write_prompt`: fresh per-page amax scale; `paged_append`: the page
  scale grows monotonically and the resident page requantizes under the
  new scale — an exact identity when the scale is unchanged), reads
  dequantize (`materialize` returns float32; the Pallas kernel dequantizes
  in-register from the prefetched scales).  Quantized decode is a
  *different sampler policy* than the dense cache: the engine records its
  log-probs as ``logp_sparse`` and the trainer's dense rescore supplies
  ``pi_old``, so Sparse-RL's xi/rejection/reweighting machinery absorbs
  the quantization mismatch unchanged (DESIGN.md §Quantized paged pool).
  ``quant="none"`` keeps every code path — and every bit — of the fp pool.

Host-side, `BlockAllocator` (free list + refcounts, double-free checked)
and `PrefixCache` (prompt-hash -> pinned page chain + last-token logits,
LRU-evicted under pool pressure) implement the sharing policy; the
continuous-batching engine drives both (`rollout/continuous.py`).
Quantization is invisible to the host side: pages, refcounts and prefix
entries track *page identities*, never their byte contents.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kvcache.attend import attend_arrays
from repro.kvcache.cache import POS_EMPTY


# ---------------------------------------------------------------------------
# Quantized storage: per-(page, kv-head) symmetric scales
# ---------------------------------------------------------------------------
# quant mode -> (pool dtype, qmax: the largest magnitude the quantized code
# can represent, so scale = amax / qmax maps the page's amax onto it)
_QUANT_SPECS = {
    "int8": (jnp.int8, 127.0),
    "fp8": (jnp.float8_e4m3fn, 448.0),
}
QUANT_MODES = ("none",) + tuple(_QUANT_SPECS)


def quant_spec(quant: str):
    """(pool dtype, qmax) for a quant mode; raises on unknown modes."""
    if quant not in _QUANT_SPECS:
        raise ValueError(f"unknown quant mode {quant!r} "
                         f"(choose from {QUANT_MODES})")
    return _QUANT_SPECS[quant]


def page_scale(x: jnp.ndarray, quant: str) -> jnp.ndarray:
    """Symmetric per-page scale: amax over the trailing (slots, Dh) axes
    of ``x`` (..., bs, Dh) divided by qmax -> (...) float32.  An all-zero
    page gets scale 0 and round-trips to exact zeros."""
    _, qmax = quant_spec(quant)
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-2, -1)) / qmax


def quantize_kv(x: jnp.ndarray, scale: jnp.ndarray, quant: str
                ) -> jnp.ndarray:
    """Quantize fp values under a given scale (``scale`` broadcasts against
    ``x``).  int8 rounds-to-nearest and clips; fp8 casts (values are within
    +-qmax by construction of the scale)."""
    qdtype, qmax = quant_spec(quant)
    safe = jnp.where(scale > 0.0, scale, 1.0)
    y = x.astype(jnp.float32) / safe
    if quant == "int8":
        y = jnp.clip(jnp.round(y), -qmax, qmax)
    return y.astype(qdtype)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Dequantize to float32 (``scale`` broadcasts against ``q``)."""
    return q.astype(jnp.float32) * scale


def _rescale_page(q_page: jnp.ndarray, old_scale: jnp.ndarray,
                  new_scale: jnp.ndarray, quant: str) -> jnp.ndarray:
    """Requantize a resident page under a grown (>= old) scale.

    ``factor = old/new <= 1`` shrinks the stored codes in fp32; when the
    scale did not grow (factor == 1) this is an exact identity — int8:
    round of ``int * 1.0``; fp8: every fp8 value round-trips f32 exactly —
    so unchanged pages stay bit-identical append after append."""
    qdtype, qmax = quant_spec(quant)
    safe = jnp.where(new_scale > 0.0, new_scale, 1.0)
    factor = jnp.where(new_scale > 0.0, old_scale / safe, 1.0)
    x = q_page.astype(jnp.float32) * factor[..., None, None]
    if quant == "int8":
        x = jnp.clip(jnp.round(x), -qmax, qmax)
    return x.astype(qdtype)


# ---------------------------------------------------------------------------
# Device side: the paged cache pytree + pure functions on it
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class PagedKVCache:
    """One layer's paged cache (callers may stack a leading layer dim on
    every array leaf; ``seq_len``/``quant`` are static aux data and survive
    stacking).

    ``seq_len`` is the contiguous-equivalent slot count S the row geometry
    was sized for (``rollout_slots``): `materialize` slices the gathered
    page chain to exactly S so attention sees the same shape as the
    contiguous backend (the token-identity requirement).

    ``quant`` selects the pool storage: ``"none"`` (fp pools, scales absent
    as ``None`` — the historical layout, bit-for-bit) or ``"int8"``/
    ``"fp8"`` (quantized pools + per-(page, head) float32 scales).
    """

    k_pool: jnp.ndarray       # (N, Hkv, bs, Dh)
    v_pool: jnp.ndarray       # (N, Hkv, bs, Dh)
    pos_pool: jnp.ndarray     # (N, bs) int32
    block_tables: jnp.ndarray  # (B, nb) int32, -1 = unmapped
    fill: jnp.ndarray         # (B,) int32
    k_scale: Optional[jnp.ndarray] = None   # (N, Hkv) f32, quantized only
    v_scale: Optional[jnp.ndarray] = None   # (N, Hkv) f32, quantized only
    seq_len: int = dataclasses.field(metadata={"static": True}, default=0)
    quant: str = dataclasses.field(metadata={"static": True}, default="none")

    def tree_flatten(self):
        return ((self.k_pool, self.v_pool, self.pos_pool,
                 self.block_tables, self.fill, self.k_scale, self.v_scale),
                (self.seq_len, self.quant))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, seq_len=aux[0], quant=aux[1])

    @property
    def block_size(self) -> int:
        return self.k_pool.shape[-2]

    @property
    def num_blocks(self) -> int:
        return self.k_pool.shape[-3]

    @property
    def blocks_per_row(self) -> int:
        return self.block_tables.shape[-1]


GARBAGE_BLOCK = 0


def init_paged(batch: int, kv_heads: int, num_blocks: int, block_size: int,
               head_dim: int, blocks_per_row: int, seq_len: int,
               dtype=jnp.bfloat16, quant: str = "none") -> PagedKVCache:
    """All-empty pool: no pages mapped, nothing written.  ``quant`` other
    than "none" stores the pools in the quantized dtype (``dtype`` then only
    names the fp dtype quantization is judged against) plus zeroed
    per-(page, head) scales — a zero scale dequantizes unwritten garbage
    pages to exact zeros."""
    pool_dtype, k_scale, v_scale = dtype, None, None
    if quant != "none":
        pool_dtype = quant_spec(quant)[0]
        k_scale = jnp.zeros((num_blocks, kv_heads), jnp.float32)
        v_scale = jnp.zeros((num_blocks, kv_heads), jnp.float32)
    return PagedKVCache(
        k_pool=jnp.zeros((num_blocks, kv_heads, block_size, head_dim),
                         pool_dtype),
        v_pool=jnp.zeros((num_blocks, kv_heads, block_size, head_dim),
                         pool_dtype),
        pos_pool=jnp.full((num_blocks, block_size), POS_EMPTY, jnp.int32),
        block_tables=jnp.full((batch, blocks_per_row), -1, jnp.int32),
        fill=jnp.zeros((batch,), jnp.int32),
        k_scale=k_scale,
        v_scale=v_scale,
        seq_len=seq_len,
        quant=quant,
    )


def paged_append(cache: PagedKVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 new_pos: jnp.ndarray) -> PagedKVCache:
    """Append one token per row through the block table.

    k_new/v_new: (B, Hkv, Dh); new_pos: (B,) absolute positions.  Rows whose
    table is unmapped (retired/empty slots kept stepping for static shapes)
    clamp to the garbage page; their junk is never attended because nothing
    maps page 0.  The allocator guarantees the addressed page of an *active*
    row is exclusively owned, so no cross-row write conflict exists.

    Quantized pools: the page scale grows to cover the incoming token
    (``new_scale = max(old_scale, amax_token / qmax)``) and the resident
    page requantizes under it — exactly a no-op when the scale is unchanged
    (see `_rescale_page`), so earlier tokens only lose precision when a
    genuinely larger-magnitude token arrives on their page.
    """
    B, Hkv, _ = k_new.shape
    bs = cache.block_size
    cap = cache.blocks_per_row * bs
    widx = jnp.minimum(cache.fill, cap - 1)                      # (B,)
    blk = jnp.take_along_axis(cache.block_tables,
                              (widx // bs)[:, None], axis=-1)[:, 0]
    blk = jnp.maximum(blk, GARBAGE_BLOCK)
    off = widx % bs
    bi = blk[:, None]
    hi = jnp.arange(Hkv)[None, :]
    oi = off[:, None]
    pos_pool = cache.pos_pool.at[blk, off].set(new_pos.astype(jnp.int32))
    fill = jnp.minimum(cache.fill + 1, cap)
    if cache.quant == "none":
        return dataclasses.replace(
            cache,
            k_pool=cache.k_pool.at[bi, hi, oi].set(
                k_new.astype(cache.k_pool.dtype)),
            v_pool=cache.v_pool.at[bi, hi, oi].set(
                v_new.astype(cache.v_pool.dtype)),
            pos_pool=pos_pool,
            fill=fill,
        )
    _, qmax = quant_spec(cache.quant)
    k32 = k_new.astype(jnp.float32)
    v32 = v_new.astype(jnp.float32)
    old_sk, old_sv = cache.k_scale[blk], cache.v_scale[blk]      # (B, Hkv)
    new_sk = jnp.maximum(old_sk, jnp.max(jnp.abs(k32), axis=-1) / qmax)
    new_sv = jnp.maximum(old_sv, jnp.max(jnp.abs(v32), axis=-1) / qmax)
    # gather each row's write page, requantize it under the grown scale,
    # insert the new token, scatter it back (exclusive ownership makes the
    # row-wise gather/scatter race-free; garbage-clamped rows all hit page
    # 0, where any write order is fine — nothing ever attends it)
    pk = _rescale_page(cache.k_pool[blk], old_sk, new_sk, cache.quant)
    pv = _rescale_page(cache.v_pool[blk], old_sv, new_sv, cache.quant)
    ri = jnp.arange(B)[:, None]
    pk = pk.at[ri, hi, oi].set(quantize_kv(k32, new_sk[..., None],
                                           cache.quant))
    pv = pv.at[ri, hi, oi].set(quantize_kv(v32, new_sv[..., None],
                                           cache.quant))
    return dataclasses.replace(
        cache,
        k_pool=cache.k_pool.at[blk].set(pk),
        v_pool=cache.v_pool.at[blk].set(pv),
        k_scale=cache.k_scale.at[blk].set(new_sk),
        v_scale=cache.v_scale.at[blk].set(new_sv),
        pos_pool=pos_pool,
        fill=fill,
    )


def materialize(cache: PagedKVCache
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Gather each row's page chain into the contiguous cache layout.

    Returns (k (B, Hkv, S, Dh), v, pos (B, Hkv, S)) with S = ``seq_len`` —
    bitwise the arrays the contiguous dense cache would hold for the same
    token stream: written slots carry the pooled values, everything beyond
    ``fill`` is zero K/V with POS_EMPTY (so the downstream attention math is
    identical, not merely close).

    Quantized pools dequantize here (per-page scales expand over the page
    tile) and return float32 K/V; a quantized cache missing its scales — or
    a raw int8 pool claiming ``quant="none"`` — raises instead of silently
    reading quantized bytes as floats.
    """
    B, nb = cache.block_tables.shape
    _, Hkv, bs, Dh = cache.k_pool.shape
    S = cache.seq_len
    assert 0 < S <= nb * bs, (S, nb, bs)
    k_pool, v_pool = cache.k_pool, cache.v_pool
    if cache.quant != "none":
        if cache.k_scale is None or cache.v_scale is None:
            raise ValueError(
                f"quant={cache.quant!r} paged cache has no k_scale/v_scale "
                f"— build it with init_paged(..., quant=...)")
        k_pool = dequantize_kv(k_pool, cache.k_scale[:, :, None, None])
        v_pool = dequantize_kv(v_pool, cache.v_scale[:, :, None, None])
    elif k_pool.dtype == jnp.int8:
        raise ValueError(
            "paged cache holds an int8 pool but quant='none' — cannot read "
            "quantized bytes as floats (set quant='int8' with scales)")
    bt = jnp.maximum(cache.block_tables, GARBAGE_BLOCK)          # (B, nb)
    def gather(pool):                                            # (B,nb,Hkv,bs,Dh)
        g = pool[bt]
        g = jnp.moveaxis(g, 2, 1)                                # (B,Hkv,nb,bs,..)
        return g.reshape((B, Hkv, nb * bs) + g.shape[4:])[:, :, :S]
    written = jnp.arange(S)[None, :] < cache.fill[:, None]       # (B, S)
    k = jnp.where(written[:, None, :, None], gather(k_pool), 0)
    v = jnp.where(written[:, None, :, None], gather(v_pool), 0)
    pos = cache.pos_pool[bt].reshape(B, nb * bs)[:, :S]
    pos = jnp.where(written, pos, POS_EMPTY)
    pos = jnp.broadcast_to(pos[:, None, :], (B, Hkv, S))
    return k, v, pos


# paged_attend backend: "auto" routes to the streaming Pallas kernel on TPU
# and the materializing jnp path elsewhere; "kernel"/"materialize" force one
# (tests force "kernel" to drive the interpret-mode kernel through the
# engine, with tuned page_tile resolution live)
_ATTEND_BACKEND = {"mode": "auto"}


def set_attend_backend(mode: str) -> None:
    if mode not in ("auto", "kernel", "materialize"):
        raise ValueError(f"unknown paged_attend backend {mode!r}")
    _ATTEND_BACKEND["mode"] = mode


def paged_attend(q: jnp.ndarray, cache: PagedKVCache) -> jnp.ndarray:
    """Decode-step attention over the paged cache.

    q: (B, Hq, Dh) roped single-token queries -> out (B, Hq, Dh).  The
    materializing path gathers the page chains to the contiguous layout and
    applies the exact attention math of `kvcache.attend` — the
    token-identity anchor; quantized pools dequantize inside `materialize`
    (so it never reads raw int8/fp8 bytes as floats).  The streaming Pallas
    kernel (`kernels/paged_decode.py`, via `kernels.ops` so tuned
    ``page_tile`` configs resolve) avoids the materialization entirely and
    dequantizes in-register — it is the TPU fast path, selected by
    `set_attend_backend` ("auto" keeps CPU on the materializing anchor).
    """
    mode = _ATTEND_BACKEND["mode"]
    if mode == "auto":
        mode = "kernel" if jax.default_backend() == "tpu" else "materialize"
    if mode == "kernel":
        from repro.kernels import ops
        return ops.paged_flash_decode(q, cache.k_pool, cache.v_pool,
                                      cache.pos_pool, cache.block_tables,
                                      cache.fill, cache.k_scale,
                                      cache.v_scale)
    k, v, pos = materialize(cache)
    out, _ = attend_arrays(q, k, v, pos)
    return out


def write_prompt(cache: PagedKVCache, k_prompt: jnp.ndarray,
                 v_prompt: jnp.ndarray, pos_prompt: jnp.ndarray,
                 blocks: jnp.ndarray, tail_dst: jnp.ndarray, *,
                 duplicate_tail: bool, skip_pages: int = 0) -> PagedKVCache:
    """Write one prefilled prompt into ``blocks`` (the prefix-cache chain).

    k_prompt/v_prompt: (Hkv, W, Dh); pos_prompt: (W,) (POS_EMPTY on left
    padding); blocks: (npb,) page ids covering the row's whole prompt
    region, with the K/V spanning the last ``npb - skip_pages`` pages.

    ``skip_pages`` (static) is the chunked-prefill partial-chain path
    (DESIGN.md §Chunked prefill & fill-aware decode): a prompt bucketed to
    width W < P leaves the leading ``(P - W) // bs`` pages of its chain as
    pure left-padding — no K/V is produced for them, so only their
    positions are wiped to POS_EMPTY (recycled pages carry a previous
    tenant's valid-looking positions; stale K/V under POS_EMPTY is inert,
    exactly like the pad columns of a full-width prefill).

    With ``duplicate_tail`` (static: P % bs != 0) the last — partial —
    page is also written to ``tail_dst``, the admitted row's private copy,
    so the shared chain stays read-only once appends start (copy-on-write
    materialized eagerly; DESIGN.md §Paged cache & prefix sharing).
    """
    Hkv, W, Dh = k_prompt.shape
    bs = cache.block_size
    npb = blocks.shape[0] - skip_pages
    assert npb >= 1, (blocks.shape[0], skip_pages)
    pad = npb * bs - W
    assert 0 <= pad < bs, (W, bs, npb)
    written = blocks[skip_pages:]

    def paginate(x, fill_value):
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)),
                    constant_values=fill_value) if x.ndim == 3 else \
            jnp.pad(x, ((0, pad),), constant_values=fill_value)
        if x.ndim == 3:
            return jnp.moveaxis(x.reshape(Hkv, npb, bs, Dh), 1, 0)
        return x.reshape(npb, bs)

    pb = paginate(pos_prompt.astype(jnp.int32), POS_EMPTY)
    k_scale, v_scale = cache.k_scale, cache.v_scale
    if cache.quant == "none":
        kb = paginate(k_prompt.astype(cache.k_pool.dtype), 0)
        vb = paginate(v_prompt.astype(cache.v_pool.dtype), 0)
    else:
        # quantize page-at-a-time: each freshly written page gets its own
        # amax scale (the pad region is zero-filled, so it never inflates
        # the scale); the duplicated tail copies the tail page's scale too
        kb32 = paginate(k_prompt.astype(jnp.float32), 0)
        vb32 = paginate(v_prompt.astype(jnp.float32), 0)
        ks = page_scale(kb32, cache.quant)                       # (npb, Hkv)
        vs = page_scale(vb32, cache.quant)
        kb = quantize_kv(kb32, ks[..., None, None], cache.quant)
        vb = quantize_kv(vb32, vs[..., None, None], cache.quant)
        k_scale = k_scale.at[written].set(ks)
        v_scale = v_scale.at[written].set(vs)
        if skip_pages:
            k_scale = k_scale.at[blocks[:skip_pages]].set(0.0)
            v_scale = v_scale.at[blocks[:skip_pages]].set(0.0)
        if duplicate_tail:
            k_scale = k_scale.at[tail_dst].set(ks[-1])
            v_scale = v_scale.at[tail_dst].set(vs[-1])
    k_pool = cache.k_pool.at[written].set(kb)
    v_pool = cache.v_pool.at[written].set(vb)
    pos_pool = cache.pos_pool.at[written].set(pb)
    if skip_pages:
        pos_pool = pos_pool.at[blocks[:skip_pages]].set(POS_EMPTY)
    if duplicate_tail:
        k_pool = k_pool.at[tail_dst].set(kb[-1])
        v_pool = v_pool.at[tail_dst].set(vb[-1])
        pos_pool = pos_pool.at[tail_dst].set(pb[-1])
    return dataclasses.replace(cache, k_pool=k_pool, v_pool=v_pool,
                               pos_pool=pos_pool, k_scale=k_scale,
                               v_scale=v_scale)


def copy_block(cache: PagedKVCache, src: jnp.ndarray, dst: jnp.ndarray
               ) -> PagedKVCache:
    """Copy one page ``src`` -> ``dst`` (the admission-time copy-on-write of
    a shared partial tail page).  Works on stacked caches too: the page axis
    is indexed from the right, so a leading layer dim copies every layer.
    Quantized pools copy the page's scales along with its codes — the pair
    is the page's value; copying one without the other would silently
    rescale the copied tokens."""
    extra = {}
    if cache.k_scale is not None:
        extra = dict(
            k_scale=cache.k_scale.at[..., dst, :].set(
                cache.k_scale[..., src, :]),
            v_scale=cache.v_scale.at[..., dst, :].set(
                cache.v_scale[..., src, :]),
        )
    return dataclasses.replace(
        cache,
        k_pool=cache.k_pool.at[..., dst, :, :, :].set(
            cache.k_pool[..., src, :, :, :]),
        v_pool=cache.v_pool.at[..., dst, :, :, :].set(
            cache.v_pool[..., src, :, :, :]),
        pos_pool=cache.pos_pool.at[..., dst, :].set(
            cache.pos_pool[..., src, :]),
        **extra,
    )


def paged_reset_rows(cache: PagedKVCache, rows, *, batch_axis: int = 0
                     ) -> PagedKVCache:
    """Unmap the given rows: table -> -1, fill -> 0 (counterpart of
    `kvcache.reset_rows`; page *content* is junk-tolerant — unmapped pages
    are unreachable, and the allocator recycles them wholesale)."""
    idx = (slice(None),) * batch_axis + (rows,)
    return dataclasses.replace(
        cache,
        block_tables=cache.block_tables.at[idx].set(-1),
        fill=cache.fill.at[idx].set(0),
    )


# ---------------------------------------------------------------------------
# Host side: allocator + prefix cache (the sharing policy)
# ---------------------------------------------------------------------------
class PoolExhausted(RuntimeError):
    """No free pages left (after prefix-cache eviction)."""


class BlockAllocator:
    """Free-list page allocator with refcounts.

    Page 0 (the garbage sink) is permanently pinned and never handed out.
    ``release`` on a zero-refcount page raises — the double-free guard the
    unit tests exercise.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 pages (page 0 is the garbage sink)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._ref = [0] * num_blocks
        self._ref[GARBAGE_BLOCK] = 1
        # pop() order 1, 2, 3, ... keeps tests/debugging deterministic
        self._free = list(range(num_blocks - 1, 0, -1))

    def alloc(self, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(
                f"requested {n} pages, {len(self._free)} free "
                f"(pool={self.num_blocks})")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._ref[b] = 1
        return out

    def retain(self, block: int) -> None:
        if self._ref[block] <= 0:
            raise ValueError(f"retain of unallocated page {block}")
        self._ref[block] += 1

    def release(self, block: int) -> bool:
        """Drop one reference; returns True when the page went back to the
        free list.  Raises on double free."""
        if block == GARBAGE_BLOCK:
            raise ValueError("page 0 is the pinned garbage sink")
        if self._ref[block] <= 0:
            raise ValueError(f"double free of page {block}")
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)
            return True
        return False

    def release_many(self, blocks) -> int:
        """Bulk `release` — one call per page chain instead of per page.

        The RL-training path retires whole groups at once (a finished row's
        chain at harvest, a cancelled straggler's chain at group close, and
        every prefix-cache pin at phase end), so the bulk form keeps those
        paths single-statement and atomic-looking in the scheduler.  Fails
        on the FIRST bad page exactly like `release` (double frees must not
        be silently swallowed mid-chain).  Returns how many pages went back
        to the free list.
        """
        freed = 0
        for b in blocks:
            freed += bool(self.release(b))
        return freed

    def refcount(self, block: int) -> int:
        return self._ref[block]

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        """Allocated pages, excluding the pinned garbage sink."""
        return self.num_blocks - 1 - len(self._free)


@dataclass
class PrefixEntry:
    """One cached prompt prefill.

    Pool backend: ``blocks`` is the refcount-pinned page chain holding the
    prompt K/V (last entry is the partial tail page when one exists).
    Splice backend (ssm/hybrid/compressed): ``sub_state`` is the full 1-row
    decode state to splice.  Both keep the prompt's last-token logits so a
    hit skips the model prefill entirely.
    """
    blocks: Tuple[int, ...] = ()
    sub_state: Any = None
    last_logits: Any = None
    next_pos: Any = None
    hits: int = 0


class PrefixCache:
    """LRU map: prompt key -> PrefixEntry, pinning pool pages via refcounts.

    ``lookup`` misses/hits feed the engine's prefix-hit-rate metric;
    ``evict_one`` releases the least-recently-used entry's pages (called by
    the engine under pool pressure, and by ``insert`` past ``max_entries``).
    """

    def __init__(self, allocator: Optional[BlockAllocator] = None,
                 max_entries: int = 32):
        self.allocator = allocator
        self.max_entries = max_entries
        self._entries: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def contains(self, key: bytes) -> bool:
        """Pure membership peek: no LRU touch, no hit/miss accounting.
        The admission scheduler uses it to cost a candidate (hits are free,
        misses consume prefill-chunk budget) without perturbing the stats
        the real ``lookup`` keeps."""
        return key in self._entries

    def lookup(self, key: bytes) -> Optional[PrefixEntry]:
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        e.hits += 1
        return e

    def insert(self, key: bytes, entry: PrefixEntry) -> None:
        assert key not in self._entries
        self._entries[key] = entry
        while len(self._entries) > self.max_entries:
            if not self.evict_one():
                break

    def evict_one(self) -> bool:
        """Release the LRU entry (and its pinned pages).  False when empty."""
        if not self._entries:
            return False
        _, entry = self._entries.popitem(last=False)
        if self.allocator is not None:
            self.allocator.release_many(entry.blocks)
        return True

    def clear(self) -> None:
        while self.evict_one():
            pass
