"""Fixed-budget slotted KV cache (TPU-native adaptation of R-KV/SnapKV/H2O/
StreamingLLM eviction).

GPU reference implementations physically compact a variable-length cache every
``B_buffer`` tokens.  XLA needs static shapes, so we keep a fixed array of
``slots = B_budget + B_buffer`` per layer and *overwrite* the lowest-scoring
unprotected slot once full (streaming eviction).  Memory is exactly the
paper's bound; all ops are masked vector ops + one scatter, and the whole
decode loop stays inside a single compiled ``lax.scan``.

Cache layout (one layer; callers stack a leading layer dim for scan):
  k, v   : (B, Hkv, S, Dh)   post-RoPE keys / values
  pos    : (B, Hkv, S) int32 original position of the token in a slot, -1=empty
  score  : (B, Hkv, S) f32   policy accumulator (e.g. cumulative attention)
  fill   : (B,)        int32 per-row count of append-order slots consumed

``fill`` is PER ROW so rows of one batch may be at different logical depths —
the property the continuous-batching scheduler (DESIGN.md §Continuous-batching)
relies on to recycle a finished row's slot block while its neighbours keep
decoding.  In the lockstep rollout every row advances together and the column
is constant.

Eviction is PER KV-HEAD (different heads retain different tokens), matching
H2O/SnapKV/R-KV semantics.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SparseRLConfig

NEG = -1e30
POS_EMPTY = -1


class KVCache(NamedTuple):
    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray
    score: jnp.ndarray
    fill: jnp.ndarray  # (B,) int32

    @property
    def slots(self) -> int:
        return self.k.shape[-2]

    def valid_mask(self) -> jnp.ndarray:
        return self.pos >= 0  # (B, Hkv, S)


def init_cache(batch: int, kv_heads: int, slots: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, kv_heads, slots, head_dim), dtype),
        v=jnp.zeros((batch, kv_heads, slots, head_dim), dtype),
        pos=jnp.full((batch, kv_heads, slots), POS_EMPTY, jnp.int32),
        score=jnp.zeros((batch, kv_heads, slots), jnp.float32),
        fill=jnp.zeros((batch,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Eviction
# ---------------------------------------------------------------------------
def eviction_scores(cache: KVCache, scfg: SparseRLConfig,
                    cur_pos: jnp.ndarray,
                    k_new: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Lower = evicted first.  (B, Hkv, S) float32.

    Policies:
      streaming : recency (evict oldest), attention sinks pinned.
      h2o       : cumulative attention mass received (``score`` field).
      snapkv    : pooled observation-window attention at prefill, then
                  cumulative attention during decode (same field).
      rkv       : lambda * importance  +  (1-lambda) * diversity, where
                  importance = normalized cumulative attention and
                  diversity = 1 - cos-sim(key, incoming key) (redundant
                  tokens — similar to what is being written — go first).
      per_head  : cumulative attention (h2o-style).  The per-head budget
                  itself is applied by :func:`enforce_budget` (this ranking
                  only decides slot reuse if a dense-sized cache ever fills).
      adaptive  : rkv scoring; the step-scheduled budget is applied by
                  :func:`enforce_budget` after every decode step.
    """
    valid = cache.valid_mask()
    if scfg.compression == "streaming":
        s = cache.pos.astype(jnp.float32)
    elif scfg.compression in ("h2o", "snapkv", "per_head"):
        s = cache.score
    elif scfg.compression in ("rkv", "adaptive"):
        imp = cache.score
        denom = jnp.max(jnp.where(valid, imp, 0.0), axis=-1, keepdims=True) + 1e-6
        imp = imp / denom
        if k_new is not None:
            kc = cache.k.astype(jnp.float32)
            kn = k_new.astype(jnp.float32)                     # (B, Hkv, Dh)
            num = jnp.einsum("bhsd,bhd->bhs", kc, kn)
            den = (jnp.linalg.norm(kc, axis=-1) *
                   jnp.linalg.norm(kn, axis=-1)[..., None] + 1e-6)
            redundancy = num / den                              # cos-sim [-1,1]
            diversity = 1.0 - redundancy
        else:
            diversity = jnp.ones_like(imp)
        s = scfg.rkv_lambda * imp + (1.0 - scfg.rkv_lambda) * diversity
    else:
        # "none": a correctly-sized dense cache never fills; if misused past
        # capacity, degrade to recency eviction rather than clobbering slot 0
        s = cache.pos.astype(jnp.float32)
    # protections: empty slots are *preferred* targets; sinks and the
    # observation window (alpha most recent tokens) are never evicted.
    s = jnp.where(valid, s, NEG)
    sink = cache.pos < scfg.num_sinks
    recent = cache.pos > (cur_pos - scfg.obs_window)
    s = jnp.where(valid & (sink | recent), jnp.inf, s)
    return s


def append(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
           new_pos: jnp.ndarray, scfg: SparseRLConfig,
           new_score: float = 0.0) -> KVCache:
    """Insert one token per (batch, kv_head).  k_new/v_new: (B, Hkv, Dh);
    new_pos: (B,) current absolute position.  Evicts per-head argmin of
    `eviction_scores` when full."""
    B, H, S, _ = cache.k.shape
    full = cache.fill >= S                                     # (B,)
    ev = eviction_scores(cache, scfg, cur_pos=new_pos[:, None, None], k_new=k_new)
    evict_idx = jnp.argmin(ev, axis=-1)                        # (B, H)
    idx = jnp.where(full[:, None], evict_idx,
                    jnp.minimum(cache.fill, S - 1)[:, None])
    bi = jnp.arange(B)[:, None]
    hi = jnp.arange(H)[None, :]
    k = cache.k.at[bi, hi, idx].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[bi, hi, idx].set(v_new.astype(cache.v.dtype))
    pos = cache.pos.at[bi, hi, idx].set(new_pos[:, None].astype(jnp.int32))
    score = cache.score.at[bi, hi, idx].set(jnp.float32(new_score))
    fill = jnp.minimum(cache.fill + 1, S)
    return KVCache(k, v, pos, score, fill)


def update_scores(cache: KVCache, probs_pooled: jnp.ndarray,
                  scfg: SparseRLConfig) -> KVCache:
    """Accumulate attention mass (B, Hkv, S) into the policy score."""
    if scfg.compression in ("h2o", "snapkv", "rkv", "per_head", "adaptive"):
        score = cache.score + jnp.where(cache.valid_mask(), probs_pooled, 0.0)
        return cache._replace(score=score)
    return cache


# ---------------------------------------------------------------------------
# Scheduled / per-head budgets (registry policies "per_head" and "adaptive")
# ---------------------------------------------------------------------------
def reasoning_heads(scfg: SparseRLConfig, kv_heads: int) -> int:
    """How many leading kv heads keep dense caches under ``per_head``."""
    frac = min(max(scfg.reasoning_head_frac, 0.0), 1.0)
    return max(1, min(kv_heads, int(-(-kv_heads * frac // 1))))


def head_budget_split(scfg: SparseRLConfig) -> tuple:
    """(reasoning-head budget, compressed-head budget) for ``per_head``.

    Reasoning heads are unbounded (the dense-sized geometry never fills);
    the rest are hard-capped at ``kv_budget`` — no buffer slack — but never
    below the always-protected sinks + observation window.
    """
    hard = max(scfg.kv_budget, scfg.num_sinks + scfg.obs_window)
    return (1 << 30), hard


def adaptive_budget(scfg: SparseRLConfig, pos):
    """Sparrow-style step schedule: effective live-slot budget at decode
    position ``pos`` (int or traced array; returns same shape, int32).

    Decays linearly from ``cache_slots`` to ``adaptive_min_frac *
    cache_slots`` over the first ``adaptive_decay_tokens`` positions, then
    stays flat; floored at sinks + obs window (the protected set).  Monotone
    non-increasing in ``pos`` — the registry conformance test pins this.
    """
    S = scfg.cache_slots
    floor = scfg.num_sinks + scfg.obs_window
    p = jnp.asarray(pos, jnp.float32)
    frac = 1.0 - (1.0 - scfg.adaptive_min_frac) * jnp.minimum(
        p / max(scfg.adaptive_decay_tokens, 1), 1.0)
    return jnp.maximum(jnp.ceil(S * frac).astype(jnp.int32), floor)


def decode_budgets(scfg: SparseRLConfig, kv_heads: int, slots: int,
                   cur_pos: jnp.ndarray) -> jnp.ndarray:
    """Per-(row, kv head) live-slot budget at the current decode position.

    cur_pos: (B,) absolute positions.  Returns (B, Hkv) int32, clipped to
    the physical slot count (a budget >= S is a no-op).
    """
    B = cur_pos.shape[0]
    if scfg.compression == "per_head":
        n_r = reasoning_heads(scfg, kv_heads)
        _, hard = head_budget_split(scfg)
        per_head = jnp.where(jnp.arange(kv_heads) < n_r, slots, min(hard, slots))
        return jnp.broadcast_to(per_head[None, :].astype(jnp.int32), (B, kv_heads))
    if scfg.compression == "adaptive":
        b = jnp.minimum(adaptive_budget(scfg, cur_pos), slots)  # (B,)
        return jnp.broadcast_to(b[:, None], (B, kv_heads))
    return jnp.full((B, kv_heads), slots, jnp.int32)


def enforce_budget(cache: KVCache, scfg: SparseRLConfig,
                   cur_pos: jnp.ndarray) -> KVCache:
    """Invalidate every live slot past the policy's current budget.

    The per-head ("per_head") and step-scheduled ("adaptive") budgets cannot
    be expressed by append-time eviction alone: one (B, Hkv, S, Dh) array
    holds every head, so heads with different budgets keep the dense slot
    count physically and apply their cap *logically* — the lowest-ranked
    surplus slots get ``pos = POS_EMPTY`` (attention masks them) and a zeroed
    score (no stale importance).  k/v bytes and ``fill`` are untouched:
    invalidated slots rank as preferred eviction targets (NEG) so a full
    cache reuses them first.  No-op for every other policy.
    """
    if scfg.compression not in ("per_head", "adaptive"):
        return cache
    B, H, S, _ = cache.k.shape
    budgets = decode_budgets(scfg, H, S, cur_pos)               # (B, H)
    s = eviction_scores(cache, scfg, cur_pos=cur_pos[:, None, None])
    # rank descending (0 = most retained); break score ties toward newer
    # tokens so the ordering is deterministic.  +inf (protected) slots rank
    # first, NEG (empty) last; the tiny recency term never reorders distinct
    # scores (score gaps are >> S * 1e-6 or the slots tie anyway).
    tie = jnp.where(jnp.isinf(s), 0.0, cache.pos.astype(jnp.float32) * 1e-6)
    rank = jnp.argsort(jnp.argsort(-(s + tie), axis=-1), axis=-1)
    keep = rank < budgets[..., None]
    pos = jnp.where(keep, cache.pos, POS_EMPTY)
    score = jnp.where(keep, cache.score, 0.0)
    return cache._replace(pos=pos, score=score)


# ---------------------------------------------------------------------------
# Prefill compression: select `slots` tokens out of a full prompt
# ---------------------------------------------------------------------------
def compress_prefill(k_full: jnp.ndarray, v_full: jnp.ndarray,
                     prompt_mask: jnp.ndarray, obs_scores: jnp.ndarray,
                     slots: int, scfg: SparseRLConfig,
                     positions: jnp.ndarray) -> KVCache:
    """Build the initial budget cache from a prefilled prompt.

    k_full/v_full: (B, Hkv, T, Dh); prompt_mask: (B, T) bool valid;
    obs_scores:   (B, Hkv, T) pooled attention of the last obs-window queries
                  over all keys (SnapKV selection signal; reused as the
                  importance init for h2o/rkv);
    positions:    (B, T) absolute positions.
    """
    B, H, T, D = k_full.shape
    if T <= slots:
        # prompt fits: copy verbatim (pad empty slots)
        pad = slots - T
        k = jnp.pad(k_full, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v_full, ((0, 0), (0, 0), (0, pad), (0, 0)))
        posbh = jnp.broadcast_to(positions[:, None, :], (B, H, T))
        posbh = jnp.where(prompt_mask[:, None, :], posbh, POS_EMPTY)
        pos = jnp.pad(posbh, ((0, 0), (0, 0), (0, pad)), constant_values=POS_EMPTY)
        score = jnp.pad(jnp.where(prompt_mask[:, None, :], obs_scores, 0.0),
                        ((0, 0), (0, 0), (0, pad)))
        fill = jnp.full((B,), T, jnp.int32)
        return KVCache(k.astype(k_full.dtype), v.astype(v_full.dtype), pos,
                       score.astype(jnp.float32), fill)

    posb = jnp.broadcast_to(positions[:, None, :], (B, H, T))
    maskb = jnp.broadcast_to(prompt_mask[:, None, :], (B, H, T))
    sel = jnp.where(maskb, obs_scores, NEG)
    # sinks + observation window always kept
    cur = jnp.max(jnp.where(prompt_mask, positions, 0), axis=-1)  # (B,)
    keep = (posb < scfg.num_sinks) | (posb > cur[:, None, None] - scfg.obs_window)
    sel = jnp.where(maskb & keep, jnp.inf, sel)
    _, top_idx = jax.lax.top_k(sel, slots)                     # (B, H, slots)
    top_idx = jnp.sort(top_idx, axis=-1)                       # keep temporal order
    gather = lambda x: jnp.take_along_axis(x, top_idx[..., None], axis=2)
    k = gather(k_full)
    v = gather(v_full)
    pos = jnp.take_along_axis(posb, top_idx, axis=2)
    pos = jnp.where(jnp.take_along_axis(maskb, top_idx, axis=2), pos, POS_EMPTY)
    score = jnp.take_along_axis(jnp.where(maskb, obs_scores, 0.0), top_idx, axis=2)
    fill = jnp.full((B,), slots, jnp.int32)
    return KVCache(k, v, pos, score.astype(jnp.float32), fill)


def dense_prefill(k_full, v_full, prompt_mask, positions, max_slots: int) -> KVCache:
    """Dense (uncompressed) cache: prompt KVs + head-room for generation."""
    B, H, T, D = k_full.shape
    assert max_slots >= T, (max_slots, T)
    zero_scores = jnp.zeros((B, H, T), jnp.float32)
    cache = compress_prefill(k_full, v_full, prompt_mask, zero_scores,
                             max_slots, SparseRLConfig(compression="none"),
                             positions)
    return cache._replace(fill=jnp.full((B,), T, jnp.int32))


# ---------------------------------------------------------------------------
# Per-row slot recycling (continuous batching)
# ---------------------------------------------------------------------------
def reset_rows(cache: KVCache, rows, *, batch_axis: int = 0) -> KVCache:
    """Return `cache` with the given batch rows wiped to the empty state.

    ``rows`` is an int scalar or (n,) int array of batch indices.  With
    ``batch_axis=1`` the same call works on an L-stacked cache (leaves carry a
    leading layer dim, as produced by prefill's scan over layers).  Nothing of
    a retired request survives: pos goes back to POS_EMPTY (so attention masks
    the slots), score to 0 (so no stale importance biases the next tenant's
    eviction), fill to 0 (so appends restart at slot 0).
    """
    idx = (slice(None),) * batch_axis + (rows,)
    return KVCache(
        k=cache.k.at[idx].set(0),
        v=cache.v.at[idx].set(0),
        pos=cache.pos.at[idx].set(POS_EMPTY),
        score=cache.score.at[idx].set(0.0),
        fill=cache.fill.at[idx].set(0),
    )


def write_rows(dst: KVCache, src: KVCache, rows, *, batch_axis: int = 0
               ) -> KVCache:
    """Copy ``src``'s whole batch into ``dst`` at batch indices ``rows``.

    ``rows`` is an (n,) int array and ``src`` must have batch size n, matching
    ``dst`` on every other dim.  Cache-level counterpart of the admission
    splice for callers holding bare KVCaches; the continuous engine itself
    splices whole decode states shape-generically
    (`rollout.continuous.insert_request_state`).
    """
    idx = (slice(None),) * batch_axis + (rows,)
    return KVCache(
        k=dst.k.at[idx].set(src.k.astype(dst.k.dtype)),
        v=dst.v.at[idx].set(src.v.astype(dst.v.dtype)),
        pos=dst.pos.at[idx].set(src.pos),
        score=dst.score.at[idx].set(src.score),
        fill=dst.fill.at[idx].set(src.fill),
    )
