"""Batched rollout engine: prefill + fixed-length lockstep decode.

Decode runs a single compiled ``lax.scan`` for ``max_new_tokens`` steps —
fixed shapes, no host sync, no per-sequence early exit (finished rows feed
padding; this is the TPU-native straggler story: a batch is never blocked on
its longest row beyond the static bound).

The single-step core (:func:`decode_sample_step`) is shared with the
continuous-batching scheduler (`repro.rollout.continuous`): the training path
scans it lockstep; the serving path drives it from a host loop with slot
recycling.  Both sampling-key disciplines live here too — the lockstep
default (one key per step, split across the batch) and the per-row chain
(``fold_in(row_key, t)``) that makes a request's tokens independent of its
batch placement (DESIGN.md §Sampling, §Continuous-batching).

Per sampled token we record the *model-distribution* log-prob under the
sparse sampler (pi_sparse, Eq. 2).  At the paper's sampling settings
(temperature=1, top_p=1) the sampling distribution and the policy coincide,
making the importance corrections exact; for other settings the deviation is
documented in DESIGN.md §Sampling.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SparseRLConfig
from repro.models import ModelFns
from repro.models.common import entropy_from_logits, log_softmax_gather


class RolloutBatch(NamedTuple):
    prompt_tokens: jnp.ndarray   # (B, P) left-padded
    prompt_mask: jnp.ndarray     # (B, P) bool
    resp_tokens: jnp.ndarray     # (B, T)
    resp_mask: jnp.ndarray       # (B, T) bool — True up to & incl. EOS
    logp_sparse: jnp.ndarray     # (B, T) f32 — sampler policy log-probs
    lengths: jnp.ndarray         # (B,) int32 response lengths
    entropy: jnp.ndarray         # (B,) f32 mean sampling entropy (telemetry)

    def full_tokens(self) -> jnp.ndarray:
        return jnp.concatenate([self.prompt_tokens, self.resp_tokens], axis=1)

    def full_mask(self) -> jnp.ndarray:
        return jnp.concatenate([self.prompt_mask, self.resp_mask], axis=1)


class TrainRollout(NamedTuple):
    """One RL rollout phase produced by the continuous engine.

    ``rollout`` is group-major and trainer-ready: the same (B, T) layout the
    lockstep `generate` returns, assembled from per-request Completions (the
    per-token ``logp_sparse`` comes from the sampler pass recorded in-engine,
    so rejection sampling and Eq. 7 reweighting consume identical inputs on
    both backends — DESIGN.md §Training on the continuous engine).  ``keep``
    maps each row back to the uid of the submitted request (group-major
    ascending; with ``group_slack`` the dropped stragglers are absent), so
    the caller can align answers/rewards.  ``finished_eos`` marks rows that
    exited on EOS before the token cap — the early-exit rows whose freed
    slots admitted the next group.

    ``weight_versions``/``tok_versions`` carry the async pipeline's
    staleness accounting (DESIGN.md §Async pipeline & staleness
    correction): per-row admission version, and per token the version of
    the weights that produced the logits the token was sampled from (the
    pad tail repeats the row's last version — masked out anyway).  Sync
    phases are all-zeros.
    """
    rollout: RolloutBatch
    keep: np.ndarray          # (B,) int32 kept request uids
    finished_eos: np.ndarray  # (B,) bool
    stats: Dict[str, float]   # engine counter snapshot for telemetry
    weight_versions: Optional[np.ndarray] = None  # (B,) int64
    tok_versions: Optional[np.ndarray] = None     # (B, T) int64


def build_train_rollout(completions: Sequence, prompt_tokens: np.ndarray,
                        prompt_mask: np.ndarray, *, max_new_tokens: int,
                        pad_id: int = 0,
                        stats: Optional[Dict[str, float]] = None
                        ) -> TrainRollout:
    """Assemble engine Completions into the lockstep `RolloutBatch` layout.

    ``prompt_tokens``/``prompt_mask`` are the tiled (total_requests, P)
    arrays the requests were cut from; rows are selected by completion uid so
    prompts stay bit-identical to the lockstep path.  Early-exited rows are
    right-padded to ``max_new_tokens`` with ``pad_id`` (the same id the
    engine fed and `generate` emits on inactive rows — pass the engine's,
    don't assume 0), ``resp_mask`` False and ``logp_sparse`` 0 on the tail
    — exactly the post-EOS convention of `generate` (active rows only), so
    both backends feed the same masked arrays to rescore and the Eq. 7
    loss.
    """
    comps = sorted(completions, key=lambda c: c.uid)
    B, T = len(comps), max_new_tokens
    keep = np.asarray([c.uid for c in comps], np.int32)
    resp = np.full((B, T), pad_id, np.int32)
    logp = np.zeros((B, T), np.float32)
    mask = np.zeros((B, T), bool)
    lengths = np.zeros((B,), np.int32)
    entropy = np.zeros((B,), np.float32)
    eos = np.zeros((B,), bool)
    row_ver = np.zeros((B,), np.int64)
    tok_ver = np.zeros((B, T), np.int64)
    for i, c in enumerate(comps):
        n = len(c.tokens)
        assert n <= T, (n, T)
        resp[i, :n] = c.tokens
        logp[i, :n] = c.logps
        mask[i, :n] = True
        lengths[i] = n
        eos[i] = c.finish_reason == "eos"
        if c.ents is not None and n:
            entropy[i] = float(np.mean(c.ents[:n]))
        row_ver[i] = getattr(c, "weight_version", 0)
        tv = getattr(c, "tok_versions", None)
        # pad tail repeats the last real version so per-version rescore
        # selection is total (the tail is resp_mask-ed out of the loss)
        tok_ver[i, :] = tv[n - 1] if (tv is not None and n) else row_ver[i]
        if tv is not None and n:
            tok_ver[i, :n] = tv[:n]
    ro = RolloutBatch(
        prompt_tokens=jnp.asarray(prompt_tokens[keep], jnp.int32),
        prompt_mask=jnp.asarray(prompt_mask[keep], bool),
        resp_tokens=jnp.asarray(resp),
        resp_mask=jnp.asarray(mask),
        logp_sparse=jnp.asarray(logp),
        lengths=jnp.asarray(lengths),
        entropy=jnp.asarray(entropy))
    return TrainRollout(rollout=ro, keep=keep, finished_eos=eos,
                        stats=dict(stats or {}),
                        weight_versions=row_ver, tok_versions=tok_ver)


def sample_token(rng, logits, temperature: float, top_p: float
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (token (B,), model_logp (B,)) — logp under the untempered
    model distribution (see module docstring)."""
    model_logp_all = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    if temperature <= 0:  # greedy
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        sl = logits.astype(jnp.float32) / temperature
        if top_p < 1.0:
            sorted_logits = jnp.sort(sl, axis=-1)[:, ::-1]
            probs = jax.nn.softmax(sorted_logits, axis=-1)
            cum = jnp.cumsum(probs, axis=-1)
            cutoff_idx = jnp.sum(cum < top_p, axis=-1)           # first idx past p
            cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
            sl = jnp.where(sl >= cutoff, sl, -1e30)
        tok = jax.random.categorical(rng, sl, axis=-1).astype(jnp.int32)
    logp = jnp.take_along_axis(model_logp_all, tok[:, None], axis=-1)[:, 0]
    return tok, logp


def sample_token_per_row(keys, logits, temperature: float, top_p: float
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row variant of :func:`sample_token`: row b draws with its own key
    ``keys[b]``.  The draw depends only on (key, that row's logits) — not on
    batch size or row index — which is what lets the continuous scheduler
    place a request in any free row and still reproduce the lockstep sample
    chain (DESIGN.md §Continuous-batching).
    """
    def one(key, lg):
        tok, logp = sample_token(key, lg[None], temperature, top_p)
        return tok[0], logp[0]

    return jax.vmap(one)(keys, logits)


def fold_row_keys(row_keys: jnp.ndarray, t) -> jnp.ndarray:
    """Step keys for token index ``t`` of every row's chain: fold_in(k_b, t)."""
    return jax.vmap(lambda k: jax.random.fold_in(k, t))(row_keys)


def decode_sample_step(params, cfg: ModelConfig, mfns: ModelFns,
                       scfg: SparseRLConfig, state, logits, step_keys,
                       active, *, pad_id: int = 0,
                       per_row_keys: bool = False):
    """One decode step, shared by the lockstep `generate` scan and the
    continuous scheduler's host loop.

    Samples the next token of every row from ``logits``, feeds ``pad_id`` on
    inactive rows (finished / empty slots), and advances the model one step.
    ``step_keys`` is a single PRNG key (default) or, with ``per_row_keys``,
    (B,) already-folded per-row keys.

    Returns (state, next_logits, tok, logp, ent).  The core is EOS-agnostic:
    detection stays with the caller (carried `done` flags in lockstep;
    host-side harvest in the scheduler).
    """
    if per_row_keys:
        tok, logp = sample_token_per_row(step_keys, logits,
                                         scfg.temperature, scfg.top_p)
    else:
        tok, logp = sample_token(step_keys, logits,
                                 scfg.temperature, scfg.top_p)
    tok = jnp.where(active, tok, pad_id)
    logp = jnp.where(active, logp, 0.0)
    ent = jnp.where(active, entropy_from_logits(logits), 0.0)
    logits_next, state = mfns.decode_step(params, cfg, state, tok, scfg)
    return state, logits_next, tok, logp, ent


def rollout_slots(scfg: SparseRLConfig, prompt_len: int, max_new_tokens: int,
                  prefix_len: int = 0) -> int:
    """Cache slots per (layer, row), owned by the sampler policy's geometry
    hook (rollout.policies): the fixed sparse budget for budget policies;
    prompt + any multimodal prefix + all new tokens (+ headroom so the
    degenerate recency eviction never triggers) for dense-sized ones
    (dense, per_head, quant-*)."""
    from repro.rollout.policies import policy_for_scfg

    return policy_for_scfg(scfg).geometry(scfg, prompt_len, max_new_tokens,
                                          prefix_len)


def paged_rollout_geometry(scfg: SparseRLConfig, prompt_len: int,
                           max_new_tokens: int, block_size: int
                           ) -> Tuple[int, int]:
    """Row geometry for the paged cache backend: (seq_len, blocks_per_row).

    ``seq_len`` is exactly :func:`rollout_slots` for the same workload — the
    paged backend materializes its page chains to this many slots so the
    attention math matches the contiguous backend bit for bit (DESIGN.md
    §Paged cache & prefix sharing); ``blocks_per_row`` rounds it up to whole
    pages (the per-row block-table width).
    """
    slots = rollout_slots(scfg, prompt_len, max_new_tokens)
    return slots, -(-slots // block_size)


def generate(params, cfg: ModelConfig, mfns: ModelFns, batch: dict,
             scfg: SparseRLConfig, rng, *, max_new_tokens: int,
             eos_id: int, pad_id: int = 0,
             per_row_keys: Optional[jnp.ndarray] = None) -> RolloutBatch:
    """Sparse (or dense, per scfg.compression) rollout for a prompt batch.

    batch: the model batch dict; batch["tokens"] are left-padded prompts and
    batch["valid_mask"] marks real prompt tokens.

    ``per_row_keys`` (optional, (B,) PRNG keys) switches sampling to the
    per-row key chains used by the continuous scheduler — token t of row b
    draws with ``fold_in(per_row_keys[b], t)`` — so the same request seeds
    yield token-identical outputs here and there.  Default (None) keeps the
    historical lockstep discipline: one key per step shared across the batch.
    """
    prompt = batch["tokens"]
    B, P = prompt.shape
    pmask = batch.get("valid_mask")
    if pmask is None:
        pmask = jnp.ones((B, P), bool)
    prefix_len = (batch["prefix_embeds"].shape[1]
                  if batch.get("prefix_embeds") is not None else 0)
    slots = rollout_slots(scfg, P, max_new_tokens, prefix_len)
    last_logits, state = mfns.prefill(params, cfg, batch, scfg, slots)

    def step(carry, x_t):
        state, logits, done, ent_sum = carry
        if per_row_keys is None:
            keys_t = x_t
        else:
            keys_t = fold_row_keys(per_row_keys, x_t)
        state, logits_next, tok, logp, ent = decode_sample_step(
            params, cfg, mfns, scfg, state, logits, keys_t, ~done,
            pad_id=pad_id, per_row_keys=per_row_keys is not None)
        mask_t = ~done
        new_done = done | (tok == eos_id)
        return (state, logits_next, new_done, ent_sum + ent), (tok, logp, mask_t)

    xs = (jax.random.split(rng, max_new_tokens) if per_row_keys is None
          else jnp.arange(max_new_tokens))
    done0 = jnp.zeros((B,), bool)
    (state, _, done, ent_sum), (toks, logps, masks) = jax.lax.scan(
        step, (state, last_logits, done0, jnp.zeros((B,), jnp.float32)), xs)
    resp_tokens = jnp.moveaxis(toks, 0, 1)                       # (B, T)
    logp_sparse = jnp.moveaxis(logps, 0, 1)
    resp_mask = jnp.moveaxis(masks, 0, 1)
    lengths = jnp.sum(resp_mask, axis=-1).astype(jnp.int32)
    entropy = ent_sum / jnp.maximum(lengths.astype(jnp.float32), 1.0)
    return RolloutBatch(prompt_tokens=prompt, prompt_mask=pmask,
                        resp_tokens=resp_tokens, resp_mask=resp_mask,
                        logp_sparse=logp_sparse.astype(jnp.float32),
                        lengths=lengths, entropy=entropy)


def rescore_parts(params, cfg: ModelConfig, mfns: ModelFns,
                  prompt_tokens, prompt_mask, resp_tokens, resp_mask,
                  extra_batch: Optional[dict] = None,
                  use_flash: Optional[bool] = None) -> jnp.ndarray:
    """Teacher-forced log-probs of response tokens under a (dense,
    full-context) policy with weights ``params``.

    This single forward serves two roles (paper §3/§4): with the *sampler*
    weights it yields pi_old (dense old policy — the xi numerator); with the
    *learner* weights it yields pi_theta (differentiated in the update step).
    Returns (B, T) float32.
    """
    del resp_mask  # padding is harmless for causal left-to-right scoring
    full = jnp.concatenate([prompt_tokens, resp_tokens], axis=1)
    mask = jnp.concatenate(
        [prompt_mask, jnp.ones(resp_tokens.shape, bool)], axis=1)
    batch = {"tokens": full, "valid_mask": mask}
    if extra_batch:
        for k in ("prefix_embeds", "frames", "enc_mask"):
            if k in extra_batch:
                batch[k] = extra_batch[k]
    logits, _ = mfns.forward(params, cfg, batch, use_flash=use_flash)
    # a prefix (VLM patches) shifts logits right by its length
    offset = logits.shape[1] - full.shape[1]
    P = prompt_tokens.shape[1]
    T = resp_tokens.shape[1]
    # logits at index (offset + P - 1 + t) predict response token t
    pred = jax.lax.dynamic_slice_in_dim(logits, offset + P - 1, T, axis=1)
    return log_softmax_gather(pred, resp_tokens)


def rescore(params, cfg: ModelConfig, mfns: ModelFns, ro: RolloutBatch,
            extra_batch: Optional[dict] = None,
            use_flash: Optional[bool] = None) -> jnp.ndarray:
    """`rescore_parts` over a RolloutBatch."""
    return rescore_parts(params, cfg, mfns, ro.prompt_tokens, ro.prompt_mask,
                         ro.resp_tokens, ro.resp_mask,
                         extra_batch=extra_batch, use_flash=use_flash)


def mismatch_kl_estimate(logp_old: jnp.ndarray, logp_sparse: jnp.ndarray,
                         mask: jnp.ndarray,
                         lengths: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Monte-Carlo KL(pi_sparse || pi_old) on sampled tokens (paper Fig. 3).

    ``lengths`` (optional, (B,) response lengths) additionally masks the
    padded tail of early-exited rows.  Continuous-engine rollouts EOS at
    per-row lengths and are right-padded to the batch width; a caller-built
    mask that covers the full width (e.g. ones) would average those pad
    positions in — their ``logp_sparse`` is exactly 0 while ``logp_old`` is
    the teacher-forced log-prob of a pad token, so the estimate gets diluted
    AND biased.  Passing ``lengths`` clips the mask to real tokens so both
    backends report the same statistic.
    """
    mask = mask.astype(bool)
    if lengths is not None:
        T = logp_sparse.shape[-1]
        mask = mask & (jnp.arange(T)[None, :] < lengths[:, None])
    d = (logp_sparse - logp_old) * mask
    return jnp.sum(d) / (jnp.sum(mask) + 1e-9)
