"""Continuous-batching generation engine (slot-recycling scheduler).

The lockstep `generate` decodes every row of a batch for the full
``max_new_tokens`` — rows that hit EOS early burn compute feeding padding.
This module keeps a fixed-size decode batch *continuously* full instead: a
request queue feeds ``batch_size`` row slots; when a row finishes, its result
is harvested, its per-layer cache block is wiped (`kvcache.reset_rows`), and
the next queued prompt is prefilled (a compiled (1, P) prefill) and spliced
into the freed row — while the other rows keep decoding.

Everything on device is static-shape, so XLA compiles exactly four programs
once — bootstrap prefill, per-request prefill, admission splice, and a
``decode_chunk``-step scan of the shared :func:`decode_sample_step` core —
and admission/eviction never recompiles anything.  The sparse budget cache is
what makes the splice cheap: every row owns the same fixed
``B_budget + B_buffer`` slot block regardless of logical sequence length, so
"replace this row's sequence" is a constant-size scatter (the memory-wall
property of the source paper, exercised at serving time).

Scheduling invariants are documented in DESIGN.md §Continuous-batching:
FIFO admission of arrived requests into free rows, per-request sampling-key
chains (``fold_in(fold_in(base, uid), t)``) so outputs are independent of row
placement and co-tenants, and host-side harvest at ``decode_chunk``
granularity.

Supports every family whose ModelFns prefill/decode_step take token-only
batches (dense / hybrid / ssm, and vlm without patch prefixes); the audio
enc-dec needs per-request frames and is not wired up here.  MoE runs too,
but with a caveat: finite expert capacity ranks tokens across the *whole
batch*, so a row's outputs can depend on its co-tenants and the
token-identical-to-lockstep guarantee only holds for dropless configs
(e.g. the smoke configs; DESIGN.md §Continuous-batching).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SparseRLConfig
from repro.kvcache import KVCache, reset_rows
from repro.models import ModelFns
from repro.rollout.engine import decode_sample_step, rollout_slots


@dataclass(frozen=True)
class Request:
    """One generation request.

    ``prompt`` holds raw (unpadded) token ids, at most the engine's
    ``prompt_len``.  ``arrival_time`` is seconds on the engine's virtual
    clock (0 = available immediately); ``max_new_tokens`` caps this request
    below the engine-wide maximum when set.
    """
    uid: int
    prompt: np.ndarray
    max_new_tokens: Optional[int] = None
    arrival_time: float = 0.0


@dataclass
class Completion:
    """Harvested result + timing for one request (times on the virtual clock)."""
    uid: int
    prompt: np.ndarray
    tokens: np.ndarray          # response ids, EOS included when emitted
    logps: np.ndarray           # pi_sparse log-probs, aligned with tokens
    finish_reason: str          # "eos" | "length"
    arrival_time: float
    admit_time: float
    finish_time: float
    row: int

    @property
    def queue_wait(self) -> float:
        return self.admit_time - self.arrival_time

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival_time


@dataclass
class _RowState:
    """Host-side view of one decode row's current tenant."""
    req: Request
    admit_time: float
    tok_chunks: List[np.ndarray] = field(default_factory=list)
    logp_chunks: List[np.ndarray] = field(default_factory=list)
    n: int = 0                  # tokens emitted so far


def _batch_axis(dst_shape, src_shape) -> Optional[int]:
    """Axis where a full-batch leaf and its 1-request counterpart differ.

    A single-request decode state matches the running state on every dim
    except batch, so the (unique) differing axis IS the batch axis.  None
    means the shapes coincide (batch_size == 1: whole-leaf replacement).
    """
    diff = [i for i, (a, b) in enumerate(zip(dst_shape, src_shape)) if a != b]
    if not diff:
        return None
    if len(diff) != 1 or src_shape[diff[0]] != 1:
        raise ValueError(f"ambiguous batch axis: {dst_shape} vs {src_shape}")
    return diff[0]


def insert_request_state(state, sub_state, row):
    """Splice a 1-request decode state into ``state`` at batch index ``row``.

    Works for any family's state pytree (KVCache slot blocks, SSM recurrent
    state, position counters): each leaf's batch axis is recovered by shape
    comparison, so no per-family wiring is needed.
    """
    def one(d, s):
        ax = _batch_axis(d.shape, s.shape)
        if ax is None:
            return s.astype(d.dtype)
        idx = (slice(None),) * ax + (row,)
        return d.at[idx].set(jnp.squeeze(s, axis=ax).astype(d.dtype))

    return jax.tree.map(one, state, sub_state)


class ContinuousEngine:
    """Fixed-batch continuous-batching scheduler over the shared decode core.

    Usage::

        eng = ContinuousEngine(params, cfg, mfns, scfg, batch_size=8,
                               prompt_len=24, max_new_tokens=64,
                               eos_id=TOKENIZER.eos_id, seed=0)
        completions = eng.run(requests)

    ``decode_chunk`` trades harvest latency for dispatch overhead: the engine
    syncs with the host (EOS detection, admission) every ``decode_chunk``
    compiled steps; a finished row wastes at most ``decode_chunk - 1`` steps
    before recycling.  ``decode_chunk=1`` harvests immediately (used by the
    equivalence tests); serving workloads amortize dispatch with 8-16.
    """

    def __init__(self, params, cfg: ModelConfig, mfns: ModelFns,
                 scfg: SparseRLConfig, *, batch_size: int, prompt_len: int,
                 max_new_tokens: int, eos_id: int, pad_id: int = 0,
                 decode_chunk: int = 8, seed: int = 0):
        if decode_chunk < 1:
            raise ValueError("decode_chunk must be >= 1")
        self.params = params
        self.cfg = cfg
        self.mfns = mfns
        self.scfg = scfg
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.decode_chunk = decode_chunk
        self.slots = rollout_slots(scfg, prompt_len, max_new_tokens)
        self._base_key = jax.random.PRNGKey(seed)

        def prefill_admit(p, batch, state, logits, counts, active, row_keys,
                          row, row_key):
            """Prefill one request and splice it into ``row`` of the running
            batch — a single dispatch per admission."""
            sub_logits, sub_state = mfns.prefill(p, cfg, batch, scfg,
                                                 self.slots)
            state = insert_request_state(state, sub_state, row)
            return (state,
                    logits.at[row].set(sub_logits[0]),
                    counts.at[row].set(0),
                    active.at[row].set(True),
                    row_keys.at[row].set(row_key))

        # donations: every program rewrites the decode state in place rather
        # than copying the slot arrays (the whole point of fixed budgets)
        self._prefill_admit = jax.jit(prefill_admit,
                                      donate_argnums=(2, 3, 4, 5, 6))

        def retire(state, active, row):
            caches = getattr(state, "caches", None)
            if isinstance(caches, KVCache):
                # stacked caches carry a leading layer dim -> batch axis 1
                state = state._replace(
                    caches=reset_rows(caches, row, batch_axis=1))
            return state, active.at[row].set(False)

        self._retire = jax.jit(retire, donate_argnums=(0,))

        def park(state, active):
            caches = getattr(state, "caches", None)
            if isinstance(caches, KVCache):
                state = state._replace(caches=reset_rows(
                    caches, jnp.arange(batch_size), batch_axis=1))
            return state, jnp.zeros_like(active)

        self._park = jax.jit(park, donate_argnums=(0,))

        def chunk(p, state, logits, counts, active, row_keys):
            def step(carry, _):
                state, logits, counts = carry
                keys_t = jax.vmap(jax.random.fold_in)(row_keys, counts)
                state, logits, tok, logp, _ = decode_sample_step(
                    p, cfg, mfns, scfg, state, logits, keys_t, active,
                    pad_id=pad_id, per_row_keys=True)
                return (state, logits, counts + 1), (tok, logp)

            (state, logits, counts), (toks, logps) = jax.lax.scan(
                step, (state, logits, counts), None, length=decode_chunk)
            return state, logits, counts, toks, logps

        self._chunk = jax.jit(chunk, donate_argnums=(1, 2, 3))

        # ---- device state ----------------------------------------------
        self.state = self._bootstrap_state()
        self.logits = jnp.zeros((batch_size, cfg.vocab_size), jnp.float32)
        self.counts = jnp.zeros((batch_size,), jnp.int32)
        self.active = jnp.zeros((batch_size,), bool)
        self.row_keys = jnp.zeros((batch_size,) + self._base_key.shape,
                                  self._base_key.dtype)
        # ---- host state ------------------------------------------------
        self.rows: List[Optional[_RowState]] = [None] * batch_size
        self.now = 0.0
        self.stats: Dict[str, float] = {
            "decode_steps": 0, "chunks": 0, "admissions": 0,
            "wasted_row_steps": 0}

    # ------------------------------------------------------------------
    def _bootstrap_state(self):
        """Decode state for an all-empty batch: one batched prefill over pad
        prompts with an all-False valid mask (every cache slot comes out
        POS_EMPTY, positions start at 0)."""
        batch = {
            "tokens": jnp.full((self.batch_size, self.prompt_len),
                               self.pad_id, jnp.int32),
            "valid_mask": jnp.zeros((self.batch_size, self.prompt_len), bool),
        }
        _, state = jax.jit(
            lambda p, b: self.mfns.prefill(p, self.cfg, b, self.scfg,
                                           self.slots))(self.params, batch)
        return state

    def _encode(self, prompt: np.ndarray):
        """Left-pad one raw prompt to (1, prompt_len) + validity mask."""
        p = np.asarray(prompt, np.int32).ravel()
        if len(p) > self.prompt_len:
            raise ValueError(
                f"prompt length {len(p)} exceeds engine prompt_len "
                f"{self.prompt_len}")
        ids = np.full((1, self.prompt_len), self.pad_id, np.int32)
        ids[0, self.prompt_len - len(p):] = p
        mask = np.zeros((1, self.prompt_len), bool)
        mask[0, self.prompt_len - len(p):] = True
        return {"tokens": jnp.asarray(ids), "valid_mask": jnp.asarray(mask)}

    def _free_rows(self) -> List[int]:
        return [i for i, r in enumerate(self.rows) if r is None]

    def _num_active(self) -> int:
        return sum(r is not None for r in self.rows)

    def _cap(self, req: Request) -> int:
        if req.max_new_tokens is None:
            return self.max_new_tokens
        return min(req.max_new_tokens, self.max_new_tokens)

    def reset_clock(self) -> None:
        """Zero the virtual clock and counters (e.g. between a compile-warmup
        run and a measured run) — compiled programs and device state stay."""
        self.now = 0.0
        for k in self.stats:
            self.stats[k] = 0

    # ------------------------------------------------------------------
    def _admit_one(self, req: Request, row: int) -> None:
        """Prefill ``req`` into the freed ``row`` (single fused dispatch);
        the splice overwrites every slot of the row's cache block, so nothing
        of the previous tenant can leak even without an explicit reset."""
        row_key = jax.random.fold_in(self._base_key, req.uid)
        (self.state, self.logits, self.counts, self.active,
         self.row_keys) = self._prefill_admit(
             self.params, self._encode(req.prompt), self.state, self.logits,
             self.counts, self.active, self.row_keys, row, row_key)
        self.rows[row] = _RowState(req=req, admit_time=self.now)
        self.stats["admissions"] += 1

    def _finish_row(self, row: int, finish_reason: str,
                    out: List[Completion]) -> None:
        rs = self.rows[row]
        toks = (np.concatenate(rs.tok_chunks) if rs.tok_chunks
                else np.zeros((0,), np.int32))
        logps = (np.concatenate(rs.logp_chunks) if rs.logp_chunks
                 else np.zeros((0,), np.float32))
        out.append(Completion(
            uid=rs.req.uid, prompt=rs.req.prompt,
            tokens=toks.astype(np.int32), logps=logps.astype(np.float32),
            finish_reason=finish_reason, arrival_time=rs.req.arrival_time,
            admit_time=rs.admit_time, finish_time=self.now, row=row))
        self.rows[row] = None

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve ``requests`` to completion; returns Completions sorted by uid.

        Requests become admissible once the virtual clock passes their
        ``arrival_time``; the clock advances by the measured wall time of
        each admission/decode chunk and jumps over idle gaps, so latency
        statistics are honest service measurements without real-time sleeps.
        """
        pending = deque(sorted(requests,
                               key=lambda r: (r.arrival_time, r.uid)))
        out: List[Completion] = []
        while pending or self._num_active():
            t0 = time.perf_counter()
            # FIFO admission of arrived requests into free rows
            for row in self._free_rows():
                if not (pending and pending[0].arrival_time <= self.now):
                    break
                self._admit_one(pending.popleft(), row)
            if not self._num_active():
                # idle: jump the virtual clock to the next arrival
                self.now = max(self.now, pending[0].arrival_time)
                continue
            (self.state, self.logits, self.counts, toks, logps) = self._chunk(
                self.params, self.state, self.logits, self.counts,
                self.active, self.row_keys)
            toks_h, logps_h = jax.device_get((toks, logps))  # (chunk, B)
            self.now += time.perf_counter() - t0
            t_harvest = time.perf_counter()
            self.stats["chunks"] += 1
            self.stats["decode_steps"] += self.decode_chunk
            for row in range(self.batch_size):
                rs = self.rows[row]
                if rs is None:
                    self.stats["wasted_row_steps"] += self.decode_chunk
                    continue
                remaining = self._cap(rs.req) - rs.n
                window = toks_h[:remaining, row]
                eos_hits = np.where(window == self.eos_id)[0]
                if eos_hits.size:
                    take, finish = int(eos_hits[0]) + 1, "eos"
                elif remaining <= self.decode_chunk:
                    take, finish = remaining, "length"
                else:
                    take, finish = self.decode_chunk, None
                rs.tok_chunks.append(toks_h[:take, row])
                rs.logp_chunks.append(logps_h[:take, row])
                rs.n += take
                if finish is None:
                    continue
                self.stats["wasted_row_steps"] += self.decode_chunk - take
                self._finish_row(row, finish, out)
                # slot recycling: re-admit straight into the freed row when
                # the queue has an arrived request (the admission splice
                # overwrites the whole block); otherwise wipe it
                if pending and pending[0].arrival_time <= self.now:
                    self._admit_one(pending.popleft(), row)
                else:
                    self.state, self.active = self._retire(
                        self.state, self.active, row)
            self.now += time.perf_counter() - t_harvest
        # park: rows keep decoding pad tokens while inactive (static shapes),
        # appending garbage KVs into their freed blocks; wipe them so the
        # drained engine ends in the all-empty state
        self.state, self.active = self._park(self.state, self.active)
        return sorted(out, key=lambda c: c.uid)


# ---------------------------------------------------------------------------
# Lockstep baseline driver (shared by benchmarks, serve CLI and tests)
# ---------------------------------------------------------------------------
class LockstepServer:
    """Serve a workload with the lockstep `generate`, one static batch at a
    time: every batch decodes the full ``max_new_tokens`` regardless of
    per-request caps or early EOS (the straggler cost continuous batching
    removes).  Uses the identical per-request key chains as ContinuousEngine
    — ``fold_in(fold_in(base, uid), t)`` — so for the same seed the two
    paths are token-identical per request (the equivalence test's oracle).

    The `generate` program compiles once in ``__init__`` and is reused across
    ``run`` calls (like ContinuousEngine's programs), so warm-run timing is a
    fair scheduling comparison.
    """

    def __init__(self, params, cfg: ModelConfig, mfns: ModelFns,
                 scfg: SparseRLConfig, *, batch_size: int, prompt_len: int,
                 max_new_tokens: int, eos_id: int, pad_id: int = 0,
                 seed: int = 0):
        from repro.rollout.engine import generate

        self.params = params
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.pad_id = pad_id
        self._base_key = jax.random.PRNGKey(seed)
        self._gen = jax.jit(lambda p, b, keys: generate(
            p, cfg, mfns, b, scfg, jax.random.PRNGKey(0),
            max_new_tokens=max_new_tokens, eos_id=eos_id, pad_id=pad_id,
            per_row_keys=keys))

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        pending = deque(sorted(requests,
                               key=lambda r: (r.arrival_time, r.uid)))
        out: List[Completion] = []
        now = 0.0
        B, P = self.batch_size, self.prompt_len
        while pending:
            if pending[0].arrival_time > now:
                now = pending[0].arrival_time
            group = []
            while pending and len(group) < B \
                    and pending[0].arrival_time <= now:
                group.append(pending.popleft())
            # pad the batch to a constant shape (single compiled program)
            ids = np.full((B, P), self.pad_id, np.int32)
            mask = np.zeros((B, P), bool)
            keys = []
            for i, req in enumerate(group):
                p = np.asarray(req.prompt, np.int32).ravel()
                ids[i, P - len(p):] = p
                mask[i, P - len(p):] = True
                keys.append(jax.random.fold_in(self._base_key, req.uid))
            for _ in range(B - len(group)):
                keys.append(self._base_key)     # dummy rows, results dropped
            batch = {"tokens": jnp.asarray(ids),
                     "valid_mask": jnp.asarray(mask)}
            t0 = time.perf_counter()
            ro = self._gen(self.params, batch, jnp.stack(keys))
            jax.block_until_ready(ro.resp_tokens)
            admit = now
            now += time.perf_counter() - t0
            toks_h = np.asarray(ro.resp_tokens)
            logps_h = np.asarray(ro.logp_sparse)
            for i, req in enumerate(group):
                cap = (self.max_new_tokens if req.max_new_tokens is None
                       else min(req.max_new_tokens, self.max_new_tokens))
                row = toks_h[i, :cap]
                eos_hits = np.where(row == self.eos_id)[0]
                if len(eos_hits):
                    end, reason = eos_hits[0] + 1, "eos"
                else:
                    end, reason = cap, "length"
                out.append(Completion(
                    uid=req.uid, prompt=req.prompt,
                    tokens=row[:end].astype(np.int32),
                    logps=logps_h[i, :end].astype(np.float32),
                    finish_reason=reason, arrival_time=req.arrival_time,
                    admit_time=admit, finish_time=now, row=i))
        return sorted(out, key=lambda c: c.uid)


def serve_lockstep(params, cfg: ModelConfig, mfns: ModelFns,
                   scfg: SparseRLConfig, requests: Sequence[Request], *,
                   batch_size: int, prompt_len: int, max_new_tokens: int,
                   eos_id: int, pad_id: int = 0, seed: int = 0
                   ) -> List[Completion]:
    """One-shot convenience wrapper around :class:`LockstepServer`."""
    return LockstepServer(
        params, cfg, mfns, scfg, batch_size=batch_size, prompt_len=prompt_len,
        max_new_tokens=max_new_tokens, eos_id=eos_id, pad_id=pad_id,
        seed=seed).run(requests)
