"""Continuous-batching generation engine (slot-recycling scheduler).

The lockstep `generate` decodes every row of a batch for the full
``max_new_tokens`` — rows that hit EOS early burn compute feeding padding.
This module keeps a fixed-size decode batch *continuously* full instead: a
request queue feeds ``batch_size`` row slots; when a row finishes, its result
is harvested, its per-layer cache block is wiped (`kvcache.reset_rows`), and
the next queued prompt is prefilled (a compiled (1, P) prefill) and spliced
into the freed row — while the other rows keep decoding.

Everything on device is static-shape, so XLA compiles exactly four programs
once — bootstrap prefill, per-request prefill, admission splice, and a
``decode_chunk``-step scan of the shared :func:`decode_sample_step` core —
and admission/eviction never recompiles anything.  The sparse budget cache is
what makes the splice cheap: every row owns the same fixed
``B_budget + B_buffer`` slot block regardless of logical sequence length, so
"replace this row's sequence" is a constant-size scatter (the memory-wall
property of the source paper, exercised at serving time).

Scheduling invariants are documented in DESIGN.md §Continuous-batching:
FIFO admission of arrived requests into free rows, per-request sampling-key
chains (``fold_in(fold_in(base, uid), t)``) so outputs are independent of row
placement and co-tenants, and host-side harvest at ``decode_chunk``
granularity.

``cache_backend="paged"`` (DESIGN.md §Paged cache & prefix sharing) swaps
the per-row contiguous slot blocks for a refcount-shared block pool
(`kvcache/paged.py`): admission consults a prompt-hash prefix cache, so G
group rollouts of one prompt (GRPO sampling) prefill it once and share its
prompt pages copy-on-write — token-identically to the contiguous backend.
Where the pool does not apply (compressing policies, ssm/hybrid families)
the same flag shares prefills by splicing the cached prefill state.

Supports every family whose ModelFns prefill/decode_step take token-only
batches (dense / hybrid / ssm, and vlm without patch prefixes); the audio
enc-dec needs per-request frames and is not wired up here.  MoE runs too,
but with a caveat: finite expert capacity ranks tokens across the *whole
batch*, so a row's outputs can depend on its co-tenants and the
token-identical-to-lockstep guarantee only holds for dropless configs
(e.g. the smoke configs; DESIGN.md §Continuous-batching).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DENSE, MOE, VLM, ModelConfig, SparseRLConfig, dtype_of
from repro.kvcache import KVCache, reset_rows
from repro.kvcache.paged import (
    BlockAllocator,
    PagedKVCache,
    PoolExhausted,
    PrefixCache,
    PrefixEntry,
    copy_block,
    init_paged,
    paged_reset_rows,
    write_prompt,
)
from repro.models import ModelFns
from repro.rollout.engine import (
    decode_sample_step,
    paged_rollout_geometry,
    rollout_slots,
)


@dataclass(frozen=True)
class Request:
    """One generation request.

    ``prompt`` holds raw (unpadded) token ids, at most the engine's
    ``prompt_len``.  ``arrival_time`` is seconds on the engine's virtual
    clock (0 = available immediately); ``max_new_tokens`` caps this request
    below the engine-wide maximum when set.
    """
    uid: int
    prompt: np.ndarray
    max_new_tokens: Optional[int] = None
    arrival_time: float = 0.0


@dataclass
class Completion:
    """Harvested result + timing for one request (times on the virtual clock)."""
    uid: int
    prompt: np.ndarray
    tokens: np.ndarray          # response ids, EOS included when emitted
    logps: np.ndarray           # pi_sparse log-probs, aligned with tokens
    finish_reason: str          # "eos" | "length"
    arrival_time: float
    admit_time: float
    finish_time: float
    row: int
    ents: Optional[np.ndarray] = None  # sampling entropy per token (training
                                # telemetry; None from the lockstep server)

    @property
    def queue_wait(self) -> float:
        return self.admit_time - self.arrival_time

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival_time


@dataclass
class _RowState:
    """Host-side view of one decode row's current tenant."""
    req: Request
    admit_time: float
    tok_chunks: List[np.ndarray] = field(default_factory=list)
    logp_chunks: List[np.ndarray] = field(default_factory=list)
    ent_chunks: List[np.ndarray] = field(default_factory=list)
    n: int = 0                  # tokens emitted so far
    blocks: List[int] = field(default_factory=list)  # paged: pages this row
                                # holds a reference on (released at finish)


def _batch_axis(dst_shape, src_shape) -> Optional[int]:
    """Axis where a full-batch leaf and its 1-request counterpart differ.

    A single-request decode state matches the running state on every dim
    except batch, so the (unique) differing axis IS the batch axis.  None
    means the shapes coincide (batch_size == 1: whole-leaf replacement).
    """
    diff = [i for i, (a, b) in enumerate(zip(dst_shape, src_shape)) if a != b]
    if not diff:
        return None
    if len(diff) != 1 or src_shape[diff[0]] != 1:
        raise ValueError(f"ambiguous batch axis: {dst_shape} vs {src_shape}")
    return diff[0]


def insert_request_state(state, sub_state, row):
    """Splice a 1-request decode state into ``state`` at batch index ``row``.

    Works for any family's state pytree (KVCache slot blocks, SSM recurrent
    state, position counters): each leaf's batch axis is recovered by shape
    comparison, so no per-family wiring is needed.
    """
    def one(d, s):
        ax = _batch_axis(d.shape, s.shape)
        if ax is None:
            return s.astype(d.dtype)
        idx = (slice(None),) * ax + (row,)
        return d.at[idx].set(jnp.squeeze(s, axis=ax).astype(d.dtype))

    return jax.tree.map(one, state, sub_state)


class ContinuousEngine:
    """Fixed-batch continuous-batching scheduler over the shared decode core.

    Usage::

        eng = ContinuousEngine(params, cfg, mfns, scfg, batch_size=8,
                               prompt_len=24, max_new_tokens=64,
                               eos_id=TOKENIZER.eos_id, seed=0)
        completions = eng.run(requests)

    ``decode_chunk`` trades harvest latency for dispatch overhead: the engine
    syncs with the host (EOS detection, admission) every ``decode_chunk``
    compiled steps; a finished row wastes at most ``decode_chunk - 1`` steps
    before recycling.  ``decode_chunk=1`` harvests immediately (used by the
    equivalence tests); serving workloads amortize dispatch with 8-16.

    ``cache_backend="paged"`` enables prefix sharing (and, for dense
    transformer configs, the page pool — ``block_size`` tokens per page,
    ``pool_blocks`` total, ``prefix_entries`` LRU prompt cache capacity);
    ``stats["prefills"]`` / ``stats["prefix_hits"]`` /
    ``stats["blocks_in_use_peak"]`` and :attr:`prefix_hit_rate` report the
    sharing behaviour.
    """

    def __init__(self, params, cfg: ModelConfig, mfns: ModelFns,
                 scfg: SparseRLConfig, *, batch_size: int, prompt_len: int,
                 max_new_tokens: int, eos_id: int, pad_id: int = 0,
                 decode_chunk: int = 8, seed: int = 0,
                 cache_backend: str = "contiguous", block_size: int = 16,
                 pool_blocks: Optional[int] = None, prefix_entries: int = 32):
        if decode_chunk < 1:
            raise ValueError("decode_chunk must be >= 1")
        if cache_backend not in ("contiguous", "paged"):
            raise ValueError(f"unknown cache_backend {cache_backend!r}")
        self.params = params
        self.cfg = cfg
        self.mfns = mfns
        self.scfg = scfg
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.decode_chunk = decode_chunk
        self.slots = rollout_slots(scfg, prompt_len, max_new_tokens)
        self._base_key = jax.random.PRNGKey(seed)

        # ---- cache backend ---------------------------------------------
        # "paged" turns on admission-time prefix sharing everywhere; the
        # block-table *pool* additionally replaces the contiguous slot
        # arrays where it applies — dense compression on a transformer
        # family.  Eviction policies score slots inside a private
        # contiguous block and would tear refcount-shared pages, and
        # ssm/hybrid recurrent state is already O(1), so those combinations
        # keep the contiguous representation and share prefills by
        # splicing the cached prefill state
        # (DESIGN.md §Paged cache & prefix sharing).
        self.cache_backend = cache_backend
        self._share_prefix = cache_backend == "paged"
        self._pool_paged = (self._share_prefix
                            and scfg.compression == "none"
                            and cfg.family in (DENSE, MOE, VLM))
        self.allocator: Optional[BlockAllocator] = None
        self.prefix: Optional[PrefixCache] = None
        if self._pool_paged:
            self.block_size = block_size
            self.slots, self.blocks_per_row = paged_rollout_geometry(
                scfg, prompt_len, max_new_tokens, block_size)
            self._npb = -(-prompt_len // block_size)   # prompt pages
            self._npb_full = prompt_len // block_size  # fully-shared pages
            self._has_tail = prompt_len % block_size != 0
            if pool_blocks is None:
                # all rows resident + 4 rows' worth of slack for the
                # prefix cache (page 0 is the pinned garbage sink)
                pool_blocks = 1 + (batch_size + 4) * self.blocks_per_row
            min_blocks = 1 + batch_size * self.blocks_per_row + self._npb
            if pool_blocks < min_blocks:
                raise ValueError(
                    f"pool_blocks={pool_blocks} < minimum {min_blocks} "
                    f"(batch {batch_size} x {self.blocks_per_row} pages "
                    f"+ one cached prompt)")
            self.pool_blocks = pool_blocks
            self.allocator = BlockAllocator(pool_blocks, block_size)
            self.prefix = PrefixCache(self.allocator,
                                      max_entries=prefix_entries)
        elif self._share_prefix:
            self.prefix = PrefixCache(None, max_entries=prefix_entries)

        def prefill_admit(p, batch, state, logits, counts, active, row_keys,
                          row, row_key):
            """Prefill one request and splice it into ``row`` of the running
            batch — a single dispatch per admission."""
            sub_logits, sub_state = mfns.prefill(p, cfg, batch, scfg,
                                                 self.slots)
            state = insert_request_state(state, sub_state, row)
            return (state,
                    logits.at[row].set(sub_logits[0]),
                    counts.at[row].set(0),
                    active.at[row].set(True),
                    row_keys.at[row].set(row_key))

        # donations: every program rewrites the decode state in place rather
        # than copying the slot arrays (the whole point of fixed budgets)
        self._prefill_admit = jax.jit(prefill_admit,
                                      donate_argnums=(2, 3, 4, 5, 6))

        def prefill_admit_share(p, batch, state, logits, counts, active,
                                row_keys, row, row_key):
            """Splice-sharing miss path: like `prefill_admit`, but also
            returns the 1-request state + last-token logits so the prefix
            cache can replay the admission without re-running the model."""
            sub_logits, sub_state = mfns.prefill(p, cfg, batch, scfg,
                                                 self.slots)
            state = insert_request_state(state, sub_state, row)
            return (state,
                    logits.at[row].set(sub_logits[0]),
                    counts.at[row].set(0),
                    active.at[row].set(True),
                    row_keys.at[row].set(row_key),
                    sub_state, sub_logits[0])

        self._prefill_admit_share = jax.jit(prefill_admit_share,
                                            donate_argnums=(2, 3, 4, 5, 6))

        def admit_cached(state, logits, counts, active, row_keys, row,
                         row_key, sub_state, sub_logits_row):
            """Splice-sharing hit path: splice the cached prefill state —
            no model forward at all.  ``sub_state`` is NOT donated: the
            prefix cache reuses it for every later hit."""
            state = insert_request_state(state, sub_state, row)
            return (state,
                    logits.at[row].set(sub_logits_row),
                    counts.at[row].set(0),
                    active.at[row].set(True),
                    row_keys.at[row].set(row_key))

        self._admit_cached = jax.jit(admit_cached,
                                     donate_argnums=(0, 1, 2, 3, 4))

        if self._pool_paged:
            npb, has_tail = self._npb, self._has_tail
            P = prompt_len

            def prefill_store(p, batch, state, logits, counts, active,
                              row_keys, row, row_key, entry_blocks,
                              row_table):
                """Pool miss path: prefill once, write the prompt K/V into
                the prefix-cache page chain (duplicating the partial tail
                page into the row's private copy), and map the row's block
                table — one dispatch."""
                sub_logits, sub_state = mfns.prefill(p, cfg, batch, scfg, P)
                kp = sub_state.caches.k[:, 0]          # (L, Hkv, P, Dh)
                vp = sub_state.caches.v[:, 0]
                pp = sub_state.caches.pos[:, 0, 0]     # (L, P)
                caches = jax.vmap(
                    functools.partial(write_prompt, duplicate_tail=has_tail),
                    in_axes=(0, 0, 0, 0, None, None))(
                        state.caches, kp, vp, pp, entry_blocks,
                        row_table[npb - 1])
                caches = dataclasses.replace(
                    caches,
                    block_tables=caches.block_tables.at[:, row].set(row_table),
                    fill=caches.fill.at[:, row].set(P))
                state = state._replace(
                    caches=caches, pos=state.pos.at[row].set(sub_state.pos[0]))
                return (state,
                        logits.at[row].set(sub_logits[0]),
                        counts.at[row].set(0),
                        active.at[row].set(True),
                        row_keys.at[row].set(row_key),
                        sub_logits[0], sub_state.pos[0])

            self._prefill_store = jax.jit(prefill_store,
                                          donate_argnums=(2, 3, 4, 5, 6))

            def admit_hit(state, logits, counts, active, row_keys, row,
                          row_key, row_table, src_tail, entry_logits,
                          entry_pos):
                """Pool hit path: map the shared prompt pages into the row's
                table and copy-on-write the partial tail page — no model
                forward, no prompt K/V traffic beyond one page."""
                caches = state.caches
                if has_tail:
                    caches = copy_block(caches, src_tail, row_table[npb - 1])
                caches = dataclasses.replace(
                    caches,
                    block_tables=caches.block_tables.at[:, row].set(row_table),
                    fill=caches.fill.at[:, row].set(P))
                state = state._replace(caches=caches,
                                       pos=state.pos.at[row].set(entry_pos))
                return (state,
                        logits.at[row].set(entry_logits),
                        counts.at[row].set(0),
                        active.at[row].set(True),
                        row_keys.at[row].set(row_key))

            self._admit_hit = jax.jit(admit_hit,
                                      donate_argnums=(0, 1, 2, 3, 4))

        def retire(state, active, row):
            caches = getattr(state, "caches", None)
            if isinstance(caches, KVCache):
                # stacked caches carry a leading layer dim -> batch axis 1
                state = state._replace(
                    caches=reset_rows(caches, row, batch_axis=1))
            elif isinstance(caches, PagedKVCache):
                state = state._replace(
                    caches=paged_reset_rows(caches, row, batch_axis=1))
            return state, active.at[row].set(False)

        self._retire = jax.jit(retire, donate_argnums=(0,))

        def park(state, active):
            caches = getattr(state, "caches", None)
            if isinstance(caches, KVCache):
                state = state._replace(caches=reset_rows(
                    caches, jnp.arange(batch_size), batch_axis=1))
            elif isinstance(caches, PagedKVCache):
                state = state._replace(caches=paged_reset_rows(
                    caches, jnp.arange(batch_size), batch_axis=1))
            return state, jnp.zeros_like(active)

        self._park = jax.jit(park, donate_argnums=(0,))

        def chunk(p, state, logits, counts, active, row_keys):
            def step(carry, _):
                state, logits, counts = carry
                keys_t = jax.vmap(jax.random.fold_in)(row_keys, counts)
                state, logits, tok, logp, ent = decode_sample_step(
                    p, cfg, mfns, scfg, state, logits, keys_t, active,
                    pad_id=pad_id, per_row_keys=True)
                return (state, logits, counts + 1), (tok, logp, ent)

            (state, logits, counts), (toks, logps, ents) = jax.lax.scan(
                step, (state, logits, counts), None, length=decode_chunk)
            return state, logits, counts, toks, logps, ents

        self._chunk = jax.jit(chunk, donate_argnums=(1, 2, 3))

        # ---- device state ----------------------------------------------
        self.state = self._bootstrap_state()
        self.logits = jnp.zeros((batch_size, cfg.vocab_size), jnp.float32)
        self.counts = jnp.zeros((batch_size,), jnp.int32)
        self.active = jnp.zeros((batch_size,), bool)
        self.row_keys = jnp.zeros((batch_size,) + self._base_key.shape,
                                  self._base_key.dtype)
        # ---- host state ------------------------------------------------
        self.rows: List[Optional[_RowState]] = [None] * batch_size
        self.now = 0.0
        self.stats: Dict[str, float] = {
            "decode_steps": 0, "chunks": 0, "admissions": 0,
            "wasted_row_steps": 0, "prefills": 0, "prefix_hits": 0,
            "blocks_in_use_peak": 0, "cancelled": 0}

    # ------------------------------------------------------------------
    def _bootstrap_state(self):
        """Decode state for an all-empty batch.

        Contiguous: one batched prefill over pad prompts with an all-False
        valid mask (every cache slot comes out POS_EMPTY, positions start at
        0).  Pool-paged: built directly — an empty pool with no pages
        mapped needs no model forward."""
        if self._pool_paged:
            from repro.models.transformer import DecodeState

            one = init_paged(
                self.batch_size, self.cfg.num_kv_heads, self.pool_blocks,
                self.block_size, self.cfg.head_dim, self.blocks_per_row,
                self.slots, dtype_of(self.cfg.compute_dtype))
            caches = jax.tree.map(
                lambda x: jnp.stack([x] * self.cfg.num_layers), one)
            return DecodeState(
                caches=caches,
                pos=jnp.zeros((self.batch_size,), jnp.int32))
        batch = {
            "tokens": jnp.full((self.batch_size, self.prompt_len),
                               self.pad_id, jnp.int32),
            "valid_mask": jnp.zeros((self.batch_size, self.prompt_len), bool),
        }
        _, state = jax.jit(
            lambda p, b: self.mfns.prefill(p, self.cfg, b, self.scfg,
                                           self.slots))(self.params, batch)
        return state

    def _encode(self, prompt: np.ndarray):
        """Left-pad one raw prompt to (1, prompt_len) + validity mask."""
        p = np.asarray(prompt, np.int32).ravel()
        if len(p) > self.prompt_len:
            raise ValueError(
                f"prompt length {len(p)} exceeds engine prompt_len "
                f"{self.prompt_len}")
        ids = np.full((1, self.prompt_len), self.pad_id, np.int32)
        ids[0, self.prompt_len - len(p):] = p
        mask = np.zeros((1, self.prompt_len), bool)
        mask[0, self.prompt_len - len(p):] = True
        return {"tokens": jnp.asarray(ids), "valid_mask": jnp.asarray(mask)}

    def _free_rows(self) -> List[int]:
        return [i for i, r in enumerate(self.rows) if r is None]

    def _num_active(self) -> int:
        return sum(r is not None for r in self.rows)

    def _cap(self, req: Request) -> int:
        if req.max_new_tokens is None:
            return self.max_new_tokens
        return min(req.max_new_tokens, self.max_new_tokens)

    def reset_clock(self) -> None:
        """Zero the virtual clock and counters (e.g. between a compile-warmup
        run and a measured run) — compiled programs, device state and the
        prefix cache stay (a warm prefix cache is the realistic steady
        state; call ``self.prefix.clear()`` to measure cold)."""
        self.now = 0.0
        for k in self.stats:
            self.stats[k] = 0

    # -- RL-phase lifecycle (training backend) --------------------------
    # (contracts: DESIGN.md §Training on the continuous engine)
    def begin_phase(self, params=None, base_key=None) -> None:
        """Point the engine at this phase's learner weights and sampling key.

        Both are plain (donation-free) arguments of the compiled programs,
        so swapping them between RL phases never recompiles anything — the
        engine built at trainer init serves every phase.  Also zeroes the
        clock/counters so per-phase stats are honest.
        """
        if params is not None:
            self.params = params
        if base_key is not None:
            self._base_key = base_key
        self.reset_clock()

    def end_phase(self) -> Dict[str, float]:
        """Bulk release at RL phase end: drop every prefix-cache pin (the
        next phase's weights invalidate cached prefills anyway) and verify
        the page pool drained — a leaked refcount here would slowly eat the
        pool across phases, so it is an error, not a warning.  Returns a
        snapshot of the phase's counters."""
        if self.prefix is not None:
            self.prefix.clear()
        if self.allocator is not None:
            leaked = self.allocator.blocks_in_use
            if leaked:
                raise RuntimeError(
                    f"paged pool leak at phase end: {leaked} page(s) still "
                    f"referenced after prefix-cache clear")
        return dict(self.stats)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admissions served from the prefix cache (0 when
        sharing is off).  G same-prompt rollouts admitted back-to-back give
        (G-1)/G — the group-sampling win the paged backend exists for."""
        adm = self.stats["admissions"]
        return self.stats["prefix_hits"] / adm if adm else 0.0

    # ------------------------------------------------------------------
    def _alloc_blocks(self, n: int) -> List[int]:
        """Allocate pool pages, evicting LRU prefix-cache entries under
        pressure (their pages come back once no active row shares them)."""
        while True:
            try:
                return self.allocator.alloc(n)
            except PoolExhausted:
                if not self.prefix.evict_one():
                    raise

    def _admit_shared(self, req: Request, row: int, row_key) -> List[int]:
        """Prefix-sharing admission (cache_backend="paged").

        Pool mode — miss: prefill once, store the prompt pages refcounted in
        the prefix cache, map them (full pages shared, tail copied) into the
        row.  Hit: map the shared pages + copy-on-write the tail; the model
        prefill is skipped entirely.  Splice mode (ssm/hybrid/compressed):
        the cached 1-request prefill *state* is spliced instead of pages.
        Returns the pages the row holds references on (pool mode).
        """
        key = np.asarray(req.prompt, np.int32).tobytes()
        entry = self.prefix.lookup(key)
        if not self._pool_paged:
            if entry is None:
                (self.state, self.logits, self.counts, self.active,
                 self.row_keys, sub_state, sub_logits_row) = \
                    self._prefill_admit_share(
                        self.params, self._encode(req.prompt), self.state,
                        self.logits, self.counts, self.active, self.row_keys,
                        row, row_key)
                self.prefix.insert(key, PrefixEntry(
                    sub_state=sub_state, last_logits=sub_logits_row))
                self.stats["prefills"] += 1
            else:
                (self.state, self.logits, self.counts, self.active,
                 self.row_keys) = self._admit_cached(
                     self.state, self.logits, self.counts, self.active,
                     self.row_keys, row, row_key, entry.sub_state,
                     entry.last_logits)
                self.stats["prefix_hits"] += 1
            return []
        # pool mode: the row shares the prompt's full pages and owns the
        # rest (tail copy + generation head-room)
        n_own = self.blocks_per_row - self._npb_full
        if entry is None:
            # one atomic alloc: a PoolExhausted after a partial grab would
            # leak the grabbed pages
            blocks = self._alloc_blocks(n_own + self._npb)
            own, entry_blocks = blocks[:n_own], blocks[n_own:]
            row_table = [*entry_blocks[:self._npb_full], *own]
            for b in entry_blocks[:self._npb_full]:
                self.allocator.retain(b)
            (self.state, self.logits, self.counts, self.active,
             self.row_keys, e_logits, e_pos) = self._prefill_store(
                 self.params, self._encode(req.prompt), self.state,
                 self.logits, self.counts, self.active, self.row_keys, row,
                 row_key, jnp.asarray(entry_blocks, jnp.int32),
                 jnp.asarray(row_table, jnp.int32))
            self.prefix.insert(key, PrefixEntry(
                blocks=tuple(entry_blocks), last_logits=e_logits,
                next_pos=e_pos))
            self.stats["prefills"] += 1
        else:
            # pin the entry's whole chain FIRST: under pool pressure
            # _alloc_blocks LRU-evicts prefix entries — possibly this very
            # one — and an unpinned chain would be freed and handed back as
            # the row's own pages (the COW source included)
            pinned = list(entry.blocks[:self._npb_full])
            src_tail = entry.blocks[-1] if self._has_tail else None
            if src_tail is not None:
                pinned.append(src_tail)
            for b in pinned:
                self.allocator.retain(b)
            try:
                own = self._alloc_blocks(n_own)
            except PoolExhausted:
                for b in pinned:
                    self.allocator.release(b)
                raise
            row_table = [*entry.blocks[:self._npb_full], *own]
            (self.state, self.logits, self.counts, self.active,
             self.row_keys) = self._admit_hit(
                 self.state, self.logits, self.counts, self.active,
                 self.row_keys, row, row_key,
                 jnp.asarray(row_table, jnp.int32),
                 jnp.asarray(src_tail if src_tail is not None else 0,
                             jnp.int32),
                 entry.last_logits, entry.next_pos)
            if src_tail is not None:
                # the COW copy is enqueued; drop the temporary source pin
                # (the row keeps its refs on the shared full pages)
                self.allocator.release(src_tail)
            self.stats["prefix_hits"] += 1
        return row_table

    def _admit_one(self, req: Request, row: int) -> None:
        """Prefill ``req`` into the freed ``row`` (single fused dispatch);
        the splice overwrites every slot of the row's cache block (or remaps
        its whole block table), so nothing of the previous tenant can leak
        even without an explicit reset."""
        row_key = jax.random.fold_in(self._base_key, req.uid)
        blocks: List[int] = []
        if self._share_prefix:
            blocks = self._admit_shared(req, row, row_key)
        else:
            (self.state, self.logits, self.counts, self.active,
             self.row_keys) = self._prefill_admit(
                 self.params, self._encode(req.prompt), self.state,
                 self.logits, self.counts, self.active, self.row_keys, row,
                 row_key)
            self.stats["prefills"] += 1
        self.rows[row] = _RowState(req=req, admit_time=self.now,
                                   blocks=blocks)
        self.stats["admissions"] += 1
        if self.allocator is not None:
            self.stats["blocks_in_use_peak"] = max(
                self.stats["blocks_in_use_peak"],
                self.allocator.blocks_in_use)

    def _finish_row(self, row: int, finish_reason: str,
                    out: List[Completion]) -> None:
        rs = self.rows[row]
        toks = (np.concatenate(rs.tok_chunks) if rs.tok_chunks
                else np.zeros((0,), np.int32))
        logps = (np.concatenate(rs.logp_chunks) if rs.logp_chunks
                 else np.zeros((0,), np.float32))
        ents = (np.concatenate(rs.ent_chunks) if rs.ent_chunks
                else np.zeros((0,), np.float32))
        out.append(Completion(
            uid=rs.req.uid, prompt=rs.req.prompt,
            tokens=toks.astype(np.int32), logps=logps.astype(np.float32),
            finish_reason=finish_reason, arrival_time=rs.req.arrival_time,
            admit_time=rs.admit_time, finish_time=self.now, row=row,
            ents=ents.astype(np.float32)))
        if rs.blocks:
            # drop this row's page references; shared prompt pages stay
            # alive as long as the prefix cache (or a sibling row) pins them
            self.allocator.release_many(rs.blocks)
        self.rows[row] = None

    def _cancel_row(self, row: int) -> None:
        """Abort a row's in-flight request (group over-provisioning: a
        straggler whose group already collected its G finishers).  No
        Completion is produced; the row's pages go back to the pool and the
        slot is wiped so the next admission sees a clean row."""
        rs = self.rows[row]
        if rs.blocks:
            self.allocator.release_many(rs.blocks)
        self.rows[row] = None
        self.state, self.active = self._retire(self.state, self.active, row)
        self.stats["cancelled"] += 1

    def run(self, requests: Sequence[Request], *,
            group_size: Optional[int] = None,
            group_slack: int = 0) -> List[Completion]:
        """Serve ``requests`` to completion; returns Completions sorted by uid.

        Requests become admissible once the virtual clock passes their
        ``arrival_time``; the clock advances by the measured wall time of
        each admission/decode chunk and jumps over idle gaps, so latency
        statistics are honest service measurements without real-time sleeps.

        ``group_size``/``group_slack`` enable the RL-training group
        discipline (DESIGN.md §Training on the continuous engine): uids must
        be group-major over groups of ``group_size + group_slack`` requests
        (``gid = uid // (G + slack)``).  With slack > 0 each group is
        over-provisioned; the *first G to finish* are kept (first-G-finished
        admission) and the moment a group collects its G finishers its
        stragglers are cancelled — queued members are dropped and in-flight
        members retired — so their slots admit the next group instead of
        decoding a tail nobody will use.  Exactly G Completions per group
        come back.
        """
        track_groups = group_size is not None and group_slack > 0
        Gs = (group_size + group_slack) if track_groups else 0
        finished_in: Dict[int, int] = {}

        def group_done(uid: int) -> bool:
            return (track_groups
                    and finished_in.get(uid // Gs, 0) >= group_size)

        def on_finished(uid: int) -> None:
            """Count a finisher; on the G-th, cancel the group's stragglers
            (queued members drop, in-flight members retire)."""
            if not track_groups:
                return
            gid = uid // Gs
            finished_in[gid] = finished_in.get(gid, 0) + 1
            if finished_in[gid] != group_size:
                return
            survivors = [r for r in pending if r.uid // Gs != gid]
            if len(survivors) != len(pending):
                self.stats["cancelled"] += len(pending) - len(survivors)
                pending.clear()
                pending.extend(survivors)
            for r2, rs2 in enumerate(self.rows):
                if rs2 is not None and rs2.req.uid // Gs == gid:
                    self._cancel_row(r2)

        pending = deque(sorted(requests,
                               key=lambda r: (r.arrival_time, r.uid)))
        out: List[Completion] = []
        while pending or self._num_active():
            t0 = time.perf_counter()
            # FIFO admission of arrived requests into free rows
            for row in self._free_rows():
                if not (pending and pending[0].arrival_time <= self.now):
                    break
                self._admit_one(pending.popleft(), row)
            if not self._num_active():
                # idle: jump the virtual clock to the next arrival
                self.now = max(self.now, pending[0].arrival_time)
                continue
            (self.state, self.logits, self.counts, toks, logps,
             ents) = self._chunk(
                self.params, self.state, self.logits, self.counts,
                self.active, self.row_keys)
            toks_h, logps_h, ents_h = jax.device_get(
                (toks, logps, ents))                           # (chunk, B)
            self.now += time.perf_counter() - t0
            t_harvest = time.perf_counter()
            self.stats["chunks"] += 1
            self.stats["decode_steps"] += self.decode_chunk
            for row in range(self.batch_size):
                rs = self.rows[row]
                if rs is None:
                    self.stats["wasted_row_steps"] += self.decode_chunk
                    continue
                if group_done(rs.req.uid):
                    # a sibling finishing earlier in this sweep closed the
                    # group; this straggler's chunk is discarded
                    self._cancel_row(row)
                    continue
                remaining = self._cap(rs.req) - rs.n
                window = toks_h[:remaining, row]
                eos_hits = np.where(window == self.eos_id)[0]
                if eos_hits.size:
                    take, finish = int(eos_hits[0]) + 1, "eos"
                elif remaining <= self.decode_chunk:
                    take, finish = remaining, "length"
                else:
                    take, finish = self.decode_chunk, None
                rs.tok_chunks.append(toks_h[:take, row])
                rs.logp_chunks.append(logps_h[:take, row])
                rs.ent_chunks.append(ents_h[:take, row])
                rs.n += take
                if finish is None:
                    continue
                self.stats["wasted_row_steps"] += self.decode_chunk - take
                uid = rs.req.uid
                self._finish_row(row, finish, out)
                on_finished(uid)
                # slot recycling: re-admit straight into the freed row when
                # the queue has an arrived request (the admission splice
                # overwrites the whole block); otherwise wipe it
                if pending and pending[0].arrival_time <= self.now:
                    self._admit_one(pending.popleft(), row)
                else:
                    self.state, self.active = self._retire(
                        self.state, self.active, row)
            self.now += time.perf_counter() - t_harvest
        # park: rows keep decoding pad tokens while inactive (static shapes),
        # appending garbage KVs into their freed blocks; wipe them so the
        # drained engine ends in the all-empty state
        self.state, self.active = self._park(self.state, self.active)
        return sorted(out, key=lambda c: c.uid)


# ---------------------------------------------------------------------------
# Lockstep baseline driver (shared by benchmarks, serve CLI and tests)
# ---------------------------------------------------------------------------
class LockstepServer:
    """Serve a workload with the lockstep `generate`, one static batch at a
    time: every batch decodes the full ``max_new_tokens`` regardless of
    per-request caps or early EOS (the straggler cost continuous batching
    removes).  Uses the identical per-request key chains as ContinuousEngine
    — ``fold_in(fold_in(base, uid), t)`` — so for the same seed the two
    paths are token-identical per request (the equivalence test's oracle).

    The `generate` program compiles once in ``__init__`` and is reused across
    ``run`` calls (like ContinuousEngine's programs), so warm-run timing is a
    fair scheduling comparison.
    """

    def __init__(self, params, cfg: ModelConfig, mfns: ModelFns,
                 scfg: SparseRLConfig, *, batch_size: int, prompt_len: int,
                 max_new_tokens: int, eos_id: int, pad_id: int = 0,
                 seed: int = 0):
        from repro.rollout.engine import generate

        self.params = params
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.pad_id = pad_id
        self._base_key = jax.random.PRNGKey(seed)
        self._gen = jax.jit(lambda p, b, keys: generate(
            p, cfg, mfns, b, scfg, jax.random.PRNGKey(0),
            max_new_tokens=max_new_tokens, eos_id=eos_id, pad_id=pad_id,
            per_row_keys=keys))

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        pending = deque(sorted(requests,
                               key=lambda r: (r.arrival_time, r.uid)))
        out: List[Completion] = []
        now = 0.0
        B, P = self.batch_size, self.prompt_len
        while pending:
            if pending[0].arrival_time > now:
                now = pending[0].arrival_time
            group = []
            while pending and len(group) < B \
                    and pending[0].arrival_time <= now:
                group.append(pending.popleft())
            # pad the batch to a constant shape (single compiled program)
            ids = np.full((B, P), self.pad_id, np.int32)
            mask = np.zeros((B, P), bool)
            keys = []
            for i, req in enumerate(group):
                p = np.asarray(req.prompt, np.int32).ravel()
                ids[i, P - len(p):] = p
                mask[i, P - len(p):] = True
                keys.append(jax.random.fold_in(self._base_key, req.uid))
            for _ in range(B - len(group)):
                keys.append(self._base_key)     # dummy rows, results dropped
            batch = {"tokens": jnp.asarray(ids),
                     "valid_mask": jnp.asarray(mask)}
            t0 = time.perf_counter()
            ro = self._gen(self.params, batch, jnp.stack(keys))
            jax.block_until_ready(ro.resp_tokens)
            admit = now
            now += time.perf_counter() - t0
            toks_h = np.asarray(ro.resp_tokens)
            logps_h = np.asarray(ro.logp_sparse)
            for i, req in enumerate(group):
                cap = (self.max_new_tokens if req.max_new_tokens is None
                       else min(req.max_new_tokens, self.max_new_tokens))
                row = toks_h[i, :cap]
                eos_hits = np.where(row == self.eos_id)[0]
                if len(eos_hits):
                    end, reason = eos_hits[0] + 1, "eos"
                else:
                    end, reason = cap, "length"
                out.append(Completion(
                    uid=req.uid, prompt=req.prompt,
                    tokens=row[:end].astype(np.int32),
                    logps=logps_h[i, :end].astype(np.float32),
                    finish_reason=reason, arrival_time=req.arrival_time,
                    admit_time=admit, finish_time=now, row=i))
        return sorted(out, key=lambda c: c.uid)


def serve_lockstep(params, cfg: ModelConfig, mfns: ModelFns,
                   scfg: SparseRLConfig, requests: Sequence[Request], *,
                   batch_size: int, prompt_len: int, max_new_tokens: int,
                   eos_id: int, pad_id: int = 0, seed: int = 0
                   ) -> List[Completion]:
    """One-shot convenience wrapper around :class:`LockstepServer`."""
    return LockstepServer(
        params, cfg, mfns, scfg, batch_size=batch_size, prompt_len=prompt_len,
        max_new_tokens=max_new_tokens, eos_id=eos_id, pad_id=pad_id,
        seed=seed).run(requests)
