"""Continuous-batching generation engine (slot-recycling scheduler).

The lockstep `generate` decodes every row of a batch for the full
``max_new_tokens`` — rows that hit EOS early burn compute feeding padding.
This module keeps a fixed-size decode batch *continuously* full instead: a
request queue feeds ``batch_size`` row slots; when a row finishes, its result
is harvested, its per-layer cache block is wiped (`kvcache.reset_rows`), and
the next queued prompts are prefilled and spliced into the freed rows —
while the other rows keep decoding.

The admission hot path is *length-aware* (DESIGN.md §Chunked prefill &
fill-aware decode).  Prompts are padded to the smallest *length bucket*
that fits them — not the engine-wide ``prompt_len`` — and every scheduler
iteration packs all same-bucket admissions into ONE batched prefill
dispatch, capped at ``prefill_chunk`` prompt tokens per iteration
(Sarathi-style chunking: an admission burst is spread over successive
decode steps instead of stalling the resident batch behind one long
serial prefill train).  Bucketed positions are offset so token *i* of a
length-``n`` prompt sits at absolute position ``P - n + i`` exactly as a
full-width prefill would place it — padding contributes exact zeros to
attention, so outputs stay token-identical to the lockstep oracle.

Harvest can be *asynchronously double-buffered* (``overlap_harvest``):
chunk ``t+1`` is dispatched before chunk ``t``'s tokens are
``device_get``-ed, so host-side EOS detection, admission bookkeeping and
allocator work overlap device compute.  Each dispatched chunk carries a
snapshot of its row tenants; a row that finishes inside chunk ``t``
decodes (discarded) tokens for the chunk already in flight and is
recycled one chunk later — a pipeline bubble of up to ``decode_chunk``
steps per finish.  The flag therefore defaults OFF: it wins when
host-side bookkeeping is material next to a chunk's device time (big
batches, long responses, real accelerators with dispatch latency) and
loses on short grouped rollouts where finishes come every chunk — both
modes are token-identical, so flipping it is purely a throughput call
(measured tradeoff in DESIGN.md §Chunked prefill & fill-aware decode).

Everything on device is static-shape, so XLA compiles a small closed set of
programs once — bootstrap prefill, one batched prefill-admit program per
(bucket width, admission count) pair actually seen, admission splice, and a
``decode_chunk``-step scan of the shared :func:`decode_sample_step` core —
and admission/eviction never recompiles anything.  The sparse budget cache is
what makes the splice cheap: every row owns the same fixed
``B_budget + B_buffer`` slot block regardless of logical sequence length, so
"replace this row's sequence" is a constant-size scatter (the memory-wall
property of the source paper, exercised at serving time).

Scheduling invariants are documented in DESIGN.md §Continuous-batching:
FIFO admission of arrived requests into free rows, per-request sampling-key
chains (``fold_in(fold_in(base, uid), t)``) so outputs are independent of row
placement and co-tenants, and host-side harvest at ``decode_chunk``
granularity.

``cache_backend="paged"`` (DESIGN.md §Paged cache & prefix sharing) swaps
the per-row contiguous slot blocks for a refcount-shared block pool
(`kvcache/paged.py`): admission consults a prompt-hash prefix cache, so G
group rollouts of one prompt (GRPO sampling) prefill it once and share its
prompt pages copy-on-write — token-identically to the contiguous backend.
Where the pool does not apply (compressing policies, ssm/hybrid families)
the same flag shares prefills by splicing the cached prefill state.

Supports every family whose ModelFns prefill/decode_step take token-only
batches (dense / hybrid / ssm, and vlm without patch prefixes); the audio
enc-dec needs per-request frames and is not wired up here.  MoE runs too,
but with a caveat: finite expert capacity ranks tokens across the *whole
batch*, so a row's outputs can depend on its co-tenants and the
token-identical-to-lockstep guarantee only holds for dropless configs
(e.g. the smoke configs; DESIGN.md §Continuous-batching).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DENSE, MOE, VLM, ModelConfig, SparseRLConfig, dtype_of
from repro.kvcache import KVCache, reset_rows
from repro.kvcache.paged import (
    BlockAllocator,
    PagedKVCache,
    PoolExhausted,
    PrefixCache,
    PrefixEntry,
    copy_block,
    init_paged,
    paged_reset_rows,
    write_prompt,
)
from repro.models import ModelFns
from repro.rollout.policies import validate_engine_config
from repro.rollout.engine import (
    decode_sample_step,
    paged_rollout_geometry,
    rollout_slots,
)
# telemetry lives at the top of the package (not under repro.runtime) so
# this import can't cycle: repro.runtime's __init__ imports the trainer,
# which imports this module.  NULL is the off-mode no-op handle
# (DESIGN.md §Observability & telemetry).
from repro.telemetry import NULL as _NULL_TELEMETRY


@dataclass(frozen=True)
class Request:
    """One generation request.

    ``prompt`` holds raw (unpadded) token ids, at most the engine's
    ``prompt_len``.  ``arrival_time`` is seconds on the engine's virtual
    clock (0 = available immediately); ``max_new_tokens`` caps this request
    below the engine-wide maximum when set.
    """
    uid: int
    prompt: np.ndarray
    max_new_tokens: Optional[int] = None
    arrival_time: float = 0.0


@dataclass
class Completion:
    """Harvested result + timing for one request (times on the virtual clock)."""
    uid: int
    prompt: np.ndarray
    tokens: np.ndarray          # response ids, EOS included when emitted
    logps: np.ndarray           # pi_sparse log-probs, aligned with tokens
    finish_reason: str          # "eos" | "length"
    arrival_time: float
    admit_time: float
    finish_time: float
    row: int
    ents: Optional[np.ndarray] = None  # sampling entropy per token (training
                                # telemetry; None from the lockstep server)
    weight_version: int = 0     # engine weight version at admission (async
                                # actor-learner pipeline; 0 = never swapped)
    tok_versions: Optional[np.ndarray] = None  # per-token weight version of
                                # the params that PRODUCED the logits each
                                # token was sampled from (exact across
                                # mid-run hot-swaps; None from lockstep)

    @property
    def queue_wait(self) -> float:
        return self.admit_time - self.arrival_time

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival_time


@dataclass
class _RowState:
    """Host-side view of one decode row's current tenant."""
    req: Request
    admit_time: float
    tok_chunks: List[np.ndarray] = field(default_factory=list)
    logp_chunks: List[np.ndarray] = field(default_factory=list)
    ent_chunks: List[np.ndarray] = field(default_factory=list)
    ver_chunks: List[np.ndarray] = field(default_factory=list)  # per-token
                                # weight version (see Completion.tok_versions)
    n: int = 0                  # tokens emitted so far
    blocks: List[int] = field(default_factory=list)  # paged: pages this row
                                # holds a reference on (released at finish)
    weight_version: int = 0     # engine weight version at admission
    done: bool = False          # finished/cancelled; an in-flight chunk that
                                # still carries this tenant is discarded


def _batch_axis(dst_shape, src_shape) -> Optional[int]:
    """Axis where a full-batch leaf and its 1-request counterpart differ.

    A single-request decode state matches the running state on every dim
    except batch, so the (unique) differing axis IS the batch axis.  None
    means the shapes coincide (batch_size == 1: whole-leaf replacement).
    """
    diff = [i for i, (a, b) in enumerate(zip(dst_shape, src_shape)) if a != b]
    if not diff:
        return None
    if len(diff) != 1 or src_shape[diff[0]] != 1:
        raise ValueError(f"ambiguous batch axis: {dst_shape} vs {src_shape}")
    return diff[0]


def insert_request_state(state, sub_state, row):
    """Splice a 1-request decode state into ``state`` at batch index ``row``.

    Works for any family's state pytree (KVCache slot blocks, SSM recurrent
    state, position counters): each leaf's batch axis is recovered by shape
    comparison, so no per-family wiring is needed.
    """
    def one(d, s):
        ax = _batch_axis(d.shape, s.shape)
        if ax is None:
            return s.astype(d.dtype)
        idx = (slice(None),) * ax + (row,)
        return d.at[idx].set(jnp.squeeze(s, axis=ax).astype(d.dtype))

    return jax.tree.map(one, state, sub_state)


def sub_batch_axes(state, sub_shapes):
    """Per-leaf batch axes of ``state`` vs a 1-request state's shapes.

    ``sub_shapes`` comes from ``jax.eval_shape`` of a 1-row prefill — no
    model forward runs.  Returns a matching pytree of ints (-1 = shapes
    coincide, i.e. batch_size == 1: whole-leaf replacement).  Computed once
    at engine init, it lets the batched admission splice scatter A
    requests at once without re-deriving the axis per dispatch (and without
    the A-vs-other-dim ambiguity the 1-row shape diff never has).
    """
    def one(d, s):
        ax = _batch_axis(d.shape, s.shape)
        return -1 if ax is None else ax

    return jax.tree.map(one, state, sub_shapes)


def insert_request_states(state, sub_state, rows, axes):
    """Splice an A-request decode state into ``state`` at batch indices
    ``rows`` (the batched counterpart of :func:`insert_request_state`;
    ``axes`` from :func:`sub_batch_axes`)."""
    def one(d, s, ax):
        if ax < 0:
            return s.astype(d.dtype)
        idx = (slice(None),) * ax + (rows,)
        return d.at[idx].set(s.astype(d.dtype))

    return jax.tree.map(one, state, sub_state, axes)


def slice_request_state(sub_state, i: int, axes):
    """1-request slice (batch dim kept) of an A-batched prefill state —
    the per-request ``PrefixEntry.sub_state`` a batched splice-sharing miss
    stores for later hits."""
    def one(s, ax):
        if ax < 0:
            return s
        return jax.lax.slice_in_dim(s, i, i + 1, axis=ax)

    return jax.tree.map(one, sub_state, axes)


class ContinuousEngine:
    """Fixed-batch continuous-batching scheduler over the shared decode core.

    Usage::

        eng = ContinuousEngine(params, cfg, mfns, scfg, batch_size=8,
                               prompt_len=24, max_new_tokens=64,
                               eos_id=TOKENIZER.eos_id, seed=0)
        completions = eng.run(requests)

    ``decode_chunk`` trades harvest latency for dispatch overhead: the engine
    syncs with the host (EOS detection, admission) every ``decode_chunk``
    compiled steps; a finished row wastes at most ``decode_chunk - 1`` steps
    before recycling.  ``decode_chunk=1`` harvests immediately (used by the
    equivalence tests); serving workloads amortize dispatch with 8-16.

    ``cache_backend="paged"`` enables prefix sharing (and, for dense
    transformer configs, the page pool — ``block_size`` tokens per page,
    ``pool_blocks`` total, ``prefix_entries`` LRU prompt cache capacity);
    ``stats["prefills"]`` / ``stats["prefix_hits"]`` /
    ``stats["blocks_in_use_peak"]`` and :attr:`prefix_hit_rate` report the
    sharing behaviour.

    ``kv_quant="int8"|"fp8"`` (pool backend only) stores the page pool
    quantized with per-(page, head) scales — ~2-4x effective KV capacity
    per HBM byte (`kv_pool_stats` / ``end_phase`` report the bytes).  The
    quantized cache is a *different sampler policy*: recorded per-token
    log-probs (``logp_sparse`` downstream) come from quantized attention,
    and the trainer's dense rescore supplies pi_old, so Sparse-RL's
    xi/rejection/reweighting absorbs the mismatch (DESIGN.md §Quantized
    paged pool).
    """

    def __init__(self, params, cfg: ModelConfig, mfns: ModelFns,
                 scfg: SparseRLConfig, *, batch_size: int, prompt_len: int,
                 max_new_tokens: int, eos_id: int, pad_id: int = 0,
                 decode_chunk: int = 8, seed: int = 0,
                 cache_backend: str = "contiguous", block_size: int = 16,
                 pool_blocks: Optional[int] = None, prefix_entries: int = 32,
                 prefill_chunk: Optional[int] = None,
                 overlap_harvest: bool = False, kv_quant: str = "none",
                 telemetry=None):
        if decode_chunk < 1:
            raise ValueError("decode_chunk must be >= 1")
        # one registry-level validator owns every engine-config legality rule
        # (unknown compression/kv_quant/backend, quant-without-pool); raises
        # ValueError on any illegal combination (DESIGN.md
        # §Sampler policy registry)
        validate_engine_config(scfg, kv_quant=kv_quant,
                               cache_backend=cache_backend, family=cfg.family)
        if prefill_chunk is None:
            # enough budget to keep admission latency low (a couple of
            # full-width prompts per decode chunk) without ever letting one
            # burst monopolize an iteration
            prefill_chunk = max(2 * prompt_len, 64)
        if prefill_chunk < prompt_len:
            raise ValueError(
                f"prefill_chunk={prefill_chunk} < prompt_len={prompt_len}: "
                f"a full-length prompt could never be admitted")
        self.prefill_chunk = prefill_chunk
        self.overlap_harvest = overlap_harvest
        self.params = params
        self.cfg = cfg
        self.mfns = mfns
        self.scfg = scfg
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.decode_chunk = decode_chunk
        self.slots = rollout_slots(scfg, prompt_len, max_new_tokens)
        self._base_key = jax.random.PRNGKey(seed)

        # ---- cache backend ---------------------------------------------
        # "paged" turns on admission-time prefix sharing everywhere; the
        # block-table *pool* additionally replaces the contiguous slot
        # arrays where it applies — dense compression on a transformer
        # family.  Eviction policies score slots inside a private
        # contiguous block and would tear refcount-shared pages, and
        # ssm/hybrid recurrent state is already O(1), so those combinations
        # keep the contiguous representation and share prefills by
        # splicing the cached prefill state
        # (DESIGN.md §Paged cache & prefix sharing).
        self.cache_backend = cache_backend
        self._share_prefix = cache_backend == "paged"
        self._pool_paged = (self._share_prefix
                            and scfg.compression == "none"
                            and cfg.family in (DENSE, MOE, VLM))
        # quantized KV storage lives in the block pool: the contiguous
        # backend (and the splice-sharing families) has no per-page scale
        # home — validate_engine_config above already rejected quantization
        # without the pool (loud config error, not a silent fp fallback)
        self.kv_quant = kv_quant
        assert not (kv_quant != "none" and not self._pool_paged)
        self.allocator: Optional[BlockAllocator] = None
        self.prefix: Optional[PrefixCache] = None
        if self._pool_paged:
            self.block_size = block_size
            self.slots, self.blocks_per_row = paged_rollout_geometry(
                scfg, prompt_len, max_new_tokens, block_size)
            self._npb = -(-prompt_len // block_size)   # prompt pages
            self._npb_full = prompt_len // block_size  # fully-shared pages
            self._has_tail = prompt_len % block_size != 0
            if pool_blocks is None:
                # all rows resident + 4 rows' worth of slack for the
                # prefix cache (page 0 is the pinned garbage sink)
                pool_blocks = 1 + (batch_size + 4) * self.blocks_per_row
            min_blocks = 1 + batch_size * self.blocks_per_row + self._npb
            if pool_blocks < min_blocks:
                raise ValueError(
                    f"pool_blocks={pool_blocks} < minimum {min_blocks} "
                    f"(batch {batch_size} x {self.blocks_per_row} pages "
                    f"+ one cached prompt)")
            self.pool_blocks = pool_blocks
            self.allocator = BlockAllocator(pool_blocks, block_size)
            self.prefix = PrefixCache(self.allocator,
                                      max_entries=prefix_entries)
        elif self._share_prefix:
            self.prefix = PrefixCache(None, max_entries=prefix_entries)

        # ---- admission length buckets ----------------------------------
        # a prompt is padded to the smallest bucket width that fits it (not
        # the engine-wide P); bucketed positions are offset by P - W so the
        # produced K/V is bit-identical to a full-width prefill.  Pool mode
        # constrains widths to P - j*block_size so the uncovered left-pad
        # region is always whole pages (cleared, not written).
        if self._pool_paged:
            self._buckets = sorted(
                w for w in (prompt_len - j * block_size
                            for j in range(self._npb)) if w >= 1)
        else:
            # compressed policies select prompt slots from an
            # obs_window-query score — keep every bucket at least that wide
            # so the selection signal (and thus the kept set) is identical
            # to a full-width prefill's
            floor = 8 if scfg.compression == "none" else max(
                8, scfg.obs_window)
            w, widths = floor, []
            while w < prompt_len:
                widths.append(w)
                w *= 2
            self._buckets = widths + [prompt_len]
        # batched-admission sizes (split larger groups): bounded so the
        # compiled-program set stays small — at most |buckets| x |A| prefill
        # programs over the engine's lifetime
        self._a_sizes = [a for a in (1, 2, 4, 8) if a <= batch_size]
        self._programs: Dict[tuple, object] = {}
        # per-request sampling keys for a batch of uids, one dispatch
        self._fold_keys = jax.jit(
            lambda base, uids: jax.vmap(
                lambda u: jax.random.fold_in(base, u))(uids))

        # donations: every program rewrites the decode state in place rather
        # than copying the slot arrays (the whole point of fixed budgets)

        def admit_cached(state, logits, counts, active, row_keys, row,
                         base_key, uid, sub_state, sub_logits_row):
            """Splice-sharing hit path: splice the cached prefill state —
            no model forward at all.  ``sub_state`` is NOT donated: the
            prefix cache reuses it for every later hit.  The per-request
            sampling key is folded in here (inside the jit) so the host
            never pays an eager fold_in dispatch per hit."""
            row_key = jax.random.fold_in(base_key, uid)
            state = insert_request_state(state, sub_state, row)
            return (state,
                    logits.at[row].set(sub_logits_row),
                    counts.at[row].set(0),
                    active.at[row].set(True),
                    row_keys.at[row].set(row_key))

        self._admit_cached = jax.jit(admit_cached,
                                     donate_argnums=(0, 1, 2, 3, 4))

        # (the pool-mode hit path is the batched "hitp" program, built on
        # first use by `_admit_program` like the prefill kinds)

        def retire(state, active, row):
            caches = getattr(state, "caches", None)
            if isinstance(caches, KVCache):
                # stacked caches carry a leading layer dim -> batch axis 1
                state = state._replace(
                    caches=reset_rows(caches, row, batch_axis=1))
            elif isinstance(caches, PagedKVCache):
                state = state._replace(
                    caches=paged_reset_rows(caches, row, batch_axis=1))
            return state, active.at[row].set(False)

        self._retire = jax.jit(retire, donate_argnums=(0,))

        def park(state, active):
            caches = getattr(state, "caches", None)
            if isinstance(caches, KVCache):
                state = state._replace(caches=reset_rows(
                    caches, jnp.arange(batch_size), batch_axis=1))
            elif isinstance(caches, PagedKVCache):
                state = state._replace(caches=paged_reset_rows(
                    caches, jnp.arange(batch_size), batch_axis=1))
            return state, jnp.zeros_like(active)

        self._park = jax.jit(park, donate_argnums=(0,))

        def chunk(p, state, logits, counts, active, row_keys):
            def step(carry, _):
                state, logits, counts = carry
                keys_t = jax.vmap(jax.random.fold_in)(row_keys, counts)
                state, logits, tok, logp, ent = decode_sample_step(
                    p, cfg, mfns, scfg, state, logits, keys_t, active,
                    pad_id=pad_id, per_row_keys=True)
                return (state, logits, counts + 1), (tok, logp, ent)

            (state, logits, counts), (toks, logps, ents) = jax.lax.scan(
                step, (state, logits, counts), None, length=decode_chunk)
            return state, logits, counts, toks, logps, ents

        self._chunk = jax.jit(chunk, donate_argnums=(1, 2, 3))

        # ---- device state ----------------------------------------------
        self.state = self._bootstrap_state()
        self.logits = jnp.zeros((batch_size, cfg.vocab_size), jnp.float32)
        self.counts = jnp.zeros((batch_size,), jnp.int32)
        self.active = jnp.zeros((batch_size,), bool)
        self.row_keys = jnp.zeros((batch_size,) + self._base_key.shape,
                                  self._base_key.dtype)
        # per-leaf batch axes of a 1-request prefill state vs the running
        # state (shapes only — eval_shape runs no model).  The pool backend
        # never splices sub-states, and its hand-built PagedKVCache state
        # does not structurally match a prefill's contiguous output.
        self._sub_axes = None
        if not self._pool_paged:
            sub_shapes = jax.eval_shape(
                lambda p, b: mfns.prefill(p, cfg, b, scfg, self.slots),
                self.params, self._encode(np.zeros((1,), np.int32)))[1]
            self._sub_axes = sub_batch_axes(self.state, sub_shapes)
        # ---- host state ------------------------------------------------
        self.rows: List[Optional[_RowState]] = [None] * batch_size
        self._staged: List[tuple] = []   # (req, row, wait) awaiting flush
        self._dirty: set = set()            # finished rows not yet retired
        self.now = 0.0
        # ---- versioned weights (async actor-learner pipeline) ----------
        # `weight_version` tags the current params; `set_params` stages a
        # hot-swap that the run loop applies at the next admission-sweep
        # boundary (never mid-chunk — DESIGN.md
        # §Async pipeline & staleness correction).  `_logits_ver[b]` is the version of the
        # params that produced row b's CARRIED logits: the first token of a
        # chunk dispatched right after a swap is still sampled from
        # pre-swap logits, and per-token version accounting must say so.
        self.weight_version = 0
        self._pending_swap: Optional[tuple] = None   # (params, version)
        self._swap_lock = threading.Lock()  # guards stage vs. take-and-clear
        self._logits_ver = np.zeros((batch_size,), np.int64)
        # per-phase timing telemetry (reset with the clock)
        self._phase_waits: List[float] = []     # arrival -> admission
        self._phase_lats: List[float] = []      # arrival -> finish
        self.stats: Dict[str, float] = {
            "decode_steps": 0, "chunks": 0, "admissions": 0,
            "wasted_row_steps": 0, "prefills": 0, "prefix_hits": 0,
            "blocks_in_use_peak": 0, "cancelled": 0, "prefill_s": 0.0,
            "prefill_dispatches": 0, "prefill_tokens": 0,
            "weight_swaps": 0, "staged_peak": 0, "pool_retry_sweeps": 0}
        # ---- fault injection (None = unarmed: zero probes anywhere) -----
        # (DESIGN.md §Fault tolerance & degraded modes)
        self._fault_plan = None
        self._fault_phase = -1
        # ---- telemetry (DESIGN.md §Observability & telemetry) -----------
        # every instrumentation site goes through this handle; the NULL
        # off-mode singleton makes an uninstrumented engine bitwise- and
        # overhead-identical to the pre-telemetry build
        self.tel = telemetry if telemetry is not None else _NULL_TELEMETRY
        # optional liveness hook, called once per run() scheduling-loop
        # iteration: the async pipeline's producer installs its watchdog
        # heartbeat here so long in-engine stretches (cold XLA compiles,
        # pool-retry sweeps, big decode batches) read as progress, not as a
        # hang — and uses the same hook as a cancellation point (raising
        # unwinds run() promptly once the producer generation is stale)
        self.heartbeat = None

    # ------------------------------------------------------------------
    def _bootstrap_state(self):
        """Decode state for an all-empty batch.

        Contiguous: one batched prefill over pad prompts with an all-False
        valid mask (every cache slot comes out POS_EMPTY, positions start at
        0).  Pool-paged: built directly — an empty pool with no pages
        mapped needs no model forward."""
        if self._pool_paged:
            from repro.models.transformer import DecodeState

            one = init_paged(
                self.batch_size, self.cfg.num_kv_heads, self.pool_blocks,
                self.block_size, self.cfg.head_dim, self.blocks_per_row,
                self.slots, dtype_of(self.cfg.compute_dtype),
                quant=self.kv_quant)
            caches = jax.tree.map(
                lambda x: jnp.stack([x] * self.cfg.num_layers), one)
            return DecodeState(
                caches=caches,
                pos=jnp.zeros((self.batch_size,), jnp.int32))
        batch = {
            "tokens": jnp.full((self.batch_size, self.prompt_len),
                               self.pad_id, jnp.int32),
            "valid_mask": jnp.zeros((self.batch_size, self.prompt_len), bool),
        }
        _, state = jax.jit(
            lambda p, b: self.mfns.prefill(p, self.cfg, b, self.scfg,
                                           self.slots))(self.params, batch)
        return state

    def _encode(self, prompt: np.ndarray):
        """Left-pad one raw prompt to (1, prompt_len) + validity mask."""
        p = np.asarray(prompt, np.int32).ravel()
        if len(p) > self.prompt_len:
            raise ValueError(
                f"prompt length {len(p)} exceeds engine prompt_len "
                f"{self.prompt_len}")
        ids = np.full((1, self.prompt_len), self.pad_id, np.int32)
        ids[0, self.prompt_len - len(p):] = p
        mask = np.zeros((1, self.prompt_len), bool)
        mask[0, self.prompt_len - len(p):] = True
        return {"tokens": jnp.asarray(ids), "valid_mask": jnp.asarray(mask)}

    # -- length-aware admission -----------------------------------------
    # (DESIGN.md §Chunked prefill & fill-aware decode)
    def _bucket(self, n: int) -> int:
        """Smallest bucket width that fits an n-token prompt."""
        for w in self._buckets:
            if w >= n:
                return w
        raise ValueError(f"prompt length {n} exceeds engine prompt_len "
                         f"{self.prompt_len}")

    def _encode_many(self, prompts: Sequence[np.ndarray], width: int):
        """Left-pad A raw prompts to (A, width) + mask + offset positions.

        Positions are ``P - width + j`` so a valid token lands at the same
        absolute position a full-width prefill gives it — the bucketing is
        invisible to RoPE, the cache and the sampler (token identity)."""
        A, P = len(prompts), self.prompt_len
        ids = np.full((A, width), self.pad_id, np.int32)
        mask = np.zeros((A, width), bool)
        for i, prompt in enumerate(prompts):
            p = np.asarray(prompt, np.int32).ravel()
            if len(p) > width:
                raise ValueError(f"prompt length {len(p)} > bucket {width}")
            ids[i, width - len(p):] = p
            mask[i, width - len(p):] = True
        pos = np.broadcast_to(np.arange(P - width, P, dtype=np.int32),
                              (A, width))
        # plain numpy: the arrays cross to the device once, at the jit call
        # boundary of the admission program (no eager per-array dispatch)
        return {"tokens": ids, "valid_mask": mask, "positions": pos}

    def _admit_program(self, kind: str, width: int, A: int):
        """Compiled batched prefill-admit program for (bucket, count).

        Built on first use and cached — the set is bounded by
        |buckets| x |A sizes| x 3 kinds.  All kinds prefill an (A, width)
        prompt batch in ONE model forward and scatter the A requests into
        their rows in the same dispatch:

          * ``admit``  — plain contiguous splice (no sharing),
          * ``share``  — splice-sharing miss: also returns per-request
            1-row sub-states + last logits for the prefix cache,
          * ``store``  — pool miss: writes the prompt K/V into each
            request's page chain (partial-chain `write_prompt`: the
            bucketed width leaves the leading pad pages cleared, not
            written) and maps the rows' block tables,
          * ``hitp``   — A pool HITS in one dispatch (``width`` ignored):
            maps each hit's shared pages + copy-on-writes its tail — the
            dominant admission kind under GRPO group sampling ((G-1)/G of
            admissions), so batching it saves most of the per-admission
            dispatch overhead.
        """
        key = (kind, width, A)
        prog = self._programs.get(key)
        if prog is not None:
            return prog
        mfns, cfg, scfg = self.mfns, self.cfg, self.scfg

        if kind in ("admit", "share"):
            axes = self._sub_axes

            def admit(p, batch, state, logits, counts, active, row_keys,
                      rows, keys):
                sub_logits, sub_state = mfns.prefill(p, cfg, batch, scfg,
                                                     self.slots)
                state = insert_request_states(state, sub_state, rows, axes)
                outs = (state,
                        logits.at[rows].set(sub_logits),
                        counts.at[rows].set(0),
                        active.at[rows].set(True),
                        row_keys.at[rows].set(keys))
                if kind == "share":
                    subs = [slice_request_state(sub_state, i, axes)
                            for i in range(A)]
                    return outs + (subs, sub_logits)
                return outs

            prog = jax.jit(admit, donate_argnums=(2, 3, 4, 5, 6))
        elif kind == "store":
            P, npb, has_tail = self.prompt_len, self._npb, self._has_tail
            skip = (P - width) // self.block_size   # leading pad-only pages

            def store(p, batch, state, logits, counts, active, row_keys,
                      rows, keys, entry_blocks, row_tables):
                sub_logits, sub_state = mfns.prefill(p, cfg, batch, scfg,
                                                     width)
                caches = state.caches
                wp = functools.partial(write_prompt,
                                       duplicate_tail=has_tail,
                                       skip_pages=skip)
                for i in range(A):
                    kp = sub_state.caches.k[:, i]      # (L, Hkv, W, Dh)
                    vp = sub_state.caches.v[:, i]
                    pp = sub_state.caches.pos[:, i, 0]  # (L, W)
                    caches = jax.vmap(wp, in_axes=(0, 0, 0, 0, None, None))(
                        caches, kp, vp, pp, entry_blocks[i],
                        row_tables[i, npb - 1])
                caches = dataclasses.replace(
                    caches,
                    block_tables=caches.block_tables.at[:, rows].set(
                        row_tables),
                    fill=caches.fill.at[:, rows].set(P))
                state = state._replace(
                    caches=caches, pos=state.pos.at[rows].set(sub_state.pos))
                return (state,
                        logits.at[rows].set(sub_logits),
                        counts.at[rows].set(0),
                        active.at[rows].set(True),
                        row_keys.at[rows].set(keys),
                        sub_logits, sub_state.pos)

            prog = jax.jit(store, donate_argnums=(2, 3, 4, 5, 6))
        elif kind == "hitp":
            P, npb, has_tail = self.prompt_len, self._npb, self._has_tail

            def hitp(state, logits, counts, active, row_keys, rows,
                     base_key, uids, row_tables, src_tails, e_logits, e_pos):
                """A batched pool hits: per-request keys folded in-jit;
                ``e_logits``/``e_pos`` arrive as A-tuples of the entries'
                cached arrays and stack on device."""
                keys = jax.vmap(
                    lambda u: jax.random.fold_in(base_key, u))(uids)
                caches = state.caches
                if has_tail:
                    caches = copy_block(caches, src_tails,
                                        row_tables[:, npb - 1])
                caches = dataclasses.replace(
                    caches,
                    block_tables=caches.block_tables.at[:, rows].set(
                        row_tables),
                    fill=caches.fill.at[:, rows].set(P))
                state = state._replace(
                    caches=caches,
                    pos=state.pos.at[rows].set(jnp.stack(e_pos)))
                return (state,
                        logits.at[rows].set(jnp.stack(e_logits)),
                        counts.at[rows].set(0),
                        active.at[rows].set(True),
                        row_keys.at[rows].set(keys))

            prog = jax.jit(hitp, donate_argnums=(0, 1, 2, 3, 4))
        else:
            raise ValueError(f"unknown admission program kind {kind!r}")
        self._programs[key] = prog
        return prog

    def _free_rows(self) -> List[int]:
        return [i for i, r in enumerate(self.rows) if r is None]

    def _num_active(self) -> int:
        return sum(r is not None for r in self.rows)

    def _cap(self, req: Request) -> int:
        if req.max_new_tokens is None:
            return self.max_new_tokens
        return min(req.max_new_tokens, self.max_new_tokens)

    def set_telemetry(self, telemetry) -> None:
        """Swap the telemetry handle (``None`` restores the off-mode
        NULL).  Used by benchmarks to measure the same warm engine with
        and without metrics; safe between phases, not mid-run."""
        self.tel = telemetry if telemetry is not None else _NULL_TELEMETRY

    def reset_clock(self) -> None:
        """Zero the virtual clock and counters (e.g. between a compile-warmup
        run and a measured run) — compiled programs, device state and the
        prefix cache stay (a warm prefix cache is the realistic steady
        state; call ``self.prefix.clear()`` to measure cold)."""
        self.now = 0.0
        for k in self.stats:
            self.stats[k] = 0
        self._phase_waits = []
        self._phase_lats = []

    # -- RL-phase lifecycle (training backend) --------------------------
    # (contracts: DESIGN.md §Training on the continuous engine)
    def begin_phase(self, params=None, base_key=None,
                    weight_version: Optional[int] = None) -> None:
        """Point the engine at this phase's learner weights and sampling key.

        Both are plain (donation-free) arguments of the compiled programs,
        so swapping them between RL phases never recompiles anything — the
        engine built at trainer init serves every phase.  Also zeroes the
        clock/counters so per-phase stats are honest.  ``weight_version``
        tags the weights for the async pipeline's per-request staleness
        accounting (sync callers may ignore it: version stays 0).
        """
        if params is not None:
            self.params = params
        if base_key is not None:
            self._base_key = base_key
        if weight_version is not None:
            self.weight_version = weight_version
        # a swap staged before this phase is subsumed by an equal-or-newer
        # explicit handoff; a strictly newer pending swap still applies at
        # the first sweep
        with self._swap_lock:
            if (self._pending_swap is not None
                    and self._pending_swap[1] <= self.weight_version):
                self._pending_swap = None
        self.reset_clock()

    def set_params(self, params, weight_version: int) -> None:
        """Stage a mid-run weight hot-swap (async learner -> actor handoff).

        Callable from another thread; the stage and the run loop's
        take-and-clear share a lock so a swap staged concurrently with a
        sweep's apply can never be silently dropped.  The swap is applied
        ONLY at a sweep boundary — never inside a dispatched decode chunk
        — so every in-flight row's per-request key chain and per-token
        version accounting stay intact, and newly admitted groups always
        sample from the freshest snapshot (DESIGN.md §Async pipeline &
        staleness correction).
        """
        with self._swap_lock:
            self._pending_swap = (params, weight_version)

    def _apply_pending_swap(self) -> None:
        """Apply a staged hot-swap at an admission-sweep boundary.

        Cached prefills were computed under the outgoing weights, so the
        prefix cache is invalidated with the swap (its pins drop; rows
        still decoding keep their own page refs).  The first post-swap
        admission of each group therefore re-prefills its prompt — the
        price of freshness, visible as a hit-rate dip in the phase stats.
        """
        with self._swap_lock:
            swap = self._pending_swap
            self._pending_swap = None
        if swap is None:
            return
        params, version = swap
        if version <= self.weight_version:
            return
        self.params = params
        self.weight_version = version
        if self.prefix is not None:
            self.prefix.clear()
        self.stats["weight_swaps"] += 1
        self.tel.instant("weight_swap", version=version)
        self.tel.log.event("weight_swap", level="debug", version=version)

    def end_phase(self) -> Dict[str, float]:
        """Bulk release at RL phase end: drop every prefix-cache pin (the
        next phase's weights invalidate cached prefills anyway) and verify
        the page pool drained — a leaked refcount here would slowly eat the
        pool across phases, so it is an error, not a warning.  Returns a
        snapshot of the phase's counters plus derived pool/queueing
        telemetry: peak pool usage (absolute and as a fraction of the
        pool), peak admission-staging depth, and p50/p99 of per-request
        admission wait and latency on the virtual clock."""
        if self.prefix is not None:
            self.prefix.clear()
        if self.allocator is not None:
            leaked = self.allocator.blocks_in_use
            if leaked:
                raise RuntimeError(
                    f"paged pool leak at phase end: {leaked} page(s) still "
                    f"referenced after prefix-cache clear")
        stats = dict(self.stats)
        if self.allocator is not None:
            stats["pool_blocks"] = self.pool_blocks
            stats["pool_peak_frac"] = (self.stats["blocks_in_use_peak"]
                                       / max(self.pool_blocks, 1))
            stats.update(self.kv_pool_stats())
        if self._phase_waits:
            w = np.asarray(self._phase_waits)
            stats["admit_wait_p50"] = float(np.percentile(w, 50))
            stats["admit_wait_p99"] = float(np.percentile(w, 99))
        if self._phase_lats:
            lt = np.asarray(self._phase_lats)
            stats["latency_p50"] = float(np.percentile(lt, 50))
            stats["latency_p99"] = float(np.percentile(lt, 99))
        self._publish_metrics(stats)
        return stats

    def _publish_metrics(self, stats: Dict[str, float]) -> None:
        """Fold the phase's counters and distributions into the telemetry
        registry — the single sink the trace report and dashboards read
        (DESIGN.md §Observability & telemetry).  Counters accumulate
        across phases (the per-phase dict stays the per-phase view);
        waits/latencies feed histograms so cross-phase percentiles come
        from the pooled samples, not averaged per-phase percentiles."""
        if not self.tel.metrics_on:
            return
        counted = ("decode_steps", "chunks", "admissions",
                   "wasted_row_steps", "prefills", "prefix_hits",
                   "cancelled", "prefill_dispatches", "prefill_tokens",
                   "weight_swaps", "pool_retry_sweeps")
        for k in counted:
            self.tel.count(f"engine.{k}", self.stats[k])
        self.tel.count("engine.prefill_s", self.stats["prefill_s"])
        if self._phase_waits:
            self.tel.observe("engine.admit_wait", self._phase_waits)
        if self._phase_lats:
            self.tel.observe("engine.latency", self._phase_lats)
        if self.allocator is not None:
            self.tel.gauge("engine.pool_blocks", self.pool_blocks)
            self.tel.gauge("engine.pool_peak_frac",
                           stats.get("pool_peak_frac", 0.0))

    def abort_phase(self) -> None:
        """Force the engine back to the drained state after its driving
        thread died mid-phase (watchdog recovery, DESIGN.md §Fault
        tolerance & degraded modes): drop staged admissions, cancel every
        resident tenant and release its pages, drop every prefix-cache pin,
        and park the device state.  Safe on an already-clean engine.  The
        next ``begin_phase`` then replays the phase from its barrier —
        token-identical, because per-phase base keys are ``fold_in(root,
        step)`` and per-request chains are ``fold_in(base, uid)``: nothing
        about the aborted attempt leaks into the retry's sampling."""
        self._staged.clear()
        for row, rs in enumerate(self.rows):
            if rs is None:
                continue
            if rs.blocks and self.allocator is not None:
                self.allocator.release_many(rs.blocks)
            rs.done = True
            self.rows[row] = None
        self._dirty.clear()
        if self.prefix is not None:
            self.prefix.clear()
        if self.allocator is not None and self.allocator.blocks_in_use:
            raise RuntimeError(
                f"paged pool leak across phase abort: "
                f"{self.allocator.blocks_in_use} page(s) still referenced")
        self.state, self.active = self._park(self.state, self.active)
        self._logits_ver[:] = self.weight_version
        self.reset_clock()

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admissions served from the prefix cache (0 when
        sharing is off).  G same-prompt rollouts admitted back-to-back give
        (G-1)/G — the group-sampling win the paged backend exists for."""
        adm = self.stats["admissions"]
        return self.stats["prefix_hits"] / adm if adm else 0.0

    def kv_pool_stats(self) -> Dict[str, float]:
        """Effective pool size under the configured ``kv_quant``:
        K/V payload bytes per layer (codes + per-page scales), bytes per
        resident pool token, and the capacity ratio vs an fp pool of the
        same block count (>= 1.8 for int8 is the quantization acceptance
        bar — the bytes-per-token attack on the rollout memory wall)."""
        assert self._pool_paged
        caches = self.state.caches          # leading stacked layer dim
        L = self.cfg.num_layers
        payload = (caches.k_pool.nbytes + caches.v_pool.nbytes) / L
        if caches.k_scale is not None:
            payload += (caches.k_scale.nbytes + caches.v_scale.nbytes) / L
        tokens = self.pool_blocks * self.block_size
        fp_payload = (2 * self.pool_blocks * self.cfg.num_kv_heads
                      * self.block_size * self.cfg.head_dim
                      * jnp.dtype(dtype_of(self.cfg.compute_dtype)).itemsize)
        return dict(kv_pool_bytes_per_layer=float(payload),
                    kv_bytes_per_token=float(payload / tokens),
                    kv_capacity_ratio=float(fp_payload / payload))

    # ------------------------------------------------------------------
    def arm_faults(self, plan, phase: int) -> None:
        """Arm fault injection for the coming phase (``plan=None`` disarms).

        The engine probes the plan only from sites already guarded by
        ``self._fault_plan is not None``, so an unarmed engine runs the
        exact pre-fault instruction stream (the bitwise no-op contract of
        DESIGN.md §Fault tolerance & degraded modes).
        """
        self._fault_plan = plan
        self._fault_phase = int(phase)

    def _alloc_blocks(self, n: int) -> List[int]:
        """Allocate pool pages, evicting LRU prefix-cache entries under
        pressure (their pages come back once no active row shares them)."""
        if self._fault_plan is not None and self._fault_plan.fire(
                "pool_exhausted_storm", self._fault_phase):
            raise PoolExhausted(
                f"injected pool-exhaustion storm @phase={self._fault_phase}")
        while True:
            try:
                return self.allocator.alloc(n)
            except PoolExhausted:
                if not self.prefix.evict_one():
                    raise

    # -- staged batched admission ---------------------------------------
    def _stage_admit(self, req: Request, row: int) -> None:
        """Reserve ``row`` for ``req``; the actual prefill happens at the
        next :meth:`_flush_admissions` (batched with co-staged requests).
        Any pending weight swap was applied at the top of this sweep, so
        the recorded admission version is the version the flush's prefill
        will actually run under."""
        self.rows[row] = _RowState(req=req, admit_time=self.now,
                                   weight_version=self.weight_version)
        self._logits_ver[row] = self.weight_version
        # the exact recorded wait rides the staged tuple so a PoolExhausted
        # unwind retracts THIS entry — recomputing `now - arrival` at unwind
        # time could remove a different duplicate or miss entirely once the
        # clock has advanced
        wait = self.now - req.arrival_time
        self._phase_waits.append(wait)
        self._dirty.discard(row)
        self._staged.append((req, row, wait))
        self.stats["staged_peak"] = max(self.stats["staged_peak"],
                                        len(self._staged))

    def _admit_one(self, req: Request, row: int) -> None:
        """Immediate single-request admission (stage + flush).  The splice
        overwrites every slot of the row's cache block (or remaps its whole
        block table), so nothing of the previous tenant can leak even
        without an explicit reset."""
        self._stage_admit(req, row)
        self._flush_admissions()

    def _admit_cost(self, req: Request, staged_keys: set) -> int:
        """Prefill-chunk budget cost of admitting ``req`` now: the bucket
        width for a prompt that must be prefilled, 0 for a prefix-cache hit
        (no model forward) or a duplicate of a co-staged prompt (it rides
        the sibling's prefill)."""
        n = len(np.asarray(req.prompt, np.int32).ravel())
        if not self._share_prefix:
            return self._bucket(n)
        key = np.asarray(req.prompt, np.int32).tobytes()
        if key in staged_keys or self.prefix.contains(key):
            return 0
        staged_keys.add(key)
        return self._bucket(n)

    def _flush_admissions(self) -> None:
        """Dispatch every staged admission, batched by length bucket.

        On ``PoolExhausted`` the already-dispatched admissions stand, the
        failed and not-yet-dispatched ones are unwound (their rows revert
        to free AND are retired on device: staging had cleared their
        deferred-retire marker, and a row left active with a table mapping
        released pages would append into the next tenant's pages) and the
        exception propagates — exactly the single-request unwind contract,
        extended to a batch.  The unwound ``Request`` objects ride out on
        the exception (``e.unadmitted``, staging order) so `run` can
        re-queue them for a later sweep instead of losing them — pool
        exhaustion under load is transient, not fatal (DESIGN.md
        §Fault tolerance & degraded modes); their wait-telemetry entries
        are retracted and re-recorded when they actually admit."""
        if not self._staged:
            return
        t0 = time.perf_counter()
        staged, self._staged = self._staged, []
        admitted: set = set()
        try:
            if self._share_prefix:
                self._flush_shared(staged, admitted)
            else:
                self._flush_plain(staged, admitted)
        except PoolExhausted as e:
            unadmitted = []
            for req, row, wait in staged:
                if req.uid not in admitted:
                    self.rows[row] = None
                    self._dirty.discard(row)
                    self.state, self.active = self._retire(
                        self.state, self.active, row)
                    self._phase_waits.remove(wait)
                    unadmitted.append(req)
            e.unadmitted = unadmitted
            raise
        finally:
            self.stats["prefill_s"] += time.perf_counter() - t0
            if self.allocator is not None:
                self.stats["blocks_in_use_peak"] = max(
                    self.stats["blocks_in_use_peak"],
                    self.allocator.blocks_in_use)
                self.tel.counter_sample("engine.pool_blocks_in_use",
                                        self.allocator.blocks_in_use)

    def _split_batches(self, group):
        """Split one bucket's admissions into compiled batch sizes."""
        while group:
            a = next(x for x in reversed(self._a_sizes) if x <= len(group))
            yield group[:a]
            group = group[a:]

    def _flush_plain(self, staged, admitted) -> None:
        by_w: Dict[int, list] = {}
        for req, row, _ in staged:
            w = self._bucket(len(np.asarray(req.prompt, np.int32).ravel()))
            by_w.setdefault(w, []).append((req, row))
        for w in sorted(by_w):
            for part in self._split_batches(by_w[w]):
                self._dispatch_plain(w, part, admitted)

    def _dispatch_plain(self, width: int, part, admitted) -> None:
        reqs = [req for req, _ in part]
        rows = np.asarray([row for _, row in part], np.int32)
        keys = self._fold_keys(self._base_key,
                               np.asarray([r.uid for r in reqs], np.int32))
        prog = self._admit_program("admit", width, len(part))
        with self.tel.span("prefill_dispatch", kind="admit", width=width,
                           a=len(part)):
            (self.state, self.logits, self.counts, self.active,
             self.row_keys) = prog(
                 self.params,
                 self._encode_many([r.prompt for r in reqs], width),
                 self.state, self.logits, self.counts, self.active,
                 self.row_keys, rows, keys)
        for req, _ in part:
            admitted.add(req.uid)
        self.stats["prefills"] += len(part)
        self.stats["admissions"] += len(part)
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += width * len(part)

    def _pin_entry(self, entry: PrefixEntry) -> List[int]:
        """Pin a pool entry's chain (full pages + the COW tail source) so
        LRU eviction under this flush's later allocation pressure cannot
        free pages about to be mapped — an unpinned chain could be freed
        and handed straight back as another row's append pages (silent KV
        corruption).  The full-page pins become the row's own refs at
        dispatch; the tail pin is dropped once the COW copy is enqueued."""
        pinned = list(entry.blocks[:self._npb_full])
        if self._has_tail:
            pinned.append(entry.blocks[-1])
        for b in pinned:
            self.allocator.retain(b)
        return pinned

    def _flush_shared(self, staged, admitted) -> None:
        """Prefix-sharing flush: hits splice cached prefills (no model
        forward — pool hits in batched ``hitp`` dispatches); distinct
        missed prompts batch into bucketed prefills; co-staged duplicates
        of a miss defer until the miss lands, then ride the same hit batch
        — G same-prompt group rollouts staged together still cost exactly
        ONE prefill.

        Hit entries are pinned at classification time, and each created
        miss entry with deferred members is pinned the moment it exists —
        always BEFORE the next allocation could LRU-evict it."""
        hit_jobs, miss_groups, order, created = [], {}, [], {}
        for req, row, _ in staged:
            key = np.asarray(req.prompt, np.int32).tobytes()
            if key in miss_groups:
                miss_groups[key].append((req, row))
                continue
            entry = self.prefix.lookup(key)
            if entry is None:
                miss_groups[key] = [(req, row)]
                order.append(key)
            else:
                pins = self._pin_entry(entry) if self._pool_paged else []
                hit_jobs.append((req, row, entry, pins))
        by_w: Dict[int, list] = {}
        for key in order:
            req, row = miss_groups[key][0]
            w = self._bucket(len(np.asarray(req.prompt, np.int32).ravel()))
            by_w.setdefault(w, []).append((key, req, row))
        try:
            for w in sorted(by_w):
                for part in self._split_batches(by_w[w]):
                    if self._pool_paged:
                        self._dispatch_store(w, part, admitted, created)
                    else:
                        self._dispatch_share(w, part, admitted, created)
                    for key, _, _ in part:
                        for req2, row2 in miss_groups[key][1:]:
                            entry = created[key]
                            pins = (self._pin_entry(entry)
                                    if self._pool_paged else [])
                            hit_jobs.append((req2, row2, entry, pins))
            if self._pool_paged:
                self._dispatch_hits_pool(hit_jobs, admitted)
            else:
                for req, row, entry, _ in hit_jobs:
                    self._admit_hit_splice(req, row, entry)
                    admitted.add(req.uid)
        except PoolExhausted:
            # drop the pins of every hit job that never dispatched
            for req, _, _, pins in hit_jobs:
                if req.uid not in admitted and pins:
                    self.allocator.release_many(pins)
            raise

    def _dispatch_share(self, width: int, part, admitted, created) -> None:
        """Splice-sharing miss batch: one (A, W) prefill; per-request 1-row
        sub-states come back for the prefix cache."""
        reqs = [req for _, req, _ in part]
        rows = np.asarray([row for _, _, row in part], np.int32)
        keys = self._fold_keys(self._base_key,
                               np.asarray([r.uid for r in reqs], np.int32))
        prog = self._admit_program("share", width, len(part))
        with self.tel.span("prefill_dispatch", kind="share", width=width,
                           a=len(part)):
            (self.state, self.logits, self.counts, self.active,
             self.row_keys, subs, sub_logits) = prog(
                 self.params,
                 self._encode_many([r.prompt for r in reqs], width),
                 self.state, self.logits, self.counts, self.active,
                 self.row_keys, rows, keys)
        for i, (key, req, _) in enumerate(part):
            entry = PrefixEntry(sub_state=subs[i], last_logits=sub_logits[i])
            self.prefix.insert(key, entry)
            created[key] = entry           # deferred hits splice from the
            admitted.add(req.uid)          # object even if later evicted
        self.stats["prefills"] += len(part)
        self.stats["admissions"] += len(part)
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += width * len(part)

    def _dispatch_store(self, width: int, part, admitted, created) -> None:
        """Pool miss batch: allocate every request's chain first (so a
        PoolExhausted mid-batch dispatches nothing and leaks nothing), then
        one (A, W) prefill writes all the prompt page chains."""
        n_own = self.blocks_per_row - self._npb_full
        allocs = []                        # (blocks, entry_blocks, row_table)
        try:
            for _ in part:
                blocks = self._alloc_blocks(n_own + self._npb)
                own, entry_blocks = blocks[:n_own], blocks[n_own:]
                row_table = [*entry_blocks[:self._npb_full], *own]
                allocs.append((blocks, entry_blocks, row_table))
        except PoolExhausted:
            for blocks, _, _ in allocs:
                self.allocator.release_many(blocks)
            raise
        reqs = [req for _, req, _ in part]
        rows = np.asarray([row for _, _, row in part], np.int32)
        keys = self._fold_keys(self._base_key,
                               np.asarray([r.uid for r in reqs], np.int32))
        for _, entry_blocks, _ in allocs:
            for b in entry_blocks[:self._npb_full]:
                self.allocator.retain(b)   # the row's refs on shared pages
        prog = self._admit_program("store", width, len(part))
        with self.tel.span("prefill_dispatch", kind="store", width=width,
                           a=len(part)):
            (self.state, self.logits, self.counts, self.active,
             self.row_keys, e_logits, e_pos) = prog(
                 self.params,
                 self._encode_many([r.prompt for r in reqs], width),
                 self.state, self.logits, self.counts, self.active,
                 self.row_keys, rows, keys,
                 np.asarray([eb for _, eb, _ in allocs], np.int32),
                 np.asarray([rt for _, _, rt in allocs], np.int32))
        for i, (key, req, row) in enumerate(part):
            _, entry_blocks, row_table = allocs[i]
            entry = PrefixEntry(
                blocks=tuple(entry_blocks), last_logits=e_logits[i],
                next_pos=e_pos[i])
            self.prefix.insert(key, entry)
            created[key] = entry
            self.rows[row].blocks = list(row_table)
            admitted.add(req.uid)
        self.stats["prefills"] += len(part)
        self.stats["admissions"] += len(part)
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += width * len(part)

    def _admit_hit_splice(self, req: Request, row: int, entry: PrefixEntry
                          ) -> None:
        """Splice-mode prefix hit: splice the cached 1-row prefill state —
        no model forward."""
        (self.state, self.logits, self.counts, self.active,
         self.row_keys) = self._admit_cached(
             self.state, self.logits, self.counts, self.active,
             self.row_keys, row, self._base_key, req.uid,
             entry.sub_state, entry.last_logits)
        self.stats["prefix_hits"] += 1
        self.stats["admissions"] += 1

    def _dispatch_hits_pool(self, jobs, admitted) -> None:
        """Pool prefix hits, batched: each row shares its entry's full
        pages (the pre-taken pins become the row's refs) and owns the rest
        (tail copy + generation head-room).  Own-page allocation may
        LRU-evict prefix entries under pressure — the pins taken at
        classification time are what keeps every mapped chain alive.  On
        exhaustion the allocatable prefix dispatches; the rest unwinds in
        the caller."""
        n_own = self.blocks_per_row - self._npb_full
        ready, exhausted = [], None
        for req, row, entry, pins in jobs:
            try:
                own = self._alloc_blocks(n_own)
            except PoolExhausted as e:
                exhausted = e
                break
            ready.append((req, row, entry, own))
        for part in self._split_batches(ready):
            prog = self._admit_program("hitp", 0, len(part))
            rows = np.asarray([row for _, row, _, _ in part], np.int32)
            uids = np.asarray([req.uid for req, _, _, _ in part], np.int32)
            tables = np.asarray(
                [[*e.blocks[:self._npb_full], *own]
                 for _, _, e, own in part], np.int32)
            tails = np.asarray(
                [e.blocks[-1] if self._has_tail else 0
                 for _, _, e, _ in part], np.int32)
            with self.tel.span("prefill_dispatch", kind="hitp",
                               a=len(part)):
                (self.state, self.logits, self.counts, self.active,
                 self.row_keys) = prog(
                     self.state, self.logits, self.counts, self.active,
                     self.row_keys, rows, self._base_key, uids, tables,
                     tails,
                     tuple(e.last_logits for _, _, e, _ in part),
                     tuple(e.next_pos for _, _, e, _ in part))
            for req, row, entry, own in part:
                if self._has_tail:
                    # the COW copy is enqueued; drop the temporary source
                    # pin (the row keeps its refs on the shared full pages)
                    self.allocator.release(entry.blocks[-1])
                self.rows[row].blocks = [*entry.blocks[:self._npb_full],
                                         *own]
                admitted.add(req.uid)
                self.stats["prefix_hits"] += 1
                self.stats["admissions"] += 1
        if exhausted is not None:
            raise exhausted

    def _finish_row(self, row: int, finish_reason: str,
                    out: List[Completion]) -> None:
        rs = self.rows[row]
        toks = (np.concatenate(rs.tok_chunks) if rs.tok_chunks
                else np.zeros((0,), np.int32))
        logps = (np.concatenate(rs.logp_chunks) if rs.logp_chunks
                 else np.zeros((0,), np.float32))
        ents = (np.concatenate(rs.ent_chunks) if rs.ent_chunks
                else np.zeros((0,), np.float32))
        vers = (np.concatenate(rs.ver_chunks) if rs.ver_chunks
                else np.zeros((0,), np.int64))
        self._phase_lats.append(self.now - rs.req.arrival_time)
        out.append(Completion(
            uid=rs.req.uid, prompt=rs.req.prompt,
            tokens=toks.astype(np.int32), logps=logps.astype(np.float32),
            finish_reason=finish_reason, arrival_time=rs.req.arrival_time,
            admit_time=rs.admit_time, finish_time=self.now, row=row,
            ents=ents.astype(np.float32),
            weight_version=rs.weight_version,
            tok_versions=vers.astype(np.int64)))
        if rs.blocks:
            # drop this row's page references; shared prompt pages stay
            # alive as long as the prefix cache (or a sibling row) pins them
            self.allocator.release_many(rs.blocks)
        rs.done = True
        self.rows[row] = None
        # retire is deferred to the next admission sweep: the row is either
        # re-admitted (the splice overwrites everything) or wiped there,
        # always before the next chunk dispatch
        self._dirty.add(row)

    def _cancel_row(self, row: int) -> None:
        """Abort a row's in-flight request (group over-provisioning: a
        straggler whose group already collected its G finishers).  No
        Completion is produced; the row's pages go back to the pool and the
        slot is wiped so the next admission sees a clean row."""
        rs = self.rows[row]
        if rs.blocks:
            self.allocator.release_many(rs.blocks)
        rs.done = True
        self.rows[row] = None
        self.state, self.active = self._retire(self.state, self.active, row)
        self._dirty.discard(row)
        self.stats["cancelled"] += 1

    def run(self, requests: Sequence[Request], *,
            group_size: Optional[int] = None,
            group_slack: int = 0,
            schedule: str = "fifo",
            on_group=None) -> List[Completion]:
        """Serve ``requests`` to completion; returns Completions sorted by uid.

        Requests become admissible once the virtual clock passes their
        ``arrival_time``; the clock advances by the measured wall time of
        each admission/decode chunk and jumps over idle gaps, so latency
        statistics are honest service measurements without real-time sleeps.

        ``schedule`` orders co-arrived requests: "fifo" (uid order — the
        fair serving default) or "longest" (descending token cap — LPT
        makespan scheduling for batch phases: the long-cap stragglers start
        first and their decode overlaps everyone else's, instead of
        draining near-alone at phase end).  Per-request sampling-key chains
        make admission order invisible in each request's tokens, so the
        policy is purely a throughput knob (arrival time stays the primary
        key: nothing is admitted before it arrives).

        ``group_size``/``group_slack`` enable the RL-training group
        discipline (DESIGN.md §Training on the continuous engine): uids must
        be group-major over groups of ``group_size + group_slack`` requests
        (``gid = uid // (G + slack)``).  With slack > 0 each group is
        over-provisioned; the *first G to finish* are kept (first-G-finished
        admission) and the moment a group collects its G finishers its
        stragglers are cancelled — queued members are dropped and in-flight
        members retired — so their slots admit the next group instead of
        decoding a tail nobody will use.  Exactly G Completions per group
        come back.

        ``on_group`` (optional, requires ``group_size``) streams finished
        groups to the caller from inside the scheduling loop: the moment a
        group collects its G finishers (and, with slack, before its
        stragglers are cancelled) ``on_group(gid, completions)`` fires with
        the G members sorted by uid.  A blocking callback back-pressures
        the whole engine — the async actor-learner pipeline uses exactly
        this to bound its staging queue.
        """
        track_groups = group_size is not None and (group_slack > 0
                                                   or on_group is not None)
        Gs = (group_size + group_slack) if track_groups else 0
        closed: set = set()           # gids that collected their G finishers
        gid_members: Dict[int, List[Completion]] = {}

        def group_done(uid: int) -> bool:
            return track_groups and group_slack > 0 and uid // Gs in closed

        def on_finished(comp: Completion) -> None:
            """Collect a finisher; on the G-th, close the group, stream it
            to ``on_group`` and cancel its stragglers (queued members drop,
            in-flight members retire).  A closed gid stays in ``closed``
            forever, so the group can never reopen or fire twice."""
            if not track_groups:
                return
            gid = comp.uid // Gs
            members = gid_members.setdefault(gid, [])
            members.append(comp)
            if len(members) != group_size:
                return
            closed.add(gid)
            if on_group is not None:
                on_group(gid, sorted(members, key=lambda c: c.uid))
            del gid_members[gid]
            if group_slack == 0:
                return
            survivors = [r for r in pending if r.uid // Gs != gid]
            if len(survivors) != len(pending):
                self.stats["cancelled"] += len(pending) - len(survivors)
                pending.clear()
                pending.extend(survivors)
            for r2, rs2 in enumerate(self.rows):
                if rs2 is not None and rs2.req.uid // Gs == gid:
                    self._cancel_row(r2)

        if schedule == "fifo":
            order = lambda r: (r.arrival_time, r.uid)            # noqa: E731
        elif schedule == "longest":
            order = lambda r: (r.arrival_time, -self._cap(r), r.uid)  # noqa: E731
        else:
            raise ValueError(f"unknown schedule {schedule!r}")
        pending = deque(sorted(requests, key=order))
        out: List[Completion] = []
        # in-flight decode chunks: (toks, logps, ents, tenant snapshot).
        # With overlap_harvest the loop keeps one chunk in flight past the
        # one being harvested (ping-pong output buffers: chunk t's outputs
        # are device_get-ed while chunk t+1 writes its own), so host-side
        # harvest/admission bookkeeping overlaps device compute.
        inflight: deque = deque()
        depth = 1 if self.overlap_harvest else 0

        fruitless_sweeps = 0

        def admit_sweep() -> None:
            """FIFO admission of arrived requests into free rows, capped at
            ``prefill_chunk`` prompt tokens per sweep (budget overflow waits
            for the next sweep — the resident batch keeps decoding instead
            of stalling behind a long admission burst), then one batched
            flush.  Freed rows that admitted nothing are retired before the
            next dispatch so they stop appending into recycled pages.  Any
            staged weight hot-swap applies first, so this sweep's
            admissions prefill — and are version-tagged — under the new
            snapshot.

            ``PoolExhausted`` from the flush is self-healing, not fatal:
            the unwound requests go back to the FRONT of the queue (their
            original order — per-request key chains make the delayed retry
            token-identical) and re-stage on a later sweep once resident
            rows drain and free their pages.  Only sustained exhaustion
            with *nothing* in flight — no row decoding, no chunk pending,
            so no page can ever come back — escalates to the caller after
            a bounded number of fruitless sweeps (a genuinely undersized
            pool, DESIGN.md §Fault tolerance & degraded modes)."""
            nonlocal fruitless_sweeps
            self._apply_pending_swap()
            spent, staged_keys = 0, set()
            for row in self._free_rows():
                if not (pending and pending[0].arrival_time <= self.now):
                    break
                cost = self._admit_cost(pending[0], staged_keys)
                if spent and spent + cost > self.prefill_chunk:
                    break
                spent += cost
                self._stage_admit(pending.popleft(), row)
            try:
                self._flush_admissions()
            except PoolExhausted as e:
                for r in reversed(getattr(e, "unadmitted", [])):
                    pending.appendleft(r)
                self.stats["pool_retry_sweeps"] += 1
                tel.log.event(
                    "pool_exhausted_retry", level="debug",
                    unadmitted=len(getattr(e, "unadmitted", [])),
                    in_flight=self._num_active() + len(inflight))
                if self._num_active() or inflight:
                    fruitless_sweeps = 0      # draining rows will free pages
                else:
                    fruitless_sweeps += 1
                    if fruitless_sweeps > 64:
                        raise
            else:
                fruitless_sweeps = 0
            for row in sorted(self._dirty):
                self.state, self.active = self._retire(
                    self.state, self.active, row)
            self._dirty.clear()

        def harvest_one() -> None:
            """Harvest the oldest in-flight chunk against its dispatch-time
            tenant snapshot (a tenant that finished meanwhile — possible
            only with overlap — marks its rows' outputs as discard)."""
            (toks_d, logps_d, ents_d, tenants, ver_first,
             chunk_ver) = inflight.popleft()
            toks_h, logps_h, ents_h = jax.device_get(
                (toks_d, logps_d, ents_d))                     # (chunk, B)
            self.stats["chunks"] += 1
            self.stats["decode_steps"] += self.decode_chunk
            for row in range(self.batch_size):
                rs = tenants[row]
                if rs is None or rs.done:
                    self.stats["wasted_row_steps"] += self.decode_chunk
                    continue
                if group_done(rs.req.uid):
                    # a sibling finishing earlier closed the group; this
                    # straggler's chunk is discarded
                    self._cancel_row(row)
                    continue
                remaining = self._cap(rs.req) - rs.n
                window = toks_h[:remaining, row]
                eos_hits = np.where(window == self.eos_id)[0]
                if eos_hits.size:
                    take, finish = int(eos_hits[0]) + 1, "eos"
                elif remaining <= self.decode_chunk:
                    take, finish = remaining, "length"
                else:
                    take, finish = self.decode_chunk, None
                rs.tok_chunks.append(toks_h[:take, row])
                rs.logp_chunks.append(logps_h[:take, row])
                rs.ent_chunks.append(ents_h[:take, row])
                # per-token sampler version: the chunk's FIRST token is
                # sampled from the logits carried into the dispatch (the
                # pre-swap params for the chunk right after a swap); the
                # rest from logits the chunk computed itself
                vers = np.full((take,), chunk_ver, np.int64)
                if take:
                    vers[0] = ver_first[row]
                rs.ver_chunks.append(vers)
                rs.n += take
                if finish is None:
                    continue
                self.stats["wasted_row_steps"] += self.decode_chunk - take
                self._finish_row(row, finish, out)
                on_finished(out[-1])

        tel = self.tel
        while pending or self._num_active() or inflight:
            if self.heartbeat is not None:
                self.heartbeat()
            t0 = time.perf_counter()
            with tel.timed("admit_sweep"):
                admit_sweep()
            dispatched = False
            if self._num_active():
                with tel.timed("decode_chunk"):
                    ver_first = self._logits_ver.copy()
                    self._logits_ver[:] = self.weight_version
                    (self.state, self.logits, self.counts, toks, logps,
                     ents) = self._chunk(
                        self.params, self.state, self.logits, self.counts,
                        self.active, self.row_keys)
                    inflight.append((toks, logps, ents, list(self.rows),
                                     ver_first, self.weight_version))
                dispatched = True
            if inflight and (len(inflight) > depth or not dispatched):
                with tel.timed("harvest"):
                    harvest_one()
            self.now += time.perf_counter() - t0
            if not (self._num_active() or inflight) and pending:
                # idle: jump the virtual clock to the next arrival
                self.now = max(self.now, pending[0].arrival_time)
        # park: rows keep decoding pad tokens while inactive (static shapes),
        # appending garbage KVs into their freed blocks; wipe them so the
        # drained engine ends in the all-empty state
        self._dirty.clear()
        self.state, self.active = self._park(self.state, self.active)
        return sorted(out, key=lambda c: c.uid)


# ---------------------------------------------------------------------------
# Lockstep baseline driver (shared by benchmarks, serve CLI and tests)
# ---------------------------------------------------------------------------
class LockstepServer:
    """Serve a workload with the lockstep `generate`, one static batch at a
    time: every batch decodes the full ``max_new_tokens`` regardless of
    per-request caps or early EOS (the straggler cost continuous batching
    removes).  Uses the identical per-request key chains as ContinuousEngine
    — ``fold_in(fold_in(base, uid), t)`` — so for the same seed the two
    paths are token-identical per request (the equivalence test's oracle).

    The `generate` program compiles once in ``__init__`` and is reused across
    ``run`` calls (like ContinuousEngine's programs), so warm-run timing is a
    fair scheduling comparison.
    """

    def __init__(self, params, cfg: ModelConfig, mfns: ModelFns,
                 scfg: SparseRLConfig, *, batch_size: int, prompt_len: int,
                 max_new_tokens: int, eos_id: int, pad_id: int = 0,
                 seed: int = 0):
        from repro.rollout.engine import generate

        self.params = params
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.pad_id = pad_id
        self._base_key = jax.random.PRNGKey(seed)
        self._gen = jax.jit(lambda p, b, keys: generate(
            p, cfg, mfns, b, scfg, jax.random.PRNGKey(0),
            max_new_tokens=max_new_tokens, eos_id=eos_id, pad_id=pad_id,
            per_row_keys=keys))

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        pending = deque(sorted(requests,
                               key=lambda r: (r.arrival_time, r.uid)))
        out: List[Completion] = []
        now = 0.0
        B, P = self.batch_size, self.prompt_len
        while pending:
            if pending[0].arrival_time > now:
                now = pending[0].arrival_time
            group = []
            while pending and len(group) < B \
                    and pending[0].arrival_time <= now:
                group.append(pending.popleft())
            # pad the batch to a constant shape (single compiled program)
            ids = np.full((B, P), self.pad_id, np.int32)
            mask = np.zeros((B, P), bool)
            keys = []
            for i, req in enumerate(group):
                p = np.asarray(req.prompt, np.int32).ravel()
                ids[i, P - len(p):] = p
                mask[i, P - len(p):] = True
                keys.append(jax.random.fold_in(self._base_key, req.uid))
            for _ in range(B - len(group)):
                keys.append(self._base_key)     # dummy rows, results dropped
            batch = {"tokens": jnp.asarray(ids),
                     "valid_mask": jnp.asarray(mask)}
            t0 = time.perf_counter()
            ro = self._gen(self.params, batch, jnp.stack(keys))
            jax.block_until_ready(ro.resp_tokens)
            admit = now
            now += time.perf_counter() - t0
            toks_h = np.asarray(ro.resp_tokens)
            logps_h = np.asarray(ro.logp_sparse)
            for i, req in enumerate(group):
                cap = (self.max_new_tokens if req.max_new_tokens is None
                       else min(req.max_new_tokens, self.max_new_tokens))
                row = toks_h[i, :cap]
                eos_hits = np.where(row == self.eos_id)[0]
                if len(eos_hits):
                    end, reason = eos_hits[0] + 1, "eos"
                else:
                    end, reason = cap, "length"
                out.append(Completion(
                    uid=req.uid, prompt=req.prompt,
                    tokens=row[:end].astype(np.int32),
                    logps=logps_h[i, :end].astype(np.float32),
                    finish_reason=reason, arrival_time=req.arrival_time,
                    admit_time=admit, finish_time=now, row=i))
        return sorted(out, key=lambda c: c.uid)


def serve_lockstep(params, cfg: ModelConfig, mfns: ModelFns,
                   scfg: SparseRLConfig, requests: Sequence[Request], *,
                   batch_size: int, prompt_len: int, max_new_tokens: int,
                   eos_id: int, pad_id: int = 0, seed: int = 0
                   ) -> List[Completion]:
    """One-shot convenience wrapper around :class:`LockstepServer`."""
    return LockstepServer(
        params, cfg, mfns, scfg, batch_size=batch_size, prompt_len=prompt_len,
        max_new_tokens=max_new_tokens, eos_id=eos_id, pad_id=pad_id,
        seed=seed).run(requests)
