"""Sampler-policy registry: every way the rollout sampler may deviate from
the dense policy, as a first-class named object.

Sparse-RL's claim (paper §4) is policy-agnostic: *any* compression-induced
sampler policy pi_sparse — eviction, quantization, per-head budgets, step
schedules — is stabilized by the same xi/rejection/reweighting correction.
Historically the policy was an ad-hoc pair of strings threaded through the
engine (``scfg.compression`` + the quantized-pool ``kv_quant`` flag).  This
module names each combination, owns its cache geometry, declares whether it
is the identity policy (token-identical to the dense oracle, so the matrix
harness knows which cells to pin bitwise), exposes its budget schedule, and
centralizes the engine-config validation that used to live inline in
``ContinuousEngine.__init__`` (DESIGN.md §Sampler policy registry).

A policy is *pure configuration*: resolving one rewrites ``scfg.compression``
/ ``kv_quant`` to the exact values the pre-registry code paths consumed, so
legacy ``--compression``/``--kv-quant`` flags alias bit-for-bit through
:func:`legacy_policy_name`.  The mechanisms stay where they always lived
(``kvcache/cache.py`` eviction + budget enforcement, ``kvcache/paged.py``
quantized pool, ``models/attention.py`` decode hooks) keyed off those same
fields — the registry adds no second dispatch path to drift from.

Registered policies:

  dense       compression="none"                   identity (the oracle itself)
  rkv         compression="rkv"                    R-KV importance+diversity
  snapkv      compression="snapkv"                 obs-window selection
  h2o         compression="h2o"                    cumulative attention mass
  streaming   compression="streaming"              sinks + recency
  per_head    compression="per_head"               reasoning heads stay dense
                                                   (kernels/budget_attention.py
                                                   fused decode), other heads
                                                   compress hard to kv_budget
  adaptive    compression="adaptive"               Sparrow-style step schedule:
                                                   budget tightens over decode
  quant-int8  compression="none", kv_quant="int8"  quantized paged pool
  quant-fp8   compression="none", kv_quant="fp8"   quantized paged pool
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Tuple

from repro.configs.base import DENSE, MOE, VLM, SparseRLConfig
from repro.kvcache.cache import adaptive_budget, head_budget_split

COMPRESSIONS = ("none", "rkv", "snapkv", "h2o", "streaming", "per_head",
                "adaptive")
KV_QUANTS = ("none", "int8", "fp8")
CACHE_BACKENDS = ("contiguous", "paged")
# families whose KV lives in the shared block pool (paged prefix sharing +
# quantized storage); ssm/hybrid/audio splice whole decode states instead
POOL_FAMILIES = (DENSE, MOE, VLM)


def _dense_geometry(scfg: SparseRLConfig, prompt_len: int,
                    max_new_tokens: int, prefix_len: int = 0) -> int:
    """Dense sizing: prompt + multimodal prefix + every new token, plus
    headroom so the degenerate recency eviction never triggers."""
    return prompt_len + prefix_len + max_new_tokens + 8


def _budget_geometry(scfg: SparseRLConfig, prompt_len: int,
                     max_new_tokens: int, prefix_len: int = 0) -> int:
    """Fixed sparse budget: S = B_budget + B_buffer, workload-independent."""
    return scfg.cache_slots


def _flat_schedule(scfg: SparseRLConfig, pos):
    """Budget constant in the decode position (fixed-budget policies)."""
    return scfg.cache_slots


def _dense_schedule(scfg: SparseRLConfig, pos):
    """No budget: the dense cache retains everything (reported as the slot
    count the geometry would allocate for a budget-sized workload)."""
    return scfg.cache_slots


def _per_head_schedule(scfg: SparseRLConfig, pos):
    """Worst-case (compressed-head) budget; reasoning heads are unbounded."""
    return head_budget_split(scfg)[1]


@dataclass(frozen=True)
class SamplerPolicy:
    """One named sampler policy (protocol + registry entry in one).

    ``geometry``       cache slots per (layer, row) for a workload — the hook
                       ``rollout_slots`` / ``paged_rollout_geometry`` route
                       through (no magic constants at call sites).
    ``budget_schedule`` effective live-slot budget at decode position ``pos``
                       (jnp-traceable; monotone non-increasing for adaptive).
    ``is_dense``       identity flag: rollouts are token-identical to the
                       dense lockstep oracle (matrix cells with this set are
                       pinned bitwise; all others assert the correction
                       invariants instead).
    """
    name: str
    compression: str
    kv_quant: str = "none"
    is_dense: bool = False
    geometry: Callable[..., int] = _budget_geometry
    budget_schedule: Callable = _flat_schedule
    description: str = ""

    def apply(self, scfg: SparseRLConfig) -> SparseRLConfig:
        """Resolve onto a config: rewrite ``compression`` to this policy's
        value (all other knobs — budgets, sinks, corrections — stay)."""
        return replace(scfg, compression=self.compression)

    def validate(self, *, cache_backend: str = "contiguous",
                 family: str = DENSE) -> None:
        validate_engine_config(self.apply(SparseRLConfig()),
                               kv_quant=self.kv_quant,
                               cache_backend=cache_backend, family=family)


POLICIES: Dict[str, SamplerPolicy] = {}


def register(policy: SamplerPolicy) -> SamplerPolicy:
    if policy.name in POLICIES:
        raise ValueError(f"duplicate sampler policy {policy.name!r}")
    POLICIES[policy.name] = policy
    return policy


def resolve_policy(name: str) -> SamplerPolicy:
    if name not in POLICIES:
        raise KeyError(
            f"unknown sampler policy {name!r}; registered: {sorted(POLICIES)}")
    return POLICIES[name]


def policy_names() -> Tuple[str, ...]:
    return tuple(POLICIES)


register(SamplerPolicy(
    "dense", compression="none", is_dense=True, geometry=_dense_geometry,
    budget_schedule=_dense_schedule,
    description="uncompressed cache; the oracle pi_old itself"))
register(SamplerPolicy(
    "rkv", compression="rkv",
    description="R-KV: lambda*importance + (1-lambda)*diversity eviction"))
register(SamplerPolicy(
    "snapkv", compression="snapkv",
    description="SnapKV: obs-window pooled-attention selection"))
register(SamplerPolicy(
    "h2o", compression="h2o",
    description="H2O: cumulative attention mass (heavy hitters)"))
register(SamplerPolicy(
    "streaming", compression="streaming",
    description="StreamingLLM: attention sinks + recency"))
register(SamplerPolicy(
    "per_head", compression="per_head", geometry=_dense_geometry,
    budget_schedule=_per_head_schedule,
    description=("reasoning heads keep dense caches (fused "
                 "budget-attention decode), others hard-capped at kv_budget")))
register(SamplerPolicy(
    "adaptive", compression="adaptive", budget_schedule=adaptive_budget,
    description=("Sparrow-style schedule: budget decays from cache_slots "
                 "toward adaptive_min_frac over adaptive_decay_tokens")))
register(SamplerPolicy(
    "quant-int8", compression="none", kv_quant="int8",
    geometry=_dense_geometry, budget_schedule=_dense_schedule,
    description="dense geometry, int8-symmetric quantized paged pool"))
register(SamplerPolicy(
    "quant-fp8", compression="none", kv_quant="fp8",
    geometry=_dense_geometry, budget_schedule=_dense_schedule,
    description="dense geometry, fp8-e4m3 quantized paged pool"))


def policy_for_scfg(scfg: SparseRLConfig, kv_quant: str = "none"
                    ) -> SamplerPolicy:
    """Reverse-map resolved config fields to their registry entry (the hook
    legacy call sites — ``rollout_slots`` — route geometry through)."""
    return resolve_policy(legacy_policy_name(scfg.compression, kv_quant))


def legacy_policy_name(compression: str, kv_quant: str = "none") -> str:
    """Deprecation shim: the pre-registry ``--compression``/``--kv-quant``
    flag pair, mapped to the policy that resolves to *exactly* those values
    (pinned bitwise-identical by tests/matrix/test_registry.py)."""
    if kv_quant not in KV_QUANTS:
        raise ValueError(f"unknown kv_quant {kv_quant!r}; choose from {KV_QUANTS}")
    if kv_quant != "none":
        if compression != "none":
            raise ValueError(
                f"kv_quant={kv_quant!r} composes only with compression='none' "
                f"(the quantized pool is the sole policy gap) — got "
                f"compression={compression!r}")
        return f"quant-{kv_quant}"
    if compression == "none":
        return "dense"
    if compression not in COMPRESSIONS:
        raise ValueError(
            f"unknown compression {compression!r}; choose from {COMPRESSIONS}")
    return compression


def resolve_cli_policy(sampler_policy, compression, kv_quant, *,
                       default_compression: str) -> SamplerPolicy:
    """CLI deprecation shim shared by the train/serve launchers.

    ``--sampler-policy`` wins when given (mixing it with a legacy flag is a
    config error — silent precedence would mask a typo).  Otherwise the
    legacy ``--compression``/``--kv-quant`` pair (None = flag not passed,
    falling back to its historical default) aliases through
    :func:`legacy_policy_name` — the resolved policy rewrites the config to
    the exact same field values, so legacy invocations stay
    bitwise-identical (pinned by tests/matrix/test_registry.py).
    """
    if sampler_policy is not None:
        if compression is not None or kv_quant is not None:
            raise ValueError(
                "--sampler-policy cannot be combined with the legacy "
                "--compression/--kv-quant flags")
        return resolve_policy(sampler_policy)
    if compression is not None or kv_quant is not None:
        import sys

        print("[deprecated] --compression/--kv-quant: prefer "
              "--sampler-policy <name> (same behavior, registry-resolved)",
              file=sys.stderr)
    return resolve_policy(legacy_policy_name(
        compression if compression is not None else default_compression,
        kv_quant if kv_quant is not None else "none"))


def validate_engine_config(scfg: SparseRLConfig, *, kv_quant: str = "none",
                           cache_backend: str = "contiguous",
                           family: str = DENSE) -> SamplerPolicy:
    """THE engine-config validator (deduplicates the checks that used to be
    scattered through ``ContinuousEngine.__init__``).  Raises ValueError on
    every illegal combination; returns the resolved policy otherwise."""
    if cache_backend not in CACHE_BACKENDS:
        raise ValueError(
            f"unknown cache_backend {cache_backend!r}; choose from {CACHE_BACKENDS}")
    if kv_quant not in KV_QUANTS:
        raise ValueError(
            f"unknown kv_quant {kv_quant!r}; choose from {KV_QUANTS}")
    if scfg.compression not in COMPRESSIONS:
        raise ValueError(
            f"unknown compression {scfg.compression!r}; choose from {COMPRESSIONS}")
    if kv_quant != "none" and not (cache_backend == "paged"
                                   and scfg.compression == "none"
                                   and family in POOL_FAMILIES):
        raise ValueError(
            f"kv_quant={kv_quant!r} requires the paged pool backend "
            f"(cache_backend='paged', compression='none', dense family)"
            f" — got cache_backend={cache_backend!r}, "
            f"compression={scfg.compression!r}, family={family!r}")
    return policy_for_scfg(scfg, kv_quant)
