"""Rollout engines.

Two drivers share one compiled decode core (`engine.decode_sample_step`):

* :func:`generate` — lockstep fixed-length rollout (the RL training path).
* :class:`ContinuousEngine` — continuous-batching scheduler (the serving
  path): request queue, slot recycling, prefill-into-running-batch.  Its
  lockstep oracle/baseline is :func:`serve_lockstep`.

See DESIGN.md §Sampling and §Continuous-batching for the sampling-key and
scheduling contracts.
"""
from repro.rollout.continuous import (
    Completion,
    ContinuousEngine,
    LockstepServer,
    Request,
    serve_lockstep,
)
from repro.rollout.engine import (
    RolloutBatch,
    decode_sample_step,
    fold_row_keys,
    generate,
    mismatch_kl_estimate,
    paged_rollout_geometry,
    rescore,
    rescore_parts,
    rollout_slots,
    sample_token,
    sample_token_per_row,
)

__all__ = [
    "RolloutBatch", "generate", "rescore", "rescore_parts",
    "sample_token", "sample_token_per_row", "fold_row_keys",
    "decode_sample_step", "rollout_slots", "paged_rollout_geometry",
    "mismatch_kl_estimate",
    "ContinuousEngine", "LockstepServer", "Request", "Completion",
    "serve_lockstep",
]
