"""Rollout engines.

Two drivers share one compiled decode core (`engine.decode_sample_step`):

* :func:`generate` — lockstep fixed-length rollout (the RL training
  baseline backend).
* :class:`ContinuousEngine` — continuous-batching scheduler: request queue,
  slot recycling, prefill-into-running-batch.  It serves inference traffic
  AND, via ``Trainer(rollout_backend="continuous")``, the RL training
  rollout phase (group admission + :func:`build_train_rollout` assembling
  Completions into the lockstep `RolloutBatch` layout).  Its lockstep
  oracle/baseline is :func:`serve_lockstep`.

See DESIGN.md §Sampling, §Continuous-batching and §Training on the
continuous engine for the sampling-key, scheduling and group contracts.
"""
from repro.rollout.continuous import (
    Completion,
    ContinuousEngine,
    LockstepServer,
    Request,
    serve_lockstep,
)
from repro.rollout.policies import (
    POLICIES,
    SamplerPolicy,
    legacy_policy_name,
    policy_for_scfg,
    policy_names,
    resolve_policy,
    validate_engine_config,
)
from repro.rollout.engine import (
    RolloutBatch,
    TrainRollout,
    build_train_rollout,
    decode_sample_step,
    fold_row_keys,
    generate,
    mismatch_kl_estimate,
    paged_rollout_geometry,
    rescore,
    rescore_parts,
    rollout_slots,
    sample_token,
    sample_token_per_row,
)

__all__ = [
    "RolloutBatch", "TrainRollout", "build_train_rollout",
    "generate", "rescore", "rescore_parts",
    "sample_token", "sample_token_per_row", "fold_row_keys",
    "decode_sample_step", "rollout_slots", "paged_rollout_geometry",
    "mismatch_kl_estimate",
    "ContinuousEngine", "LockstepServer", "Request", "Completion",
    "serve_lockstep",
    "POLICIES", "SamplerPolicy", "resolve_policy", "policy_names",
    "policy_for_scfg", "legacy_policy_name", "validate_engine_config",
]
