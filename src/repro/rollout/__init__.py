from repro.rollout.engine import (
    RolloutBatch,
    generate,
    mismatch_kl_estimate,
    rescore,
    rescore_parts,
    sample_token,
)

__all__ = ["RolloutBatch", "generate", "rescore", "rescore_parts",
           "sample_token", "mismatch_kl_estimate"]
