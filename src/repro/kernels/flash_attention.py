"""Pallas TPU kernel: forward flash attention (prefill / dense re-scoring).

The dense re-scoring pass (pi_old for every rollout token, paper §4) is a
forward-only teacher-forced attention — no backward needed on this path, so
a fwd kernel is the complete TPU story for it.  Online softmax over KV tiles
with VMEM scratch carried across the innermost (sequential) kv-tile grid dim.

TPU mapping:
  grid = (B * Hq, nQ, nK); q tile (block_q, Dh) resident; K/V stream in
  (block_k, Dh) tiles; GQA folds the q-head index to its kv head in the
  BlockSpec index map (no K/V duplication in HBM).  Causal masking compares
  absolute position tiles, so left-padded prompts mask correctly; tiles
  enter VMEM at (block, 128)-aligned shapes for the MXU.

Tunables (kernels/autotune.py; performance model in PERFORMANCE.md):
  * ``block_q`` / ``block_k`` — the resident query tile and streamed KV
    tile heights.  Bigger tiles cut grid-step overhead and revisits of the
    q tile; smaller tiles cut the VMEM footprint (q + 2 KV tiles + f32
    scratch must fit under the double-buffering budget).  The hand-picked
    512/512 default is the fallback when no tuned entry exists; the ops
    wrapper resolves both at trace time via
    `kernels.autotune.get_tuned_config`.

Oracle: `kernels.ref.flash_attention_ref` (masked dense softmax);
`kernels.ops.flash_attention` is the dispatching wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            acc, m_s, l_s, *, scale: float, nk: int, causal: bool):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].astype(jnp.float32)                       # (bq, Dh)
    k = k_ref[0].astype(jnp.float32)                       # (bk, Dh)
    v = v_ref[0].astype(jnp.float32)
    qp = qpos_ref[0]                                       # (bq,) int32
    kp = kpos_ref[0]                                       # (bk,)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    msk = kp[None, :] >= 0
    if causal:
        msk = msk & (qp[:, None] >= kp[None, :])
    s = jnp.where(msk, s, NEG)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(msk, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc[...] = acc[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                              "interpret"))
def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        q_positions: jnp.ndarray, kv_positions: jnp.ndarray,
                        *, block_q: int = 512, block_k: int = 512,
                        causal: bool = True,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh);
    q_positions: (B, Sq) int32 (-1 = padding); kv_positions: (B, Sk).
    Returns (B, Sq, Hq, Dh) in q.dtype."""
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pq)), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pk)), constant_values=-1)
    Sqp, Skp = Sq + pq, Sk + pk
    nq, nk = Sqp // bq, Skp // bk
    # layouts: fold heads into the leading grid dim
    qf = jnp.swapaxes(q, 1, 2).reshape(B * Hq, Sqp, Dh)
    kf = jnp.swapaxes(k, 1, 2).reshape(B * Hkv, Skp, Dh)
    vf = jnp.swapaxes(v, 1, 2).reshape(B * Hkv, Skp, Dh)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (Dh ** 0.5), nk=nk,
                          causal=causal),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda i, qi, ki: (i // Hq, qi)),
            pl.BlockSpec((1, bk), lambda i, qi, ki: (i // Hq, ki)),
            pl.BlockSpec((1, bq, Dh), lambda i, qi, ki: (i, qi, 0)),
            # GQA: q-head i maps to kv row (batch * Hkv + head // G)
            pl.BlockSpec((1, bk, Dh),
                         lambda i, qi, ki: ((i // Hq) * Hkv + (i % Hq) // G, ki, 0)),
            pl.BlockSpec((1, bk, Dh),
                         lambda i, qi, ki: ((i // Hq) * Hkv + (i % Hq) // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, qf, kf, vf)
    out = out.reshape(B, Hq, Sqp, Dh)[:, :, :Sq]
    return jnp.swapaxes(out, 1, 2)
