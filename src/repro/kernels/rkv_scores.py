"""Pallas TPU kernel: fused R-KV eviction scoring.

R-KV (Cai et al., 2025) scores each cached token by
``lambda * importance + (1 - lambda) * diversity`` where importance is the
normalized cumulative attention mass and diversity = 1 - cos(key, incoming
key).  The GPU reference computes similarity and importance normalization in
separate launches; here a single VMEM pass over the (S, Dh) key tile emits
the final per-slot score (with sink / observation-window pins applied), ready
for the argmin eviction.

grid = (B * Hkv,); one (S, Dh) key tile + (S,) vectors per program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30
BIG = 1e30


def _kernel(k_ref, knew_ref, imp_ref, pos_ref, cur_ref, o_ref, *,
            lam: float, num_sinks: int, obs_window: int):
    k = k_ref[0].astype(jnp.float32)                      # (S, Dh)
    kn = knew_ref[0].astype(jnp.float32)                  # (1, Dh)
    imp = imp_ref[0]                                      # (S,)
    pos = pos_ref[0]                                      # (S,)
    cur = cur_ref[0, 0]                                   # scalar int32
    valid = pos >= 0

    # importance: normalize cumulative attention to [0, 1] within the row
    denom = jnp.max(jnp.where(valid, imp, 0.0)) + 1e-6
    imp_n = imp / denom

    # redundancy: cosine similarity with the incoming key
    dot = jax.lax.dot_general(k, kn, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)[:, 0]
    kk = jnp.sqrt(jnp.sum(k * k, axis=1))
    nn = jnp.sqrt(jnp.sum(kn * kn))
    cos = dot / (kk * nn + 1e-6)
    diversity = 1.0 - cos

    score = lam * imp_n + (1.0 - lam) * diversity
    score = jnp.where(valid, score, NEG)                  # empty slots first
    protected = valid & ((pos < num_sinks) | (pos > cur - obs_window))
    score = jnp.where(protected, BIG, score)
    o_ref[0] = score


@functools.partial(jax.jit, static_argnames=("lam", "num_sinks", "obs_window",
                                              "interpret"))
def rkv_scores(k_cache: jnp.ndarray, k_new: jnp.ndarray, importance: jnp.ndarray,
               pos: jnp.ndarray, cur_pos: jnp.ndarray, *,
               lam: float = 0.1, num_sinks: int = 4, obs_window: int = 8,
               interpret: bool = False) -> jnp.ndarray:
    """k_cache: (B, Hkv, S, Dh); k_new: (B, Hkv, Dh); importance/pos:
    (B, Hkv, S); cur_pos: (B,).  Returns eviction scores (B, Hkv, S) f32
    (lower = evict first; empty slots lowest, protected slots highest)."""
    B, Hkv, S, Dh = k_cache.shape
    BH = B * Hkv
    kf = k_cache.reshape(BH, S, Dh)
    knf = k_new.reshape(BH, 1, Dh)
    impf = importance.reshape(BH, S)
    posf = pos.reshape(BH, S)
    curf = jnp.repeat(cur_pos.astype(jnp.int32), Hkv).reshape(BH, 1)
    out = pl.pallas_call(
        functools.partial(_kernel, lam=lam, num_sinks=num_sinks,
                          obs_window=obs_window),
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, S, Dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, Dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, S), lambda i: (i, 0)),
            pl.BlockSpec((1, S), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, S), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S), jnp.float32),
        interpret=interpret,
    )(kf, knf, impf, posf, curf)
    return out.reshape(B, Hkv, S)
