from repro.kernels.autotune import (
    TunedConfigError,
    get_tuned_config,
    tune_key,
)
from repro.kernels.ops import (
    budget_attention,
    config_provenance,
    config_sources,
    flash_attention,
    flash_decode,
    paged_flash_decode,
    reset_config_sources,
    rkv_scores,
    use_kernels,
)

__all__ = ["budget_attention", "flash_decode", "flash_attention",
           "paged_flash_decode", "rkv_scores", "use_kernels",
           "get_tuned_config", "tune_key", "TunedConfigError",
           "config_provenance", "config_sources", "reset_config_sources"]
