from repro.kernels.ops import (
    budget_attention,
    flash_attention,
    flash_decode,
    paged_flash_decode,
    rkv_scores,
    use_kernels,
)

__all__ = ["budget_attention", "flash_decode", "flash_attention",
           "paged_flash_decode", "rkv_scores", "use_kernels"]
