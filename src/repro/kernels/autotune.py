"""Pallas kernel autotuner + tuned-config registry.

The three hot-path kernels (`kernels/paged_decode.py`,
`kernels/flash_attention.py`, `kernels/budget_attention.py`, plus the dense
long-context `kernels/flash_decode.py`) expose a small set of tunable
parameters — block sizes, grid tiling, chunk widths — whose best values are
device-dependent.  This module owns both halves of the story
(PERFORMANCE.md is the written-down performance model; DESIGN.md §Kernel
autotuning is the design rationale):

1. **Lookup** (`get_tuned_config`): kernels resolve their parameters at
   trace time through `kernels.ops`.  Resolution order is tuned file ->
   hand-picked default: a checked-in ``kernels/tuned/<device_kind>.json``
   maps sweep keys ``kernel/arch/hd<head_dim>/ps<page_size>`` to winning
   configs; a missing file or missing entry falls back to the historical
   hand-picked constants (bitwise-unchanged default path, pinned by
   tests/test_autotune.py).  A *malformed or stale* tuned file is a loud
   `TunedConfigError`, never a silent fallback — a typo'd schema silently
   reverting every kernel to defaults would be an invisible perf
   regression.  On CPU the device kind is ``interpret`` and the shipped
   ``tuned/interpret.json`` pins the defaults explicitly, so CI is
   deterministic.

2. **Sweep** (`sweep`, driven by ``tools/autotune.py``): per sweep key,
   benchmark every legal candidate config (warm-up + median-of-k timing on
   synthetic operands shaped like the production workload), verify each
   winner against the pure-jnp ``kernels/ref.py`` oracle BEFORE it can be
   persisted, and sanity-check its timing against the analytic roofline
   bound (`launch/roofline.py::kernel_bound_s`) — a "winner" beating the
   bound is a measurement bug, not a win, and is rejected.  Winners land in
   the tuned JSON via `persist` and a per-candidate report row lands in
   ``reports/autotune.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SCHEMA_VERSION = 1
TUNED_DIR_ENV = "SPARSE_RL_TUNED_DIR"

KERNELS = ("paged_decode", "flash_attention", "budget_attention",
           "flash_decode")
# the exact tunable-parameter names per kernel; a tuned entry whose config
# carries anything else is stale (written against a different kernel
# signature) and fails validation loudly
TUNABLES: Dict[str, Tuple[str, ...]] = {
    "paged_decode": ("page_tile",),
    "flash_attention": ("block_q", "block_k"),
    "budget_attention": ("bh_tile",),
    "flash_decode": ("block_s",),
}

SUBLANES = 8                       # f32 tile: (8, 128); sublane-aligned tiles
VMEM_BYTES = 16 * 1024 * 1024      # per-core VMEM (TPU v4/v5 class)
VMEM_BUDGET = VMEM_BYTES // 2      # headroom for Mosaic's double buffering
_SOURCES = ("default", "tuned")


class TunedConfigError(ValueError):
    """Malformed or stale tuned-config JSON (loud, never a silent fallback)."""


# ---------------------------------------------------------------- keys ----

@dataclasses.dataclass(frozen=True)
class TuneKey:
    """One sweep cell: (kernel, arch family, head_dim, page_size); the
    device kind is the file the entry lives in, not part of the key."""
    kernel: str
    arch: str = "any"
    head_dim: int = 128
    page_size: int = 0             # 0 = not paged (non-pool kernels)

    @property
    def s(self) -> str:
        return (f"{self.kernel}/{self.arch}/hd{self.head_dim}"
                f"/ps{self.page_size}")


def tune_key(kernel: str, *, head_dim: int, page_size: int = 0,
             arch: str = "any") -> TuneKey:
    if kernel not in KERNELS:
        raise TunedConfigError(f"unknown kernel {kernel!r} "
                               f"(known: {', '.join(KERNELS)})")
    return TuneKey(kernel, arch, int(head_dim), int(page_size))


def parse_key(s: str) -> TuneKey:
    """Inverse of ``TuneKey.s`` (validates tuned-file entry keys)."""
    parts = s.split("/")
    try:
        kernel, arch, hd, ps = parts
        if not (hd.startswith("hd") and ps.startswith("ps")):
            raise ValueError(s)
        return tune_key(kernel, arch=arch, head_dim=int(hd[2:]),
                        page_size=int(ps[2:]))
    except (ValueError, TypeError) as e:
        raise TunedConfigError(f"unparseable tuned-config key {s!r} "
                               f"(want kernel/arch/hd<D>/ps<P>)") from e


def default_config(key: TuneKey) -> Dict[str, int]:
    """Today's hand-picked constants — the fallback when no tuned entry
    exists, and the exact values every pre-autotune benchmark ran under."""
    if key.kernel == "paged_decode":
        # one pool page per sequential grid step (page_tile == page_size)
        return {"page_tile": key.page_size}
    if key.kernel == "flash_attention":
        return {"block_q": 512, "block_k": 512}
    if key.kernel == "budget_attention":
        return {"bh_tile": 1}      # one (row, kv-head) program per grid step
    if key.kernel == "flash_decode":
        return {"block_s": 512}
    raise TunedConfigError(f"unknown kernel {key.kernel!r}")


# ----------------------------------------------------------- resolution ----

def device_kind() -> str:
    """Normalized device kind naming the tuned file: ``tpu_v5e``-style on
    TPU, ``interpret`` everywhere else (the kernels execute in Pallas
    interpret mode off-TPU, so CPU timings never masquerade as a device)."""
    if jax.default_backend() != "tpu":
        return "interpret"
    kind = jax.devices()[0].device_kind
    return "".join(c if c.isalnum() else "_" for c in kind.lower())


def tuned_dir() -> str:
    return os.environ.get(TUNED_DIR_ENV) or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "tuned")


_CACHE: Dict[Tuple[str, str], Dict[str, dict]] = {}


def reset_cache() -> None:
    """Drop memoized tuned files (tests repoint ``SPARSE_RL_TUNED_DIR``)."""
    _CACHE.clear()


def validate_tuned(data, *, kind: Optional[str] = None) -> Dict[str, dict]:
    """Schema-check one tuned-config document; returns its entries.

    Raises `TunedConfigError` on anything malformed or stale: wrong schema
    version, a key that does not parse, a config whose parameter names do
    not exactly match the kernel's tunables, a non-positive value, a
    ``page_tile`` that no longer divides the key's page_size, or a
    ``tuned``-sourced entry missing its oracle/roofline check bits."""
    if not isinstance(data, dict):
        raise TunedConfigError("tuned config must be a JSON object")
    if data.get("schema") != SCHEMA_VERSION:
        raise TunedConfigError(
            f"tuned config schema {data.get('schema')!r} != "
            f"{SCHEMA_VERSION} — regenerate with tools/autotune.py")
    if kind is not None and data.get("device_kind") != kind:
        raise TunedConfigError(
            f"tuned config device_kind {data.get('device_kind')!r} does not "
            f"match its file ({kind!r})")
    entries = data.get("entries")
    if not isinstance(entries, dict):
        raise TunedConfigError("tuned config has no 'entries' object")
    for key_s, e in entries.items():
        key = parse_key(key_s)
        cfg = e.get("config") if isinstance(e, dict) else None
        if not isinstance(cfg, dict):
            raise TunedConfigError(f"{key_s}: entry has no 'config' object")
        want = TUNABLES[key.kernel]
        if tuple(sorted(cfg)) != tuple(sorted(want)):
            raise TunedConfigError(
                f"{key_s}: stale config parameters {sorted(cfg)} != "
                f"{sorted(want)} for kernel {key.kernel!r}")
        for name, v in cfg.items():
            if not isinstance(v, int) or v <= 0:
                raise TunedConfigError(f"{key_s}: {name}={v!r} must be a "
                                       f"positive integer")
        if key.kernel == "paged_decode":
            if key.page_size <= 0:
                raise TunedConfigError(f"{key_s}: paged_decode entries need "
                                       f"a real page_size (ps > 0)")
            if key.page_size % cfg["page_tile"]:
                raise TunedConfigError(
                    f"{key_s}: stale page_tile {cfg['page_tile']} does not "
                    f"divide page_size {key.page_size}")
        if e.get("source") not in _SOURCES:
            raise TunedConfigError(f"{key_s}: source {e.get('source')!r} "
                                   f"not in {_SOURCES}")
        if e["source"] == "tuned":
            if not isinstance(e.get("us"), (int, float)):
                raise TunedConfigError(f"{key_s}: tuned entry has no "
                                       f"measured 'us'")
            if not (e.get("oracle_ok") is True
                    and e.get("roofline_ok") is True):
                raise TunedConfigError(
                    f"{key_s}: tuned entry persisted without passing the "
                    f"ref-oracle + roofline checks")
    return entries


def load_tuned(kind: Optional[str] = None) -> Dict[str, dict]:
    """Entries of ``<tuned_dir>/<kind>.json`` (validated, memoized).
    A missing file is the empty registry (pure-default resolution); a
    present-but-broken file raises."""
    kind = kind or device_kind()
    ck = (tuned_dir(), kind)
    if ck not in _CACHE:
        path = os.path.join(*ck) + ".json"
        if not os.path.exists(path):
            _CACHE[ck] = {}
        else:
            try:
                with open(path) as f:
                    data = json.load(f)
            except json.JSONDecodeError as e:
                raise TunedConfigError(f"{path}: invalid JSON: {e}") from e
            try:
                _CACHE[ck] = validate_tuned(data, kind=kind)
            except TunedConfigError as e:
                raise TunedConfigError(f"{path}: {e}") from e
    return _CACHE[ck]


def get_tuned_config(kernel: str, key) -> Tuple[Dict[str, int], str]:
    """Trace-time lookup: (config, source) for a kernel's sweep key.

    ``key`` is a `TuneKey` (or its string form).  Returns the tuned file's
    entry when one exists for the current device kind, else the hand-picked
    defaults; ``source`` is the entry's provenance (``"tuned"`` only for
    configs that passed the oracle + roofline checks at persist time) and
    flows into BENCH_* rows via `kernels.ops.config_provenance`."""
    if isinstance(key, str):
        key = parse_key(key)
    if key.kernel != kernel:
        raise TunedConfigError(f"key {key.s!r} is not a {kernel!r} key")
    entry = load_tuned().get(key.s)
    if entry is None:
        return default_config(key), "default"
    return dict(entry["config"]), entry["source"]


# ------------------------------------------------------ candidate spaces ----

def _pow2s(lo: int, hi: int) -> List[int]:
    out, v = [], lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def vmem_bytes(key: TuneKey, config: Dict[str, int], *,
               slots: int = 640) -> int:
    """f32 VMEM residency estimate of one grid step under ``config`` —
    blocks + scratch, the quantity the candidate pruner holds under
    `VMEM_BUDGET` (PERFORMANCE.md derives these per kernel)."""
    Dh = key.head_dim
    if key.kernel == "paged_decode":
        pt = config["page_tile"]
        g = 8                                  # GQA group upper bound
        return 4 * (2 * pt * Dh + g * Dh + g * Dh + 2 * g)
    if key.kernel == "flash_attention":
        bq, bk = config["block_q"], config["block_k"]
        return 4 * (bq * Dh + 2 * bk * Dh + bq * Dh + 2 * bq)
    if key.kernel == "budget_attention":
        r, g = config["bh_tile"], 8
        return 4 * r * (g * Dh + 2 * slots * Dh + slots + g * slots)
    if key.kernel == "flash_decode":
        bs = config["block_s"]
        g = 8
        return 4 * (2 * bs * Dh + 2 * g * Dh + 2 * g)
    raise TunedConfigError(key.kernel)


def candidate_space(key: TuneKey) -> List[Dict[str, int]]:
    """Legal candidate configs for one sweep key, VMEM-pruned.  The
    hand-picked default is always a member, so a sweep can never do worse
    than today's constants."""
    if key.kernel == "paged_decode":
        ps = key.page_size
        if ps <= 0:
            raise TunedConfigError("paged_decode sweeps need page_size > 0")
        # sublane-aligned divisors of the page: DMA granularity candidates
        tiles = [t for t in range(SUBLANES, ps, SUBLANES) if ps % t == 0]
        cands = [{"page_tile": t} for t in tiles + [ps]]
    elif key.kernel == "flash_attention":
        cands = [{"block_q": bq, "block_k": bk}
                 for bq in _pow2s(128, 1024) for bk in _pow2s(128, 1024)]
        if default_config(key) not in cands:       # pragma: no cover
            cands.append(default_config(key))
    elif key.kernel == "budget_attention":
        cands = [{"bh_tile": r} for r in (1, 2, 4, 8)]
    elif key.kernel == "flash_decode":
        cands = [{"block_s": s} for s in _pow2s(128, 2048)]
    else:
        raise TunedConfigError(f"unknown kernel {key.kernel!r}")
    pruned = [c for c in cands if vmem_bytes(key, c) <= VMEM_BUDGET]
    return pruned or [default_config(key)]


# ------------------------------------------------------------ bench cases ----

@dataclasses.dataclass(frozen=True)
class Workload:
    """Synthetic operand shape for one sweep cell (decode-batch rows,
    GQA heads, sequence/slot extent)."""
    B: int
    Hq: int
    Hkv: int
    S: int


def default_workload(key: TuneKey, scale: str = "full") -> Workload:
    smoke = scale == "smoke"
    if key.kernel == "paged_decode":
        nb = 4 if smoke else 16
        return Workload(B=4 if smoke else 16, Hq=8, Hkv=2,
                        S=nb * key.page_size)
    if key.kernel == "flash_attention":
        return Workload(B=2, Hq=4, Hkv=2, S=64 if smoke else 2048)
    if key.kernel == "budget_attention":
        return Workload(B=4 if smoke else 16, Hq=8, Hkv=2,
                        S=64 if smoke else 640)
    if key.kernel == "flash_decode":
        return Workload(B=2, Hq=8, Hkv=2, S=256 if smoke else 8192)
    raise TunedConfigError(key.kernel)


@dataclasses.dataclass
class KernelCase:
    """One benchable cell: operands, a config->output runner, the oracle
    output it must match, and the roofline terms of the workload."""
    key: TuneKey
    workload: Workload
    run: Callable[[Dict[str, int]], object]
    oracle_out: object
    flops: float
    hbm_bytes: float


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


def make_case(key: TuneKey, *, workload: Optional[Workload] = None,
              seed: int = 0, interpret: Optional[bool] = None) -> KernelCase:
    """Build the synthetic cell for ``key``: operands shaped like the
    production workload (ragged fills for the paged kernel, left-padding
    for prefill), the kernel runner, and its `kernels/ref.py` oracle."""
    from repro.kernels import ref
    from repro.kernels.budget_attention import budget_attention
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.kernels.flash_decode import flash_decode
    from repro.kernels.paged_decode import paged_flash_decode

    w = workload or default_workload(key)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rng = np.random.default_rng(seed)
    Dh = key.head_dim
    if key.kernel == "paged_decode":
        bs = key.page_size
        nb = w.S // bs
        N = w.B * nb + 2
        q = _rand(rng, (w.B, w.Hq, Dh))
        k_pool = _rand(rng, (N, w.Hkv, bs, Dh))
        v_pool = _rand(rng, (N, w.Hkv, bs, Dh))
        pos_pool = jnp.asarray(rng.integers(0, 999, (N, bs)), jnp.int32)
        bt = jnp.asarray(
            rng.permutation(np.arange(1, N))[:w.B * nb].reshape(w.B, nb),
            jnp.int32)
        # ragged fills — the serving state the fill-aware exit lives in
        fill = jnp.asarray([(b % nb) * bs + bs // 2 + 1
                            for b in range(w.B)], jnp.int32)

        def run(config):
            return paged_flash_decode(q, k_pool, v_pool, pos_pool, bt, fill,
                                      page_tile=config["page_tile"],
                                      interpret=interpret)

        oracle = ref.paged_decode_ref(q, k_pool, v_pool, pos_pool, bt, fill)
        live = float(jnp.sum(fill))
        flops = 4.0 * w.Hq * live * Dh
        hbm = 4.0 * (2 * w.Hkv * live * Dh + 2 * w.B * w.Hq * Dh)
    elif key.kernel == "flash_attention":
        q = _rand(rng, (w.B, w.S, w.Hq, Dh))
        k = _rand(rng, (w.B, w.S, w.Hkv, Dh))
        v = _rand(rng, (w.B, w.S, w.Hkv, Dh))
        pos = jnp.broadcast_to(jnp.arange(w.S)[None], (w.B, w.S)
                               ).astype(jnp.int32)

        def run(config):
            return flash_attention_fwd(q, k, v, pos, pos,
                                       block_q=config["block_q"],
                                       block_k=config["block_k"],
                                       interpret=interpret)

        oracle = ref.flash_attention_ref(q, k, v, pos, pos)
        flops = 2.0 * w.B * w.Hq * w.S * w.S * Dh          # causal half
        hbm = 4.0 * w.B * w.S * Dh * (2 * w.Hq + 2 * w.Hkv)
    elif key.kernel == "budget_attention":
        q = _rand(rng, (w.B, w.Hq, Dh))
        k = _rand(rng, (w.B, w.Hkv, w.S, Dh))
        v = _rand(rng, (w.B, w.Hkv, w.S, Dh))
        pos = jnp.asarray(rng.integers(-1, 99, (w.B, w.Hkv, w.S)), jnp.int32)
        pos = pos.at[:, :, 0].set(0)

        def run(config):
            return budget_attention(q, k, v, pos,
                                    bh_tile=config["bh_tile"],
                                    interpret=interpret)

        oracle = ref.budget_attention_ref(q, k, v, pos)
        flops = 4.0 * w.B * w.Hq * w.S * Dh
        hbm = 4.0 * w.B * (2 * w.Hkv * w.S * Dh + 2 * w.Hq * Dh
                           + w.Hkv * w.S)
    elif key.kernel == "flash_decode":
        q = _rand(rng, (w.B, w.Hq, Dh))
        k = _rand(rng, (w.B, w.Hkv, w.S, Dh))
        v = _rand(rng, (w.B, w.Hkv, w.S, Dh))
        pos = jnp.asarray(rng.integers(0, 999, (w.B, w.Hkv, w.S)), jnp.int32)

        def run(config):
            return flash_decode(q, k, v, pos, block_s=config["block_s"],
                                interpret=interpret)

        oracle = ref.flash_decode_ref(q, k, v, pos)
        flops = 4.0 * w.B * w.Hq * w.S * Dh
        hbm = 4.0 * w.B * (2 * w.Hkv * w.S * Dh + 2 * w.Hq * Dh)
    else:
        raise TunedConfigError(f"unknown kernel {key.kernel!r}")
    return KernelCase(key=key, workload=w, run=run, oracle_out=oracle,
                      flops=flops, hbm_bytes=hbm)


# ------------------------------------------------------------- the sweep ----

@dataclasses.dataclass
class Candidate:
    config: Dict[str, int]
    us: Optional[float] = None
    bound_us: Optional[float] = None
    oracle_ok: Optional[bool] = None
    accepted: bool = False
    reject_reason: Optional[str] = None


def _oracle_ok(out, oracle_out, rtol=2e-5, atol=2e-5) -> bool:
    outs = out if isinstance(out, (tuple, list)) else (out,)
    oracles = (oracle_out if isinstance(oracle_out, (tuple, list))
               else (oracle_out,))
    return all(np.allclose(np.asarray(a, np.float32),
                           np.asarray(b, np.float32), rtol=rtol, atol=atol)
               for a, b in zip(outs, oracles))


def median_us(thunk: Callable[[], object], *, warmup: int = 1,
              repeats: int = 5) -> float:
    """Warm-up (compile) then median-of-k wall-clock, block_until_ready."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(thunk())
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        times.append(time.perf_counter() - t0)
    return statistics.median(times) * 1e6


def evaluate_candidate(case: KernelCase, config: Dict[str, int], *,
                       kind: Optional[str] = None, repeats: int = 5,
                       warmup: int = 1,
                       runner: Optional[Callable] = None,
                       timer: Optional[Callable] = None) -> Candidate:
    """Correctness gate -> timing -> roofline sanity for one config.

    ``runner(config)`` and ``timer(thunk, warmup=..., repeats=...)`` are
    injectable so tests can simulate a wrong kernel or an impossible
    timing; production uses the real kernel and `median_us`."""
    from repro.launch.roofline import kernel_bound_s

    kind = kind or device_kind()
    runner = runner or case.run
    timer = timer or median_us
    cand = Candidate(config=dict(config))
    cand.bound_us = kernel_bound_s(case.flops, case.hbm_bytes, kind) * 1e6
    try:
        out = runner(config)
    except Exception as e:                          # illegal config at trace
        cand.oracle_ok = False
        cand.reject_reason = f"failed to run: {e}"
        return cand
    cand.oracle_ok = _oracle_ok(out, case.oracle_out)
    if not cand.oracle_ok:
        cand.reject_reason = "output disagrees with the ref oracle"
        return cand
    cand.us = float(timer(lambda: runner(config), warmup=warmup,
                          repeats=repeats))
    if cand.us < cand.bound_us:
        cand.reject_reason = (
            f"measured {cand.us:.2f}us beats the roofline bound "
            f"{cand.bound_us:.2f}us — a measurement bug, not a win")
        return cand
    cand.accepted = True
    return cand


@dataclasses.dataclass
class SweepResult:
    key: TuneKey
    kind: str
    workload: Workload
    candidates: List[Candidate]
    winner: Optional[Candidate]
    default_us: Optional[float]

    def report_rows(self) -> List[dict]:
        rows = []
        for c in self.candidates:
            rows.append(dict(
                kernel=self.key.kernel, key=self.key.s,
                device_kind=self.kind, config=c.config, us=c.us,
                roofline_bound_us=c.bound_us, oracle_ok=c.oracle_ok,
                accepted=c.accepted, reject_reason=c.reject_reason,
                winner=(self.winner is not None
                        and c.config == self.winner.config),
                default_us=self.default_us,
                speedup_vs_default=(
                    self.default_us / c.us
                    if c.us and self.default_us else None)))
        return rows


def sweep(key: TuneKey, *, kind: Optional[str] = None,
          workload: Optional[Workload] = None, seed: int = 0,
          repeats: int = 5, warmup: int = 1,
          runner_factory: Optional[Callable] = None,
          timer: Optional[Callable] = None) -> SweepResult:
    """Sweep one key's candidate space; the winner is the fastest candidate
    that passed BOTH the ref-oracle check and the roofline sanity bound."""
    kind = kind or device_kind()
    case = make_case(key, workload=workload, seed=seed)
    runner = runner_factory(case) if runner_factory else None
    cands = [evaluate_candidate(case, cfg, kind=kind, repeats=repeats,
                                warmup=warmup, runner=runner, timer=timer)
             for cfg in candidate_space(key)]
    accepted = [c for c in cands if c.accepted]
    winner = min(accepted, key=lambda c: c.us) if accepted else None
    dflt = default_config(key)
    default_us = next((c.us for c in cands if c.config == dflt and c.us),
                      None)
    return SweepResult(key=key, kind=kind,
                       workload=workload or default_workload(key),
                       candidates=cands, winner=winner,
                       default_us=default_us)


def persist(results: Sequence[SweepResult], *, kind: Optional[str] = None,
            directory: Optional[str] = None) -> str:
    """Merge sweep winners into ``<dir>/<kind>.json`` (schema-validated on
    read AND write).  Only accepted winners — configs that passed the
    oracle and roofline checks — are ever written; sweeps with no winner
    are skipped."""
    kind = kind or device_kind()
    directory = directory or tuned_dir()
    path = os.path.join(directory, f"{kind}.json")
    entries: Dict[str, dict] = {}
    if os.path.exists(path):
        with open(path) as f:
            entries = dict(validate_tuned(json.load(f), kind=kind))
    for r in results:
        if r.winner is None:
            continue
        entries[r.key.s] = dict(config=r.winner.config, source="tuned",
                                us=round(r.winner.us, 3), oracle_ok=True,
                                roofline_ok=True)
    data = dict(schema=SCHEMA_VERSION, device_kind=kind,
                entries=dict(sorted(entries.items())))
    validate_tuned(data, kind=kind)
    os.makedirs(directory, exist_ok=True)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")
    reset_cache()
    return path
