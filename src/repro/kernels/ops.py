"""jit'd public wrappers for the Pallas kernels.

``interpret`` auto-detects the backend: on CPU (this container) the kernel
body executes in interpret mode — bit-accurate semantics, Python speed; on
TPU it compiles to Mosaic.  ``use_kernels(False)`` flips every wrapper to its
pure-jnp oracle (the production fallback / A-B testing switch).
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.budget_attention import budget_attention as _budget_attention
from repro.kernels.flash_attention import flash_attention_fwd as _flash_attention_fwd
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.paged_decode import paged_flash_decode as _paged_flash_decode
from repro.kernels.rkv_scores import rkv_scores as _rkv_scores

_STATE = {"enabled": True}


def use_kernels(enabled: bool):
    _STATE["enabled"] = enabled


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def budget_attention(q, k, v, pos):
    if not _STATE["enabled"]:
        return ref.budget_attention_ref(q, k, v, pos)
    return _budget_attention(q, k, v, pos, interpret=_interpret())


def flash_decode(q, k, v, pos, *, block_s: int = 512):
    if not _STATE["enabled"]:
        return ref.flash_decode_ref(q, k, v, pos)
    return _flash_decode(q, k, v, pos, block_s=block_s, interpret=_interpret())


def paged_flash_decode(q, k_pool, v_pool, pos_pool, block_tables, fill,
                       k_scale=None, v_scale=None):
    """``k_scale``/``v_scale`` (N, Hkv) switch on the dequantizing path for
    int8/fp8 pools (kvcache/paged.py quantized storage)."""
    if not _STATE["enabled"]:
        if k_scale is not None:
            return ref.paged_decode_quant_ref(q, k_pool, v_pool, k_scale,
                                              v_scale, pos_pool,
                                              block_tables, fill)
        return ref.paged_decode_ref(q, k_pool, v_pool, pos_pool,
                                    block_tables, fill)
    return _paged_flash_decode(q, k_pool, v_pool, pos_pool, block_tables,
                               fill, k_scale, v_scale,
                               interpret=_interpret())


def flash_attention(q, k, v, q_positions, kv_positions, *, causal=True,
                    block_q: int = 512, block_k: int = 512):
    if not _STATE["enabled"]:
        return ref.flash_attention_ref(q, k, v, q_positions, kv_positions,
                                       causal=causal)
    return _flash_attention_fwd(q, k, v, q_positions, kv_positions,
                                block_q=block_q, block_k=block_k,
                                causal=causal, interpret=_interpret())


def rkv_scores(k_cache, k_new, importance, pos, cur_pos, *, lam=0.1,
               num_sinks=4, obs_window=8):
    if not _STATE["enabled"]:
        return ref.rkv_scores_ref(k_cache, k_new, importance, pos, cur_pos,
                                  lam=lam, num_sinks=num_sinks,
                                  obs_window=obs_window)
    return _rkv_scores(k_cache, k_new, importance, pos, cur_pos, lam=lam,
                       num_sinks=num_sinks, obs_window=obs_window,
                       interpret=_interpret())
