"""jit'd public wrappers for the Pallas kernels.

``interpret`` auto-detects the backend: on CPU (this container) the kernel
body executes in interpret mode — bit-accurate semantics, Python speed; on
TPU it compiles to Mosaic.  ``use_kernels(False)`` flips every wrapper to its
pure-jnp oracle (the production fallback / A-B testing switch).

Tunable kernel parameters resolve here, at trace time: when a caller does
not pin them explicitly, each wrapper asks `kernels.autotune` for the
(kernel, arch, head_dim, page_size) entry of the current device kind's
tuned file, falling back to the hand-picked defaults when none exists —
the bitwise-unchanged path CI pins (tests/test_autotune.py).  Every
resolution records its provenance; `config_provenance()` collapses the
record to ``"tuned"``/``"default"`` and flows into BENCH_* rows so
benchmark numbers stay attributable to the configs they ran under
(DESIGN.md §Kernel autotuning, PERFORMANCE.md).
"""
from __future__ import annotations

import jax

from repro.kernels import autotune as _at
from repro.kernels import ref
from repro.kernels.budget_attention import budget_attention as _budget_attention
from repro.kernels.flash_attention import flash_attention_fwd as _flash_attention_fwd
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.paged_decode import paged_flash_decode as _paged_flash_decode
from repro.kernels.rkv_scores import rkv_scores as _rkv_scores

_STATE = {"enabled": True}
# last resolution source per kernel ("tuned" | "default"); explicit caller
# overrides bypass resolution and leave no record
_SOURCES: dict = {}


def use_kernels(enabled: bool):
    _STATE["enabled"] = enabled


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(kernel: str, *, head_dim: int, page_size: int = 0) -> dict:
    cfg, src = _at.get_tuned_config(
        kernel, _at.tune_key(kernel, head_dim=head_dim, page_size=page_size))
    _SOURCES[kernel] = src
    return cfg


def config_sources() -> dict:
    """Per-kernel provenance of the configs resolved so far."""
    return dict(_SOURCES)


def config_provenance() -> str:
    """``"tuned"`` if any kernel resolved a tuned entry, else ``"default"``
    — the value BENCH_* rows carry as ``config_source``."""
    return "tuned" if "tuned" in _SOURCES.values() else "default"


def reset_config_sources():
    """Clear the provenance record (benchmarks call this per phase)."""
    _SOURCES.clear()


def budget_attention(q, k, v, pos, *, bh_tile: int = None):
    if not _STATE["enabled"]:
        return ref.budget_attention_ref(q, k, v, pos)
    if bh_tile is None:
        bh_tile = _resolve("budget_attention",
                           head_dim=q.shape[-1])["bh_tile"]
    return _budget_attention(q, k, v, pos, bh_tile=bh_tile,
                             interpret=_interpret())


def flash_decode(q, k, v, pos, *, block_s: int = None):
    if not _STATE["enabled"]:
        return ref.flash_decode_ref(q, k, v, pos)
    if block_s is None:
        block_s = _resolve("flash_decode", head_dim=q.shape[-1])["block_s"]
    return _flash_decode(q, k, v, pos, block_s=block_s, interpret=_interpret())


def paged_flash_decode(q, k_pool, v_pool, pos_pool, block_tables, fill,
                       k_scale=None, v_scale=None, *, page_tile: int = None):
    """``k_scale``/``v_scale`` (N, Hkv) switch on the dequantizing path for
    int8/fp8 pools (kvcache/paged.py quantized storage)."""
    if not _STATE["enabled"]:
        if k_scale is not None:
            return ref.paged_decode_quant_ref(q, k_pool, v_pool, k_scale,
                                              v_scale, pos_pool,
                                              block_tables, fill)
        return ref.paged_decode_ref(q, k_pool, v_pool, pos_pool,
                                    block_tables, fill)
    if page_tile is None:
        page_tile = _resolve("paged_decode", head_dim=q.shape[-1],
                             page_size=k_pool.shape[2])["page_tile"]
    return _paged_flash_decode(q, k_pool, v_pool, pos_pool, block_tables,
                               fill, k_scale, v_scale, page_tile=page_tile,
                               interpret=_interpret())


def flash_attention(q, k, v, q_positions, kv_positions, *, causal=True,
                    block_q: int = None, block_k: int = None):
    if not _STATE["enabled"]:
        return ref.flash_attention_ref(q, k, v, q_positions, kv_positions,
                                       causal=causal)
    if block_q is None or block_k is None:
        cfg = _resolve("flash_attention", head_dim=q.shape[-1])
        block_q = cfg["block_q"] if block_q is None else block_q
        block_k = cfg["block_k"] if block_k is None else block_k
    return _flash_attention_fwd(q, k, v, q_positions, kv_positions,
                                block_q=block_q, block_k=block_k,
                                causal=causal, interpret=_interpret())


def rkv_scores(k_cache, k_new, importance, pos, cur_pos, *, lam=0.1,
               num_sinks=4, obs_window=8):
    if not _STATE["enabled"]:
        return ref.rkv_scores_ref(k_cache, k_new, importance, pos, cur_pos,
                                  lam=lam, num_sinks=num_sinks,
                                  obs_window=obs_window)
    return _rkv_scores(k_cache, k_new, importance, pos, cur_pos, lam=lam,
                       num_sinks=num_sinks, obs_window=obs_window,
                       interpret=_interpret())
