"""Pure-jnp oracles for every Pallas kernel (tested with assert_allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30
BIG = 1e30


def budget_attention_ref(q, k, v, pos):
    """Oracle for kernels.budget_attention."""
    B, Hq, Dh = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, kf) / jnp.sqrt(jnp.float32(Dh))
    valid = (pos >= 0)[:, :, None, :]
    s = jnp.where(valid, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid, p, 0.0)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, vf)
    return o.reshape(B, Hq, Dh).astype(q.dtype), p.sum(axis=2)


def flash_decode_ref(q, k, v, pos):
    out, _ = budget_attention_ref(q, k, v, pos)
    return out


def paged_decode_ref(q, k_pool, v_pool, pos_pool, block_tables, fill):
    """Oracle for kernels.paged_decode: gather each row's page chain from
    the pool, then masked decode attention (invalid = unwritten slot,
    padding position, or unmapped page)."""
    B, Hq, Dh = q.shape
    N, Hkv, bs, _ = k_pool.shape
    nb = block_tables.shape[1]
    bt = jnp.maximum(block_tables, 0)
    k = jnp.moveaxis(k_pool[bt], 2, 1).reshape(B, Hkv, nb * bs, Dh)
    v = jnp.moveaxis(v_pool[bt], 2, 1).reshape(B, Hkv, nb * bs, Dh)
    pos = pos_pool[bt].reshape(B, nb * bs)
    slot = jnp.arange(nb * bs)[None, :]
    mapped = jnp.repeat(block_tables >= 0, bs, axis=1)
    valid = (pos >= 0) & (slot < fill[:, None]) & mapped        # (B, nb*bs)
    pos = jnp.where(valid, pos, -1)
    out, _ = budget_attention_ref(
        q, k, v, jnp.broadcast_to(pos[:, None, :], (B, Hkv, nb * bs)))
    return out


def paged_decode_quant_ref(q, k_pool, v_pool, k_scale, v_scale, pos_pool,
                           block_tables, fill):
    """Oracle for the dequantizing kernels.paged_decode path: per-(page,
    kv-head) scales (N, Hkv) expand over each page tile, the int8/fp8 pools
    dequantize to float32, then the plain paged oracle runs — exactly the
    in-register dequant the kernel performs, in gather form."""
    kf = k_pool.astype(jnp.float32) * k_scale[:, :, None, None]
    vf = v_pool.astype(jnp.float32) * v_scale[:, :, None, None]
    return paged_decode_ref(q, kf, vf, pos_pool, block_tables, fill)


def flash_attention_ref(q, k, v, q_positions, kv_positions, causal=True):
    """Oracle for kernels.flash_attention_fwd.  (B,S,H,D) layouts."""
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(Dh))
    msk = (kv_positions >= 0)[:, None, None, None, :]
    if causal:
        cm = q_positions[:, :, None] >= kv_positions[:, None, :]
        msk = msk & cm[:, None, None, :, :]
    s = jnp.where(msk, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(msk, p, 0.0)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def rkv_scores_ref(k_cache, k_new, importance, pos, cur_pos, *,
                   lam=0.1, num_sinks=4, obs_window=8):
    """Oracle for kernels.rkv_scores."""
    valid = pos >= 0
    denom = jnp.max(jnp.where(valid, importance, 0.0), axis=-1, keepdims=True) + 1e-6
    imp_n = importance / denom
    kc = k_cache.astype(jnp.float32)
    kn = k_new.astype(jnp.float32)
    dot = jnp.einsum("bhsd,bhd->bhs", kc, kn)
    cos = dot / (jnp.linalg.norm(kc, axis=-1)
                 * jnp.linalg.norm(kn, axis=-1)[..., None] + 1e-6)
    score = lam * imp_n + (1.0 - lam) * (1.0 - cos)
    score = jnp.where(valid, score, NEG)
    protected = valid & ((pos < num_sinks)
                         | (pos > cur_pos[:, None, None] - obs_window))
    return jnp.where(protected, BIG, score)
