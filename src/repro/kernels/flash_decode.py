"""Pallas TPU kernel: flash-decode — 1-token attention over a LONG dense KV
cache, tiled over slots with an online softmax.

This is the memory-wall *baseline* path (dense decode_32k / long_500k cells):
the cache no longer fits a single VMEM tile, so slots stream through VMEM in
``block_s`` tiles; running max / normalizer / weighted accumulator live in
VMEM scratch across the (sequential) slot-tile grid dimension.  No eviction
scores are produced — dense caches never evict.

TPU mapping: grid = (B*Hkv, S // block_s); the slot dim is the innermost
(sequential) grid axis, so Mosaic revisits the same (G, Dh) scratch while
double-buffering the K/V tile loads (compute/DMA overlap for free).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, acc, m_s, l_s, *,
            scale: float, ns: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].astype(jnp.float32)                    # (G, Dh)
    k = k_ref[0].astype(jnp.float32)                    # (bs, Dh)
    v = v_ref[0].astype(jnp.float32)
    valid = pos_ref[0] >= 0                             # (bs,)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, :], s, NEG)               # (G, bs)
    m_prev = m_s[...]                                   # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc[...] = acc[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == ns - 1)
    def _finish():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 pos: jnp.ndarray, *, block_s: int = 512,
                 interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Dh); k/v: (B, Hkv, S, Dh); pos: (B, Hkv, S).
    Returns out (B, Hq, Dh)."""
    B, Hq, Dh = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    BH = B * Hkv
    bs = min(block_s, S)
    pad = (-S) % bs
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, 0), (0, pad)), constant_values=-1)
    Sp = S + pad
    ns = Sp // bs
    qf = q.reshape(BH, G, Dh)
    kf = k.reshape(BH, Sp, Dh)
    vf = v.reshape(BH, Sp, Dh)
    posf = pos.reshape(BH, Sp)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (Dh ** 0.5), ns=ns),
        grid=(BH, ns),
        in_specs=[
            pl.BlockSpec((1, G, Dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bs, Dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, Dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, G, Dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, posf)
    return out.reshape(B, Hq, Dh)
