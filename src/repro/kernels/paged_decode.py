"""Pallas TPU kernel: paged flash-decode — 1-token attention over a
block-table-paged KV pool (the serving path behind prefix sharing).

Same online-softmax loop as `kernels/flash_decode.py`, but K/V tiles are not
contiguous per row: each row's cache is a chain of fixed-size pool pages
named by a block table, so shared prompt pages are read G times without
being stored G times (`kvcache/paged.py`; DESIGN.md §Paged cache & prefix
sharing).  The table is a *scalar-prefetch* operand — Mosaic reads it before
the kernel body runs, so each grid step's page id feeds the K/V BlockSpec
index maps directly and the gather costs nothing beyond the DMA it would
issue anyway.

TPU mapping:
  * grid = (B, Hkv, nb): one program chain per (row, kv head); the page dim
    ``nb`` is the innermost (sequential) axis, so Mosaic revisits the same
    scratch while double-buffering page loads (compute/DMA overlap).
  * scalar prefetch: block_tables (B, nb), fill (B,) and the derived
    num_pages (B,) = ceil(fill / bs) live in SMEM; index maps clamp unmapped
    entries (-1) to page 0, and the in-kernel mask (slot >= fill, pos < 0,
    unmapped page) zeroes their contribution.
  * fill-aware early exit: a row only has ``num_pages[b]`` live pages — a
    freshly admitted row's table maps its whole generation head-room, but
    everything past ceil(fill/bs) is unwritten.  The K/V index maps clamp
    the page index to ``num_pages[b] - 1``, so every trailing grid step
    re-addresses the page already resident in VMEM and Mosaic elides the
    DMA (the block index did not change); the kernel body is ``pl.when``-
    guarded on ``j < num_pages[b]`` so those steps are pure no-ops.  A row
    whose fill is one page costs one page of K/V traffic, not nb.
  * VMEM scratch: acc (G, Dh) f32 weighted accumulator, m/l (G, 1) f32
    running max / normalizer — carried across the nb sequential steps,
    finalized into o_ref on the last grid step (which may itself be a
    skipped page: the scratch simply passes through).
  * blocks: the GQA query group (G, Dh) and one (bs, Dh) page tile resident
    per step; Dh = 128 aligns the MXU contraction, bs is a multiple of the
    sublane count (>= 8) for dense tiling.

Quantized pools (`kvcache/paged.py` ``quant="int8"|"fp8"``) stream their
per-(page, kv-head) float32 scales through the same clamped block-table
index maps as the pages themselves — one (1, 1) scale tile per K and V —
and dequantize in-register at the top of the softmax update, so the pool
crosses HBM at quantized width and the arithmetic stays f32.

Tunables (kernels/autotune.py; performance model in PERFORMANCE.md):
  * ``page_tile`` — the K/V tile width per sequential grid step, a
    sublane-aligned divisor of the page size.  ``page_tile == page_size``
    (the default) is today's one-page-per-step kernel, bit-for-bit; smaller
    tiles trade more grid steps for a finer fill-aware exit (a row whose
    fill ends mid-page stops DMAing at the tile holding its last token,
    not the page end) and a smaller VMEM working set.  Resolved at trace
    time via `kernels.autotune.get_tuned_config`, falling back to the
    hand-picked default when no tuned entry exists for this device kind.

Oracle: `kernels.ref.paged_decode_ref` (gather + masked softmax) and
`kernels.ref.paged_decode_quant_ref` (dequantize, then gather), tested
with assert_allclose; `kernels.ops.paged_flash_decode` is the dispatching
wrapper (interpret mode on CPU, Mosaic on TPU, jnp fallback switchable).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(bt_ref, fill_ref, ntiles_ref, q_ref, k_ref, v_ref, pos_ref,
            *refs, scale: float, pt: int, tpp: int, nt: int, quantized: bool):
    # quantized pools add two (1, 1) per-(page, head) scale operands right
    # after pos; the trailing refs are always (out, 3 scratch)
    if quantized:
        ks_ref, vs_ref, o_ref, acc, m_s, l_s = refs
    else:
        o_ref, acc, m_s, l_s = refs
    b = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)

    # fill-aware skip: tiles at/past the row's live count contribute nothing
    # (their slots are all >= fill), so the whole update is predicated out —
    # the index maps already re-addressed the resident tile, eliding the DMA
    @pl.when(t < ntiles_ref[b])
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, Dh)
        k = k_ref[0, 0].astype(jnp.float32)             # (pt, Dh)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            # in-register dequant: the page's int8/fp8 codes scale by its
            # per-(page, head) factor before entering the softmax math
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        slot = t * pt + jax.lax.broadcasted_iota(jnp.int32, (1, pt), 1)
        mapped = bt_ref[b, t // tpp] >= 0
        valid = (pos_ref[...] >= 0) & (slot < fill_ref[b]) & mapped  # (1, pt)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, NEG)                    # (G, pt) via broadcast
        m_prev = m_s[...]                               # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(t == nt - 1)
    def _finish():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("page_tile", "interpret"))
def paged_flash_decode(q: jnp.ndarray, k_pool: jnp.ndarray,
                       v_pool: jnp.ndarray, pos_pool: jnp.ndarray,
                       block_tables: jnp.ndarray, fill: jnp.ndarray,
                       k_scale: jnp.ndarray = None,
                       v_scale: jnp.ndarray = None, *,
                       page_tile: int = None,
                       interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Dh); k_pool/v_pool: (N, Hkv, bs, Dh); pos_pool: (N, bs);
    block_tables: (B, nb) int32 (-1 = unmapped); fill: (B,) int32.
    Returns out (B, Hq, Dh).

    ``k_scale``/``v_scale`` (N, Hkv) float32 switch on the dequantizing
    path for int8/fp8 pools: each page's scale rides the same clamped
    scalar-prefetch block table as the page itself, lands next to the K/V
    tile, and the codes dequantize in-register inside the softmax update —
    the quantized pool never touches HBM in fp width.  Oracle:
    `kernels.ref.paged_decode_quant_ref`.

    ``page_tile`` (autotuned; default = page size) splits each page into
    ``bs // page_tile`` sequential sub-tiles: the grid's inner axis becomes
    tiles rather than pages, the fill-aware exit truncates at tile (not
    page) granularity, and ``page_tile == bs`` reproduces the historical
    kernel exactly (same grid, same index arithmetic, same float op
    order)."""
    B, Hq, Dh = q.shape
    N, Hkv, bs, _ = k_pool.shape
    nb = block_tables.shape[1]
    G = Hq // Hkv
    quantized = k_scale is not None
    pt = bs if page_tile is None else int(page_tile)
    if pt <= 0 or bs % pt:
        raise ValueError(f"page_tile {pt} must be a positive divisor of the "
                         f"page size {bs}")
    tpp = bs // pt                 # tiles per page
    nt = nb * tpp                  # inner (sequential) grid extent
    qf = q.reshape(B, Hkv, G, Dh)
    # live tiles per row: everything past ceil(fill / pt) is unwritten
    # head-room whose slots the fill mask rejects anyway — skip it wholesale
    num_tiles = jnp.minimum(-(-fill // pt), nt).astype(jnp.int32)  # (B,)

    # index maps receive (grid indices..., *scalar-prefetch refs); the tile
    # index is clamped to the row's last live tile so skipped steps
    # re-address the resident block (same index -> the DMA is elided).
    # tile t lives at sub-tile t % tpp of page bt[b, t // tpp].
    def k_map(b, h, t, bt, fl, ntl):
        tc = jnp.maximum(jnp.minimum(t, ntl[b] - 1), 0)
        return (jnp.maximum(bt[b, tc // tpp], 0), h, tc % tpp, 0)

    def pos_map(b, h, t, bt, fl, ntl):
        tc = jnp.maximum(jnp.minimum(t, ntl[b] - 1), 0)
        return (jnp.maximum(bt[b, tc // tpp], 0), tc % tpp)

    def scale_map(b, h, t, bt, fl, ntl):
        tc = jnp.maximum(jnp.minimum(t, ntl[b] - 1), 0)
        return (jnp.maximum(bt[b, tc // tpp], 0), h)

    in_specs = [
        pl.BlockSpec((1, 1, G, Dh),
                     lambda b, h, t, bt, fl, ntl: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, pt, Dh), k_map),
        pl.BlockSpec((1, 1, pt, Dh), k_map),
        pl.BlockSpec((1, pt), pos_map),
    ]
    operands = [block_tables, fill, num_tiles, qf, k_pool, v_pool, pos_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1), scale_map),
                     pl.BlockSpec((1, 1), scale_map)]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, nt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, h, t, bt, fl, ntl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (Dh ** 0.5), pt=pt, tpp=tpp,
                          nt=nt, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(B, Hq, Dh)
