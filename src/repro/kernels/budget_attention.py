"""Pallas TPU kernel: decode attention over the fixed-budget KV cache.

THE hot spot of sparse rollouts (paper's technique): every decode step
attends a 1-token query against ``S = B_budget + B_buffer`` cached slots and
must also produce the per-slot attention mass that feeds the eviction policy
(H2O/R-KV/SnapKV score update).  The GPU reference does attention and score
accumulation as separate passes; on TPU we fuse them — one HBM read of K/V
per step, everything else stays in VMEM.

TPU mapping:
  * grid = (B * Hkv,): one program per (batch row, kv head) — embarrassingly
    parallel, no cross-program reduction.
  * blocks: the GQA query group (G, Dh) stays resident in VMEM; K/V slots
    (S, Dh) are a single VMEM tile (budget caches are <= ~2k slots; a 640 x
    128 bf16 tile is 160 KiB — trivially VMEM-resident).  Dh = 128 aligns
    the MXU contraction; G is zero-padded to the sublane count by Mosaic.
  * logits/softmax in f32 (MXU accumulates bf16 x bf16 -> f32), output cast
    back to the cache dtype.

Tunables (kernels/autotune.py; performance model in PERFORMANCE.md):
  * ``bh_tile`` — how many (row, kv-head) programs one grid step batches.
    The default 1 is the historical one-program-per-(row, head) kernel,
    bit-for-bit (it takes the original kernel body, not a degenerate tiled
    one); larger tiles amortize per-step overhead into batched
    ``dot_general`` contractions at the cost of an R x larger VMEM working
    set.  ``B * Hkv`` is zero-padded to a multiple of the tile with
    ``pos = -1`` rows, whose fully-masked softmax yields exactly zero
    output and pooled mass.  Resolved at trace time via
    `kernels.autotune.get_tuned_config`.

Oracle: `kernels.ref.budget_attention_ref` (masked softmax + group-pooled
probabilities); `kernels.ops.budget_attention` is the dispatching wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, p_ref, *, scale: float):
    q = q_ref[0].astype(jnp.float32)                    # (G, Dh)
    k = k_ref[0].astype(jnp.float32)                    # (S, Dh)
    v = v_ref[0].astype(jnp.float32)
    valid = pos_ref[0] >= 0                             # (S,)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, :], s, NEG)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[None, :], p, 0.0)
    l = jnp.sum(p, axis=1, keepdims=True)
    pn = p / jnp.maximum(l, 1e-30)                      # (G, S)
    o = jax.lax.dot_general(pn, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)
    p_ref[0] = jnp.sum(pn, axis=0)                      # pooled over the group


def _kernel_tiled(q_ref, k_ref, v_ref, pos_ref, o_ref, p_ref, *,
                  scale: float):
    # bh_tile > 1: R (row, kv-head) programs batched into one grid step via
    # batched dot_general; padded rows (pos all -1) mask to zero exactly
    q = q_ref[...].astype(jnp.float32)                  # (R, G, Dh)
    k = k_ref[...].astype(jnp.float32)                  # (R, S, Dh)
    v = v_ref[...].astype(jnp.float32)
    valid = pos_ref[...] >= 0                           # (R, S)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[:, None, :], s, NEG)            # (R, G, S)
    m = jnp.max(s, axis=2, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[:, None, :], p, 0.0)
    l = jnp.sum(p, axis=2, keepdims=True)
    pn = p / jnp.maximum(l, 1e-30)
    o = jax.lax.dot_general(pn, v, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)
    p_ref[...] = jnp.sum(pn, axis=1)                    # (R, S) group-pooled


@functools.partial(jax.jit, static_argnames=("bh_tile", "interpret"))
def budget_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     pos: jnp.ndarray, *, bh_tile: int = None,
                     interpret: bool = False):
    """q: (B, Hq, Dh); k/v: (B, Hkv, S, Dh); pos: (B, Hkv, S) (-1 = empty).

    Returns (out (B, Hq, Dh) in q.dtype, probs_pooled (B, Hkv, S) f32).

    ``bh_tile`` (autotuned; default 1) batches that many (row, kv-head)
    programs per grid step; 1 runs the historical per-program kernel body
    unchanged (bitwise-identical default path).
    """
    B, Hq, Dh = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    BH = B * Hkv
    R = 1 if bh_tile is None else int(bh_tile)
    if R <= 0:
        raise ValueError(f"bh_tile {R} must be a positive integer")
    qf = q.reshape(BH, G, Dh)
    kf = k.reshape(BH, S, Dh)
    vf = v.reshape(BH, S, Dh)
    posf = pos.reshape(BH, S)
    if R == 1:
        out, pooled = pl.pallas_call(
            functools.partial(_kernel, scale=1.0 / (Dh ** 0.5)),
            grid=(BH,),
            in_specs=[
                pl.BlockSpec((1, G, Dh), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, S, Dh), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, S, Dh), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, S), lambda i: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, G, Dh), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, S), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((BH, G, Dh), q.dtype),
                jax.ShapeDtypeStruct((BH, S), jnp.float32),
            ],
            interpret=interpret,
        )(qf, kf, vf, posf)
        return out.reshape(B, Hq, Dh), pooled.reshape(B, Hkv, S)
    # pad BH up to a multiple of the tile with empty (pos = -1) rows — their
    # fully-masked softmax contributes exactly zero output and pooled mass
    BHp = -(-BH // R) * R
    pad = BHp - BH
    if pad:
        qf = jnp.pad(qf, ((0, pad), (0, 0), (0, 0)))
        kf = jnp.pad(kf, ((0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, pad), (0, 0), (0, 0)))
        posf = jnp.pad(posf, ((0, pad), (0, 0)), constant_values=-1)
    out, pooled = pl.pallas_call(
        functools.partial(_kernel_tiled, scale=1.0 / (Dh ** 0.5)),
        grid=(BHp // R,),
        in_specs=[
            pl.BlockSpec((R, G, Dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((R, S, Dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((R, S, Dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((R, S), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((R, G, Dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((R, S), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BHp, G, Dh), q.dtype),
            jax.ShapeDtypeStruct((BHp, S), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, posf)
    return (out[:BH].reshape(B, Hq, Dh),
            pooled[:BH].reshape(B, Hkv, S))
