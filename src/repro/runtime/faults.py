"""Deterministic fault-injection plans for the self-healing runtime.

A :class:`FaultPlan` is a seeded, step-addressed schedule of injected
failures (DESIGN.md §Fault tolerance & degraded modes).  Each spec names a
fault *kind* and the phase/step index where it fires, e.g.::

    FaultPlan.parse("producer_crash@phase=3 nan_grads@step=7 "
                    "pool_exhausted_storm@phase=1*4")

The hooks that consume a plan live in ``ContinuousEngine`` (pool-exhaustion
storms), ``AsyncPipeline`` (producer crash/hang) and ``Trainer`` (NaN
gradients, checkpoint corruption, rejection storms).  Every hook is guarded
by ``if <plan> is not None`` — with no plan armed the runtime takes
*exactly* the pre-fault code path, so rollouts and updates stay
bitwise-identical to the unarmed build (pinned by ``tests/test_faults.py``).

Addressing is the trainer's own step line: ``phase=s`` and ``step=s`` name
the same integer (one rollout phase drives one learner step); the two
spellings exist so a plan reads like the failure it simulates.  ``*N``
makes a spec fire on its first N matching probes (a storm's width) —
``pool_exhausted_storm@phase=1*4`` fails the first four page allocations of
phase 1.

Determinism: firing is a pure function of (plan text, probe sequence), and
every randomized payload (which rows a storm poisons, which bytes a
checkpoint corruption flips) derives from ``seed`` + the fault address —
re-running the same plan on the same config reproduces the same failure,
which is what makes the recovery matrix a regression test rather than a
flake generator.
"""
from __future__ import annotations

import re
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

#: every injectable kind -> the address axis its spec must use
FAULT_SITES: Dict[str, str] = {
    "producer_crash": "phase",        # async producer dies w/o exit marker
    "producer_hang": "phase",         # async producer stops heartbeating
    "pool_exhausted_storm": "phase",  # paged-pool alloc failures in-engine
    "rejection_storm": "phase",       # Eq. 6 vetoes most of the batch
    "nan_grads": "step",              # non-finite update (poisoned advantage)
    "corrupt_checkpoint": "step",     # bit-flip the checkpoint just saved
}

_SPEC_RE = re.compile(
    r"^(?P<kind>[a-z_]+)@(?P<site>phase|step)=(?P<at>\d+)(?:\*(?P<count>\d+))?$")


class InjectedFault(RuntimeError):
    """Base class for failures raised by an armed :class:`FaultPlan`."""


class InjectedCrash(InjectedFault):
    """Simulated hard kill of the rollout producer: the producer thread
    swallows this and dies WITHOUT enqueueing its exit marker, so recovery
    must come from the learner-side liveness poll, not the error channel."""


@dataclass(frozen=True)
class FaultSpec:
    kind: str
    at: int          # phase/step index on the trainer's step line
    count: int = 1   # matching probes that fire before the spec is spent

    def __post_init__(self):
        if self.kind not in FAULT_SITES:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; choose from "
                f"{sorted(FAULT_SITES)}")
        if self.at < 0 or self.count < 1:
            raise ValueError(f"bad fault address {self!r}")

    def __str__(self) -> str:
        s = f"{self.kind}@{FAULT_SITES[self.kind]}={self.at}"
        return s if self.count == 1 else f"{s}*{self.count}"


class FaultPlan:
    """A parsed, thread-safe, one-shot-per-count fault schedule.

    ``fire(kind, at)`` is the single probe API: it returns True (and
    consumes one count) iff an unspent spec of that kind matches ``at``.
    Probes are cheap (a dict lookup under a lock) and the runtime only
    probes when a plan is armed at all.  Every firing is appended to
    ``events`` so the fault matrix can report injected-fault -> outcome.
    """

    def __init__(self, specs: List[FaultSpec], seed: int = 0):
        self.specs = list(specs)
        self.seed = int(seed)
        self._remaining: Dict[tuple, int] = {}
        for sp in self.specs:
            key = (sp.kind, sp.at)
            self._remaining[key] = self._remaining.get(key, 0) + sp.count
        self._lock = threading.Lock()
        self.events: List[dict] = []
        self._log = None    # optional RunLog (attach_log)

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Parse ``"kind@site=N"`` / ``"kind@site=N*count"`` tokens
        (whitespace/comma separated) into a plan."""
        specs = []
        for tok in re.split(r"[,\s]+", text.strip()):
            if not tok:
                continue
            m = _SPEC_RE.match(tok)
            if not m:
                raise ValueError(
                    f"malformed fault spec {tok!r} (want kind@phase=N or "
                    f"kind@step=N, optionally *count)")
            kind, site = m.group("kind"), m.group("site")
            if kind not in FAULT_SITES:
                raise ValueError(
                    f"unknown fault kind {kind!r}; choose from "
                    f"{sorted(FAULT_SITES)}")
            if FAULT_SITES[kind] != site:
                raise ValueError(
                    f"fault {kind!r} is addressed by "
                    f"{FAULT_SITES[kind]!r}, not {site!r}")
            specs.append(FaultSpec(kind=kind, at=int(m.group("at")),
                                   count=int(m.group("count") or 1)))
        if not specs:
            raise ValueError(f"empty fault plan {text!r}")
        return cls(specs, seed=seed)

    def attach_log(self, log) -> None:
        """Report firings as structured run-log events (DESIGN.md
        §Observability & telemetry).  Optional: an unattached plan keeps
        the pre-telemetry behavior (``events`` only)."""
        self._log = log

    def fire(self, kind: str, at: int) -> bool:
        """Probe the plan at (kind, at); True consumes one count."""
        with self._lock:
            key = (kind, int(at))
            left = self._remaining.get(key, 0)
            if left <= 0:
                return False
            self._remaining[key] = left - 1
            self.events.append({"kind": kind, "at": int(at),
                                "seq": len(self.events)})
            log, seq = self._log, len(self.events) - 1
        # emit outside the lock: a console/file write never serializes
        # concurrent probes
        if log is not None:
            log.event("fault_injected", level="warn", step=int(at),
                      kind=kind, seq=seq,
                      msg=f"fault injected: {kind}@{at} (seq {seq})")
        return True

    def fired(self, kind: Optional[str] = None) -> int:
        with self._lock:
            if kind is None:
                return len(self.events)
            return sum(e["kind"] == kind for e in self.events)

    def spent(self) -> bool:
        """True once every spec has fired its full count."""
        with self._lock:
            return all(v <= 0 for v in self._remaining.values())

    def payload_rng(self, at: int) -> np.random.Generator:
        """Deterministic RNG for a fault's payload (which rows/bytes to
        poison), derived from (seed, address) only."""
        return np.random.default_rng((self.seed, int(at)))

    def __str__(self) -> str:
        return " ".join(str(sp) for sp in self.specs)


def corrupt_checkpoint_file(ckpt_path: str, *, at: int = 0,
                            seed: int = 0) -> None:
    """Bit-flip a handful of payload bytes of a saved checkpoint's
    ``arrays.npz`` in place (deterministically, from ``(seed, at)``) —
    the on-disk damage profile of a torn write or silent media corruption
    that the save-time content hash exists to catch."""
    import os

    path = os.path.join(ckpt_path, "arrays.npz")
    size = os.path.getsize(path)
    rng = np.random.default_rng((seed, int(at)))
    # skip the zip header region so np.load still *opens* the file — the
    # nastier failure mode is a checkpoint that loads but holds garbage
    offsets = sorted(set(int(o) for o in rng.integers(
        low=min(256, size - 1), high=size, size=8)))
    with open(path, "r+b") as f:
        for off in offsets:
            f.seek(off)
            b = f.read(1)
            if not b:
                continue
            f.seek(off)
            f.write(bytes([b[0] ^ 0xFF]))
