from repro.runtime.async_pipeline import AsyncPipeline, WeightStore
from repro.runtime.faults import FaultPlan, InjectedCrash, InjectedFault
from repro.runtime.trainer import Trainer, TrainerOptions

__all__ = ["Trainer", "TrainerOptions", "AsyncPipeline", "WeightStore",
           "FaultPlan", "InjectedFault", "InjectedCrash"]
