from repro.runtime.async_pipeline import AsyncPipeline, WeightStore
from repro.runtime.faults import FaultPlan, InjectedCrash, InjectedFault
from repro.telemetry import (
    MetricsRegistry,
    RunLog,
    Telemetry,
    Tracer,
)
from repro.runtime.trainer import Trainer, TrainerOptions

__all__ = ["Trainer", "TrainerOptions", "AsyncPipeline", "WeightStore",
           "FaultPlan", "InjectedFault", "InjectedCrash",
           "Telemetry", "Tracer", "MetricsRegistry", "RunLog"]
