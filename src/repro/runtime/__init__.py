from repro.runtime.trainer import Trainer, TrainerOptions

__all__ = ["Trainer", "TrainerOptions"]
