from repro.runtime.async_pipeline import AsyncPipeline, WeightStore
from repro.runtime.trainer import Trainer, TrainerOptions

__all__ = ["Trainer", "TrainerOptions", "AsyncPipeline", "WeightStore"]
