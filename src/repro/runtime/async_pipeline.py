"""Async actor-learner pipeline: overlapped rollout/update with versioned
weights, staleness-aware off-policy correction, and a self-healing
producer watchdog.

The sync Trainer alternates two serial stages — a rollout phase (the actor)
and a minibatched Sparse-RL update (the learner).  This module overlaps
them (DESIGN.md §Async pipeline & staleness correction):

  * :class:`WeightStore` — a versioned snapshot ring with refcounts.  The
    learner publishes its params after every phase update; snapshots stay
    alive while any in-flight rollout group still needs them for the
    behavior-policy rescore, and the ring evicts unreferenced history
    beyond its capacity.  JAX arrays are immutable, so a snapshot is a
    reference, not a copy — publishing is O(1).
  * a rollout **producer** thread that drives `ContinuousEngine` phase by
    phase and streams each finished group (`run(on_group=...)`) into a
    bounded staging queue.  A full queue blocks the callback inside the
    engine's scheduling loop — backpressure reaches all the way into
    admission.
  * the **learner** consumer (the caller's thread): drains the queue,
    verifies/rewards each group the moment it lands, and runs the phase's
    minibatch updates when the phase's last group arrives.  After each
    update it bumps the weight version, publishes the snapshot, and stages
    a mid-run hot-swap into the engine (`set_params`, applied at the next
    admission-sweep boundary), so groups admitted later in the producer's
    current phase already sample from the freshest weights.

``max_lag`` is the backpressure bound: the producer may run at most
``max_lag`` phases ahead of the learner's completed-update count —
rollout phase ``s`` waits until updates through ``s - max_lag - 1``.  At
``max_lag=0`` the handoff fully serializes — rollout ``s`` starts only
after update ``s-1`` — and because the staleness ratio degenerates to 1.0
bitwise (see `core/sparse_rl.py`), the pipeline is token-, logp- and
param-identical to the sync trainer (pinned by the e2e test).  At
``max_lag>=1`` phase ``s+1``'s rollout overlaps update ``s``; the measured
weight staleness is absorbed by the loss's clipped per-token behavior
ratio, fed from the per-token weight versions the engine records across
hot-swaps.

Watchdog & restart (DESIGN.md §Fault tolerance & degraded modes): the
producer stamps a heartbeat at every phase barrier, group finish and
queue-put; the learner polls ``queue.get(timeout=...)`` and, on a dead
thread (no exit marker — an interpreter-level kill) or a stale heartbeat
(a hang), restarts the producer from the last phase barrier with bounded
exponential backoff.  Every queue item carries the producer *generation*:
a bumped generation invalidates the old thread's puts (its next put raises
and the thread unwinds), the learner discards stale items (releasing their
WeightStore pins), aborts the engine's half-rolled phase, and respawns
from ``_done_step``.  The retry is token-identical because per-phase keys
are ``fold_in(root, step)`` and per-request chains fold uids — nothing
about the failed attempt leaks into the retry's sampling.

Thread model: exactly two threads touch trainer state, with a strict
split — the producer reads the loader/WeightStore and owns the engine; the
learner owns ``params``/``opt_state``/``step`` and never touches the
engine beyond the (atomic) ``set_params`` staging.  All crossings go
through the staging queue or the WeightStore's lock.  A restart never
overlaps producers: the learner spawns generation g+1 only after
generation g's thread is provably dead (join), so engine ownership passes
cleanly.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.rewards import binary_rewards
from repro.rollout import Request, build_train_rollout
from repro.runtime.faults import InjectedCrash


class WeightStore:
    """Versioned ring of immutable param snapshots with refcounts.

    ``publish`` assigns monotonically increasing versions (starting at
    ``start_version`` so a resumed run continues its checkpointed version
    line).  ``acquire``/``release`` pin a snapshot across the
    producer->learner handoff; eviction drops only unreferenced snapshots,
    oldest first, once the ring exceeds ``capacity`` — a referenced
    snapshot is never dropped, so ``capacity`` bounds garbage, not safety.
    Acquiring an evicted version raises ``KeyError``: with the pipeline's
    ``max_lag`` gating and ``capacity >= max_lag + 2`` that is a real
    bookkeeping bug, not an expected race.
    """

    def __init__(self, capacity: int = 4, start_version: int = 0):
        if capacity < 1:
            raise ValueError("WeightStore capacity must be >= 1")
        self.capacity = capacity
        self._next = start_version
        self._snaps: "OrderedDict[int, list]" = OrderedDict()  # v -> [params, refs]
        self._lock = threading.Lock()

    def publish(self, params) -> int:
        with self._lock:
            v = self._next
            self._next += 1
            self._snaps[v] = [params, 0]
            self._evict_locked()
            return v

    def _evict_locked(self) -> None:
        # oldest-first, unreferenced only, never the newest snapshot
        for v in list(self._snaps):
            if len(self._snaps) <= self.capacity:
                break
            if self._snaps[v][1] == 0 and v != next(reversed(self._snaps)):
                del self._snaps[v]

    def acquire(self, version: Optional[int] = None):
        """Pin and return ``(version, params)``; None pins the newest."""
        with self._lock:
            if not self._snaps:
                raise KeyError("WeightStore is empty")
            if version is None:
                version = next(reversed(self._snaps))
            snap = self._snaps[version]     # KeyError on evicted = real bug
            snap[1] += 1
            return version, snap[0]

    def release(self, version: int) -> None:
        with self._lock:
            snap = self._snaps.get(version)
            if snap is None or snap[1] <= 0:
                raise ValueError(f"release of unheld version {version}")
            snap[1] -= 1
            self._evict_locked()

    @property
    def latest_version(self) -> int:
        with self._lock:
            return next(reversed(self._snaps)) if self._snaps else self._next - 1

    def refs(self, version: int) -> int:
        with self._lock:
            return self._snaps[version][1]

    def __len__(self) -> int:
        with self._lock:
            return len(self._snaps)

    def __contains__(self, version: int) -> bool:
        with self._lock:
            return version in self._snaps


# ---------------------------------------------------------------------------
# staging-queue items (producer -> learner), strictly phase-ordered within a
# producer generation; `gen` lets the learner drop a dead generation's
# stragglers after a watchdog restart
# ---------------------------------------------------------------------------
@dataclass
class _PhaseStart:
    step: int
    np_tokens: np.ndarray        # (total, P) tiled prompts
    np_mask: np.ndarray          # (total, P)
    answers_rep: list            # per-uid answers
    n_groups: int
    gen: int = 0


@dataclass
class _Group:
    step: int
    gid: int
    comps: list                  # G Completions, uid-ascending
    params_by_ver: dict          # version -> params (store refs held)
    rewards: Optional[np.ndarray] = None   # filled by the learner on arrival
    gen: int = 0


@dataclass
class _PhaseEnd:
    step: int
    stats: Dict[str, float]
    rollout_s: float
    gen: int = 0


@dataclass
class _ProducerExit:
    error: Optional[BaseException] = None
    gen: int = 0


@dataclass
class _PhaseBuf:
    meta: _PhaseStart
    groups: Dict[int, _Group] = field(default_factory=dict)


class AsyncPipeline:
    """Overlapped producer/consumer driver around a configured Trainer.

    Built by ``Trainer.train`` when ``opts.pipeline == "async"``; reuses
    the trainer's engine, loader, jitted update programs and checkpoint
    schedule, so the learner side is the sync trainer's phase update
    verbatim (which is what makes the lag-0 equivalence provable rather
    than approximate).
    """

    def __init__(self, trainer):
        opts = trainer.opts
        if trainer.engine is None:
            raise ValueError("AsyncPipeline requires the continuous engine")
        self.t = trainer
        self.max_lag = opts.max_lag
        self.store = WeightStore(
            capacity=opts.weight_ring or (opts.max_lag + 2),
            start_version=trainer.weight_version)
        # bounded staging queue: group payloads + the light phase markers
        qsize = opts.stage_groups or max(2 * opts.num_prompts, 4)
        self.queue: "queue.Queue" = queue.Queue(maxsize=qsize)
        self._cv = threading.Condition()
        self._done_step = trainer.step      # steps whose update completed
        self._stop = False
        # -- watchdog / restart state --
        # (DESIGN.md §Fault tolerance & degraded modes)
        self.faults = trainer.faults
        self.watchdog_timeout = opts.watchdog_timeout
        self.max_restarts = opts.max_producer_restarts
        self.restart_backoff = opts.restart_backoff
        self.restarts = 0
        self._gen = 0                       # live producer generation
        self._heartbeat = time.monotonic()
        self._producer: Optional[threading.Thread] = None
        self._final_step = trainer.step
        self._phases: Dict[int, _PhaseBuf] = {}

    # -- producer (background thread) -----------------------------------
    def _beat(self) -> None:
        self._heartbeat = time.monotonic()

    def _put(self, item, gen: int) -> None:
        """queue.put that stays interruptible if the learner died or this
        producer generation was superseded by a watchdog restart."""
        while True:
            try:
                self.queue.put(item, timeout=0.2)
                return
            except queue.Full:
                # backpressured, not hung: the learner is mid-update
                self._beat()
                if self._stop or gen != self._gen:
                    raise RuntimeError("pipeline stopped")

    def _hang(self, gen: int) -> None:
        """``producer_hang`` injection: stop heartbeating but stay alive
        (``is_alive()`` keeps returning True), so only the staleness branch
        of the watchdog can detect it; unwinds once superseded."""
        while not self._stop and gen == self._gen:
            time.sleep(0.01)

    def _produce(self, start: int, end: int, gen: int) -> None:
        t = self.t
        opts, scfg = t.opts, t.scfg
        G, slack = scfg.group_size, opts.group_slack

        def _tick() -> None:
            # engine-side heartbeat (once per scheduling-loop iteration):
            # without it, any long in-engine stretch with no finished group
            # — a cold XLA compile, a pool-retry storm, a slow decode batch
            # — reads as a wedged producer and trips a false watchdog
            # restart.  Doubles as the cancellation point that lets a
            # superseded generation unwind out of a half-rolled phase at
            # the next iteration instead of decoding to phase end.
            self._beat()
            if self._stop or gen != self._gen:
                raise RuntimeError("pipeline stopped")

        t.engine.heartbeat = _tick
        try:
            for s in range(start, end):
                with self._cv:
                    # max_lag backpressure: do not run ahead of the learner
                    while s - self._done_step > self.max_lag:
                        if self._stop or gen != self._gen:
                            return
                        self._beat()        # gated by design, not hung
                        self._cv.wait(0.2)
                    if self._stop or gen != self._gen:
                        return
                self._beat()
                if self.faults is not None:
                    if self.faults.fire("producer_crash", s):
                        raise InjectedCrash(
                            f"injected producer crash @phase={s}")
                    if self.faults.fire("producer_hang", s):
                        self._hang(gen)
                        return
                np_tokens, np_mask, answers_rep = t.tiled_phase_inputs(s)
                self._put(_PhaseStart(step=s, np_tokens=np_tokens,
                                      np_mask=np_mask,
                                      answers_rep=answers_rep,
                                      n_groups=opts.num_prompts, gen=gen),
                          gen)
                t0 = time.time()
                ver, params_v = self.store.acquire()    # freshest snapshot
                try:
                    if self.faults is not None:
                        t.engine.arm_faults(self.faults, s)
                    t.engine.begin_phase(params=params_v,
                                         base_key=t.phase_key(s),
                                         weight_version=ver)
                    reqs = [Request(uid=u, prompt=np_tokens[u][np_mask[u]])
                            for u in range(np_tokens.shape[0])]

                    def on_group(gid: int, comps: list, _s=s) -> None:
                        # pin every sampler version this group's tokens
                        # used BEFORE queueing (the learner releases after
                        # its update); blocking put = engine-wide
                        # backpressure
                        self._beat()
                        by_ver = {}
                        for c in comps:
                            for v in np.unique(c.tok_versions):
                                v = int(v)
                                if v not in by_ver:
                                    by_ver[v] = self.store.acquire(v)[1]
                        try:
                            self._put(_Group(step=_s, gid=gid, comps=comps,
                                             params_by_ver=by_ver, gen=gen),
                                      gen)
                        except BaseException:
                            for v in by_ver:
                                self.store.release(v)
                            raise

                    # this span records on the PRODUCER thread — its tid
                    # (and the engine spans nested under it) land on the
                    # producer's trace track, so the overlap with the
                    # learner's update spans is visible in Perfetto
                    with t.tel.timed("rollout_phase", phase=s,
                                     role="producer", gen=gen):
                        t.engine.run(reqs, group_size=G, group_slack=slack,
                                     on_group=on_group)
                        stats = t.engine.end_phase()
                finally:
                    self.store.release(ver)
                self._put(_PhaseEnd(step=s, stats=stats,
                                    rollout_s=time.time() - t0, gen=gen),
                          gen)
            self._put(_ProducerExit(gen=gen), gen)
        except InjectedCrash:
            # simulated interpreter-level kill: die WITHOUT the exit
            # marker — recovery must come from the learner-side liveness
            # poll, which is exactly what the fault exists to exercise
            return
        except BaseException as e:                     # noqa: BLE001
            # surface the failure on the learner thread (a daemon thread's
            # traceback would otherwise vanish)
            try:
                self._put(_ProducerExit(error=e, gen=gen), gen)
            except RuntimeError:
                pass

    def _spawn(self) -> None:
        """Start the current generation's producer from the last phase
        barrier (``_done_step``) — token-identical to the phases the dead
        generation would have produced (per-phase fold_in keys)."""
        self._beat()
        self._producer = threading.Thread(
            target=self._produce,
            args=(self._done_step, self._final_step, self._gen),
            name=f"rollout-producer-g{self._gen}", daemon=True)
        self._producer.start()

    # -- learner (caller's thread) ---------------------------------------
    def _discard_item(self, item) -> None:
        """Drop a stale-generation queue item, releasing any WeightStore
        pins a buffered group still holds (a leaked ref would pin its
        snapshot in the ring forever)."""
        if isinstance(item, _Group):
            for v in item.params_by_ver:
                self.store.release(v)

    def _restart_producer(self, reason: str) -> None:
        """Bounded producer restart with backoff (the watchdog's recovery
        arm).  Ordering invariant: bump generation -> drain until the old
        thread provably exits -> discard its buffered phases (releasing
        store pins) -> abort the engine's half-rolled phase -> spawn.  The
        new generation only ever starts on a dead predecessor and a
        drained engine."""
        t = self.t
        if self.restarts >= self.max_restarts:
            raise RuntimeError(
                f"rollout producer failed {self.restarts + 1} time(s) "
                f"(last: {reason}); max_producer_restarts="
                f"{self.max_restarts} exhausted")
        self.restarts += 1
        t.resilience["producer_restarts"] += 1
        t.tel.count("resilience.watchdog_restarts")
        t.tel.instant("watchdog_restart", reason=reason,
                      restart=self.restarts)
        t.tel.log.event(
            "watchdog_restart", level="warn", step=self._done_step,
            reason=reason, restart=self.restarts,
            max_restarts=self.max_restarts,
            msg=f"[async watchdog] {reason}; restarting producer from "
                f"phase {self._done_step} (restart {self.restarts}/"
                f"{self.max_restarts})")
        old = self._producer
        with self._cv:
            self._gen += 1          # invalidates the old generation's puts
            self._cv.notify_all()   # wake a lag-gated producer to unwind
        # unblock + drain: a producer stuck in a full-queue put exits at
        # its next timeout once its generation is stale, and one busy
        # inside the engine exits at its next heartbeat tick.  The
        # deadline is deliberately looser than the watchdog bound — a
        # stale-but-busy thread may be one cold XLA compile away from its
        # next cancellation point, and waiting it out is recoverable where
        # restarting over a live engine consumer is not.
        join_bound = max(30.0, 2.0 * self.watchdog_timeout)
        deadline = time.monotonic() + join_bound
        while old is not None and old.is_alive():
            try:
                self._discard_item(self.queue.get(timeout=0.05))
            except queue.Empty:
                pass
            old.join(timeout=0.05)
            if old.is_alive() and time.monotonic() > deadline:
                raise RuntimeError(
                    f"stale rollout producer refused to exit within "
                    f"{join_bound:.0f}s; engine state cannot be "
                    f"recovered safely")
        while True:     # the dead thread can put nothing more: final drain
            try:
                self._discard_item(self.queue.get_nowait())
            except queue.Empty:
                break
        for buf in self._phases.values():
            for g in buf.groups.values():
                self._discard_item(g)
        self._phases.clear()
        t.engine.abort_phase()
        time.sleep(min(2.0, self.restart_backoff * (2 ** (self.restarts - 1))))
        self._spawn()

    def _next_item(self):
        """Watchdog ``queue.get``: poll with a timeout, and on starvation
        check (a) thread liveness — a producer that died without its exit
        marker (hard kill) — and (b) heartbeat staleness — a producer that
        is alive but wedged.  Either triggers a bounded restart.  Stale-
        generation items are discarded inline."""
        poll = max(0.05, min(0.5, self.watchdog_timeout / 4.0))
        while True:
            try:
                item = self.queue.get(timeout=poll)
            except queue.Empty:
                if not self._producer.is_alive():
                    self._restart_producer(
                        "producer thread died without an exit marker")
                elif (time.monotonic() - self._heartbeat
                        > self.watchdog_timeout):
                    self._restart_producer(
                        f"producer heartbeat stale for > "
                        f"{self.watchdog_timeout:.1f}s")
                continue
            if item.gen != self._gen:
                self._discard_item(item)
                continue
            return item

    def _group_rewards(self, meta: _PhaseStart, item: _Group) -> np.ndarray:
        """Verify a group the moment it arrives (overlapped with the
        engine's decode of the rest of the phase)."""
        T = self.t.opts.max_new_tokens
        toks = np.full((len(item.comps), T), self.t.engine.pad_id, np.int32)
        for i, c in enumerate(item.comps):
            toks[i, :len(c.tokens)] = c.tokens
        return binary_rewards(
            toks, [meta.answers_rep[c.uid] for c in item.comps])

    def _behavior_logps(self, ro, tok_versions: np.ndarray,
                        params_by_ver: dict, logp_old):
        """Per-token dense log-probs under each token's sampler-version
        weights: one teacher-forced rescore per distinct STALE version,
        gathered per token.  Tokens sampled under the learner's current
        version reuse ``logp_old`` — the proximal rescore the phase update
        needs anyway IS their behavior policy (same param arrays), so no
        forward runs twice.  Returns None when every token was sampled
        under the current weights (the lag-0 case) so the caller can take
        the sync update graph."""
        t = self.t
        distinct = [int(v) for v in np.unique(tok_versions)]
        if distinct == [t.weight_version]:
            return None
        lb = np.zeros(tok_versions.shape, np.float32)
        for v in distinct:
            if v == t.weight_version:
                lv = np.asarray(jax.device_get(logp_old))
            else:
                lv = np.asarray(jax.device_get(
                    t._rescore_fn(params_by_ver[v], ro)))
            lb = np.where(tok_versions == v, lv, lb)
        return jax.numpy.asarray(lb)

    def _phase_update(self, buf: _PhaseBuf) -> Dict[str, float]:
        t = self.t
        meta = buf.meta
        groups = [buf.groups[g] for g in sorted(buf.groups)]
        comps = [c for g in groups for c in g.comps]
        rewards = np.concatenate([g.rewards for g in groups])
        tr = build_train_rollout(
            comps, meta.np_tokens, meta.np_mask,
            max_new_tokens=t.opts.max_new_tokens, pad_id=t.engine.pad_id)
        t.last_rollout = tr.rollout
        params_by_ver: dict = {}
        for g in groups:
            params_by_ver.update(g.params_by_ver)
        logp_old = t._rescore_fn(t.params, tr.rollout)
        logp_behave = self._behavior_logps(tr.rollout, tr.tok_versions,
                                           params_by_ver, logp_old)
        agg = t._phase_update(tr.rollout, rewards, logp_behave=logp_behave,
                              logp_old=logp_old,
                              phase_ctx=dict(
                                  np_tokens=meta.np_tokens,
                                  np_mask=meta.np_mask,
                                  answers_rep=meta.answers_rep,
                                  keep=tr.keep,
                                  rng=t.phase_key(meta.step)))
        if logp_behave is not None:
            # staleness telemetry in learner-steps (the "measurable
            # fourth mismatch"): how many updates behind each token's
            # sampler snapshot was, averaged over real tokens
            mask = np.asarray(tr.rollout.resp_mask)
            lagv = (t.weight_version - 1) - tr.tok_versions  # pre-bump ver
            agg["staleness_lag"] = float(lagv[mask].mean()) if mask.any() \
                else 0.0
        else:
            agg["staleness_lag"] = 0.0
        for g in groups:
            for v in g.params_by_ver:
                self.store.release(v)
        return agg

    def train(self, steps: int, log_every: int = 10,
              callback=None) -> List[Dict[str, float]]:
        t = self.t
        if steps <= 0:
            return []
        v0 = self.store.publish(t.params)
        assert v0 == t.weight_version, (v0, t.weight_version)
        self._final_step = t.step + steps
        self._phases = {}
        self._spawn()
        history: List[Dict[str, float]] = []
        phases = self._phases
        t_step = time.time()
        try:
            while len(history) < steps:
                item = self._next_item()
                if isinstance(item, _ProducerExit):
                    if item.error is not None:
                        # a producer that crashed but managed to report is
                        # restartable exactly like one that vanished; the
                        # restart budget bounds deterministic re-crashes
                        self._restart_producer(
                            f"producer raised: {item.error!r}")
                        phases = self._phases
                        continue
                    raise RuntimeError(
                        "rollout producer exited before the learner "
                        "finished (max_lag gate out of sync?)")
                if isinstance(item, _PhaseStart):
                    phases[item.step] = _PhaseBuf(meta=item)
                elif isinstance(item, _Group):
                    buf = phases[item.step]
                    item.rewards = self._group_rewards(buf.meta, item)
                    buf.groups[item.gid] = item
                elif isinstance(item, _PhaseEnd):
                    buf = phases.pop(item.step)
                    assert len(buf.groups) == buf.meta.n_groups, \
                        (len(buf.groups), buf.meta.n_groups)
                    metrics = self._phase_update(buf)
                    metrics.update(
                        rollout_s=item.rollout_s,
                        step_time_s=time.time() - t_step,
                        producer_restarts=float(self.restarts),
                        **t._engine_stat_metrics(item.stats))
                    t_step = time.time()
                    # publish + stage the hot-swap so groups the producer
                    # admits from here on sample the fresh weights
                    v = self.store.publish(t.params)
                    assert v == t.weight_version, (v, t.weight_version)
                    t.engine.set_params(t.params, v)
                    with self._cv:
                        self._done_step = item.step + 1
                        self._cv.notify_all()
                    history.append(metrics)
                    if callback:
                        callback(t.step, metrics)
                    if log_every and t.step % log_every == 0:
                        floats = {k: v for k, v in sorted(metrics.items())
                                  if isinstance(v, float)}
                        t.tel.log.event(
                            "train_step", step=t.step, pipeline="async",
                            msg="async " + " ".join(
                                f"{k}={v:.4f}" for k, v in floats.items()),
                            **floats)
        finally:
            with self._cv:
                self._stop = True
                self._cv.notify_all()
            # drain so a blocked producer can exit, then join it — with a
            # deadline: a thread that won't die is LOUDLY reported, never
            # silently leaked (it would keep a dead run's engine pinned)
            producer = self._producer
            deadline = time.monotonic() + max(5.0, self.watchdog_timeout)
            while producer is not None and producer.is_alive():
                try:
                    self._discard_item(self.queue.get(timeout=0.1))
                except queue.Empty:
                    pass
                producer.join(timeout=0.1)
                if producer.is_alive() and time.monotonic() > deadline:
                    t.tel.log.event(
                        "producer_leak", level="error",
                        timeout_s=max(5.0, self.watchdog_timeout),
                        msg=f"[async] rollout-producer thread failed to "
                            f"exit within "
                            f"{max(5.0, self.watchdog_timeout):.0f}s of "
                            f"stop; leaking a daemon thread")
                    break
            if producer is None or not producer.is_alive():
                # producer provably gone: detach its heartbeat hook so any
                # later direct engine use doesn't trip a stale-generation
                # cancellation.  A leaked thread keeps the hook — it is the
                # only thing that can still cancel it mid-phase.
                self.t.engine.heartbeat = None
        return history
