"""RL training runtime: rollout -> verify -> re-score -> Sparse-RL update.

One Trainer drives the paper's full loop:

  1. ROLLOUT  — sample G responses/prompt from the *sparse* sampler
                (budget KV cache), recording pi_sparse per token.
  2. VERIFY   — host-side rule verifier, binary reward (paper §5.1).
  3. RESCORE  — one dense teacher-forced forward with the rollout weights
                gives pi_old for every token (the xi numerator); with
                kl_coef > 0 a second forward under the frozen reference
                policy gives the KL anchor.
  4. UPDATE   — Eq. 7 loss over minibatches of ``update_batch`` sequences
                (rollout_batch / update_batch updates per phase; the w ratio
                corrects intra-phase staleness), AdamW, global-norm clip.

Fault tolerance: auto-resume from the newest checkpoint; atomic saves every
``checkpoint_every`` steps (params, opt state, step).  Straggler mitigation:
rollouts are fixed-length lockstep (no host sync on the long tail) and groups
can be over-provisioned (``group_slack``: sample G+k, keep the G best-formed
— finished preferred).  Composes with the paper's rejection sampling.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs.base import ModelConfig, SparseRLConfig, TrainConfig
from repro.core import group_advantages, sparse_rl_loss
from repro.data import TOKENIZER, PromptLoader
from repro.models import get_model
from repro.optim import adamw
from repro.rewards import binary_rewards
from repro.rollout import generate, rescore


@dataclass
class TrainerOptions:
    num_prompts: int = 16          # prompts per rollout phase
    prompt_len: int = 24
    max_new_tokens: int = 24
    group_slack: int = 0           # over-provisioned rollouts per group
    use_ref_kl: bool = False
    level: str = "easy"
    log_samples: bool = False


class Trainer:
    def __init__(self, cfg: ModelConfig, scfg: SparseRLConfig,
                 tcfg: TrainConfig, opts: TrainerOptions,
                 rng: Optional[jax.Array] = None):
        self.cfg, self.scfg, self.tcfg, self.opts = cfg, scfg, tcfg, opts
        self.m = get_model(cfg)
        self.tok = TOKENIZER
        rng = jax.random.PRNGKey(tcfg.seed) if rng is None else rng
        self.rng, init_rng = jax.random.split(rng)
        self.params = self.m.init_params(cfg, init_rng)
        self.opt_state = adamw.init(self.params)
        self.ref_params = jax.tree.map(jnp.copy, self.params) if opts.use_ref_kl else None
        self.step = 0
        self.loader = PromptLoader(batch_prompts=opts.num_prompts,
                                   prompt_len=opts.prompt_len,
                                   seed=tcfg.seed, level=opts.level)
        self._maybe_resume()
        self._build_jit()

    # -- persistence ---------------------------------------------------------
    def _maybe_resume(self):
        last = latest_step(self.tcfg.checkpoint_dir)
        if last is not None:
            tree = {"params": self.params, "opt": self.opt_state}
            restored, step, extra = restore(self.tcfg.checkpoint_dir, tree)
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.step = step
            rng_key = extra.get("rng")
            if rng_key is not None:
                self.rng = jnp.asarray(np.array(rng_key, dtype=np.uint32))

    def save_checkpoint(self):
        save(self.tcfg.checkpoint_dir, self.step,
             {"params": self.params, "opt": self.opt_state},
             keep=self.tcfg.keep_checkpoints,
             extra={"rng": np.asarray(jax.device_get(self.rng)).tolist()})

    # -- jitted inner functions ----------------------------------------------
    def _build_jit(self):
        cfg, scfg, m = self.cfg, self.scfg, self.m

        @partial(jax.jit, static_argnames=("max_new",))
        def _rollout(params, tokens, mask, rng, max_new):
            batch = {"tokens": tokens, "valid_mask": mask}
            return generate(params, cfg, m, batch, scfg, rng,
                            max_new_tokens=max_new, eos_id=self.tok.eos_id,
                            pad_id=self.tok.pad_id)

        @jax.jit
        def _rescore(params, ro):
            return rescore(params, cfg, m, ro)

        def _loss(params, ro, logp_old, logp_ref, adv):
            logp_theta = rescore(params, cfg, m, ro)
            out = sparse_rl_loss(logp_theta, logp_old, ro.logp_sparse, adv,
                                 ro.resp_mask, scfg, logp_ref=logp_ref)
            return out.loss, out.metrics

        @jax.jit
        def _update(params, opt_state, ro, logp_old, logp_ref, adv, lr):
            (loss, metrics), grads = jax.value_and_grad(_loss, has_aux=True)(
                params, ro, logp_old, logp_ref, adv)
            params, opt_state, om = adamw.update(
                params, grads, opt_state, lr=lr,
                b1=self.tcfg.adam_b1, b2=self.tcfg.adam_b2,
                eps=self.tcfg.adam_eps, weight_decay=self.tcfg.weight_decay,
                grad_clip=self.tcfg.grad_clip)
            metrics = dict(metrics, loss=loss, **om)
            return params, opt_state, metrics

        self._rollout_fn = _rollout
        self._rescore_fn = _rescore
        self._update_fn = _update

    # -- group helpers ---------------------------------------------------------
    def _select_groups(self, ro, rewards: np.ndarray, G: int, slack: int):
        """Straggler mitigation: from G+slack rollouts per prompt keep G,
        preferring finished (EOS'd) then shorter responses."""
        if slack == 0:
            return ro, rewards
        Gs = G + slack
        lengths = np.asarray(jax.device_get(ro.lengths))
        T = ro.resp_tokens.shape[1]
        n_prompts = lengths.shape[0] // Gs
        keep_idx = []
        for p in range(n_prompts):
            rows = np.arange(p * Gs, (p + 1) * Gs)
            finished = lengths[rows] < T
            order = np.lexsort((lengths[rows], ~finished))
            keep_idx.extend(rows[order[:G]])
        keep = np.asarray(keep_idx)
        take = lambda x: x[keep]
        ro2 = jax.tree.map(lambda x: jnp.asarray(np.asarray(jax.device_get(x))[keep]), ro)
        return ro2, rewards[keep]

    # -- one full RL step -------------------------------------------------------
    def train_step(self) -> Dict[str, float]:
        t0 = time.time()
        opts, scfg, tcfg = self.opts, self.scfg, self.tcfg
        prompts, pmask, answers = self.loader.get(self.step)
        G = scfg.group_size
        Gs = G + opts.group_slack
        # tile prompts G+slack times (group-major)
        tokens = jnp.asarray(np.repeat(prompts, Gs, axis=0))
        mask = jnp.asarray(np.repeat(pmask, Gs, axis=0))
        answers_rep = list(np.repeat(np.asarray(answers, dtype=object), Gs))

        self.rng, r1 = jax.random.split(self.rng)
        ro = self._rollout_fn(self.params, tokens, mask, r1,
                              max_new=opts.max_new_tokens)
        rewards = binary_rewards(np.asarray(jax.device_get(ro.resp_tokens)),
                                 answers_rep)
        ro, rewards = self._select_groups(ro, rewards, G, opts.group_slack)

        adv = group_advantages(jnp.asarray(rewards.reshape(-1, G))).reshape(-1)
        logp_old = self._rescore_fn(self.params, ro)
        logp_ref = (self._rescore_fn(self.ref_params, ro)
                    if self.ref_params is not None else None)

        B = ro.resp_tokens.shape[0]
        ub = min(tcfg.update_batch, B)
        n_updates = max(B // ub, 1)
        lr = adamw.warmup_cosine(jnp.asarray(self.step),
                                 base_lr=scfg.learning_rate,
                                 warmup=tcfg.warmup_steps,
                                 total=tcfg.total_steps)
        agg: Dict[str, float] = {}
        for u in range(n_updates):
            sl = slice(u * ub, (u + 1) * ub)
            ro_u = jax.tree.map(lambda x: x[sl], ro)
            lo = logp_old[sl]
            lrf = logp_ref[sl] if logp_ref is not None else None
            self.params, self.opt_state, metrics = self._update_fn(
                self.params, self.opt_state, ro_u, lo, lrf, adv[sl], lr)
            for k, v in metrics.items():
                agg[k] = agg.get(k, 0.0) + float(jax.device_get(v)) / n_updates

        self.step += 1
        if tcfg.checkpoint_every and self.step % tcfg.checkpoint_every == 0:
            self.save_checkpoint()

        agg.update(
            reward=float(rewards.mean()),
            resp_len=float(jax.device_get(ro.lengths).mean()),
            entropy=float(jax.device_get(ro.entropy).mean()),
            lr=float(jax.device_get(lr)),
            step_time_s=time.time() - t0,
        )
        return agg

    def train(self, steps: int, log_every: int = 10, callback=None):
        history = []
        for _ in range(steps):
            metrics = self.train_step()
            history.append(metrics)
            if callback:
                callback(self.step, metrics)
            if log_every and self.step % log_every == 0:
                msg = " ".join(f"{k}={v:.4f}" for k, v in sorted(metrics.items())
                               if isinstance(v, float))
                print(f"[step {self.step}] {msg}", flush=True)
        return history
