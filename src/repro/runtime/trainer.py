"""RL training runtime: rollout -> verify -> re-score -> Sparse-RL update.

One Trainer drives the paper's full loop:

  1. ROLLOUT  — sample G responses/prompt from the *sparse* sampler
                (budget KV cache), recording pi_sparse per token.
  2. VERIFY   — host-side rule verifier, binary reward (paper §5.1).
  3. RESCORE  — one dense teacher-forced forward with the rollout weights
                gives pi_old for every token (the xi numerator); with
                kl_coef > 0 a second forward under the frozen reference
                policy gives the KL anchor.
  4. UPDATE   — Eq. 7 loss over minibatches of ``update_batch`` sequences
                (rollout_batch / update_batch updates per phase; the w ratio
                corrects intra-phase staleness), AdamW, global-norm clip.

Fault tolerance: auto-resume from the newest checkpoint; atomic saves every
``checkpoint_every`` steps (params, opt state, step).

Rollout backends (``rollout_backend``; DESIGN.md §Training on the continuous
engine): ``"lockstep"`` decodes every row for the full ``max_new_tokens`` in
one compiled scan — no host sync on the long tail; ``"continuous"`` streams
the phase's num_prompts x G group requests through the serving
`ContinuousEngine` — shared prompt pages prefilled once per group
(``cache_backend="paged"``), per-request EOS early-exit freeing slots for
the next group instead of lockstep's pad-to-max tail.  Both use the same
per-request sampling-key chains, so a fixed-length phase is token-identical
across backends.  Straggler mitigation composes with either: groups can be
over-provisioned (``group_slack``: sample G+k, keep G — lockstep keeps the
best-formed after the fact, continuous keeps the first G to finish and
cancels the stragglers mid-flight).

Pipelines (``pipeline``; DESIGN.md §Async pipeline & staleness correction):
``"sync"`` is the lockstep phase loop above; ``"async"`` overlaps the
rollout producer and the learner (`runtime/async_pipeline.py`): a
background thread streams finished groups from the continuous engine into
a bounded staging queue while the learner updates, with ``max_lag``
bounding how many steps the sampler weights may trail the learner and a
clipped per-token staleness correction absorbing the measured lag in the
loss.  ``max_lag=0`` serializes the handoff and is bit-identical to the
sync trainer.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs.base import ModelConfig, SparseRLConfig, TrainConfig
from repro.core import (
    group_advantages,
    mismatch_metrics,
    resolved_policy,
    sparse_rl_loss,
)
from repro.data import TOKENIZER, PromptLoader
from repro.models import get_model
from repro.optim import adamw
from repro.runtime.faults import FaultPlan, corrupt_checkpoint_file
from repro.telemetry import Telemetry
from repro.rollout import (
    ContinuousEngine,
    Request,
    RolloutBatch,
    build_train_rollout,
    generate,
    paged_rollout_geometry,
    rescore,
    resolve_policy,
    validate_engine_config,
)
from repro.rewards import binary_rewards


@dataclass
class TrainerOptions:
    num_prompts: int = 16          # prompts per rollout phase
    prompt_len: int = 24
    max_new_tokens: int = 24
    group_slack: int = 0           # over-provisioned rollouts per group
    use_ref_kl: bool = False
    level: str = "easy"
    log_samples: bool = False
    # -- rollout backend (DESIGN.md §Training on the continuous engine) --
    rollout_backend: str = "lockstep"   # "lockstep" | "continuous"
    cache_backend: str = "contiguous"   # continuous only: "contiguous"|"paged"
    sampler_policy: Optional[str] = None  # registry name (rollout.policies):
                                   # resolves scfg.compression + kv_quant in
                                   # one shot ("dense", "rkv", "per_head",
                                   # "adaptive", "quant-int8", ...).  None
                                   # keeps the legacy compression/kv_quant
                                   # pair (aliased through the same registry
                                   # — bitwise-identical, pinned by
                                   # tests/matrix/test_registry.py)
    decode_batch: int = 0          # continuous: engine row slots (0 = auto:
                                   # half the phase's requests, >= G)
    decode_chunk: int = 4          # continuous: steps between host harvests
    block_size: int = 16           # paged pool: tokens per page
    kv_quant: str = "none"         # paged pool storage: "none"|"int8"|"fp8"
                                   # — quantized rollouts are a corrected
                                   # sampler policy: logp_sparse records the
                                   # quantized-cache log-probs, the dense
                                   # rescore supplies pi_old, and the
                                   # existing xi/rejection machinery absorbs
                                   # the mismatch
                                   # (DESIGN.md §Quantized paged pool)
    prefill_chunk: Optional[int] = None  # continuous: prompt-token budget
                                   # per admission sweep (None = auto)
    overlap_harvest: bool = False  # continuous: async double-buffered
                                   # harvest (chunk t+1 dispatched before
                                   # chunk t's tokens are fetched); wins on
                                   # long-response/accelerator workloads,
                                   # costs a chunk-sized bubble per finish
    # -- actor-learner pipeline (DESIGN.md
    # §Async pipeline & staleness correction) --
    pipeline: str = "sync"         # "sync" | "async" (async requires the
                                   # continuous rollout backend)
    max_lag: int = 1               # async: max learner steps the rollout
                                   # producer may run behind (0 = lockstep
                                   # with the sync trainer, bit-identical)
    stage_groups: int = 0          # async: bounded staging-queue capacity
                                   # in groups (0 = auto: 2 phases' worth)
    weight_ring: int = 0           # async: WeightStore snapshot-ring
                                   # capacity (0 = auto: max_lag + 2)
    # -- self-healing runtime (DESIGN.md
    # §Fault tolerance & degraded modes) --
    watchdog_timeout: float = 60.0  # async: producer heartbeat staleness
                                   # bound; must exceed the longest gap
                                   # between group finishes in a phase
    max_producer_restarts: int = 2  # async: watchdog restart budget per
                                   # train() call before escalating
    restart_backoff: float = 0.1   # async: base backoff (s), doubled per
                                   # restart (capped at 2s)
    storm_threshold: float = 0.9   # rejection-storm degraded mode: phase
                                   # veto-rate above which vetoed groups
                                   # re-roll through the dense fallback
                                   # policy (1.0 effectively disables)
    anomaly_max_skips: int = 3     # anomaly guard: consecutive non-finite
                                   # updates tolerated (skipped) before
                                   # raising loudly
    faults: Optional[FaultPlan] = None  # armed fault-injection plan (None =
                                   # unarmed: every hook is a no-op and the
                                   # run is bitwise-identical to a build
                                   # without the harness, pinned by
                                   # tests/test_faults.py)
    # -- telemetry (DESIGN.md §Observability & telemetry) --
    telemetry: str = "off"         # "off" (bitwise no-op) | "metrics"
                                   # (registry only, <= 3% phase overhead)
                                   # | "trace" (spans + registry + run log)
    run_log: Optional[str] = None  # JSONL run-log path (None = console-only
                                   # rendering of the structured events)
    jax_annotations: bool = False  # trace mode: wrap host spans in
                                   # jax.profiler.TraceAnnotation so device
                                   # profiles line up with them


class Trainer:
    def __init__(self, cfg: ModelConfig, scfg: SparseRLConfig,
                 tcfg: TrainConfig, opts: TrainerOptions,
                 rng: Optional[jax.Array] = None):
        if opts.sampler_policy is not None:
            # the registry entry owns the (compression, kv_quant) pair; any
            # explicit legacy kv_quant option is superseded
            pol = resolve_policy(opts.sampler_policy)
            scfg = pol.apply(scfg)
            opts = dataclasses.replace(opts, kv_quant=pol.kv_quant)
        # registry-level validation (one home for every illegal combination;
        # DESIGN.md §Sampler policy registry).  Lockstep/contiguous runs
        # validate against their actual backend too.
        validate_engine_config(
            scfg, kv_quant=opts.kv_quant,
            cache_backend=(opts.cache_backend
                           if opts.rollout_backend == "continuous"
                           else "contiguous"),
            family=cfg.family)
        self.cfg, self.scfg, self.tcfg, self.opts = cfg, scfg, tcfg, opts
        self.m = get_model(cfg)
        self.tok = TOKENIZER
        rng = jax.random.PRNGKey(tcfg.seed) if rng is None else rng
        self.rng, init_rng = jax.random.split(rng)
        self.params = self.m.init_params(cfg, init_rng)
        self.opt_state = adamw.init(self.params)
        self.ref_params = jax.tree.map(jnp.copy, self.params) if opts.use_ref_kl else None
        self.step = 0
        self.last_rollout: Optional[RolloutBatch] = None
        self.loader = PromptLoader(batch_prompts=opts.num_prompts,
                                   prompt_len=opts.prompt_len,
                                   seed=tcfg.seed, level=opts.level)
        if opts.rollout_backend not in ("lockstep", "continuous"):
            raise ValueError(
                f"unknown rollout_backend {opts.rollout_backend!r}")
        if opts.pipeline not in ("sync", "async"):
            raise ValueError(f"unknown pipeline {opts.pipeline!r}")
        if opts.pipeline == "async":
            if opts.rollout_backend != "continuous":
                raise ValueError(
                    "pipeline='async' requires rollout_backend='continuous'"
                    " (the producer streams groups from ContinuousEngine)")
            if opts.max_lag < 0:
                raise ValueError(f"max_lag must be >= 0, got {opts.max_lag}")
            if opts.stage_groups < 0:
                raise ValueError(
                    f"stage_groups must be >= 0, got {opts.stage_groups}")
            if opts.watchdog_timeout <= 0:
                raise ValueError(
                    f"watchdog_timeout must be > 0, got "
                    f"{opts.watchdog_timeout}")
            if opts.max_producer_restarts < 0:
                raise ValueError(
                    f"max_producer_restarts must be >= 0, got "
                    f"{opts.max_producer_restarts}")
            if opts.weight_ring and opts.weight_ring < opts.max_lag + 2:
                # a ring smaller than max_lag+2 can evict a snapshot that
                # an in-flight rollout group still needs for its behavior
                # rescore — a guaranteed mid-run KeyError, not a tuning knob
                raise ValueError(
                    f"weight_ring={opts.weight_ring} < max_lag+2="
                    f"{opts.max_lag + 2}: in-flight sampler versions could "
                    f"be evicted (0 = auto)")
        if not 0.0 < opts.storm_threshold <= 1.0:
            raise ValueError(
                f"storm_threshold must be in (0, 1], got "
                f"{opts.storm_threshold}")
        if opts.anomaly_max_skips < 1:
            raise ValueError(
                f"anomaly_max_skips must be >= 1, got "
                f"{opts.anomaly_max_skips}")
        # -- telemetry (DESIGN.md §Observability & telemetry) --
        # one handle for the whole run: the engine, the async pipeline and
        # the fault runtime all report through it.  "off" is the NULL-path
        # no-op — bitwise-identical rollouts/losses, pinned by
        # tests/test_telemetry.py
        self.tel = Telemetry(opts.telemetry, run_log_path=opts.run_log,
                             jax_annotations=opts.jax_annotations)
        # -- self-healing state --
        # (DESIGN.md §Fault tolerance & degraded modes): the armed fault plan (None = every hook is a no-op),
        # cumulative recovery counters surfaced in the phase metrics, and
        # the anomaly guard's consecutive-skip tally
        self.faults = opts.faults
        if self.faults is not None:
            self.faults.attach_log(self.tel.log)
        self.resilience: Dict[str, int] = {
            "skipped_updates": 0, "producer_restarts": 0,
            "storm_rerolls": 0, "storm_phases": 0,
            "checkpoint_rollbacks": 0}
        self._consec_skips = 0
        self._dense_fallback_fn = None   # built lazily on first storm
        # rejection storms only exist where Eq. 6 can fire: rejection on
        # and a genuinely sparse sampler (an identity-class policy has
        # xi == 1 structurally, so the veto probe would be dead weight)
        self._storm_eligible = (
            scfg.reject
            and not resolved_policy(scfg, opts.kv_quant).is_dense)
        # monotone weight-version counter: bumped once per completed phase
        # update; tags rollouts for the async staleness correction and is
        # checkpointed so a resumed run keeps a consistent version line
        self.weight_version = 0
        self.engine: Optional[ContinuousEngine] = None
        if opts.rollout_backend == "continuous":
            self.engine = self._build_engine()
        self._maybe_resume()
        self._build_jit()

    def _build_engine(self) -> ContinuousEngine:
        """One ContinuousEngine for the whole run: programs compile once;
        per-phase weights/keys swap in via `begin_phase` (no recompiles)."""
        opts, scfg = self.opts, self.scfg
        total = opts.num_prompts * (scfg.group_size + opts.group_slack)
        bs = opts.decode_batch or min(total,
                                      max(scfg.group_size, total // 2))
        kw = dict(batch_size=bs, prompt_len=opts.prompt_len,
                  max_new_tokens=opts.max_new_tokens,
                  eos_id=self.tok.eos_id, pad_id=self.tok.pad_id,
                  decode_chunk=opts.decode_chunk, seed=self.tcfg.seed,
                  cache_backend=opts.cache_backend,
                  prefill_chunk=opts.prefill_chunk,
                  overlap_harvest=opts.overlap_harvest,
                  kv_quant=opts.kv_quant, telemetry=self.tel)
        if opts.cache_backend == "paged":
            # pool sizing: every resident row's chain + one pinned prompt
            # chain per distinct prompt in the phase + COW/tail headroom
            _, bpr = paged_rollout_geometry(
                scfg, opts.prompt_len, opts.max_new_tokens, opts.block_size)
            npb = -(-opts.prompt_len // opts.block_size)
            kw.update(block_size=opts.block_size,
                      pool_blocks=1 + bs * bpr + opts.num_prompts * npb
                      + 2 * bpr,
                      prefix_entries=opts.num_prompts + 4)
        return ContinuousEngine(self.params, self.cfg, self.m, scfg, **kw)

    # -- persistence ---------------------------------------------------------
    def _maybe_resume(self):
        last = latest_step(self.tcfg.checkpoint_dir)
        if last is not None:
            tree = {"params": self.params, "opt": self.opt_state}
            # restore() verifies content hashes and rolls back past corrupt
            # snapshots on its own (warning per skip); all we add here is
            # the rollback counter for the resilience telemetry
            restored, step, extra = restore(self.tcfg.checkpoint_dir, tree)
            if step != last:
                self.resilience["checkpoint_rollbacks"] += 1
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.step = step
            self.weight_version = int(extra.get("weight_version", step))
            rng_key = extra.get("rng")
            if rng_key is not None:
                self.rng = jnp.asarray(np.array(rng_key, dtype=np.uint32))

    def save_checkpoint(self):
        path = save(self.tcfg.checkpoint_dir, self.step,
                    {"params": self.params, "opt": self.opt_state},
                    keep=self.tcfg.keep_checkpoints,
                    extra={"rng": np.asarray(
                               jax.device_get(self.rng)).tolist(),
                           "weight_version": int(self.weight_version)})
        if self.faults is not None and self.faults.fire(
                "corrupt_checkpoint", self.step):
            corrupt_checkpoint_file(path, at=self.step,
                                    seed=self.faults.seed)

    # -- jitted inner functions ----------------------------------------------
    def _build_jit(self):
        cfg, scfg, m = self.cfg, self.scfg, self.m

        @partial(jax.jit, static_argnames=("max_new",))
        def _rollout(params, tokens, mask, rng, max_new):
            batch = {"tokens": tokens, "valid_mask": mask}
            # per-request key chains — fold_in(fold_in(rng, uid), t), uid =
            # row index — the continuous engine's sampling discipline, so
            # the two backends draw identical tokens for identical phases
            # (DESIGN.md §Training on the continuous engine)
            row_keys = jax.vmap(lambda u: jax.random.fold_in(rng, u))(
                jnp.arange(tokens.shape[0]))
            return generate(params, cfg, m, batch, scfg, rng,
                            max_new_tokens=max_new, eos_id=self.tok.eos_id,
                            pad_id=self.tok.pad_id, per_row_keys=row_keys)

        @jax.jit
        def _rescore(params, ro):
            return rescore(params, cfg, m, ro)

        def _loss(params, ro, logp_old, logp_behave, logp_ref, adv):
            logp_theta = rescore(params, cfg, m, ro)
            out = sparse_rl_loss(logp_theta, logp_old, ro.logp_sparse, adv,
                                 ro.resp_mask, scfg, logp_ref=logp_ref,
                                 logp_behave=logp_behave)
            return out.loss, out.metrics

        def _update(params, opt_state, ro, logp_old, logp_behave, logp_ref,
                    adv, lr):
            (loss, metrics), grads = jax.value_and_grad(_loss, has_aux=True)(
                params, ro, logp_old, logp_behave, logp_ref, adv)
            params, opt_state, om = adamw.update(
                params, grads, opt_state, lr=lr,
                b1=self.tcfg.adam_b1, b2=self.tcfg.adam_b2,
                eps=self.tcfg.adam_eps, weight_decay=self.tcfg.weight_decay,
                grad_clip=self.tcfg.grad_clip)
            metrics = dict(metrics, loss=loss, **om)
            return params, opt_state, metrics

        # two jitted variants: the sync path (logp_behave=None baked out of
        # the graph — bitwise identical to the historical update) and the
        # async staleness-corrected path (extra (B, T) behavior log-probs)
        _update_sync = jax.jit(
            lambda p, o, ro, lo, lrf, adv, lr:
            _update(p, o, ro, lo, None, lrf, adv, lr))
        _update_stale = jax.jit(_update)

        self._rollout_fn = _rollout
        self._rescore_fn = _rescore
        self._update_fn = _update_sync
        self._update_stale_fn = _update_stale

    # -- phase inputs ----------------------------------------------------------
    def tiled_phase_inputs(self, step: int):
        """The (G+slack)-tiled, group-major prompt arrays for one phase:
        ``(np_tokens, np_mask, answers_rep)``.  Shared by the sync step and
        the async rollout producer — the lag-0 bit-identity guarantee
        requires both paths to assemble exactly these arrays."""
        prompts, pmask, answers = self.loader.get(step)
        Gs = self.scfg.group_size + self.opts.group_slack
        np_tokens = np.repeat(np.asarray(prompts, np.int32), Gs, axis=0)
        np_mask = np.repeat(np.asarray(pmask, bool), Gs, axis=0)
        answers_rep = list(np.repeat(np.asarray(answers, dtype=object), Gs))
        return np_tokens, np_mask, answers_rep

    # -- sampling-key discipline ----------------------------------------------
    def phase_key(self, step: int) -> jax.Array:
        """Rollout base key for phase ``step``: ``fold_in(root, step)``.

        Deriving per-phase keys from the checkpointed root (instead of
        advancing a split chain) makes the key sequence a pure function of
        (seed, step): a resumed run — sync or async, where the rollout
        producer may have run ahead of the last checkpoint — regenerates
        exactly the keys the uninterrupted run would have used.
        """
        return jax.random.fold_in(self.rng, step)

    # -- group helpers ---------------------------------------------------------
    @staticmethod
    def _select_keep(lengths: np.ndarray, T: int, G: int,
                     slack: int) -> np.ndarray:
        """Lockstep straggler mitigation: from G+slack rollouts per prompt
        keep G, preferring finished (EOS'd) then shorter responses.  Returns
        the kept row indices, group-major ascending (the layout
        `group_advantages` reshapes over)."""
        Gs = G + slack
        n_prompts = lengths.shape[0] // Gs
        keep_idx = []
        for p in range(n_prompts):
            rows = np.arange(p * Gs, (p + 1) * Gs)
            finished = lengths[rows] < T
            order = np.lexsort((lengths[rows], ~finished))
            keep_idx.extend(sorted(rows[order[:G]]))
        return np.asarray(keep_idx)

    # -- rollout phase (backend dispatch) --------------------------------------
    def _rollout_phase(self, np_tokens: np.ndarray, np_mask: np.ndarray,
                       rng) -> Tuple[RolloutBatch, np.ndarray,
                                     Dict[str, float]]:
        """Sample the phase's G+slack rollouts per prompt and reduce to G.

        ``np_tokens``/``np_mask`` are the tiled (num_prompts * (G+slack), P)
        prompt arrays.  Returns (rollout, keep, stats): ``rollout`` is the
        trainer-ready (num_prompts * G, T) batch, ``keep`` the kept row
        indices into the tiled arrays (aligns rewards/answers), ``stats``
        engine counters (empty for lockstep).
        """
        opts, scfg = self.opts, self.scfg
        G, slack = scfg.group_size, opts.group_slack
        if opts.rollout_backend == "continuous":
            eng = self.engine
            if self.faults is not None:
                eng.arm_faults(self.faults, self.step)
            with self.tel.timed("phase_setup"):
                eng.begin_phase(params=self.params, base_key=rng)
                reqs = [Request(uid=u, prompt=np_tokens[u][np_mask[u]])
                        for u in range(np_tokens.shape[0])]
            comps = eng.run(reqs, group_size=G, group_slack=slack)
            with self.tel.timed("collate"):
                tr = build_train_rollout(
                    comps, np_tokens, np_mask,
                    max_new_tokens=opts.max_new_tokens, pad_id=eng.pad_id,
                    stats=eng.end_phase())
            return tr.rollout, tr.keep, tr.stats
        ro = self._rollout_fn(self.params, jnp.asarray(np_tokens),
                              jnp.asarray(np_mask), rng,
                              max_new=opts.max_new_tokens)
        if slack == 0:
            return ro, np.arange(np_tokens.shape[0]), {}
        lengths = np.asarray(jax.device_get(ro.lengths))
        keep = self._select_keep(lengths, ro.resp_tokens.shape[1], G, slack)
        ro = jax.tree.map(
            lambda x: jnp.asarray(np.asarray(jax.device_get(x))[keep]), ro)
        return ro, keep, {}

    # -- rejection-storm degraded mode -----------------------------------------
    # (DESIGN.md §Fault tolerance & degraded modes)
    def _dense_fallback_rollout(self, phase_ctx: dict) -> RolloutBatch:
        """Lockstep dense-policy rollout over the phase's full tiled prompt
        arrays — the degraded-mode fallback sampler.  Same phase key and
        per-request (uid-folded) key chains as the sparse rollout, same
        static shapes (one compile, reused across storms), sampled under
        the learner's CURRENT params."""
        if self._dense_fallback_fn is None:
            cfg, m = self.cfg, self.m
            scfg_d = resolve_policy("dense").apply(self.scfg)

            @partial(jax.jit, static_argnames=("max_new",))
            def _dense_roll(params, tokens, mask, rng, max_new):
                batch = {"tokens": tokens, "valid_mask": mask}
                row_keys = jax.vmap(
                    lambda u: jax.random.fold_in(rng, u))(
                        jnp.arange(tokens.shape[0]))
                return generate(params, cfg, m, batch, scfg_d, rng,
                                max_new_tokens=max_new,
                                eos_id=self.tok.eos_id,
                                pad_id=self.tok.pad_id,
                                per_row_keys=row_keys)

            self._dense_fallback_fn = _dense_roll
        return self._dense_fallback_fn(
            self.params, jnp.asarray(phase_ctx["np_tokens"]),
            jnp.asarray(phase_ctx["np_mask"]), phase_ctx["rng"],
            max_new=self.opts.max_new_tokens)

    def _storm_guard(self, ro: RolloutBatch, rewards: np.ndarray,
                     logp_old, logp_behave, phase_ctx: Optional[dict]):
        """Detect a rejection storm (Eq. 6 veto rate over the phase) and,
        above ``storm_threshold``, re-roll every vetoed group through the
        dense fallback policy so the update batch is never starved.

        Re-rolled rows are sampled under the current learner weights, so
        their ``logp_sparse`` is set to ``logp_old`` BITWISE (the
        identity-class contract: xi == 1 exactly, the veto can never
        re-fire on them) and, in the async case, their behavior plane is
        the proximal plane (rho == 1 exactly).  Returns the possibly
        rebuilt ``(ro, rewards, logp_old, logp_behave, sparse_rows,
        metrics)`` — ``sparse_rows`` masks the rows still carrying genuine
        sparse-sampler evidence (None = no reroll happened), which the
        caller uses to keep the mismatch metrics honest.
        """
        scfg, opts = self.scfg, self.opts
        G = scfg.group_size
        lo = np.asarray(jax.device_get(logp_old), np.float32)
        lb = (np.asarray(jax.device_get(logp_behave), np.float32)
              if logp_behave is not None else lo)
        ls = np.asarray(jax.device_get(ro.logp_sparse), np.float32)
        mask = np.asarray(jax.device_get(ro.resp_mask), bool)
        veto = ((lb - ls < np.log(scfg.rejection_eps)) & mask).any(axis=1)
        veto_rate = float(veto.mean()) if veto.size else 0.0
        metrics = {"veto_rate": veto_rate, "storm_rerolls": 0.0}
        if veto_rate <= opts.storm_threshold or phase_ctx is None:
            return ro, rewards, logp_old, logp_behave, None, metrics
        # -- degraded mode: group-granular dense re-roll ------------------
        gveto = veto.reshape(-1, G).any(axis=1)
        rows = np.repeat(gveto, G)                 # rerolled kept-batch rows
        keep = np.asarray(phase_ctx["keep"])
        dense_ro = self._dense_fallback_rollout(phase_ctx)
        ro = jax.tree.map(
            lambda a, b: jnp.asarray(np.where(
                rows.reshape((-1,) + (1,) * (np.ndim(a) - 1)),
                np.asarray(jax.device_get(b))[keep],
                np.asarray(jax.device_get(a)))),
            ro, dense_ro)
        answers = [phase_ctx["answers_rep"][u] for u in keep]
        rewards = np.asarray(rewards).copy()
        rewards[rows] = binary_rewards(
            np.asarray(jax.device_get(ro.resp_tokens))[rows],
            [a for a, r in zip(answers, rows) if r])
        # proximal rescore of the rebuilt batch: unchanged rows rescore to
        # bit-identical values (same params, same deterministic program),
        # rerolled rows get their true dense log-probs
        logp_old = self._rescore_fn(self.params, ro)
        lo = np.asarray(jax.device_get(logp_old), np.float32)
        ls = np.array(jax.device_get(ro.logp_sparse), np.float32)
        ls[rows] = lo[rows]                        # xi == 1 exactly
        ro = ro._replace(logp_sparse=jnp.asarray(ls))
        if logp_behave is not None:
            lb = np.array(jax.device_get(logp_behave), np.float32)
            lb[rows] = lo[rows]                    # rho == 1 exactly
            logp_behave = jnp.asarray(lb)
        n_rerolled = int(gveto.sum())
        self.resilience["storm_rerolls"] += n_rerolled
        self.resilience["storm_phases"] += 1
        metrics["storm_rerolls"] = float(n_rerolled)
        self.tel.log.event(
            "rejection_storm", level="warn", step=self.step,
            veto_rate=veto_rate, threshold=opts.storm_threshold,
            rerolled_groups=n_rerolled,
            msg=f"rejection storm: veto_rate={veto_rate:.2f} > "
                f"{opts.storm_threshold:.2f}; re-rolled {n_rerolled} "
                f"group(s) through the dense fallback")
        return ro, rewards, logp_old, logp_behave, ~rows, metrics

    def _poison_rejection(self, ro: RolloutBatch) -> RolloutBatch:
        """``rejection_storm`` injection payload: shift ``logp_sparse`` of
        ~90% of rows (chosen from the plan's seeded RNG) far enough down
        that Eq. 6 vetoes them — the on-batch signature of a sampler whose
        compressed cache went pathological."""
        rng = self.faults.payload_rng(self.step)
        ls = np.array(jax.device_get(ro.logp_sparse), np.float32)
        B = ls.shape[0]
        hit = rng.permutation(B)[:max(1, int(0.9 * B))]
        # the veto (Eq. 6) fires when logp_old - logp_sparse < log(eps):
        # inflate logp_sparse so the hit rows look catastrophically
        # over-confident under the sparse cache
        ls[hit] += 40.0
        return ro._replace(logp_sparse=jnp.asarray(ls))

    # -- the phase update (shared by the sync step and the async learner) ------
    def _phase_update(self, ro: RolloutBatch, rewards: np.ndarray, *,
                      logp_behave=None, logp_old=None,
                      phase_ctx: Optional[dict] = None) -> Dict[str, float]:
        """Run one phase's Sparse-RL update on an assembled rollout batch.

        ``rewards`` aligns with ``ro`` rows (group-major).  ``logp_behave``
        (async only) carries the dense per-token log-probs under each
        token's sampler-version weights; None selects the sync update
        graph, which the staleness-corrected loss degenerates to bitwise
        at lag 0.  ``logp_old`` lets the async learner pass the proximal
        rescore it already computed (it doubles as the current-version
        behavior plane) instead of paying the forward twice.

        ``phase_ctx`` (optional) carries the phase's tiled prompt arrays,
        answers, kept-row map and rollout key — what the rejection-storm
        degraded mode needs to re-roll vetoed groups through the dense
        fallback (without it, detection still runs but reroll is
        unavailable).  Non-finite losses/grads are absorbed by the anomaly
        guard: the minibatch's update is skipped with params/opt-state
        untouched (the update programs donate nothing, so the old arrays
        are still live), raising after ``anomaly_max_skips`` consecutive
        skips (DESIGN.md §Fault tolerance & degraded modes).  Advances
        ``step`` and ``weight_version`` and saves a checkpoint on schedule.
        """
        scfg, tcfg = self.scfg, self.tcfg
        G = scfg.group_size
        if logp_old is None:
            with self.tel.timed("rescore"):
                logp_old = self._rescore_fn(self.params, ro)
        if self.faults is not None and self.faults.fire(
                "rejection_storm", self.step):
            ro = self._poison_rejection(ro)
        sparse_rows, storm_metrics = None, {}
        if self._storm_eligible:
            # timed: the guard's veto scan device_gets the full logp planes
            # — real wall-clock that belongs in the update column
            with self.tel.timed("storm_guard"):
                (ro, rewards, logp_old, logp_behave, sparse_rows,
                 storm_metrics) = self._storm_guard(
                     ro, rewards, logp_old, logp_behave, phase_ctx)
        with self.tel.timed("advantages"):
            adv = group_advantages(
                jnp.asarray(rewards.reshape(-1, G))).reshape(-1)
        if self.faults is not None and self.faults.fire(
                "nan_grads", self.step):
            adv = adv.at[0].set(jnp.nan)
        if self.ref_params is not None:
            with self.tel.timed("rescore"):
                logp_ref = self._rescore_fn(self.ref_params, ro)
        else:
            logp_ref = None

        B = ro.resp_tokens.shape[0]
        ub = min(tcfg.update_batch, B)
        n_updates = max(B // ub, 1)
        with self.tel.timed("advantages"):
            # the schedule is jitted jnp — its step-0 compile is real
            # wall-clock that would otherwise show up as bubble
            lr = adamw.warmup_cosine(jnp.asarray(self.step),
                                     base_lr=scfg.learning_rate,
                                     warmup=tcfg.warmup_steps,
                                     total=tcfg.total_steps)
        agg: Dict[str, float] = {}
        skipped = 0
        with self.tel.timed("update", n_updates=n_updates):
            for u in range(n_updates):
                sl = slice(u * ub, (u + 1) * ub)
                ro_u = jax.tree.map(lambda x: x[sl], ro)
                lo = logp_old[sl]
                lrf = logp_ref[sl] if logp_ref is not None else None
                if logp_behave is None:
                    new_params, new_opt, metrics = self._update_fn(
                        self.params, self.opt_state, ro_u, lo, lrf, adv[sl],
                        lr)
                else:
                    new_params, new_opt, metrics = self._update_stale_fn(
                        self.params, self.opt_state, ro_u, lo,
                        logp_behave[sl], lrf, adv[sl], lr)
                loss_v = float(jax.device_get(metrics["loss"]))
                gn_v = (float(jax.device_get(metrics["grad_norm"]))
                        if "grad_norm" in metrics else 0.0)
                if not (np.isfinite(loss_v) and np.isfinite(gn_v)):
                    # anomaly guard: drop the poisoned step — the update
                    # programs donate nothing, so self.params/self.opt_state
                    # still hold the pre-update arrays (a bitwise no-op)
                    skipped += 1
                    self.resilience["skipped_updates"] += 1
                    self._consec_skips += 1
                    self.tel.log.event(
                        "anomaly_skip", level="warn", step=self.step,
                        loss=loss_v, grad_norm=gn_v,
                        consecutive=self._consec_skips,
                        msg=f"anomaly guard: non-finite update skipped "
                            f"(loss={loss_v}, grad_norm={gn_v}; "
                            f"{self._consec_skips} consecutive)")
                    if self._consec_skips >= self.opts.anomaly_max_skips:
                        raise RuntimeError(
                            f"anomaly guard: {self._consec_skips} "
                            f"consecutive non-finite updates at step "
                            f"{self.step} (loss={loss_v}, grad_norm={gn_v})"
                            f" — params are intact but the batch stream is "
                            f"poisoned; refusing to continue")
                    continue
                self._consec_skips = 0
                self.params, self.opt_state = new_params, new_opt
                for k, v in metrics.items():
                    agg[k] = agg.get(k, 0.0) + float(jax.device_get(v))
        n_applied = n_updates - skipped
        for k in agg:
            agg[k] /= max(n_applied, 1)

        self.step += 1
        self.weight_version += 1
        if tcfg.checkpoint_every and self.step % tcfg.checkpoint_every == 0:
            with self.tel.timed("checkpoint", step=self.step):
                self.save_checkpoint()
        # metric assembly device_gets full rollout planes — timed so the
        # phase breakdown attributes it (to "other") instead of bubble
        with self.tel.timed("metrics_publish"):
            agg.update(
                reward=float(rewards.mean()),
                resp_len=float(jax.device_get(ro.lengths).mean()),
                entropy=float(jax.device_get(ro.entropy).mean()),
                lr=float(jax.device_get(lr)),
            )
            agg.update(storm_metrics)
            if sparse_rows is not None:
                # degraded-mode metric hygiene: mismatch telemetry
                # aggregates over genuinely-sparse rows only — the rerolled
                # identity-class rows (xi == 1 exactly) would otherwise
                # dilute it
                lbf = logp_behave if logp_behave is not None else logp_old
                agg.update(mismatch_metrics(
                    lbf, ro.logp_sparse, ro.resp_mask, row_mask=sparse_rows,
                    xi_clip_max=scfg.xi_clip_max))
            agg["skipped_update_frac"] = skipped / n_updates
            agg["resilience_skipped_updates"] = float(
                self.resilience["skipped_updates"])
            agg["resilience_storm_rerolls"] = float(
                self.resilience["storm_rerolls"])
            agg["checkpoint_rollbacks"] = float(
                self.resilience["checkpoint_rollbacks"])
            if self.tel.metrics_on:
                self._publish_mismatch(ro, logp_old, logp_behave, agg)
        return agg

    def _publish_mismatch(self, ro: RolloutBatch, logp_old, logp_behave,
                          agg: Dict[str, float]) -> None:
        """Sparse-RL mismatch health -> the telemetry registry (DESIGN.md
        §Observability & telemetry): the per-phase xi histogram over
        response tokens (log xi = log pi_old - log pi_sparse — Eq. 6's
        veto reads its left tail), per-phase rejection/veto-rate series,
        and the staleness diagnostics when the async path reports them.
        Device fetches happen only here, i.e. only when metrics are on —
        the off path never adds a transfer."""
        lo = np.asarray(jax.device_get(
            logp_behave if logp_behave is not None else logp_old),
            np.float32)
        ls = np.asarray(jax.device_get(ro.logp_sparse), np.float32)
        mask = np.asarray(jax.device_get(ro.resp_mask), bool)
        log_xi = (lo - ls)[mask]
        if log_xi.size:
            self.tel.observe("mismatch.log_xi", log_xi)
        for key, name in (("rejection_rate", "mismatch.rejection_rate"),
                          ("veto_rate", "mismatch.veto_rate"),
                          ("mismatch_kl", "mismatch.kl"),
                          ("mean_xi", "mismatch.mean_xi"),
                          ("mean_rho", "mismatch.mean_rho"),
                          ("staleness_kl", "mismatch.staleness_kl"),
                          ("reward", "train.reward"),
                          ("loss", "train.loss"),
                          ("grad_norm", "train.grad_norm")):
            if key in agg and np.isfinite(agg[key]):
                self.tel.observe(name, float(agg[key]))
        for k, v in self.resilience.items():
            self.tel.gauge(f"resilience.{k}", float(v))
        self.tel.gauge("train.weight_version", float(self.weight_version))

    @staticmethod
    def _engine_stat_metrics(ro_stats: Dict[str, float]) -> Dict[str, float]:
        """Engine phase counters -> trainer log metrics (pool pressure,
        admission staging/wait telemetry, swap count)."""
        out = dict(
            prefix_hit_rate=(float(ro_stats["prefix_hits"])
                             / max(float(ro_stats["admissions"]), 1.0)),
            rollout_prefills=float(ro_stats["prefills"]),
            rollout_cancelled=float(ro_stats["cancelled"]),
            rollout_decode_steps=float(ro_stats["decode_steps"]),
            rollout_staged_peak=float(ro_stats["staged_peak"]),
            rollout_weight_swaps=float(ro_stats.get("weight_swaps", 0)),
        )
        for src, dst in (("pool_peak_frac", "rollout_pool_peak_frac"),
                         ("pool_retry_sweeps", "rollout_pool_retry_sweeps"),
                         ("blocks_in_use_peak", "rollout_pool_peak_blocks"),
                         ("kv_bytes_per_token", "rollout_kv_bytes_per_token"),
                         ("kv_capacity_ratio", "rollout_kv_capacity_ratio"),
                         ("admit_wait_p50", "rollout_admit_wait_p50"),
                         ("admit_wait_p99", "rollout_admit_wait_p99"),
                         ("latency_p50", "rollout_latency_p50"),
                         ("latency_p99", "rollout_latency_p99")):
            if src in ro_stats:
                out[dst] = float(ro_stats[src])
        return out

    # -- one full RL step -------------------------------------------------------
    def train_step(self) -> Dict[str, float]:
        t0 = time.time()
        with self.tel.span("train_step", step=self.step):
            with self.tel.span("phase_inputs", step=self.step):
                np_tokens, np_mask, answers_rep = self.tiled_phase_inputs(
                    self.step)
                r1 = self.phase_key(self.step)
            t_roll = time.time()
            with self.tel.timed("rollout_phase", step=self.step):
                ro, keep, ro_stats = self._rollout_phase(np_tokens, np_mask,
                                                         r1)
            rollout_s = time.time() - t_roll
            self.last_rollout = ro          # equivalence-test hook
            with self.tel.timed("verify"):
                rewards = binary_rewards(
                    np.asarray(jax.device_get(ro.resp_tokens)),
                    [answers_rep[u] for u in keep])

            agg = self._phase_update(ro, rewards, phase_ctx=dict(
                np_tokens=np_tokens, np_mask=np_mask,
                answers_rep=answers_rep, keep=keep, rng=r1))
        agg.update(rollout_s=rollout_s, step_time_s=time.time() - t0)
        if ro_stats:
            agg.update(self._engine_stat_metrics(ro_stats))
        return agg

    def train(self, steps: int, log_every: int = 10, callback=None):
        if self.opts.pipeline == "async":
            from repro.runtime.async_pipeline import AsyncPipeline

            return AsyncPipeline(self).train(steps, log_every=log_every,
                                             callback=callback)
        history = []
        for _ in range(steps):
            metrics = self.train_step()
            history.append(metrics)
            if callback:
                callback(self.step, metrics)
            if log_every and self.step % log_every == 0:
                floats = {k: v for k, v in sorted(metrics.items())
                          if isinstance(v, float)}
                self.tel.log.event(
                    "train_step", step=self.step,
                    msg=" ".join(f"{k}={v:.4f}" for k, v in floats.items()),
                    **floats)
        return history
