"""RL training runtime: rollout -> verify -> re-score -> Sparse-RL update.

One Trainer drives the paper's full loop:

  1. ROLLOUT  — sample G responses/prompt from the *sparse* sampler
                (budget KV cache), recording pi_sparse per token.
  2. VERIFY   — host-side rule verifier, binary reward (paper §5.1).
  3. RESCORE  — one dense teacher-forced forward with the rollout weights
                gives pi_old for every token (the xi numerator); with
                kl_coef > 0 a second forward under the frozen reference
                policy gives the KL anchor.
  4. UPDATE   — Eq. 7 loss over minibatches of ``update_batch`` sequences
                (rollout_batch / update_batch updates per phase; the w ratio
                corrects intra-phase staleness), AdamW, global-norm clip.

Fault tolerance: auto-resume from the newest checkpoint; atomic saves every
``checkpoint_every`` steps (params, opt state, step).

Rollout backends (``rollout_backend``; DESIGN.md §Training on the continuous
engine): ``"lockstep"`` decodes every row for the full ``max_new_tokens`` in
one compiled scan — no host sync on the long tail; ``"continuous"`` streams
the phase's num_prompts x G group requests through the serving
`ContinuousEngine` — shared prompt pages prefilled once per group
(``cache_backend="paged"``), per-request EOS early-exit freeing slots for
the next group instead of lockstep's pad-to-max tail.  Both use the same
per-request sampling-key chains, so a fixed-length phase is token-identical
across backends.  Straggler mitigation composes with either: groups can be
over-provisioned (``group_slack``: sample G+k, keep G — lockstep keeps the
best-formed after the fact, continuous keeps the first G to finish and
cancels the stragglers mid-flight).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs.base import ModelConfig, SparseRLConfig, TrainConfig
from repro.core import group_advantages, sparse_rl_loss
from repro.data import TOKENIZER, PromptLoader
from repro.models import get_model
from repro.optim import adamw
from repro.rollout import (
    ContinuousEngine,
    Request,
    RolloutBatch,
    build_train_rollout,
    generate,
    paged_rollout_geometry,
    rescore,
)
from repro.rewards import binary_rewards


@dataclass
class TrainerOptions:
    num_prompts: int = 16          # prompts per rollout phase
    prompt_len: int = 24
    max_new_tokens: int = 24
    group_slack: int = 0           # over-provisioned rollouts per group
    use_ref_kl: bool = False
    level: str = "easy"
    log_samples: bool = False
    # -- rollout backend (DESIGN.md §Training on the continuous engine) --
    rollout_backend: str = "lockstep"   # "lockstep" | "continuous"
    cache_backend: str = "contiguous"   # continuous only: "contiguous"|"paged"
    decode_batch: int = 0          # continuous: engine row slots (0 = auto:
                                   # half the phase's requests, >= G)
    decode_chunk: int = 4          # continuous: steps between host harvests
    block_size: int = 16           # paged pool: tokens per page
    prefill_chunk: Optional[int] = None  # continuous: prompt-token budget
                                   # per admission sweep (None = auto)
    overlap_harvest: bool = False  # continuous: async double-buffered
                                   # harvest (chunk t+1 dispatched before
                                   # chunk t's tokens are fetched); wins on
                                   # long-response/accelerator workloads,
                                   # costs a chunk-sized bubble per finish


class Trainer:
    def __init__(self, cfg: ModelConfig, scfg: SparseRLConfig,
                 tcfg: TrainConfig, opts: TrainerOptions,
                 rng: Optional[jax.Array] = None):
        self.cfg, self.scfg, self.tcfg, self.opts = cfg, scfg, tcfg, opts
        self.m = get_model(cfg)
        self.tok = TOKENIZER
        rng = jax.random.PRNGKey(tcfg.seed) if rng is None else rng
        self.rng, init_rng = jax.random.split(rng)
        self.params = self.m.init_params(cfg, init_rng)
        self.opt_state = adamw.init(self.params)
        self.ref_params = jax.tree.map(jnp.copy, self.params) if opts.use_ref_kl else None
        self.step = 0
        self.loader = PromptLoader(batch_prompts=opts.num_prompts,
                                   prompt_len=opts.prompt_len,
                                   seed=tcfg.seed, level=opts.level)
        if opts.rollout_backend not in ("lockstep", "continuous"):
            raise ValueError(
                f"unknown rollout_backend {opts.rollout_backend!r}")
        self.engine: Optional[ContinuousEngine] = None
        if opts.rollout_backend == "continuous":
            self.engine = self._build_engine()
        self._maybe_resume()
        self._build_jit()

    def _build_engine(self) -> ContinuousEngine:
        """One ContinuousEngine for the whole run: programs compile once;
        per-phase weights/keys swap in via `begin_phase` (no recompiles)."""
        opts, scfg = self.opts, self.scfg
        total = opts.num_prompts * (scfg.group_size + opts.group_slack)
        bs = opts.decode_batch or min(total,
                                      max(scfg.group_size, total // 2))
        kw = dict(batch_size=bs, prompt_len=opts.prompt_len,
                  max_new_tokens=opts.max_new_tokens,
                  eos_id=self.tok.eos_id, pad_id=self.tok.pad_id,
                  decode_chunk=opts.decode_chunk, seed=self.tcfg.seed,
                  cache_backend=opts.cache_backend,
                  prefill_chunk=opts.prefill_chunk,
                  overlap_harvest=opts.overlap_harvest)
        if opts.cache_backend == "paged":
            # pool sizing: every resident row's chain + one pinned prompt
            # chain per distinct prompt in the phase + COW/tail headroom
            _, bpr = paged_rollout_geometry(
                scfg, opts.prompt_len, opts.max_new_tokens, opts.block_size)
            npb = -(-opts.prompt_len // opts.block_size)
            kw.update(block_size=opts.block_size,
                      pool_blocks=1 + bs * bpr + opts.num_prompts * npb
                      + 2 * bpr,
                      prefix_entries=opts.num_prompts + 4)
        return ContinuousEngine(self.params, self.cfg, self.m, scfg, **kw)

    # -- persistence ---------------------------------------------------------
    def _maybe_resume(self):
        last = latest_step(self.tcfg.checkpoint_dir)
        if last is not None:
            tree = {"params": self.params, "opt": self.opt_state}
            restored, step, extra = restore(self.tcfg.checkpoint_dir, tree)
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.step = step
            rng_key = extra.get("rng")
            if rng_key is not None:
                self.rng = jnp.asarray(np.array(rng_key, dtype=np.uint32))

    def save_checkpoint(self):
        save(self.tcfg.checkpoint_dir, self.step,
             {"params": self.params, "opt": self.opt_state},
             keep=self.tcfg.keep_checkpoints,
             extra={"rng": np.asarray(jax.device_get(self.rng)).tolist()})

    # -- jitted inner functions ----------------------------------------------
    def _build_jit(self):
        cfg, scfg, m = self.cfg, self.scfg, self.m

        @partial(jax.jit, static_argnames=("max_new",))
        def _rollout(params, tokens, mask, rng, max_new):
            batch = {"tokens": tokens, "valid_mask": mask}
            # per-request key chains — fold_in(fold_in(rng, uid), t), uid =
            # row index — the continuous engine's sampling discipline, so
            # the two backends draw identical tokens for identical phases
            # (DESIGN.md §Training on the continuous engine)
            row_keys = jax.vmap(lambda u: jax.random.fold_in(rng, u))(
                jnp.arange(tokens.shape[0]))
            return generate(params, cfg, m, batch, scfg, rng,
                            max_new_tokens=max_new, eos_id=self.tok.eos_id,
                            pad_id=self.tok.pad_id, per_row_keys=row_keys)

        @jax.jit
        def _rescore(params, ro):
            return rescore(params, cfg, m, ro)

        def _loss(params, ro, logp_old, logp_ref, adv):
            logp_theta = rescore(params, cfg, m, ro)
            out = sparse_rl_loss(logp_theta, logp_old, ro.logp_sparse, adv,
                                 ro.resp_mask, scfg, logp_ref=logp_ref)
            return out.loss, out.metrics

        @jax.jit
        def _update(params, opt_state, ro, logp_old, logp_ref, adv, lr):
            (loss, metrics), grads = jax.value_and_grad(_loss, has_aux=True)(
                params, ro, logp_old, logp_ref, adv)
            params, opt_state, om = adamw.update(
                params, grads, opt_state, lr=lr,
                b1=self.tcfg.adam_b1, b2=self.tcfg.adam_b2,
                eps=self.tcfg.adam_eps, weight_decay=self.tcfg.weight_decay,
                grad_clip=self.tcfg.grad_clip)
            metrics = dict(metrics, loss=loss, **om)
            return params, opt_state, metrics

        self._rollout_fn = _rollout
        self._rescore_fn = _rescore
        self._update_fn = _update

    # -- group helpers ---------------------------------------------------------
    @staticmethod
    def _select_keep(lengths: np.ndarray, T: int, G: int,
                     slack: int) -> np.ndarray:
        """Lockstep straggler mitigation: from G+slack rollouts per prompt
        keep G, preferring finished (EOS'd) then shorter responses.  Returns
        the kept row indices, group-major ascending (the layout
        `group_advantages` reshapes over)."""
        Gs = G + slack
        n_prompts = lengths.shape[0] // Gs
        keep_idx = []
        for p in range(n_prompts):
            rows = np.arange(p * Gs, (p + 1) * Gs)
            finished = lengths[rows] < T
            order = np.lexsort((lengths[rows], ~finished))
            keep_idx.extend(sorted(rows[order[:G]]))
        return np.asarray(keep_idx)

    # -- rollout phase (backend dispatch) --------------------------------------
    def _rollout_phase(self, np_tokens: np.ndarray, np_mask: np.ndarray,
                       rng) -> Tuple[RolloutBatch, np.ndarray,
                                     Dict[str, float]]:
        """Sample the phase's G+slack rollouts per prompt and reduce to G.

        ``np_tokens``/``np_mask`` are the tiled (num_prompts * (G+slack), P)
        prompt arrays.  Returns (rollout, keep, stats): ``rollout`` is the
        trainer-ready (num_prompts * G, T) batch, ``keep`` the kept row
        indices into the tiled arrays (aligns rewards/answers), ``stats``
        engine counters (empty for lockstep).
        """
        opts, scfg = self.opts, self.scfg
        G, slack = scfg.group_size, opts.group_slack
        if opts.rollout_backend == "continuous":
            eng = self.engine
            eng.begin_phase(params=self.params, base_key=rng)
            reqs = [Request(uid=u, prompt=np_tokens[u][np_mask[u]])
                    for u in range(np_tokens.shape[0])]
            comps = eng.run(reqs, group_size=G, group_slack=slack)
            tr = build_train_rollout(
                comps, np_tokens, np_mask,
                max_new_tokens=opts.max_new_tokens, pad_id=eng.pad_id,
                stats=eng.end_phase())
            return tr.rollout, tr.keep, tr.stats
        ro = self._rollout_fn(self.params, jnp.asarray(np_tokens),
                              jnp.asarray(np_mask), rng,
                              max_new=opts.max_new_tokens)
        if slack == 0:
            return ro, np.arange(np_tokens.shape[0]), {}
        lengths = np.asarray(jax.device_get(ro.lengths))
        keep = self._select_keep(lengths, ro.resp_tokens.shape[1], G, slack)
        ro = jax.tree.map(
            lambda x: jnp.asarray(np.asarray(jax.device_get(x))[keep]), ro)
        return ro, keep, {}

    # -- one full RL step -------------------------------------------------------
    def train_step(self) -> Dict[str, float]:
        t0 = time.time()
        opts, scfg, tcfg = self.opts, self.scfg, self.tcfg
        prompts, pmask, answers = self.loader.get(self.step)
        G = scfg.group_size
        Gs = G + opts.group_slack
        # tile prompts G+slack times (group-major)
        np_tokens = np.repeat(np.asarray(prompts, np.int32), Gs, axis=0)
        np_mask = np.repeat(np.asarray(pmask, bool), Gs, axis=0)
        answers_rep = list(np.repeat(np.asarray(answers, dtype=object), Gs))

        self.rng, r1 = jax.random.split(self.rng)
        t_roll = time.time()
        ro, keep, ro_stats = self._rollout_phase(np_tokens, np_mask, r1)
        rollout_s = time.time() - t_roll
        rewards = binary_rewards(np.asarray(jax.device_get(ro.resp_tokens)),
                                 [answers_rep[u] for u in keep])

        adv = group_advantages(jnp.asarray(rewards.reshape(-1, G))).reshape(-1)
        logp_old = self._rescore_fn(self.params, ro)
        logp_ref = (self._rescore_fn(self.ref_params, ro)
                    if self.ref_params is not None else None)

        B = ro.resp_tokens.shape[0]
        ub = min(tcfg.update_batch, B)
        n_updates = max(B // ub, 1)
        lr = adamw.warmup_cosine(jnp.asarray(self.step),
                                 base_lr=scfg.learning_rate,
                                 warmup=tcfg.warmup_steps,
                                 total=tcfg.total_steps)
        agg: Dict[str, float] = {}
        for u in range(n_updates):
            sl = slice(u * ub, (u + 1) * ub)
            ro_u = jax.tree.map(lambda x: x[sl], ro)
            lo = logp_old[sl]
            lrf = logp_ref[sl] if logp_ref is not None else None
            self.params, self.opt_state, metrics = self._update_fn(
                self.params, self.opt_state, ro_u, lo, lrf, adv[sl], lr)
            for k, v in metrics.items():
                agg[k] = agg.get(k, 0.0) + float(jax.device_get(v)) / n_updates

        self.step += 1
        if tcfg.checkpoint_every and self.step % tcfg.checkpoint_every == 0:
            self.save_checkpoint()

        agg.update(
            reward=float(rewards.mean()),
            resp_len=float(jax.device_get(ro.lengths).mean()),
            entropy=float(jax.device_get(ro.entropy).mean()),
            lr=float(jax.device_get(lr)),
            rollout_s=rollout_s,
            step_time_s=time.time() - t0,
        )
        if ro_stats:
            agg.update(
                prefix_hit_rate=(float(ro_stats["prefix_hits"])
                                 / max(float(ro_stats["admissions"]), 1.0)),
                rollout_prefills=float(ro_stats["prefills"]),
                rollout_cancelled=float(ro_stats["cancelled"]),
                rollout_decode_steps=float(ro_stats["decode_steps"]),
            )
        return agg

    def train(self, steps: int, log_every: int = 10, callback=None):
        history = []
        for _ in range(steps):
            metrics = self.train_step()
            history.append(metrics)
            if callback:
                callback(self.step, metrics)
            if log_every and self.step % log_every == 0:
                msg = " ".join(f"{k}={v:.4f}" for k, v in sorted(metrics.items())
                               if isinstance(v, float))
                print(f"[step {self.step}] {msg}", flush=True)
        return history
