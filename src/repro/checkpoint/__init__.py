from repro.checkpoint.checkpointer import (
    latest_step,
    latest_verifiable_step,
    restore,
    save,
    verify_checkpoint,
)

__all__ = ["save", "restore", "latest_step", "latest_verifiable_step",
           "verify_checkpoint"]
