"""Sharding-agnostic checkpointing with atomic writes, content integrity
and auto-resume.

Arrays are host-gathered and stored by flattened tree path in a single
``.npz`` per step, with a JSON manifest.  Restore re-shards onto whatever
mesh the restarted job has — the elastic-scaling story: a job that loses a
pod restarts on the smaller mesh and `restore` device_puts every leaf with
the new sharding.  Writes go to ``<dir>/tmp.<step>`` then ``os.rename`` to
``<dir>/step_<N>`` (atomic on POSIX), so a crash mid-write never corrupts
the resume point.  Keeps the newest ``keep`` checkpoints.

Integrity (DESIGN.md §Fault tolerance & degraded modes): ``save`` records
the sha256 of ``arrays.npz`` in the manifest; ``verify_checkpoint`` re-hashes
at read time, and ``restore(step=None)`` walks newest-to-oldest, skipping —
with a loud warning — any snapshot whose payload no longer matches its hash
(torn write survived the rename, silent media corruption, an operator's
stray truncate).  Pruning (``_gc``) never deletes the newest *verifiable*
snapshot, even when it has aged past ``keep``: a run whose recent saves are
all corrupt must still have somewhere to roll back to.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import warnings
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz cannot store ml_dtypes; widen losslessly (restore casts
            # back to the target leaf dtype)
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {"step": step, "keys": sorted(flat), "extra": extra or {},
            "sha256": _sha256_file(os.path.join(tmp, "arrays.npz"))}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def verify_checkpoint(ckpt_path: str) -> bool:
    """True iff the snapshot directory's payload matches its manifest.

    Hash-bearing manifests (everything ``save`` writes now) get a full
    sha256 re-hash; legacy manifests without a hash fall back to a load
    check (npz opens, key set matches) so pre-integrity checkpoints keep
    restoring.  Any I/O or parse error is a verification failure, never an
    exception — callers use this to *choose* a resume point.
    """
    try:
        with open(os.path.join(ckpt_path, "meta.json")) as f:
            meta = json.load(f)
        arrays_path = os.path.join(ckpt_path, "arrays.npz")
        digest = meta.get("sha256")
        if digest is not None:
            return _sha256_file(arrays_path) == digest
        with np.load(arrays_path) as arrays:
            return sorted(arrays.files) == list(meta["keys"])
    except Exception:                                    # noqa: BLE001
        return False


def _step_dirs(ckpt_dir: str) -> List[str]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))


def _gc(ckpt_dir: str, keep: int):
    steps = _step_dirs(ckpt_dir)
    if keep <= 0 or len(steps) <= keep:
        return
    doomed, kept = steps[:-keep], steps[-keep:]
    if not any(verify_checkpoint(os.path.join(ckpt_dir, d))
               for d in reversed(kept)):
        # every retained snapshot is corrupt: spare the newest verifiable
        # one among the doomed — deleting it would leave nothing to roll
        # back to (DESIGN.md §Fault tolerance & degraded modes)
        for d in reversed(doomed):
            if verify_checkpoint(os.path.join(ckpt_dir, d)):
                doomed = [x for x in doomed if x != d]
                break
    for d in doomed:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _step_dirs(ckpt_dir)
    return int(steps[-1].split("_")[1]) if steps else None


def latest_verifiable_step(ckpt_dir: str) -> Optional[int]:
    """Newest step whose snapshot passes :func:`verify_checkpoint`."""
    for d in reversed(_step_dirs(ckpt_dir)):
        if verify_checkpoint(os.path.join(ckpt_dir, d)):
            return int(d.split("_")[1])
    return None


def restore(ckpt_dir: str, target_tree, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``target_tree`` (shapes must match).

    ``step=None`` resumes from the newest *verifiable* checkpoint: corrupt
    snapshots are skipped newest-first, each with a ``UserWarning`` naming
    the rollback (automatic recovery — the caller needs no retry loop).
    An explicit ``step`` is an exact request: a corrupt target raises.

    ``shardings``: optional pytree (same structure) of NamedSharding — each
    leaf is device_put with its sharding (reshard-on-load for elastic
    restarts).  Returns (tree, step, extra).
    """
    if step is None:
        candidates = [int(d.split("_")[1]) for d in _step_dirs(ckpt_dir)]
        if not candidates:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
        step = None
        for s in reversed(candidates):
            if verify_checkpoint(os.path.join(ckpt_dir, f"step_{s:08d}")):
                step = s
                break
            warnings.warn(
                f"checkpoint step_{s:08d} under {ckpt_dir} failed integrity "
                f"verification; rolling back to the previous snapshot",
                stacklevel=2)
        if step is None:
            raise FileNotFoundError(
                f"no verifiable checkpoint under {ckpt_dir} "
                f"({len(candidates)} corrupt snapshot(s) skipped)")
    else:
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        if not verify_checkpoint(d):
            raise ValueError(
                f"checkpoint {d} failed integrity verification "
                f"(explicitly requested step — not rolling back)")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(paths))
    for (path, ref), shard in zip(paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = arrays[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} vs "
                             f"target {ref.shape}")
        arr = arr.astype(ref.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step, meta["extra"]
