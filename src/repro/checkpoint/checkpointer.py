"""Sharding-agnostic checkpointing with atomic writes and auto-resume.

Arrays are host-gathered and stored by flattened tree path in a single
``.npz`` per step, with a JSON manifest.  Restore re-shards onto whatever
mesh the restarted job has — the elastic-scaling story: a job that loses a
pod restarts on the smaller mesh and `restore` device_puts every leaf with
the new sharding.  Writes go to ``<dir>/tmp.<step>`` then ``os.rename`` to
``<dir>/step_<N>`` (atomic on POSIX), so a crash mid-write never corrupts
the resume point.  Keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz cannot store ml_dtypes; widen losslessly (restore casts
            # back to the target leaf dtype)
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {"step": step, "keys": sorted(flat), "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, target_tree, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``target_tree`` (shapes must match).

    ``shardings``: optional pytree (same structure) of NamedSharding — each
    leaf is device_put with its sharding (reshard-on-load for elastic
    restarts).  Returns (tree, step, extra).
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(paths))
    for (path, ref), shard in zip(paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = arrays[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} vs "
                             f"target {ref.shape}")
        arr = arr.astype(ref.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step, meta["extra"]
