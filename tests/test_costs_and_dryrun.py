"""Roofline cost-model validation + small-mesh dry-run integration.

The analytic model (launch/costs.py) is the roofline source of truth because
XLA cost_analysis counts while bodies once.  Here we validate it on UNROLLED
micro-configs where cost_analysis IS exact, and exercise the dry-run path on
a small forced-host-device mesh in a subprocess (so the main test process
keeps its single CPU device).
"""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import LM_SHAPES, SparseRLConfig, get_config
from repro.launch.costs import MeshShape, cell_cost, decode_cost, prefill_cost


def test_prefill_flops_match_hlo_unrolled():
    """Unrolled (scan_layers=False), no flash, single device: cost_analysis
    is exact -> analytic linear+attention FLOPs must agree within 20%."""
    from dataclasses import replace
    from repro.configs.base import ShapeSpec
    from repro.models import get_model

    cfg = replace(get_config("qwen2.5-14b").smoke(), scan_layers=False,
                  remat="none", num_layers=3, compute_dtype="float32")
    m = get_model(cfg)
    B, S = 2, 64
    shape = ShapeSpec("tiny", S, B, "prefill")

    def fwd(params, tokens):
        logits, _ = m.forward(params, cfg, {"tokens": tokens}, use_flash=False)
        return logits

    p_sds = jax.eval_shape(lambda: m.init_params(cfg, jax.random.PRNGKey(0)))
    t_sds = jax.ShapeDtypeStruct((B, S), jnp.int32)
    compiled = jax.jit(fwd).lower(p_sds, t_sds).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):        # older jax: list of per-device dicts
        ca = ca[0]
    hlo_flops = ca["flops"]

    cost = prefill_cost(cfg, shape, MeshShape(pod=1, data=1, model=1),
                        SparseRLConfig())
    # analytic counts matmul+attention only; HLO adds elementwise noise
    assert cost.flops == pytest.approx(hlo_flops, rel=0.2), \
        (cost.flops, hlo_flops)


def test_decode_flops_scale_with_cache():
    cfg = get_config("qwen2.5-14b")
    from repro.configs.base import ShapeSpec
    mesh = MeshShape()
    scfg = SparseRLConfig()
    dense = decode_cost(cfg, ShapeSpec("d", 32768, 128, "decode"), mesh, scfg,
                        sparse_cache=False)
    sparse = decode_cost(cfg, ShapeSpec("d", 32768, 128, "decode"), mesh, scfg,
                         sparse_cache=True)
    # sparse cache: attention flops and cache bytes collapse by ~S/slots
    assert dense.detail["attention"] / sparse.detail["attention"] == \
        pytest.approx(32768 / scfg.cache_slots, rel=0.01)
    assert dense.detail["cache_bytes"] > 50 * sparse.detail["cache_bytes"]
    # linear part identical
    assert dense.detail["linear"] == sparse.detail["linear"]


def test_train_cost_model_flops_ratio_sane():
    """useful ratio = 6ND / total must be in (0.4, 1.0) for dense archs
    (bwd+remat overhead bounded), and MoE-aware for MoE archs."""
    mesh = MeshShape()
    for arch in ("qwen2.5-14b", "llama3-405b", "qwen3-moe-30b-a3b"):
        cfg = get_config(arch)
        shape = LM_SHAPES[0]
        cost = cell_cost(cfg, shape, mesh, num_micro=16)
        ratio = cost.model_flops / cost.flops
        assert 0.4 < ratio < 1.0, (arch, ratio)


def test_roofline_terms_positive_all_cells():
    from repro.configs import ARCH_IDS, get_shapes
    mesh = MeshShape()
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in get_shapes(arch):
            cost = cell_cost(cfg, shape, mesh,
                             sparse_cache=shape.sparse_cache_only)
            t = cost.terms(mesh)
            assert t["compute_s"] > 0 and t["memory_s"] > 0, (arch, shape)
            assert t["bottleneck"] in ("compute", "memory", "collective")
            assert 0 < t["useful_ratio"] <= 1.0 + 1e-6, (arch, shape.name, t)


_DRYRUN_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.launch.dryrun import build_cell, summarize
    from repro.configs import get_shapes
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = [s for s in get_shapes("whisper-small") if s.name == "{shape}"][0]
    compiled, lowered, info = build_cell("whisper-small", shape, mesh)
    row = summarize(compiled, lowered, info)
    import json
    print("RESULT" + json.dumps({{
        "status": "ok",
        "temp": row["memory"]["temp_bytes"],
        "colls": row["collectives"]["total_bytes"]}}))
""")


@pytest.mark.parametrize("shape", ["decode_32k"])
def test_dryrun_small_mesh_subprocess(shape):
    """lower+compile one real cell on an 8-device host mesh; collective
    parser returns nonzero trip-adjusted bytes."""
    code = _DRYRUN_SNIPPET.format(shape=shape)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    got = json.loads(line[len("RESULT"):])
    assert got["status"] == "ok"
    assert got["temp"] > 0


def test_hlo_stats_trip_adjustment():
    """unit: collective inside a known-trip scan is multiplied."""
    from repro.launch.hlo_stats import collective_stats
    hlo = """
HloModule test, entry_computation_layout={()->f32[4]}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(%x), channel_id=1, to_apply=%add
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""
    st = collective_stats(hlo)
    assert st["bytes_by_kind"]["all-reduce"] == 7 * 16
