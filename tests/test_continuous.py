"""Continuous-batching scheduler: lockstep equivalence + slot recycling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SparseRLConfig, get_config
from repro.data import TOKENIZER, encode_prompts, make_problems
from repro.kvcache import KVCache, init_cache, reset_rows, write_rows
from repro.kvcache.cache import POS_EMPTY
from repro.models import get_model
from repro.rollout import ContinuousEngine, Request, serve_lockstep

CFG = get_config("qwen2.5-14b").smoke()
M = get_model(CFG)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
PROMPT_LEN = 16


def _requests(n, caps, seed=1):
    problems = make_problems(n, seed, "easy")
    ids, mask, _ = encode_prompts(problems, PROMPT_LEN)
    return [Request(uid=i, prompt=ids[i][mask[i]], max_new_tokens=caps[i])
            for i in range(n)]


def _run_both(scfg, *, n=5, caps=(3, 7, 5, 8, 2), batch=2, max_new=8,
              chunk=1, seed=42):
    reqs = _requests(n, list(caps))
    eng = ContinuousEngine(PARAMS, CFG, M, scfg, batch_size=batch,
                           prompt_len=PROMPT_LEN, max_new_tokens=max_new,
                           eos_id=TOKENIZER.eos_id, decode_chunk=chunk,
                           seed=seed)
    cont = eng.run(reqs)
    lock = serve_lockstep(PARAMS, CFG, M, scfg, reqs, batch_size=batch,
                          prompt_len=PROMPT_LEN, max_new_tokens=max_new,
                          eos_id=TOKENIZER.eos_id, seed=seed)
    return eng, cont, lock


@pytest.mark.parametrize("compression", ["rkv", "none"])
def test_continuous_matches_lockstep_token_identical(compression):
    """N > batch-size requests through the continuous engine must produce
    token-identical outputs (and log-probs) to the same prompts through
    lockstep `generate`, one batch at a time, under the same seeds — i.e.
    admission order, row placement and co-tenants are invisible to a
    request."""
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression=compression)
    _, cont, lock = _run_both(scfg)
    assert len(cont) == len(lock) == 5
    for c, l in zip(cont, lock):
        assert c.uid == l.uid
        np.testing.assert_array_equal(c.tokens, l.tokens)
        np.testing.assert_allclose(c.logps, l.logps, atol=1e-6)
        assert c.finish_reason == l.finish_reason


def test_continuous_chunked_harvest_same_tokens():
    """decode_chunk only changes harvest granularity, never the tokens."""
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression="rkv")
    _, cont1, _ = _run_both(scfg, chunk=1)
    _, cont4, _ = _run_both(scfg, chunk=4)
    for a, b in zip(cont1, cont4):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_continuous_hybrid_family():
    """Slot recycling also splices SSM recurrent state + the shared-block
    KV caches (zamba2-style hybrid)."""
    cfg = get_config("zamba2-1.2b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression="rkv")
    reqs = _requests(4, [3, 6, 4, 5])
    eng = ContinuousEngine(params, cfg, m, scfg, batch_size=2,
                           prompt_len=PROMPT_LEN, max_new_tokens=6,
                           eos_id=TOKENIZER.eos_id, decode_chunk=2, seed=3)
    cont = eng.run(reqs)
    lock = serve_lockstep(params, cfg, m, scfg, reqs, batch_size=2,
                          prompt_len=PROMPT_LEN, max_new_tokens=6,
                          eos_id=TOKENIZER.eos_id, seed=3)
    for c, l in zip(cont, lock):
        np.testing.assert_array_equal(c.tokens, l.tokens)


def test_slot_recycling_leaves_no_stale_entries():
    """After the queue drains, every retired row's cache block must be fully
    wiped: pos back to POS_EMPTY, score zero, fill zero — stale entries would
    bias the next tenant's eviction policy."""
    scfg = SparseRLConfig(kv_budget=8, kv_buffer=2, obs_window=2,
                          num_sinks=1, compression="rkv")
    eng, cont, _ = _run_both(scfg, caps=(3, 7, 5, 8, 2))
    assert eng.stats["admissions"] == 5          # rows were recycled (N > B)
    caches = eng.state.caches                    # (L, B, H, S[, D]) leaves
    assert (np.asarray(caches.pos) == POS_EMPTY).all()
    assert (np.asarray(caches.score) == 0.0).all()
    assert (np.asarray(caches.fill) == 0).all()
    assert not bool(np.asarray(eng.active).any())


def test_mid_run_recycled_row_is_fully_overwritten():
    """While the engine is running, a row's valid cache entries must belong
    exclusively to its *current* tenant: positions never exceed what that
    request can have produced (prompt + emitted tokens)."""
    scfg = SparseRLConfig(kv_budget=8, kv_buffer=2, obs_window=2,
                          num_sinks=1, compression="rkv")
    reqs = _requests(6, [2, 2, 9, 9, 3, 3])
    eng = ContinuousEngine(PARAMS, CFG, M, scfg, batch_size=2,
                           prompt_len=PROMPT_LEN, max_new_tokens=12,
                           eos_id=TOKENIZER.eos_id, decode_chunk=1, seed=5)

    orig_stage = eng._stage_admit
    orig_flush = eng._flush_admissions
    seen, flushed = [], []

    def checking_stage(req, row):
        orig_stage(req, row)
        seen.append(req.uid)
        flushed.append(row)

    def checking_flush():
        rows, flushed[:] = list(flushed), []
        orig_flush()
        # straight after the admission flush each admitted row's cache holds
        # ONLY prompt tokens: every valid pos < prompt_len, nothing from the
        # previous tenant
        for row in rows:
            pos = np.asarray(eng.state.caches.pos)[:, row]   # (L, H, S)
            valid = pos[pos >= 0]
            assert valid.size, "admitted row has an empty cache"
            assert valid.max() < PROMPT_LEN

    eng._stage_admit = checking_stage
    eng._flush_admissions = checking_flush
    eng.run(reqs)
    assert seen == [0, 1, 2, 3, 4, 5]            # FIFO admission order


# ---------------------------------------------------------------------------
# kvcache row helpers
# ---------------------------------------------------------------------------
def test_reset_rows_wipes_only_target_rows():
    cache = KVCache(
        k=jnp.ones((3, 2, 4, 5)), v=jnp.ones((3, 2, 4, 5)),
        pos=jnp.arange(3 * 2 * 4).reshape(3, 2, 4).astype(jnp.int32),
        score=jnp.ones((3, 2, 4)), fill=jnp.full((3,), 4, jnp.int32))
    out = reset_rows(cache, jnp.asarray([1]))
    assert (np.asarray(out.pos[1]) == POS_EMPTY).all()
    assert (np.asarray(out.score[1]) == 0).all()
    assert int(out.fill[1]) == 0
    for row in (0, 2):
        np.testing.assert_array_equal(np.asarray(out.pos[row]),
                                      np.asarray(cache.pos[row]))
        assert int(out.fill[row]) == 4


def test_write_rows_splices_and_preserves_others():
    dst = init_cache(3, 2, 4, 5, jnp.float32)
    src = KVCache(
        k=jnp.full((1, 2, 4, 5), 3.0), v=jnp.full((1, 2, 4, 5), 4.0),
        pos=jnp.full((1, 2, 4), 7, jnp.int32),
        score=jnp.full((1, 2, 4), 0.5), fill=jnp.full((1,), 2, jnp.int32))
    out = write_rows(dst, src, jnp.asarray([2]))
    assert (np.asarray(out.k[2]) == 3.0).all()
    assert (np.asarray(out.pos[2]) == 7).all()
    assert int(out.fill[2]) == 2
    assert (np.asarray(out.pos[:2]) == POS_EMPTY).all()   # untouched rows
    assert (np.asarray(out.fill[:2]) == 0).all()


def test_stacked_reset_rows_batch_axis():
    """reset_rows with batch_axis=1 operates on L-stacked caches (the layout
    the engine's retire path sees)."""
    L, B, H, S, D = 2, 3, 2, 4, 5
    cache = KVCache(
        k=jnp.ones((L, B, H, S, D)), v=jnp.ones((L, B, H, S, D)),
        pos=jnp.zeros((L, B, H, S), jnp.int32),
        score=jnp.ones((L, B, H, S)), fill=jnp.full((L, B), 4, jnp.int32))
    out = reset_rows(cache, 1, batch_axis=1)
    assert (np.asarray(out.pos[:, 1]) == POS_EMPTY).all()
    assert (np.asarray(out.fill[:, 1]) == 0).all()
    assert (np.asarray(out.pos[:, 0]) == 0).all()
    assert (np.asarray(out.fill[:, [0, 2]]) == 4).all()


def test_group_slack_first_g_finished_cancels_stragglers():
    """RL group discipline (DESIGN.md §Training on the continuous engine):
    G+k uids per group, exactly the first G finishers survive, and each
    survivor's tokens equal its own slack-free run (placement/cancellation
    invisible to a request)."""
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression="rkv")
    G, slack, n_groups = 2, 1, 2
    Gs = G + slack
    problems = make_problems(n_groups, 9, "easy")
    ids, mask, _ = encode_prompts(problems, PROMPT_LEN)
    reqs = [Request(uid=g * Gs + i, prompt=ids[g][mask[g]])
            for g in range(n_groups) for i in range(Gs)]
    eng = ContinuousEngine(PARAMS, CFG, M, scfg, batch_size=3,
                           prompt_len=PROMPT_LEN, max_new_tokens=8,
                           eos_id=TOKENIZER.eos_id, decode_chunk=1, seed=21)
    kept = eng.run(reqs, group_size=G, group_slack=slack)
    assert len(kept) == n_groups * G
    per_group = {}
    for c in kept:
        per_group.setdefault(c.uid // Gs, []).append(c.uid)
    assert all(len(v) == G for v in per_group.values())
    assert eng.stats["cancelled"] == n_groups * slack
    # survivors' tokens match their own run without any group machinery
    eng2 = ContinuousEngine(PARAMS, CFG, M, scfg, batch_size=3,
                            prompt_len=PROMPT_LEN, max_new_tokens=8,
                            eos_id=TOKENIZER.eos_id, decode_chunk=1, seed=21)
    alone = {c.uid: c for c in eng2.run([r for r in reqs
                                         if r.uid in {c.uid for c in kept}])}
    for c in kept:
        np.testing.assert_array_equal(c.tokens, alone[c.uid].tokens)


# ---------------------------------------------------------------------------
# Length-aware hot loop: chunked batched prefill + async harvest
# (DESIGN.md §Chunked prefill & fill-aware decode)
# ---------------------------------------------------------------------------
def _run_engine(scfg, reqs, *, batch=4, max_new=8, chunk=2, seed=42, **kw):
    eng = ContinuousEngine(PARAMS, CFG, M, scfg, batch_size=batch,
                           prompt_len=PROMPT_LEN, max_new_tokens=max_new,
                           eos_id=TOKENIZER.eos_id, decode_chunk=chunk,
                           seed=seed, **kw)
    return eng, eng.run(reqs)


def test_overlap_harvest_tokens_identical_to_sync():
    """Async double-buffered harvest only changes WHEN chunks are fetched,
    never the tokens: per-request key chains make the pipeline bubble
    (a finished row decoding one extra in-flight chunk) invisible."""
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression="rkv")
    reqs = _requests(7, [3, 9, 5, 8, 2, 6, 4])
    _, sync = _run_engine(scfg, reqs, overlap_harvest=False)
    eng, overlapped = _run_engine(scfg, reqs, overlap_harvest=True)
    for a, b in zip(sync, overlapped):
        assert a.uid == b.uid
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.logps, b.logps, atol=1e-6)
        assert a.finish_reason == b.finish_reason


def test_chunked_prefill_budget_invariant_and_batched():
    """prefill_chunk only paces admissions (Sarathi chunking): a budget of
    exactly one full-width prompt per sweep and an effectively-unbounded
    budget must emit identical tokens; the unbounded run actually batches
    (fewer prefill dispatches than prefills)."""
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression="rkv")
    reqs = _requests(8, [3, 7, 5, 8, 2, 6, 4, 5])
    _, tight = _run_engine(scfg, reqs, prefill_chunk=PROMPT_LEN)
    eng, loose = _run_engine(scfg, reqs, prefill_chunk=64 * PROMPT_LEN)
    for a, b in zip(tight, loose):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert eng.stats["prefills"] == 8
    # the first sweep admits a whole batch of 4 in at most 2 dispatches
    assert eng.stats["prefill_dispatches"] < eng.stats["prefills"]


@pytest.mark.parametrize("compression", ["rkv", "none"])
def test_length_buckets_shrink_prefill_padding(compression):
    """Mixed-length prompts: short ones are padded to their bucket, not the
    engine-wide P — and the bucketed positions keep outputs
    lockstep-identical (the lockstep oracle always pads to P)."""
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression=compression)
    base = _requests(6, [3, 7, 5, 8, 2, 6])
    # truncate half the prompts below the smallest (8) bucket
    reqs = [r if i % 2 else
            Request(uid=r.uid, prompt=r.prompt[:4],
                    max_new_tokens=r.max_new_tokens)
            for i, r in enumerate(base)]
    eng, cont = _run_engine(scfg, reqs, batch=2)
    lock = serve_lockstep(PARAMS, CFG, M, scfg, reqs, batch_size=2,
                          prompt_len=PROMPT_LEN, max_new_tokens=8,
                          eos_id=TOKENIZER.eos_id, seed=42)
    for c, l in zip(cont, lock):
        np.testing.assert_array_equal(c.tokens, l.tokens)
        np.testing.assert_allclose(c.logps, l.logps, atol=1e-6)
    # 3 prompts fit the 8-bucket, 3 pay full width
    assert eng.stats["prefill_tokens"] < PROMPT_LEN * eng.stats["prefills"]


def test_lpt_schedule_tokens_identical_to_fifo():
    """schedule="longest" (LPT makespan admission for batch phases) only
    reorders co-arrived admissions; per-request key chains keep every
    request's tokens identical to the FIFO run."""
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression="rkv")
    reqs = _requests(6, [9, 2, 8, 3, 7, 4])
    eng_f = ContinuousEngine(PARAMS, CFG, M, scfg, batch_size=2,
                             prompt_len=PROMPT_LEN, max_new_tokens=12,
                             eos_id=TOKENIZER.eos_id, decode_chunk=2, seed=11)
    fifo = eng_f.run(reqs)
    eng_l = ContinuousEngine(PARAMS, CFG, M, scfg, batch_size=2,
                             prompt_len=PROMPT_LEN, max_new_tokens=12,
                             eos_id=TOKENIZER.eos_id, decode_chunk=2, seed=11)
    lpt = eng_l.run(reqs, schedule="longest")
    assert [c.uid for c in fifo] == [c.uid for c in lpt]
    for a, b in zip(fifo, lpt):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.logps, b.logps, atol=1e-6)
    with pytest.raises(ValueError):
        eng_l.run(reqs, schedule="shortest")
