"""Rollout engine: sampling, EOS lockstep, and the pi_sparse/pi_old contract."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SparseRLConfig, get_config
from repro.models import get_model
from repro.rollout import generate, mismatch_kl_estimate, rescore, sample_token

CFG = get_config("qwen2.5-14b").smoke()
M = get_model(CFG)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))


def _prompts(B=4, P=12, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, P), 3,
                              CFG.vocab_size)
    pad = jnp.arange(P)[None, :] >= jnp.array([0, 2, 5, 0])[:B, None]
    return {"tokens": jnp.where(pad, toks, 0), "valid_mask": pad}


def test_dense_rollout_rescore_identity():
    """THE invariant behind Eq. 5: with no compression, pi_sparse == pi_old
    (same weights), so rescoring must reproduce the recorded log-probs."""
    scfg = SparseRLConfig(compression="none")
    ro = generate(PARAMS, CFG, M, _prompts(), scfg, jax.random.PRNGKey(2),
                  max_new_tokens=10, eos_id=1)
    lp = rescore(PARAMS, CFG, M, ro)
    err = jnp.abs(jnp.where(ro.resp_mask, lp - ro.logp_sparse, 0.0)).max()
    assert float(err) < 1e-4


def test_sparse_rollout_has_mismatch():
    scfg = SparseRLConfig(kv_budget=6, kv_buffer=2, obs_window=2, num_sinks=1)
    ro = generate(PARAMS, CFG, M, _prompts(), scfg, jax.random.PRNGKey(2),
                  max_new_tokens=12, eos_id=1)
    lp_old = rescore(PARAMS, CFG, M, ro)
    diff = jnp.abs(jnp.where(ro.resp_mask, lp_old - ro.logp_sparse, 0.0))
    assert float(diff.max()) > 1e-3  # compression causes real divergence
    kl = mismatch_kl_estimate(lp_old, ro.logp_sparse, ro.resp_mask)
    assert jnp.isfinite(kl)


def test_eos_lockstep_masking():
    """after EOS: mask off, pad fed, logp zeroed."""
    scfg = SparseRLConfig(compression="none", temperature=1.0)
    # force tiny vocab sampling to hit EOS (id 1) quickly via temperature
    ro = generate(PARAMS, CFG, M, _prompts(), scfg, jax.random.PRNGKey(7),
                  max_new_tokens=30, eos_id=1)
    toks = np.asarray(ro.resp_tokens)
    mask = np.asarray(ro.resp_mask)
    lp = np.asarray(ro.logp_sparse)
    for b in range(toks.shape[0]):
        eos_hits = np.where(toks[b] == 1)[0]
        if len(eos_hits):
            e = eos_hits[0]
            assert mask[b, e]                      # EOS itself counted
            assert not mask[b, e + 1:].any()       # nothing after
            np.testing.assert_allclose(lp[b, e + 1:], 0.0)
            assert (toks[b, e + 1:] == 0).all()    # pad fed
        assert int(ro.lengths[b]) == int(mask[b].sum())


def test_greedy_deterministic():
    scfg = SparseRLConfig(compression="none", temperature=0.0)
    ro1 = generate(PARAMS, CFG, M, _prompts(), scfg, jax.random.PRNGKey(1),
                   max_new_tokens=8, eos_id=1)
    ro2 = generate(PARAMS, CFG, M, _prompts(), scfg, jax.random.PRNGKey(99),
                   max_new_tokens=8, eos_id=1)
    np.testing.assert_array_equal(np.asarray(ro1.resp_tokens),
                                  np.asarray(ro2.resp_tokens))


def test_sample_token_top_p():
    logits = jnp.log(jnp.array([[0.65, 0.2, 0.1, 0.05]]))
    toks = [int(sample_token(jax.random.PRNGKey(i), logits, 1.0, 0.6)[0][0])
            for i in range(50)]
    assert set(toks) == {0}  # p=0.6 keeps only the top token (0.65 >= 0.6)
    toks = [int(sample_token(jax.random.PRNGKey(i), logits, 1.0, 0.9)[0][0])
            for i in range(100)]
    assert set(toks) <= {0, 1, 2} and len(set(toks)) >= 2


def test_sample_token_logp_is_model_dist():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 16)),
                         jnp.float32)
    tok, lp = sample_token(jax.random.PRNGKey(0), logits, 1.0, 1.0)
    full = jax.nn.log_softmax(logits, axis=-1)
    want = jnp.take_along_axis(full, tok[:, None], axis=-1)[:, 0]
    np.testing.assert_allclose(np.asarray(lp), np.asarray(want), rtol=1e-6)


def test_rescore_vlm_prefix_offset():
    """VLM: patch prefix shifts logits; rescore must still align."""
    cfg = get_config("internvl2-2b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    B, P = 2, 8
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, P), 3,
                                          cfg.vocab_size),
             "valid_mask": jnp.ones((B, P), bool),
             "prefix_embeds": 0.02 * jax.random.normal(
                 jax.random.PRNGKey(2), (B, cfg.num_patches, cfg.d_model))}
    scfg = SparseRLConfig(compression="none")
    ro = generate(params, cfg, m, batch, scfg, jax.random.PRNGKey(3),
                  max_new_tokens=6, eos_id=1)
    lp = rescore(params, cfg, m, ro, extra_batch=batch)
    err = jnp.abs(jnp.where(ro.resp_mask, lp - ro.logp_sparse, 0.0)).max()
    assert float(err) < 1e-4


def test_mismatch_kl_lengths_mask_excludes_padded_tail():
    """Early-exited rows are right-padded; passing ``lengths`` must clip any
    over-wide caller mask so the pad tail (logp_sparse exactly 0, logp_old a
    real pad-token log-prob) neither dilutes nor biases the estimate."""
    logp_sparse = jnp.asarray([[-1.0, -2.0, 0.0, 0.0],
                               [-0.5, -0.5, -0.5, -0.5]])
    logp_old = jnp.asarray([[-1.5, -1.5, -9.0, -9.0],
                            [-0.25, -0.25, -0.25, -0.25]])
    lengths = jnp.asarray([2, 4])
    ones = jnp.ones((2, 4), bool)
    exact = jnp.asarray([[True, True, False, False],
                         [True, True, True, True]])
    clipped = mismatch_kl_estimate(logp_old, logp_sparse, ones,
                                   lengths=lengths)
    reference = mismatch_kl_estimate(logp_old, logp_sparse, exact)
    np.testing.assert_allclose(float(clipped), float(reference), rtol=1e-6)
    # the unmasked average really is different (the bug being guarded)
    diluted = mismatch_kl_estimate(logp_old, logp_sparse, ones)
    assert abs(float(diluted) - float(reference)) > 1e-3
