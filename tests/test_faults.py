"""Fault-injection matrix: every injectable fault recovers end-to-end
without operator intervention (DESIGN.md §Fault tolerance & degraded modes).

Each matrix test drives a short training run with one armed
:class:`FaultPlan`, asserts the plan actually fired, the run completed, and
— where the recovery mechanism promises it — that the result is
bitwise-identical to the fault-free run (deterministic retry from phase
barriers) or within tolerance of it.  A module-level collector writes the
outcome table to ``reports/fault_matrix.json`` (uploaded as a CI artifact),
so the recovery matrix is a persistent, diffable report rather than just a
green checkmark.

Also here: the unarmed-runtime bitwise pin (a plan that never fires must
change nothing), FaultPlan parsing/addressing semantics, and the
checkpoint-integrity unit tests (hash verification, rollback-on-restore,
pruning that never deletes the last verifiable snapshot).
"""
import json
import os
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint import (
    latest_verifiable_step,
    restore,
    save,
    verify_checkpoint,
)
from repro.checkpoint.checkpointer import _gc
from repro.configs import SparseRLConfig, TrainConfig, get_config
from repro.runtime import FaultPlan, Trainer, TrainerOptions
from repro.runtime.faults import FaultSpec, corrupt_checkpoint_file

REPORT = Path(__file__).resolve().parent.parent / "reports" / \
    "fault_matrix.json"
_CELLS: list = []


def _cell(kind: str, recovered: bool, **detail):
    _CELLS.append(dict(kind=kind, recovered=bool(recovered), **detail))


@pytest.fixture(scope="module", autouse=True)
def fault_matrix_report():
    """Collect every matrix cell and persist the outcome table."""
    yield
    if not _CELLS:
        return
    REPORT.parent.mkdir(parents=True, exist_ok=True)
    REPORT.write_text(json.dumps(
        {"cells": _CELLS,
         "distinct_kinds": sorted({c["kind"] for c in _CELLS}),
         "all_recovered": all(c["recovered"] for c in _CELLS)},
        indent=2, sort_keys=True))


def _cfgs(tmp, *, dense=False, checkpoint_every=0):
    cfg = get_config("qwen2.5-14b").smoke()
    if dense:
        scfg = SparseRLConfig(compression="none", group_size=4,
                              max_new_tokens=10, learning_rate=3e-4,
                              kl_coef=0.0)
    else:
        scfg = SparseRLConfig(kv_budget=12, kv_buffer=4, obs_window=2,
                              num_sinks=1, group_size=4, max_new_tokens=10,
                              learning_rate=3e-4, kl_coef=0.0)
    tcfg = TrainConfig(update_batch=16, total_steps=10, warmup_steps=1,
                       checkpoint_every=checkpoint_every,
                       checkpoint_dir=str(tmp))
    return cfg, scfg, tcfg


def _mk_sync(tmp, faults=None, *, dense=False, checkpoint_every=0,
             **opts_kw):
    cfg, scfg, tcfg = _cfgs(tmp, dense=dense,
                            checkpoint_every=checkpoint_every)
    opts = TrainerOptions(num_prompts=4, prompt_len=16, max_new_tokens=10,
                          faults=faults, **opts_kw)
    return Trainer(cfg, scfg, tcfg, opts)


def _mk_async(tmp, faults=None, *, max_lag=0, **opts_kw):
    cfg, scfg, tcfg = _cfgs(tmp)
    opts = TrainerOptions(num_prompts=4, prompt_len=16, max_new_tokens=10,
                          rollout_backend="continuous", cache_backend="paged",
                          decode_chunk=2, pipeline="async", max_lag=max_lag,
                          faults=faults, **opts_kw)
    return Trainer(cfg, scfg, tcfg, opts)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(jax.device_get(tree))]


def _assert_trees_equal(a, b):
    for x, y in zip(_leaves(a), _leaves(b)):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# the unarmed contract: no plan / a never-firing plan == bitwise no-op
# ---------------------------------------------------------------------------
def test_unarmed_and_never_firing_plan_are_bitwise_noops(tmp_path):
    """The whole harness must vanish when unarmed: faults=None and a plan
    that never matches produce bit-identical rollouts and params."""
    runs = {}
    for name, plan in (("none", None),
                       ("never", FaultPlan.parse("nan_grads@step=99"))):
        tr = _mk_sync(tmp_path / name, faults=plan)
        hist = tr.train(2, log_every=0)
        runs[name] = (tr, hist)
    ta, tb = runs["none"][0], runs["never"][0]
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(ta.last_rollout.resp_tokens)),
        np.asarray(jax.device_get(tb.last_rollout.resp_tokens)))
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(ta.last_rollout.logp_sparse)),
        np.asarray(jax.device_get(tb.last_rollout.logp_sparse)))
    _assert_trees_equal(ta.params, tb.params)
    _assert_trees_equal(ta.opt_state, tb.opt_state)
    assert runs["never"][0].faults.fired() == 0


# ---------------------------------------------------------------------------
# async producer faults: crash (dead thread) and hang (stale heartbeat)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def async_baseline(tmp_path_factory):
    """Fault-free async lag-0 reference: per-step tokens + final params."""
    tr = _mk_async(tmp_path_factory.mktemp("async_base"))
    tokens = []

    def cap(step, metrics):
        tokens.append(np.asarray(jax.device_get(tr.last_rollout.resp_tokens)))

    hist = tr.train(3, log_every=0, callback=cap)
    return dict(tokens=tokens, params=_leaves(tr.params),
                rewards=[m["reward"] for m in hist])


def _check_async_recovery(tr, hist, baseline, kind):
    """Shared asserts: run completed, exactly one restart, retry was
    token-identical (phase keys fold step, nothing from the failed attempt
    leaks), final params bitwise equal to the fault-free run."""
    assert len(hist) == 3
    assert tr.resilience["producer_restarts"] == 1
    assert all(np.isfinite(m["loss"]) for m in hist)
    for x, y in zip(baseline["params"], _leaves(tr.params)):
        np.testing.assert_array_equal(x, y)
    _cell(kind, True, restarts=tr.resilience["producer_restarts"],
          reward_faulty=float(np.mean([m["reward"] for m in hist])),
          reward_clean=float(np.mean(baseline["rewards"])),
          bitwise_identical=True)


def test_producer_crash_watchdog_restart(tmp_path, async_baseline):
    """A producer that dies WITHOUT its exit marker (hard kill) is caught
    by the liveness poll; the restarted producer replays the phase
    token-identically."""
    plan = FaultPlan.parse("producer_crash@phase=1")
    tr = _mk_async(tmp_path / "crash", faults=plan)
    hist = tr.train(3, log_every=0)
    assert plan.spent()
    _check_async_recovery(tr, hist, async_baseline, "producer_crash")


def test_producer_hang_watchdog_restart(tmp_path, async_baseline):
    """A producer that stays alive but stops heartbeating is caught by the
    staleness branch (is_alive() can't see a wedge) within
    watchdog_timeout."""
    plan = FaultPlan.parse("producer_hang@phase=1")
    tr = _mk_async(tmp_path / "hang", faults=plan, watchdog_timeout=3.0)
    hist = tr.train(3, log_every=0)
    assert plan.spent()
    _check_async_recovery(tr, hist, async_baseline, "producer_hang")


def test_restart_budget_exhaustion_raises(tmp_path):
    """Recovery is bounded: more distinct crashes than
    max_producer_restarts escalates instead of looping forever."""
    plan = FaultPlan.parse("producer_crash@phase=0 producer_crash@phase=1")
    tr = _mk_async(tmp_path / "budget", faults=plan,
                   max_producer_restarts=1)
    with pytest.raises(RuntimeError, match="max_producer_restarts"):
        tr.train(3, log_every=0)


# ---------------------------------------------------------------------------
# pool-exhaustion storm: transient PoolExhausted retries instead of aborting
# ---------------------------------------------------------------------------
def test_pool_exhaustion_storm_retries_admission(tmp_path):
    """Injected allocation failures at admission re-queue the unadmitted
    requests for a later sweep; the phase completes with the same tokens a
    fault-free run produces (admission order is telemetry, sampling keys
    are uid-bound)."""
    clean = _mk_sync(tmp_path / "clean", dense=True,
                     rollout_backend="continuous", cache_backend="paged",
                     decode_chunk=2, block_size=4)
    m_clean = clean.train_step()
    tokens_clean = np.asarray(jax.device_get(clean.last_rollout.resp_tokens))

    plan = FaultPlan.parse("pool_exhausted_storm@phase=0*3")
    tr = _mk_sync(tmp_path / "storm", faults=plan, dense=True,
                  rollout_backend="continuous", cache_backend="paged",
                  decode_chunk=2, block_size=4)
    m = tr.train_step()
    assert plan.spent()
    assert m["rollout_pool_retry_sweeps"] >= 1
    np.testing.assert_array_equal(
        tokens_clean,
        np.asarray(jax.device_get(tr.last_rollout.resp_tokens)))
    _cell("pool_exhausted_storm", True,
          retry_sweeps=m["rollout_pool_retry_sweeps"],
          reward_faulty=m["reward"], reward_clean=m_clean["reward"],
          bitwise_identical=True)


# ---------------------------------------------------------------------------
# anomaly-guarded update: non-finite steps skip, params stay intact
# ---------------------------------------------------------------------------
def test_nan_grads_skips_update_leaving_params_intact(tmp_path):
    """A poisoned (non-finite) update is dropped — params/opt bitwise
    untouched — and training continues on the next phase."""
    plan = FaultPlan.parse("nan_grads@step=0")
    tr = _mk_sync(tmp_path / "nan", faults=plan)
    p_before = _leaves(tr.params)
    o_before = _leaves(tr.opt_state)
    m0 = tr.train_step()
    assert plan.spent()
    assert m0["skipped_update_frac"] == 1.0
    assert tr.resilience["skipped_updates"] == 1
    for x, y in zip(p_before, _leaves(tr.params)):
        np.testing.assert_array_equal(x, y)      # bitwise no-op on skip
    for x, y in zip(o_before, _leaves(tr.opt_state)):
        np.testing.assert_array_equal(x, y)
    m1 = tr.train_step()                          # next phase trains
    assert m1["skipped_update_frac"] == 0.0
    assert np.isfinite(m1["loss"])
    # the healthy step APPLIED its update (params may be numerically
    # unchanged at smoke scale — zero reward -> zero grads — but the
    # optimizer state always advances on an applied minibatch)
    changed = any(not np.array_equal(x, y)
                  for x, y in zip(o_before, _leaves(tr.opt_state)))
    assert changed, "healthy step after the skip must apply its update"
    _cell("nan_grads", True, skipped=tr.resilience["skipped_updates"],
          reward_faulty=m1["reward"], params_intact_on_skip=True)


def test_nan_grads_consecutive_skips_escalate(tmp_path):
    """The guard is bounded: anomaly_max_skips consecutive non-finite
    updates raise loudly instead of silently free-running."""
    plan = FaultPlan.parse("nan_grads@step=0 nan_grads@step=1")
    tr = _mk_sync(tmp_path / "nan2", faults=plan, anomaly_max_skips=2)
    tr.train_step()                               # skip 1 of 2: tolerated
    with pytest.raises(RuntimeError, match="anomaly guard"):
        tr.train_step()                           # skip 2 of 2: escalates


# ---------------------------------------------------------------------------
# rejection storm: degraded mode re-rolls vetoed groups via dense fallback
# ---------------------------------------------------------------------------
def test_rejection_storm_dense_fallback_reroll(tmp_path):
    """An Eq. 6 veto rate above storm_threshold re-rolls the vetoed groups
    through the dense fallback policy: the update batch is not starved, the
    rerolled rows carry xi == 1 exactly, and the mismatch metrics aggregate
    over genuinely-sparse rows only."""
    plan = FaultPlan.parse("rejection_storm@phase=0")
    tr = _mk_sync(tmp_path / "storm", faults=plan, storm_threshold=0.5)
    m0 = tr.train_step()
    assert plan.spent()
    assert m0["storm_rerolls"] > 0
    assert m0["veto_rate"] > 0.5
    assert tr.resilience["storm_phases"] == 1
    # post-reroll batch: the veto can't re-fire on identity-class rows
    assert m0["rejection_rate"] == 0.0
    # metric hygiene: with every group rerolled there is no sparse evidence
    # left — min_log_xi reports +inf ("nothing to measure"), never a
    # diluted average over xi==1 rows
    assert m0["min_log_xi"] == np.inf
    assert m0["mean_xi"] == 1.0
    assert np.isfinite(m0["loss"])
    m1 = tr.train_step()                          # storm over: normal phase
    assert m1["storm_rerolls"] == 0.0
    assert m1["veto_rate"] <= 0.5
    _cell("rejection_storm", True, veto_rate=m0["veto_rate"],
          rerolled_groups=int(m0["storm_rerolls"]),
          reward_faulty=m0["reward"], reward_next=m1["reward"])


def test_identity_class_policy_skips_storm_probe(tmp_path):
    """A dense sampler has xi == 1 structurally — the storm guard must not
    even probe (no veto_rate metric), keeping the hot path unchanged."""
    tr = _mk_sync(tmp_path / "dense", dense=True)
    m = tr.train_step()
    assert "veto_rate" not in m


# ---------------------------------------------------------------------------
# checkpoint integrity: corruption detected at restore, auto-rollback
# ---------------------------------------------------------------------------
def test_corrupt_checkpoint_rolls_back_on_resume(tmp_path):
    """A checkpoint corrupted after save fails hash verification at
    restore; the resumed trainer rolls back to the previous snapshot with a
    warning and keeps training."""
    d = tmp_path / "ck"
    plan = FaultPlan.parse("corrupt_checkpoint@step=2")
    tr = _mk_sync(d, faults=plan, checkpoint_every=1)
    tr.train_step()
    tr.train_step()                               # step-2 save is corrupted
    assert plan.spent()
    del tr
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        tr2 = _mk_sync(d, checkpoint_every=1)
    assert tr2.step == 1                          # rolled back, not crashed
    assert tr2.resilience["checkpoint_rollbacks"] == 1
    assert any("failed integrity verification" in str(x.message) for x in w)
    m = tr2.train_step()                          # continues from step 1
    assert tr2.step == 2 and np.isfinite(m["loss"])
    assert m["checkpoint_rollbacks"] == 1.0       # surfaced in metrics
    _cell("corrupt_checkpoint", True, resumed_step=2,
          rollbacks=tr2.resilience["checkpoint_rollbacks"],
          reward_faulty=m["reward"])


def _save_steps(d, steps, extra=None):
    tree = {"w": np.arange(6, dtype=np.float32)}
    for s in steps:
        save(str(d), s, tree, keep=10, extra=extra)
    return tree


def test_verify_checkpoint_catches_truncation_and_bitflips(tmp_path):
    d = tmp_path / "v"
    tree = _save_steps(d, [1, 2])
    p1, p2 = d / "step_00000001", d / "step_00000002"
    assert verify_checkpoint(str(p1)) and verify_checkpoint(str(p2))
    # bit-flip newest
    corrupt_checkpoint_file(str(p2))
    assert not verify_checkpoint(str(p2))
    # truncate the other
    arr = p1 / "arrays.npz"
    arr.write_bytes(arr.read_bytes()[:-16])
    assert not verify_checkpoint(str(p1))
    assert latest_verifiable_step(str(d)) is None


def test_restore_skips_corrupt_newest_with_warning(tmp_path):
    d = tmp_path / "r"
    tree = _save_steps(d, [1, 2])
    corrupt_checkpoint_file(str(d / "step_00000002"))
    assert latest_verifiable_step(str(d)) == 1
    target = {"w": np.zeros(6, dtype=np.float32)}
    with pytest.warns(UserWarning, match="rolling back"):
        restored, step, _ = restore(str(d), target)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    # an explicit request for the corrupt step must NOT roll back silently
    with pytest.raises(ValueError, match="not rolling back"):
        restore(str(d), target, step=2)


def test_restore_raises_when_nothing_verifiable(tmp_path):
    d = tmp_path / "x"
    _save_steps(d, [1])
    corrupt_checkpoint_file(str(d / "step_00000001"))
    with pytest.raises(FileNotFoundError, match="no verifiable checkpoint"):
        restore(str(d), {"w": np.zeros(6, dtype=np.float32)})


def test_gc_never_deletes_last_verifiable_snapshot(tmp_path):
    """Pruning under keep=N spares the newest verifiable snapshot even when
    it has aged past the keep window and every retained snapshot is
    corrupt — a run must always have somewhere to roll back to."""
    d = tmp_path / "gc"
    _save_steps(d, [1, 2, 3])
    corrupt_checkpoint_file(str(d / "step_00000002"))
    corrupt_checkpoint_file(str(d / "step_00000003"))
    _gc(str(d), keep=2)
    assert (d / "step_00000001").is_dir()         # spared: last verifiable
    assert latest_verifiable_step(str(d)) == 1
    # healthy history prunes normally
    d2 = tmp_path / "gc2"
    _save_steps(d2, [1, 2, 3])
    _gc(str(d2), keep=2)
    assert not (d2 / "step_00000001").exists()
    assert (d2 / "step_00000002").is_dir() and (d2 / "step_00000003").is_dir()


# ---------------------------------------------------------------------------
# FaultPlan semantics
# ---------------------------------------------------------------------------
def test_fault_plan_parse_and_fire_semantics():
    plan = FaultPlan.parse("producer_crash@phase=3, nan_grads@step=7*2")
    assert str(plan) == "producer_crash@phase=3 nan_grads@step=7*2"
    assert not plan.fire("producer_crash", 2)     # wrong address
    assert not plan.fire("producer_hang", 3)      # wrong kind
    assert plan.fire("producer_crash", 3)
    assert not plan.fire("producer_crash", 3)     # count spent
    assert plan.fire("nan_grads", 7) and plan.fire("nan_grads", 7)
    assert not plan.fire("nan_grads", 7)
    assert plan.spent() and plan.fired() == 3
    assert plan.fired("nan_grads") == 2


def test_fault_plan_rejects_malformed_specs():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("disk_on_fire@phase=1")
    with pytest.raises(ValueError, match="addressed by"):
        FaultPlan.parse("nan_grads@phase=1")      # step-site kind
    with pytest.raises(ValueError, match="malformed"):
        FaultPlan.parse("nan_grads@step=x")
    with pytest.raises(ValueError, match="empty fault plan"):
        FaultPlan.parse("   ")
    with pytest.raises(ValueError, match="bad fault address"):
        FaultSpec(kind="nan_grads", at=0, count=0)


def test_fault_payloads_are_deterministic():
    a = FaultPlan.parse("rejection_storm@phase=4", seed=11)
    b = FaultPlan.parse("rejection_storm@phase=4", seed=11)
    np.testing.assert_array_equal(a.payload_rng(4).integers(0, 1000, 16),
                                  b.payload_rng(4).integers(0, 1000, 16))
    c = FaultPlan.parse("rejection_storm@phase=4", seed=12)
    assert not np.array_equal(a.payload_rng(4).integers(0, 1000, 16),
                              c.payload_rng(4).integers(0, 1000, 16))
