"""Documentation cross-reference integrity (tier-1 twin of the CI
link-check step): markdown links and DESIGN.md section references must
resolve, so renaming a section without updating its citations fails fast."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _run_checker(root):
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_doc_links.py"),
         str(root)],
        capture_output=True, text=True)


def test_markdown_links_and_design_sections_resolve():
    proc = _run_checker(ROOT)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_checker_catches_danglers(tmp_path):
    """The checker itself must actually fail on rot (guard the guard).
    The bad section reference is assembled at runtime so this test file
    itself stays clean under the checker's source scan."""
    sec = chr(0xA7)  # the section sign
    (tmp_path / "DESIGN.md").write_text("# DESIGN\n\n## Real section\n")
    (tmp_path / "README.md").write_text(
        "[gone](missing.md) and [bad](DESIGN.md#no-such-heading) "
        f"and DESIGN.md {sec}Imaginary section\n")
    (tmp_path / "ROADMAP.md").write_text("# ROADMAP\n")
    proc = _run_checker(tmp_path)
    assert proc.returncode == 1
    assert "broken link" in proc.stdout
    assert "dangling anchor" in proc.stdout
    assert "does not match any" in proc.stdout
