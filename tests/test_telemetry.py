"""Unified telemetry layer (DESIGN.md §Observability & telemetry): span
tracer, metrics registry, structured run log, the off-mode bitwise pin, the
PoolExhausted wait-retraction fix, and the trace_report breakdown math."""
import importlib.util
import io
import json
import sys
import threading
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.telemetry import (
    Histogram,
    MetricsRegistry,
    RunLog,
    Telemetry,
)

ROOT = Path(__file__).resolve().parent.parent
spec = importlib.util.spec_from_file_location(
    "trace_report", ROOT / "tools" / "trace_report.py")
trace_report = importlib.util.module_from_spec(spec)
sys.modules["trace_report"] = trace_report
spec.loader.exec_module(trace_report)


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------
def test_spans_nest_and_close_on_exception():
    tel = Telemetry("trace")
    with pytest.raises(ValueError, match="boom"):
        with tel.span("outer", phase=3):
            with tel.span("inner"):
                raise ValueError("boom")
    events = tel.tracer.to_chrome()["traceEvents"]
    by_name = {e["name"]: e for e in events}
    assert set(by_name) == {"outer", "inner"}
    # both spans closed (recorded) despite the exception, error stamped
    assert by_name["inner"]["args"]["error"] == "ValueError"
    assert by_name["outer"]["args"]["error"] == "ValueError"
    assert by_name["outer"]["args"]["phase"] == 3
    # inner nests inside outer on the time axis
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3


def test_producer_thread_spans_carry_thread_ids():
    tel = Telemetry("trace")
    seen = {}

    def producer():
        seen["tid"] = threading.get_ident()
        with tel.span("rollout_phase", role="producer"):
            pass

    with tel.span("train_step"):
        t = threading.Thread(target=producer)
        t.start()
        t.join()
    events = {e["name"]: e for e in tel.tracer.to_chrome()["traceEvents"]}
    assert events["train_step"]["tid"] == threading.get_ident()
    assert events["rollout_phase"]["tid"] == seen["tid"]
    assert events["train_step"]["tid"] != events["rollout_phase"]["tid"]


def test_chrome_trace_export_is_valid(tmp_path):
    tel = Telemetry("trace")
    with tel.timed("admit_sweep"):
        pass
    tel.instant("weight_swap", version=2)
    tel.counter_sample("engine.pool_blocks_in_use", 7)
    tel.count("engine.admissions", 3)
    out = tel.export_trace(str(tmp_path / "t.json"))
    doc = json.loads(Path(out).read_text())        # valid JSON, reparses
    assert doc["displayTimeUnit"] == "ms"
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert phs == {"X", "i", "C"}
    for e in doc["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e) or e["ph"] == "C"
        assert np.isfinite(e["ts"])
    # the registry snapshot rides in otherData for trace_report
    m = doc["otherData"]["metrics"]
    assert m["engine.admissions"]["value"] == 3
    assert m["admit_sweep_s"]["count"] == 1


def test_trace_buffer_bound_counts_drops():
    tel = Telemetry("trace")
    tel.tracer._max_events = 4
    for i in range(10):
        tel.instant("tick", i=i)
    assert len(tel.tracer.to_chrome()["traceEvents"]) == 4
    assert tel.tracer.dropped_events == 6
    assert tel.tracer.to_chrome()["otherData"]["dropped_events"] == 6


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(7)
    xs = rng.normal(size=1000)
    h = Histogram("x")
    h.observe_many(xs)
    for q in (0, 10, 50, 90, 99, 100):
        assert h.percentile(q) == np.percentile(xs, q)
    np.testing.assert_array_equal(h.percentile([50, 90]),
                                  np.percentile(xs, [50, 90]))
    snap = h.snapshot()
    assert snap["count"] == 1000
    assert snap["p99"] == np.percentile(xs, 99)
    np.testing.assert_allclose(snap["sum"], xs.sum())


def test_histogram_reservoir_is_bounded_and_deterministic():
    xs = np.arange(5000, dtype=np.float64)
    a = Histogram("lat", max_samples=64)
    b = Histogram("lat", max_samples=64)
    a.observe_many(xs)
    b.observe_many(xs)
    assert len(a._samples) == 64          # bounded
    assert a.count == 5000                # exact count/sum survive
    assert a.sum == xs.sum()
    assert a.snapshot() == b.snapshot()   # seeded per-name: reproducible


def test_registry_type_mismatch_is_loud():
    reg = MetricsRegistry()
    reg.counter("engine.admissions").inc()
    with pytest.raises(TypeError, match="engine.admissions"):
        reg.gauge("engine.admissions")
    assert reg.snapshot()["engine.admissions"]["value"] == 1


# ---------------------------------------------------------------------------
# run log
# ---------------------------------------------------------------------------
def test_run_log_jsonl_and_console_levels(tmp_path):
    path = tmp_path / "run_log.jsonl"
    out = io.StringIO()
    log = RunLog(str(path), console_level="info", stream=out)
    log.event("weight_swap", level="debug", version=3)
    log.event("train_step", step=4, msg="reward=0.5000", reward=0.5)
    log.event("anomaly_skip", level="warn", step=5, msg="non-finite update")
    log.close()
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["event"] for r in recs] == ["weight_swap", "train_step",
                                         "anomaly_skip"]
    assert recs[0]["level"] == "debug" and recs[0]["version"] == 3
    assert recs[1]["step"] == 4 and recs[1]["reward"] == 0.5
    console = out.getvalue()
    assert "weight_swap" not in console       # debug below console level
    assert "[step 4] reward=0.5000" in console
    assert "[step 5] WARN non-finite update" in console


def test_run_log_jsonable_numpy_fields(tmp_path):
    path = tmp_path / "log.jsonl"
    log = RunLog(str(path), console_level=None)
    log.event("e", x=np.float32(1.5), n=np.int64(2), a=np.arange(3))
    log.close()
    rec = json.loads(path.read_text())
    assert rec["x"] == 1.5 and rec["n"] == 2 and rec["a"] == [0, 1, 2]


# ---------------------------------------------------------------------------
# the facade / modes
# ---------------------------------------------------------------------------
def test_off_mode_is_inert():
    tel = Telemetry("off")
    ctx = tel.span("x")
    assert ctx is tel.timed("y")              # the shared no-op singleton
    with ctx:
        pass
    tel.count("c")
    tel.gauge("g", 1)
    tel.observe("h", 2)
    tel.instant("i")
    tel.counter_sample("cs", 3)
    assert tel.tracer is None and tel.metrics is None
    assert tel.export_trace("/nonexistent/never_written.json") is None


def test_metrics_mode_times_without_tracing():
    tel = Telemetry("metrics")
    with tel.timed("harvest"):
        pass
    assert tel.tracer is None
    assert tel.metrics.snapshot()["harvest_s"]["count"] == 1


def test_bad_mode_rejected():
    with pytest.raises(ValueError, match="telemetry mode"):
        Telemetry("verbose")


# ---------------------------------------------------------------------------
# trace_report breakdown math
# ---------------------------------------------------------------------------
def _x(name, ts, dur, **args):
    ev = {"name": name, "ph": "X", "pid": 1, "tid": 1, "ts": ts, "dur": dur}
    if args:
        ev["args"] = args
    return ev


def test_trace_report_breakdown_and_check():
    # one 10ms train_step: 4ms admit, 3ms decode, 2ms update, 1ms bubble;
    # container + nested prefill_dispatch must not double-count
    events = [
        _x("train_step", 0, 10_000, step=0),
        _x("rollout_phase", 0, 7_000),
        _x("admit_sweep", 0, 4_000),
        _x("prefill_dispatch", 500, 3_000, kind="admit"),
        _x("decode_chunk", 4_000, 3_000),
        _x("update", 7_500, 2_000),
    ]
    bd = trace_report.breakdown(events)
    assert bd["container"] == "train_step" and bd["steps"] == 1
    np.testing.assert_allclose(bd["wall"], 10e-3)
    np.testing.assert_allclose(bd["prefill"], 4e-3)
    np.testing.assert_allclose(bd["decode"], 3e-3)
    np.testing.assert_allclose(bd["update"], 2e-3)
    np.testing.assert_allclose(bd["bubble"], 1e-3)
    covered = sum(bd[c] for c in trace_report.CATEGORIES)
    np.testing.assert_allclose(covered + bd["bubble"], bd["wall"])


def test_trace_report_check_mode_exit_codes(tmp_path, capsys):
    good = {"traceEvents": [_x("train_step", 0, 10_000),
                            _x("admit_sweep", 0, 9_800)]}
    bad = {"traceEvents": [_x("train_step", 0, 10_000),
                           _x("admit_sweep", 0, 5_000)]}
    g, b = tmp_path / "good.json", tmp_path / "bad.json"
    g.write_text(json.dumps(good))
    b.write_text(json.dumps(bad))
    assert trace_report.main([str(g), "--check", "--max-bubble", "0.05"]) == 0
    assert trace_report.main([str(b), "--check", "--max-bubble", "0.05"]) == 1
    capsys.readouterr()


# ---------------------------------------------------------------------------
# PoolExhausted unwind retracts the EXACT recorded waits (regression)
# ---------------------------------------------------------------------------
def test_pool_exhausted_unwind_retracts_exact_waits():
    """Staged admissions record their wait once; if the flush dies with
    PoolExhausted *after the virtual clock moved on*, the unwind must
    retract the recorded entries — recomputing ``now - arrival`` at unwind
    time raised ValueError (value no longer in the list) or silently
    removed a different request's duplicate."""
    from repro.configs import SparseRLConfig, get_config
    from repro.data import TOKENIZER
    from repro.kvcache.paged import PoolExhausted
    from repro.models import get_model
    from repro.rollout import ContinuousEngine, Request

    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SparseRLConfig(kv_budget=8, kv_buffer=2, obs_window=2,
                          num_sinks=1, compression="rkv")
    eng = ContinuousEngine(params, cfg, m, scfg, batch_size=2,
                           prompt_len=8, max_new_tokens=4,
                           eos_id=TOKENIZER.eos_id, seed=0)
    reqs = [Request(uid=i, prompt=np.arange(3, 6, dtype=np.int32))
            for i in range(2)]
    eng.now = 1.5
    eng._stage_admit(reqs[0], 0)
    eng._stage_admit(reqs[1], 1)
    assert eng._phase_waits == [1.5, 1.5]

    def boom(staged, admitted):
        raise PoolExhausted("pool full")

    eng._flush_shared = boom
    eng._flush_plain = boom
    eng.now += 7.0                      # clock advances before the flush
    with pytest.raises(PoolExhausted) as ei:
        eng._flush_admissions()
    assert [r.uid for r in ei.value.unadmitted] == [0, 1]
    assert eng._phase_waits == []       # exact retraction, no ValueError
    assert eng.rows[0] is None and eng.rows[1] is None
    assert not bool(np.asarray(eng.active).any())


# ---------------------------------------------------------------------------
# the off-mode bitwise pin: telemetry never changes the computation
# ---------------------------------------------------------------------------
def test_telemetry_off_metrics_trace_bitwise_identical(tmp_path):
    """Two trainer steps on the continuous-paged backend under
    telemetry=off / metrics / trace produce bitwise-identical tokens,
    engine log-probs and final parameters — instrumentation only observes
    host-side values, it never feeds the compiled programs."""
    from repro.configs import SparseRLConfig, TrainConfig, get_config
    from repro.runtime import Trainer, TrainerOptions

    def run(mode, sub):
        cfg = get_config("qwen2.5-14b").smoke()
        scfg = SparseRLConfig(kv_budget=12, kv_buffer=4, obs_window=2,
                              num_sinks=1, group_size=4, max_new_tokens=8,
                              learning_rate=3e-4, kl_coef=0.0)
        tcfg = TrainConfig(update_batch=16, total_steps=4, warmup_steps=1,
                           checkpoint_every=0,
                           checkpoint_dir=str(tmp_path / sub))
        opts = TrainerOptions(num_prompts=4, prompt_len=16, max_new_tokens=8,
                              rollout_backend="continuous",
                              cache_backend="paged", decode_chunk=2,
                              telemetry=mode,
                              run_log=str(tmp_path / sub / "log.jsonl"))
        tr = Trainer(cfg, scfg, tcfg, opts)
        for _ in range(2):
            tr.train_step()
        ro = tr.last_rollout
        return (np.asarray(jax.device_get(ro.resp_tokens)),
                np.asarray(jax.device_get(ro.logp_sparse)),
                [np.asarray(x) for x in jax.tree.leaves(
                    jax.device_get(tr.params))])

    tok_off, lp_off, p_off = run("off", "off")
    for mode in ("metrics", "trace"):
        tok, lp, p = run(mode, mode)
        np.testing.assert_array_equal(tok, tok_off)
        np.testing.assert_array_equal(lp, lp_off)
        assert len(p) == len(p_off)
        for a, b in zip(p, p_off):
            np.testing.assert_array_equal(a, b)
