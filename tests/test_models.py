"""Per-architecture smoke tests (reduced same-family configs, CPU) +
decode-vs-teacher-forcing consistency for every cached family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SparseRLConfig, get_config
from repro.models import get_model

SCFG = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2, num_sinks=1)


def _batch(cfg, B=2, S=24, seed=1):
    rng = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(rng, (B, S), 3, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.num_patches, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one decode step on the reduced config: exact output
    shapes, no NaNs."""
    cfg = get_config(arch).smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, B, S)
    logits, aux = m.forward(params, cfg, batch)
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + prefix, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(aux)

    last, state = m.prefill(params, cfg, batch, SCFG, SCFG.cache_slots)
    assert last.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    lg, state = m.decode_step(params, cfg, state, tok, SCFG)
    assert lg.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_gradients_finite(arch):
    """One backward pass: finite grads for every leaf (train step viability)."""
    cfg = get_config(arch).smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 16)

    def loss(p):
        logits, aux = m.forward(p, cfg, batch)
        tgt = batch["tokens"]
        lp = jax.nn.log_softmax(logits[:, -tgt.shape[1]:-1].astype(jnp.float32))
        nll = -jnp.take_along_axis(lp, tgt[:, 1:, None], axis=-1).mean()
        return nll + aux

    g = jax.grad(loss)(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert bool(jnp.isfinite(leaf).all()), f"non-finite grad at {path}"


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "qwen3-moe-30b-a3b",
                                  "mamba2-370m", "zamba2-1.2b",
                                  "internvl2-2b", "whisper-small"])
def test_decode_matches_teacher_forcing(arch):
    """Dense-cache greedy decode logits == teacher-forced forward logits.
    One test per model family (transformer/moe/ssm/hybrid/vlm/encdec)."""
    cfg = get_config(arch).smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    scfg = SparseRLConfig(compression="none")
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    last, state = m.prefill(params, cfg, batch, scfg, prefix + S + 8)
    toks = [jnp.argmax(last, -1).astype(jnp.int32)]
    logits_steps = []
    for _ in range(3):
        lg, state = m.decode_step(params, cfg, state, toks[-1], scfg)
        logits_steps.append(lg)
        toks.append(jnp.argmax(lg, -1).astype(jnp.int32))
    full_tokens = jnp.concatenate(
        [batch["tokens"]] + [t[:, None] for t in toks[:-1]], axis=1)
    fb = dict(batch, tokens=full_tokens)
    fb.pop("valid_mask", None)
    full_logits, _ = m.forward(params, cfg, fb)
    for i, lg in enumerate(logits_steps):
        want = full_logits[:, -(len(logits_steps) - i) - 0 - 1 + 0]
        got_idx = full_logits.shape[1] - len(logits_steps) + i
        want = full_logits[:, got_idx]
        np.testing.assert_allclose(np.asarray(lg), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


def test_moe_routing_respects_topk():
    """Every token gets <= k experts' outputs; aux loss positive."""
    cfg = get_config("qwen3-moe-30b-a3b").smoke()
    from repro.models.moe import apply_moe, moe_init
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) > 0
    assert bool(jnp.isfinite(y).all())


def test_mamba2_state_invariance_to_padding():
    """Left padding (dt=0 masked) must not change the final state."""
    from repro.models import mamba2 as M
    cfg = get_config("mamba2-370m").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 3, cfg.vocab_size)
    pad = jnp.zeros((1, 4), jnp.int32)
    padded = jnp.concatenate([pad, toks], axis=1)
    vm = jnp.concatenate([jnp.zeros((1, 4), bool), jnp.ones((1, 10), bool)], 1)
    l1, s1 = M.prefill(params, cfg, toks)
    l2, s2 = M.prefill(params, cfg, padded, valid_mask=vm)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1.h), np.asarray(s2.h), rtol=2e-4,
                               atol=2e-4)


def test_flash_matches_materialized_attention():
    """model-level flash path == materialized path (same params/tokens)."""
    cfg = get_config("yi-34b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32)
    l1, _ = m.forward(params, cfg, batch, use_flash=False)
    l2, _ = m.forward(params, cfg, batch, use_flash=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-3,
                               atol=2e-3)
