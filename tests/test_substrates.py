"""Substrate tests: optimizer, checkpoint (fault tolerance), data, rewards,
sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import TOKENIZER, PromptLoader, encode_prompts, make_problems
from repro.optim import adamw
from repro.rewards import binary_rewards, parse_answer


# -- optimizer ---------------------------------------------------------------
def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array([2.0])}
    st = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, st, _ = adamw.update(params, g, st, lr=0.05)
    assert float(loss(params)) < 1e-3


def test_grad_clip_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    np.testing.assert_allclose(adamw.global_norm(clipped), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    lr0 = adamw.warmup_cosine(jnp.asarray(0), base_lr=1.0, warmup=10, total=100)
    lr_w = adamw.warmup_cosine(jnp.asarray(10), base_lr=1.0, warmup=10, total=100)
    lr_end = adamw.warmup_cosine(jnp.asarray(100), base_lr=1.0, warmup=10, total=100)
    assert float(lr0) == 0.0
    np.testing.assert_allclose(float(lr_w), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(lr_end), 0.1, rtol=1e-5)


def test_adamw_accum_dtype():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw.init(params, accum_dtype=jnp.bfloat16)
    assert st.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, st2, _ = adamw.update(params, g, st, lr=0.1)
    assert p2["w"].dtype == jnp.bfloat16
    assert st2.mu["w"].dtype == jnp.bfloat16


# -- checkpoint (fault tolerance) ---------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nest": {"b": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3, 4):
        save(d, step, tree, keep=2, extra={"rng": [0, step]})
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"
    target = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    got, step, extra = restore(d, target)
    assert step == 4 and extra["rng"] == [0, 4]
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert got["nest"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path / "ck")
    save(d, 7, {"x": jnp.ones(3)})
    assert not any(p.startswith("tmp.") for p in os.listdir(d))
    assert latest_step(d) == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    save(d, 1, {"x": jnp.ones((3,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(d, {"x": jnp.ones((4,))})


def test_checkpoint_crash_mid_write_recovers(tmp_path):
    """A stale tmp dir from a crashed writer must not break later saves."""
    d = str(tmp_path / "ck")
    os.makedirs(os.path.join(d, "tmp.5"))
    with open(os.path.join(d, "tmp.5", "garbage"), "w") as f:
        f.write("partial")
    save(d, 5, {"x": jnp.ones(2)})
    got, step, _ = restore(d, {"x": jnp.zeros(2)})
    assert step == 5


# -- data / rewards -----------------------------------------------------------
def test_tokenizer_roundtrip():
    s = "Q:(3+5)*2=?A:"
    ids = TOKENIZER.encode(s, bos=True, eos=True)
    assert ids[0] == TOKENIZER.bos_id and ids[-1] == TOKENIZER.eos_id
    assert TOKENIZER.decode(ids) == s


def test_problems_deterministic_and_verifiable():
    p1 = make_problems(50, seed=3, level="hard")
    p2 = make_problems(50, seed=3, level="hard")
    assert p1 == p2
    for p in p1:
        # gold answer must verify against itself
        ids = TOKENIZER.encode(p.answer, eos=True)
        r = binary_rewards(np.asarray([ids + [0] * 4]), [p.answer])
        assert r[0] == 1.0


def test_verifier_rejects_wrong():
    ids = TOKENIZER.encode("42", eos=True)
    assert binary_rewards(np.asarray([ids]), ["41"])[0] == 0.0
    assert binary_rewards(np.asarray([ids]), ["42"])[0] == 1.0
    # garbage after EOS is ignored
    ids2 = TOKENIZER.encode("42") + [TOKENIZER.eos_id] + TOKENIZER.encode("9")
    assert binary_rewards(np.asarray([ids2]), ["42"])[0] == 1.0


def test_parse_answer():
    assert parse_answer(" -17 blah") == "-17"
    assert parse_answer("answer: 9") == "9"
    assert parse_answer("") == ""
    assert parse_answer("-") == ""


def test_loader_host_sharding_partitions():
    common = dict(batch_prompts=8, prompt_len=16, seed=1, num_problems=100)
    full = PromptLoader(host_count=1, host_index=0, **common)
    h0 = PromptLoader(host_count=2, host_index=0,
                      batch_prompts=4, prompt_len=16, seed=1, num_problems=100)
    h1 = PromptLoader(host_count=2, host_index=1,
                      batch_prompts=4, prompt_len=16, seed=1, num_problems=100)
    ids_f, _, ans_f = full.get(0)
    ids_0, _, ans_0 = h0.get(0)
    ids_1, _, ans_1 = h1.get(0)
    # the two host shards are disjoint slices of the global batch
    merged = sorted(ans_0 + ans_1)
    assert merged == sorted(ans_f)


def test_left_padding():
    ids, mask, _ = encode_prompts(make_problems(4, 0), 32)
    assert ids.shape == (4, 32)
    # left padded: first column mostly pad, last column real
    assert (ids[:, -1] != 0).all()
    assert (mask.sum(1) > 0).all()


# -- sharding rules -----------------------------------------------------------
def test_logical_spec_divisibility_fallback():
    import jax as _jax
    if len(_jax.devices()) != 1:
        pytest.skip("single-device test")
    from repro.distributed.sharding import _resolve, DEFAULT_RULES

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 16)

    # heads=40 not divisible by model=16 -> replicated
    spec = _resolve(FakeMesh, DEFAULT_RULES, (2, 40, 64), ("batch", "heads", None))
    assert len(spec) < 2 or spec[1] is None
    # heads=32 divisible -> sharded
    spec = _resolve(FakeMesh, DEFAULT_RULES, (2, 32, 64), ("batch", "heads", None))
    assert spec[1] == "model"
    # same mesh axis never used twice within one shape
    spec = _resolve(FakeMesh, DEFAULT_RULES, (16, 16), ("heads", "ffn"))
    assert spec == __import__("jax").sharding.PartitionSpec("model")


def test_lsc_noop_outside_context():
    from repro.distributed.sharding import lsc
    x = jnp.ones((4, 4))
    y = lsc(x, "batch", "embed")
    assert y is x
