"""Paged KV cache: allocator/refcount invariants, copy-on-write, and
token-identity of the paged continuous-batching backend vs the contiguous
one across model families (DESIGN.md §Paged cache & prefix sharing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SparseRLConfig, get_config
from repro.data import TOKENIZER, encode_prompts, make_problems
from repro.kvcache.cache import POS_EMPTY
from repro.kvcache.paged import (
    BlockAllocator,
    PagedKVCache,
    PoolExhausted,
    PrefixCache,
    PrefixEntry,
    init_paged,
    materialize,
    paged_append,
    paged_reset_rows,
    write_prompt,
)
from repro.models import get_model
from repro.rollout import ContinuousEngine, Request

PROMPT_LEN = 16


# ---------------------------------------------------------------------------
# Allocator / prefix cache (host side)
# ---------------------------------------------------------------------------
def test_allocator_alloc_free_refcount():
    a = BlockAllocator(8, 4)
    xs = a.alloc(3)
    assert 0 not in xs                      # page 0 is the garbage sink
    assert a.blocks_in_use == 3
    a.retain(xs[0])
    assert a.release(xs[0]) is False        # still referenced by the retain
    assert a.release(xs[0]) is True         # now actually freed
    assert a.blocks_in_use == 2
    a2 = a.alloc(1)[0]                      # freed page is reusable
    assert a.refcount(a2) == 1


def test_allocator_never_double_frees():
    a = BlockAllocator(4, 4)
    (b,) = a.alloc(1)
    a.release(b)
    with pytest.raises(ValueError):
        a.release(b)
    with pytest.raises(ValueError):
        a.release(0)                        # the garbage sink is pinned
    with pytest.raises(ValueError):
        a.retain(b)                         # can't resurrect a freed page


def test_allocator_exhaustion_and_prefix_eviction():
    a = BlockAllocator(4, 4)                # 3 usable pages
    with pytest.raises(PoolExhausted):
        a.alloc(4)
    pc = PrefixCache(a, max_entries=8)
    pc.insert(b"x", PrefixEntry(blocks=tuple(a.alloc(2))))
    with pytest.raises(PoolExhausted):
        a.alloc(2)
    assert pc.evict_one()                   # LRU eviction releases the pages
    assert len(a.alloc(2)) == 2


def test_prefix_cache_lru_and_capacity():
    a = BlockAllocator(16, 4)
    pc = PrefixCache(a, max_entries=2)
    ba, bb, bc = a.alloc(1), a.alloc(1), a.alloc(1)
    pc.insert(b"a", PrefixEntry(blocks=tuple(ba)))
    pc.insert(b"b", PrefixEntry(blocks=tuple(bb)))
    assert pc.lookup(b"a") is not None      # touches "a": "b" is now LRU
    pc.insert(b"c", PrefixEntry(blocks=tuple(bc)))
    assert len(pc) == 2
    assert pc.lookup(b"b") is None          # evicted...
    assert a.refcount(bb[0]) == 0           # ...and its page released
    assert a.refcount(ba[0]) == 1


# ---------------------------------------------------------------------------
# Device-side pool semantics
# ---------------------------------------------------------------------------
def _prompt_arrays(rng, Hkv, P, Dh, pad=2):
    k = jnp.asarray(rng.normal(size=(Hkv, P, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(Hkv, P, Dh)), jnp.float32)
    pos = jnp.asarray([POS_EMPTY] * pad + list(range(P - pad)), jnp.int32)
    return k, v, pos


def test_copy_on_write_preserves_shared_prefix():
    """Two rows mapping the same shared prompt pages diverge via appends;
    the shared pages (the prefix-cache entry) must stay bit-identical."""
    rng = np.random.default_rng(0)
    Hkv, Dh, bs, nb, P, S = 2, 4, 4, 4, 6, 14
    c = init_paged(2, Hkv, num_blocks=16, block_size=bs, head_dim=Dh,
                   blocks_per_row=nb, seq_len=S, dtype=jnp.float32)
    kp, vp, pp = _prompt_arrays(rng, Hkv, P, Dh)
    # entry chain: full page 1, tail page 2 (P=6, bs=4); rows share page 1
    # and own private tail copies 3 / 4 plus generation pages 5..8
    c = write_prompt(c, kp, vp, pp, jnp.asarray([1, 2]), jnp.asarray(3),
                     duplicate_tail=True)
    from repro.kvcache.paged import copy_block
    c = copy_block(c, jnp.asarray(2), jnp.asarray(4))
    tables = jnp.asarray([[1, 3, 5, 6], [1, 4, 7, 8]], jnp.int32)
    c = PagedKVCache(c.k_pool, c.v_pool, c.pos_pool, tables,
                     jnp.full((2,), P, jnp.int32), seq_len=S)
    entry_k = np.asarray(c.k_pool[jnp.asarray([1, 2])])
    entry_pos = np.asarray(c.pos_pool[jnp.asarray([1, 2])])
    for t in range(5):                       # divergent appends per row
        kn = jnp.asarray(rng.normal(size=(2, Hkv, Dh)), jnp.float32)
        c = paged_append(c, kn, kn * 2, jnp.full((2,), P - 2 + t, jnp.int32))
    # shared pages untouched
    np.testing.assert_array_equal(np.asarray(c.k_pool[jnp.asarray([1, 2])]),
                                  entry_k)
    np.testing.assert_array_equal(np.asarray(c.pos_pool[jnp.asarray([1, 2])]),
                                  entry_pos)
    k, v, pos = materialize(c)
    # both rows still see the identical shared prefix...
    np.testing.assert_array_equal(np.asarray(k[0, :, :P]),
                                  np.asarray(k[1, :, :P]))
    np.testing.assert_array_equal(np.asarray(pos[0, :, :P]),
                                  np.asarray(pos[1, :, :P]))
    # ...and genuinely diverged after it
    assert not np.array_equal(np.asarray(k[0, :, P:P + 5]),
                              np.asarray(k[1, :, P:P + 5]))


def test_materialize_matches_contiguous_layout():
    """A paged row materializes to exactly the contiguous cache arrays:
    prompt + appends in temporal order, zeros/POS_EMPTY beyond fill."""
    rng = np.random.default_rng(1)
    Hkv, Dh, bs, nb, P, S = 2, 4, 4, 3, 6, 12
    c = init_paged(1, Hkv, num_blocks=8, block_size=bs, head_dim=Dh,
                   blocks_per_row=nb, seq_len=S, dtype=jnp.float32)
    kp, vp, pp = _prompt_arrays(rng, Hkv, P, Dh)
    c = write_prompt(c, kp, vp, pp, jnp.asarray([1, 2]), jnp.asarray(0),
                     duplicate_tail=False)
    c = PagedKVCache(c.k_pool, c.v_pool, c.pos_pool,
                     jnp.asarray([[1, 2, 3]], jnp.int32),
                     jnp.asarray([P], jnp.int32), seq_len=S)
    appends = []
    for t in range(4):
        kn = jnp.asarray(rng.normal(size=(1, Hkv, Dh)), jnp.float32)
        appends.append(np.asarray(kn[0]))
        c = paged_append(c, kn, kn, jnp.asarray([P - 2 + t], jnp.int32))
    k, _, pos = materialize(c)
    want_k = np.concatenate([np.asarray(kp),
                             np.stack(appends, axis=1),
                             np.zeros((Hkv, S - P - 4, Dh), np.float32)],
                            axis=1)
    np.testing.assert_array_equal(np.asarray(k[0]), want_k)
    want_pos = np.concatenate([np.asarray(pp), np.arange(P - 2, P + 2),
                               np.full(S - P - 4, POS_EMPTY)])
    np.testing.assert_array_equal(np.asarray(pos[0, 0]), want_pos)


def test_paged_reset_rows_unmaps_only_targets():
    c = init_paged(3, 2, num_blocks=8, block_size=4, head_dim=4,
                   blocks_per_row=2, seq_len=8, dtype=jnp.float32)
    c = PagedKVCache(c.k_pool, c.v_pool, c.pos_pool,
                     jnp.ones((3, 2), jnp.int32),
                     jnp.full((3,), 5, jnp.int32), seq_len=8)
    out = paged_reset_rows(c, jnp.asarray([1]))
    assert (np.asarray(out.block_tables[1]) == -1).all()
    assert int(out.fill[1]) == 0
    for row in (0, 2):
        assert (np.asarray(out.block_tables[row]) == 1).all()
        assert int(out.fill[row]) == 5


# ---------------------------------------------------------------------------
# Engine: token identity + prefix sharing across families
# ---------------------------------------------------------------------------
def _group_requests(n_prompts, group_size, caps, seed=1):
    problems = make_problems(n_prompts, seed, "easy")
    ids, mask, _ = encode_prompts(problems, PROMPT_LEN)
    reqs, uid = [], 0
    for i in range(n_prompts):
        for _ in range(group_size):
            reqs.append(Request(uid=uid, prompt=ids[i][mask[i]],
                                max_new_tokens=caps[uid % len(caps)]))
            uid += 1
    return reqs


def _run_pair(arch, compression, *, group=2, n_prompts=2, caps=(4, 6, 5, 3),
              max_new=6, chunk=1, seed=7, block_size=12, kv_quant="none"):
    cfg = get_config(arch).smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                          num_sinks=1, compression=compression)
    reqs = _group_requests(n_prompts, group, list(caps))
    kw = dict(batch_size=2, prompt_len=PROMPT_LEN, max_new_tokens=max_new,
              eos_id=TOKENIZER.eos_id, decode_chunk=chunk, seed=seed)
    cont = ContinuousEngine(params, cfg, m, scfg, **kw).run(reqs)
    eng = ContinuousEngine(params, cfg, m, scfg, cache_backend="paged",
                           block_size=block_size, kv_quant=kv_quant, **kw)
    paged = eng.run(reqs)
    return eng, cont, paged


@pytest.mark.parametrize("arch,compression,pool", [
    ("qwen2.5-14b", "none", True),    # dense transformer: block-table pool
    ("zamba2-1.2b", "rkv", False),    # hybrid: prefill-state splice sharing
    ("mamba2-370m", "none", False),   # pure SSM: O(1) state, splice sharing
])
def test_paged_backend_token_identical(arch, compression, pool):
    """The paged backend must emit byte-identical tokens and log-probs to
    the contiguous continuous-batching path on the same seeds, for dense,
    hybrid and SSM families — prefix sharing (and, for dense, the page
    pool) must be invisible in the outputs."""
    eng, cont, paged = _run_pair(arch, compression)
    assert eng._pool_paged == pool
    assert len(cont) == len(paged) == 4
    for c, p in zip(cont, paged):
        assert c.uid == p.uid
        np.testing.assert_array_equal(c.tokens, p.tokens)
        np.testing.assert_allclose(c.logps, p.logps, atol=0)
        assert c.finish_reason == p.finish_reason
    # shared prompts were prefilled once each
    assert eng.stats["prefills"] == 2
    assert eng.stats["prefix_hits"] == 2


def test_prefix_hit_rate_group_sampling():
    """G rollouts of one prompt: exactly one model prefill, cold hit rate
    (G-1)/G (the paged backend's acceptance bar), and a block-table tail
    page that does not divide the prompt length (copy-on-write exercised)."""
    G = 4
    eng, cont, paged = _run_pair("qwen2.5-14b", "none", group=G, n_prompts=1,
                                 caps=(3, 6, 4, 5), block_size=12)
    assert eng._has_tail                    # 16 % 12 != 0: COW path active
    assert eng.stats["admissions"] == G
    assert eng.stats["prefills"] == 1
    assert eng.prefix_hit_rate == pytest.approx((G - 1) / G)
    for c, p in zip(cont, paged):
        np.testing.assert_array_equal(c.tokens, p.tokens)
    # group members genuinely diverged (distinct uids -> distinct key chains)
    assert len({p.tokens.tobytes() for p in paged}) > 1


def test_paged_pool_pages_released_after_drain():
    """After the queue drains, every row's page references are released —
    only the prefix-cache entries keep pages pinned (no leak, no double
    free across recycled rows)."""
    eng, _, _ = _run_pair("qwen2.5-14b", "none", group=3, n_prompts=2,
                          caps=(3, 7, 5, 8, 2, 4), chunk=2)
    assert all(r is None for r in eng.rows)
    assert eng.allocator.blocks_in_use == len(eng.prefix) * eng._npb
    assert not bool(np.asarray(eng.active).any())
    # retired rows are unmapped on device
    assert (np.asarray(eng.state.caches.block_tables) == -1).all()
    assert (np.asarray(eng.state.caches.fill) == 0).all()


def test_paged_decode_chunk_invariance():
    """decode_chunk changes harvest granularity only, pool backend included."""
    _, _, paged1 = _run_pair("qwen2.5-14b", "none", chunk=1)
    _, _, paged4 = _run_pair("qwen2.5-14b", "none", chunk=4)
    for a, b in zip(paged1, paged4):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_hit_path_pins_entry_against_lru_eviction():
    """A prefix-cache hit must pin the entry's pages before allocating the
    row's own pages: under pool pressure the LRU eviction can reach the
    very entry being admitted, and unpinned pages would be freed and handed
    straight back as the row's append pages (silent KV corruption).  With
    the pin, a genuinely-too-small pool fails loudly (PoolExhausted) and
    rolls the pins back cleanly."""
    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SparseRLConfig(compression="none")
    problems = make_problems(2, 3, "easy")
    ids, mask, _ = encode_prompts(problems, PROMPT_LEN)
    X, Y = ids[0][mask[0]], ids[1][mask[1]]
    eng = ContinuousEngine(params, cfg, m, scfg, batch_size=2,
                           prompt_len=PROMPT_LEN, max_new_tokens=8,
                           eos_id=TOKENIZER.eos_id, cache_backend="paged",
                           block_size=12, seed=0)
    n_own = eng.blocks_per_row - eng._npb_full
    eng._admit_one(Request(uid=0, prompt=Y), 0)   # long-running pins entry Y
    eng._admit_one(Request(uid=1, prompt=X), 1)
    for b in eng.rows[1].blocks:                  # X finishes, pages released
        eng.allocator.release(b)
    eng.rows[1] = None
    x_blocks = eng.prefix.lookup(np.asarray(X, np.int32).tobytes()).blocks
    # drain the free list completely: the admission is forced through
    # eviction, which (after Y, whose full page row 0 pins) reaches entry X
    # itself — whose pages the hit path must have pinned
    eng.allocator.alloc(eng.allocator.num_free)
    with pytest.raises(PoolExhausted):
        eng._admit_one(Request(uid=2, prompt=X), 1)
    assert eng.rows[1] is None                    # admission fully unwound
    # entry X was evicted and the temporary pins rolled back: its pages are
    # free again, never aliased into another row's table mid-admission
    for b in x_blocks:
        assert eng.allocator.refcount(b) == 0
    # freed: X's npb pages + Y's entry-only tail page(s)
    assert eng.allocator.num_free == eng._npb + (eng._npb - eng._npb_full)


def test_paged_rejects_unknown_backend():
    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ContinuousEngine(params, cfg, m, SparseRLConfig(compression="none"),
                         batch_size=2, prompt_len=8, max_new_tokens=4,
                         eos_id=1, cache_backend="virtual")


def test_write_prompt_partial_chain_skip_pages():
    """Chunked-prefill partial write: a bucketed prompt covers only the
    trailing pages of its chain; the leading pad-only pages are wiped to
    POS_EMPTY (recycled pages carry a previous tenant's valid positions)
    and the materialized row matches a full-width write on every *valid*
    slot."""
    Hkv, Dh, bs, P, W = 2, 4, 4, 12, 8            # skip = (12-8)/4 = 1 page
    npb = P // bs
    c = init_paged(1, Hkv, num_blocks=2 * npb + 1, block_size=bs, head_dim=Dh,
                   blocks_per_row=npb, seq_len=P, dtype=jnp.float32)
    # poison the pool: every page starts with valid-looking positions
    c = PagedKVCache(c.k_pool, jnp.full_like(c.v_pool, 7.0),
                     jnp.zeros_like(c.pos_pool), c.block_tables, c.fill,
                     seq_len=P)
    rng = np.random.default_rng(0)
    kW = jnp.asarray(rng.normal(size=(Hkv, W, Dh)), jnp.float32)
    posW = jnp.asarray([POS_EMPTY] * 2 + list(range(P - W + 2, P)), jnp.int32)
    chain = jnp.asarray([1, 2, 3], jnp.int32)
    out = write_prompt(c, kW, kW * 0.5, posW, chain, jnp.int32(0),
                       duplicate_tail=False, skip_pages=1)
    out = PagedKVCache(out.k_pool, out.v_pool, out.pos_pool,
                       jnp.asarray([[1, 2, 3]], jnp.int32),
                       jnp.asarray([P], jnp.int32), seq_len=P)
    # full-width oracle: same prompt written without skip into another chain
    kP = jnp.concatenate([jnp.zeros((Hkv, P - W, Dh)), kW], axis=1)
    posP = jnp.concatenate([jnp.full((P - W,), POS_EMPTY, jnp.int32),
                            posW])
    full = write_prompt(c, kP, kP * 0.5, posP,
                        jnp.asarray([4, 5, 6], jnp.int32), jnp.int32(0),
                        duplicate_tail=False)
    full = PagedKVCache(full.k_pool, full.v_pool, full.pos_pool,
                        jnp.asarray([[4, 5, 6]], jnp.int32),
                        jnp.asarray([P], jnp.int32), seq_len=P)
    k_a, v_a, pos_a = materialize(out)
    k_b, v_b, pos_b = materialize(full)
    np.testing.assert_array_equal(pos_a, pos_b)   # skip page wiped to EMPTY
    valid = np.asarray(pos_a[0, 0]) >= 0
    np.testing.assert_array_equal(np.asarray(k_a)[..., valid, :],
                                  np.asarray(k_b)[..., valid, :])
    np.testing.assert_array_equal(np.asarray(v_a)[..., valid, :],
                                  np.asarray(v_b)[..., valid, :])


def _pressure_engine(pool_slack=0, prefix_entries=2, batch=2):
    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SparseRLConfig(compression="none")
    eng = ContinuousEngine(params, cfg, m, scfg, batch_size=batch,
                           prompt_len=PROMPT_LEN, max_new_tokens=8,
                           eos_id=TOKENIZER.eos_id, cache_backend="paged",
                           block_size=8, seed=0,
                           pool_blocks=1 + batch * 4 + 2 + pool_slack,
                           prefix_entries=prefix_entries)
    return eng


def test_lru_eviction_under_pool_pressure_no_leak_at_end_phase():
    """More distinct prompts than the prefix cache/pool can pin: LRU
    entries evict mid-phase, evicted prompts re-admit as fresh misses in a
    later phase, refcounts stay consistent (end_phase's leak check is the
    assertion) and outputs are reproducible."""
    problems = make_problems(4, 11, "easy")
    ids, mask, _ = encode_prompts(problems, PROMPT_LEN)
    reqs = [Request(uid=i, prompt=ids[i][mask[i]], max_new_tokens=4)
            for i in range(4)]
    eng = _pressure_engine()
    first = eng.run(reqs)
    assert eng.stats["prefills"] == 4             # all distinct prompts
    assert len(eng.prefix) <= 2                   # LRU evictions happened
    stats = eng.end_phase()                       # raises on any page leak
    assert eng.allocator.blocks_in_use == 0
    assert stats["admissions"] == 4
    # evicted prompts come back as misses; same seeds -> same tokens
    eng.begin_phase()
    second = eng.run(reqs)
    assert eng.stats["prefills"] == 4             # phase-end cleared them all
    eng.end_phase()
    assert eng.allocator.blocks_in_use == 0
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_pool_exhausted_under_pressure_unwinds_and_keeps_refcounts():
    """A genuinely-too-full pool: the admission evicts every prefix entry
    trying to make room, then fails loudly with PoolExhausted — the staged
    row reverts to free and no page reference is left dangling."""
    problems = make_problems(2, 13, "easy")
    ids, mask, _ = encode_prompts(problems, PROMPT_LEN)
    eng = _pressure_engine()
    eng._admit_one(Request(uid=0, prompt=ids[0][mask[0]]), 0)
    free_before_squat = eng.allocator.num_free
    squat = eng.allocator.alloc(free_before_squat)   # external pressure
    with pytest.raises(PoolExhausted):
        eng._admit_one(Request(uid=1, prompt=ids[1][mask[1]]), 1)
    assert eng.rows[1] is None                    # admission fully unwound
    assert len(eng.prefix) == 0                   # evicted trying to fit
    # only the first row's pages + the squatter remain referenced
    assert eng.allocator.blocks_in_use == len(squat) + len(eng.rows[0].blocks)
    eng.allocator.release_many(squat)
    # the engine still works once pressure lifts
    eng._admit_one(Request(uid=1, prompt=ids[1][mask[1]]), 1)
    assert eng.rows[1] is not None


def test_pool_bucketed_prefill_short_prompts_identical():
    """Pool-mode chunked prefill: prompts short enough for a sub-chain
    bucket (width P - j*block_size) leave their leading pad pages cleared,
    not written — and stay token-identical to the contiguous backend."""
    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SparseRLConfig(compression="none")
    problems = make_problems(2, 5, "easy")
    ids, mask, _ = encode_prompts(problems, PROMPT_LEN)
    # 3/4-token prompts -> bucket 16 - 2*6 = 4 with block_size=6 (skip=2)
    reqs = [Request(uid=u, prompt=ids[u // 2][mask[u // 2]][:3 + u // 2],
                    max_new_tokens=(4, 6, 5, 3)[u]) for u in range(4)]
    kw = dict(batch_size=2, prompt_len=PROMPT_LEN, max_new_tokens=6,
              eos_id=TOKENIZER.eos_id, decode_chunk=1, seed=7)
    cont = ContinuousEngine(params, cfg, m, scfg, **kw).run(reqs)
    eng = ContinuousEngine(params, cfg, m, scfg, cache_backend="paged",
                           block_size=6, **kw)
    paged = eng.run(reqs)
    assert 4 in eng._buckets                      # the short bucket exists
    assert eng.stats["prefills"] == 2             # two distinct prompts
    # every miss prefilled at the 4-wide bucket, not the engine-wide P
    assert eng.stats["prefill_tokens"] == 4 * eng.stats["prefills"]
    for c, p in zip(cont, paged):
        np.testing.assert_array_equal(c.tokens, p.tokens)
        np.testing.assert_allclose(c.logps, p.logps, atol=0)


# ---------------------------------------------------------------------------
# Quantized pool: the host-side sharing machinery is storage-agnostic
# (DESIGN.md §Quantized paged pool)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_quant", ["int8", "fp8"])
def test_quant_pool_prefix_hit_rate_and_phase_drain(kv_quant):
    """G same-prompt rollouts against a quantized pool: one model prefill,
    cold hit rate (G-1)/G, genuinely diverged group members, full drain —
    exactly the fp-pool invariants.  The quantized entries share *codes +
    scales* via the same pinned page chains, so the allocator/prefix
    accounting must not notice the storage dtype."""
    G = 4
    eng, _, paged = _run_pair("qwen2.5-14b", "none", group=G, n_prompts=1,
                              caps=(3, 6, 4, 5), kv_quant=kv_quant)
    assert eng.kv_quant == kv_quant
    assert eng.state.caches.k_scale is not None   # scales really resident
    assert eng.stats["admissions"] == G
    assert eng.stats["prefills"] == 1
    assert eng.prefix_hit_rate == pytest.approx((G - 1) / G)
    assert len({p.tokens.tobytes() for p in paged}) > 1
    # drained: rows retired, only the prefix-cache pins remain
    assert all(r is None for r in eng.rows)
    assert eng.allocator.blocks_in_use == len(eng.prefix) * eng._npb
    # end_phase's leak check passes and reports the shrunken pool
    stats = eng.end_phase()
    assert eng.allocator.blocks_in_use == 0
    assert stats["kv_capacity_ratio"] >= (1.8 if kv_quant == "int8" else 1.0)
    assert stats["kv_bytes_per_token"] > 0


def test_quant_pool_end_phase_flags_leaks_and_double_free():
    """The phase-end leak check stays armed under quantization: a page
    still referenced after the prefix-cache clear raises, releasing it
    clears the phase, and a second release of the same page is the
    double-free the allocator refuses."""
    eng, _, _ = _run_pair("qwen2.5-14b", "none", group=2, n_prompts=1,
                          caps=(3, 5), kv_quant="int8")
    [leak] = eng.allocator.alloc(1)
    with pytest.raises(RuntimeError, match="leak"):
        eng.end_phase()
    assert eng.allocator.release(leak) is True
    with pytest.raises(ValueError):
        eng.allocator.release(leak)               # double free refused
    stats = eng.end_phase()                       # now clean
    assert eng.allocator.blocks_in_use == 0
    assert stats["kv_capacity_ratio"] >= 1.8
