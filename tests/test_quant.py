"""int8 serving-weight quantization (§Perf C1 feature)."""
import jax
import jax.numpy as jnp
import numpy as np
from dataclasses import replace

from repro.configs import SparseRLConfig, get_config
from repro.models import get_model
from repro.models.common import quantize_int8


def test_quantize_int8_error_bound():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.05, (64, 32)), jnp.float32)
    q, scale = quantize_int8(w)
    assert q.dtype == jnp.int8 and scale.shape == (32,)
    deq = q.astype(jnp.float32) * scale[None, :]
    # max error <= half an LSB per channel (+ float eps)
    err = np.asarray(jnp.abs(deq - w))
    bound = np.asarray(scale)[None, :] * 0.5 + 1e-6
    assert (err <= bound).all(), float((err - bound).max())


def test_int8_model_close_to_fp():
    cfg = get_config("qwen2.5-14b").smoke()
    cfg8 = replace(cfg, weight_quant="int8")
    m, m8 = get_model(cfg), get_model(cfg8)
    # same rng -> int8 params are the quantized version of the fp params
    p = m.init_params(cfg, jax.random.PRNGKey(0))
    p8 = m8.init_params(cfg8, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 3,
                                          cfg.vocab_size)}
    lg, _ = m.forward(p, cfg, batch)
    lg8, _ = m8.forward(p8, cfg8, batch)
    # logits stay within quantization noise of the fp model
    assert float(jnp.abs(lg - lg8).max()) < 2.0
    corr = np.corrcoef(np.asarray(lg).ravel(), np.asarray(lg8).ravel())[0, 1]
    assert corr > 0.98


def test_int8_param_bytes_halved():
    cfg = get_config("qwen2.5-14b").smoke()
    cfg8 = replace(cfg, weight_quant="int8")
    m, m8 = get_model(cfg), get_model(cfg8)
    nbytes = lambda tree: sum(x.size * x.dtype.itemsize
                              for x in jax.tree.leaves(tree))
    sds = jax.eval_shape(lambda: m.init_params(cfg, jax.random.PRNGKey(0)))
    sds8 = jax.eval_shape(lambda: m8.init_params(cfg8, jax.random.PRNGKey(0)))
    # dense matmul weights dominate the layer stack; embeddings unchanged
    layers = nbytes(sds.children()[0]["layers"]) if hasattr(sds, "children") \
        else nbytes(sds["layers"])
    layers8 = nbytes(sds8["layers"])
    assert layers8 < 0.45 * nbytes(sds["layers"])  # f32 -> int8 (+ scales)


def test_int8_decode_and_rollout():
    from repro.rollout import generate, rescore
    from repro.data import TOKENIZER
    cfg8 = replace(get_config("qwen2.5-14b").smoke(), weight_quant="int8")
    m8 = get_model(cfg8)
    p8 = m8.init_params(cfg8, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 10), 3,
                                          cfg8.vocab_size),
             "valid_mask": jnp.ones((2, 10), bool)}
    scfg = SparseRLConfig(kv_budget=8, kv_buffer=2, obs_window=2, num_sinks=1)
    ro = generate(p8, cfg8, m8, batch, scfg, jax.random.PRNGKey(2),
                  max_new_tokens=6, eos_id=TOKENIZER.eos_id)
    lp = rescore(p8, cfg8, m8, ro)
    assert bool(jnp.isfinite(lp).all())
