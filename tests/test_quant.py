"""Quantization batteries: int8 serving weights (§Perf C1) and the
quantized paged KV pool (int8/fp8 storage with per-(page, kv-head) scales —
DESIGN.md §Quantized paged pool)."""
import dataclasses
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SparseRLConfig, get_config
from repro.kvcache.cache import POS_EMPTY
from repro.kvcache.paged import (
    GARBAGE_BLOCK,
    QUANT_MODES,
    dequantize_kv,
    init_paged,
    materialize,
    page_scale,
    paged_append,
    quant_spec,
    quantize_kv,
    write_prompt,
)
from repro.models import get_model
from repro.models.common import quantize_int8


def test_quantize_int8_error_bound():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.05, (64, 32)), jnp.float32)
    q, scale = quantize_int8(w)
    assert q.dtype == jnp.int8 and scale.shape == (32,)
    deq = q.astype(jnp.float32) * scale[None, :]
    # max error <= half an LSB per channel (+ float eps)
    err = np.asarray(jnp.abs(deq - w))
    bound = np.asarray(scale)[None, :] * 0.5 + 1e-6
    assert (err <= bound).all(), float((err - bound).max())


def test_int8_model_close_to_fp():
    cfg = get_config("qwen2.5-14b").smoke()
    cfg8 = replace(cfg, weight_quant="int8")
    m, m8 = get_model(cfg), get_model(cfg8)
    # same rng -> int8 params are the quantized version of the fp params
    p = m.init_params(cfg, jax.random.PRNGKey(0))
    p8 = m8.init_params(cfg8, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 3,
                                          cfg.vocab_size)}
    lg, _ = m.forward(p, cfg, batch)
    lg8, _ = m8.forward(p8, cfg8, batch)
    # logits stay within quantization noise of the fp model
    assert float(jnp.abs(lg - lg8).max()) < 2.0
    corr = np.corrcoef(np.asarray(lg).ravel(), np.asarray(lg8).ravel())[0, 1]
    assert corr > 0.98


def test_int8_param_bytes_halved():
    cfg = get_config("qwen2.5-14b").smoke()
    cfg8 = replace(cfg, weight_quant="int8")
    m, m8 = get_model(cfg), get_model(cfg8)
    nbytes = lambda tree: sum(x.size * x.dtype.itemsize
                              for x in jax.tree.leaves(tree))
    sds = jax.eval_shape(lambda: m.init_params(cfg, jax.random.PRNGKey(0)))
    sds8 = jax.eval_shape(lambda: m8.init_params(cfg8, jax.random.PRNGKey(0)))
    # dense matmul weights dominate the layer stack; embeddings unchanged
    layers = nbytes(sds.children()[0]["layers"]) if hasattr(sds, "children") \
        else nbytes(sds["layers"])
    layers8 = nbytes(sds8["layers"])
    assert layers8 < 0.45 * nbytes(sds["layers"])  # f32 -> int8 (+ scales)


def test_int8_decode_and_rollout():
    from repro.rollout import generate, rescore
    from repro.data import TOKENIZER
    cfg8 = replace(get_config("qwen2.5-14b").smoke(), weight_quant="int8")
    m8 = get_model(cfg8)
    p8 = m8.init_params(cfg8, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 10), 3,
                                          cfg8.vocab_size),
             "valid_mask": jnp.ones((2, 10), bool)}
    scfg = SparseRLConfig(kv_budget=8, kv_buffer=2, obs_window=2, num_sinks=1)
    ro = generate(p8, cfg8, m8, batch, scfg, jax.random.PRNGKey(2),
                  max_new_tokens=6, eos_id=TOKENIZER.eos_id)
    lp = rescore(p8, cfg8, m8, ro)
    assert bool(jnp.isfinite(lp).all())


# ---------------------------------------------------------------------------
# Quantized paged KV pool: round-trip battery
# ---------------------------------------------------------------------------
def _roundtrip_bound(x: np.ndarray, scale: np.ndarray, quant: str
                     ) -> np.ndarray:
    """Worst-case |dequant(quant(x)) - x| per element; ``scale`` must
    already broadcast against ``x``.

    int8 rounds to nearest under a per-page scale: half an LSB.  fp8 e4m3
    has a 3-bit mantissa: relative half-ULP 2^-4 for normals, plus the
    subnormal absolute floor (half the smallest subnormal, 2^-10) times the
    page scale."""
    if quant == "int8":
        return 0.5 * scale + 1e-6 + np.zeros_like(x)
    return np.abs(x) * 2.0 ** -4 + scale * 2.0 ** -10 + 1e-6


def _check_roundtrip(x: np.ndarray, quant: str):
    """Quantize a batch of pages under their own amax scales and assert the
    per-mode error bound element-wise."""
    xj = jnp.asarray(x, jnp.float32)
    scale = page_scale(xj, quant)
    q = quantize_kv(xj, scale[..., None, None], quant)
    assert q.dtype == quant_spec(quant)[0]
    deq = np.asarray(dequantize_kv(q, np.asarray(scale)[..., None, None]))
    err = np.abs(deq - x)
    bound = _roundtrip_bound(x, np.asarray(scale)[..., None, None], quant)
    assert (err <= bound).all(), (quant, float((err - bound).max()))


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_kv_page_roundtrip_error_bound(quant):
    rng = np.random.default_rng(0)
    # pages spanning magnitudes (normal kv-activation scale to outliers)
    for sigma in (1e-3, 0.05, 1.0, 30.0):
        x = rng.normal(0.0, sigma, (5, 2, 8, 16)).astype(np.float32)
        _check_roundtrip(x, quant)


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_kv_all_zero_page_roundtrips_exactly(quant):
    """An all-zero (or never-written) page has scale 0 and must dequantize
    to exact zeros — this is what keeps unwritten pool garbage inert."""
    x = jnp.zeros((3, 2, 8, 16), jnp.float32)
    scale = page_scale(x, quant)
    assert not np.asarray(scale).any()
    q = quantize_kv(x, scale[..., None, None], quant)
    deq = np.asarray(dequantize_kv(q, scale[..., None, None]))
    assert (deq == 0.0).all()


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_kv_scale_is_per_page_per_head(quant):
    """One outlier page/head must not degrade any other page or head: the
    scale layout is (page, kv-head), not pool-global."""
    rng = np.random.default_rng(1)
    x = rng.normal(0.0, 0.05, (4, 2, 8, 16)).astype(np.float32)
    x[2, 1] *= 1000.0                       # outlier page 2, head 1 only
    scale = np.asarray(page_scale(jnp.asarray(x), quant))
    assert scale.shape == (4, 2)
    clean = np.ones((4, 2), bool)
    clean[2, 1] = False
    assert scale[2, 1] > 100.0 * scale[clean].max()
    _check_roundtrip(x, quant)              # bound holds pointwise anyway


def test_kv_quant_unknown_mode_raises():
    with pytest.raises(ValueError, match="unknown quant mode"):
        quant_spec("int4")
    with pytest.raises(ValueError, match="unknown quant mode"):
        init_paged(2, 2, 4, 8, 16, 2, 16, quant="int4")
    assert QUANT_MODES == ("none", "int8", "fp8")


def _alloc_rows(cache, tables):
    """Map each row's page chain (list of lists, -1 = unmapped tail)."""
    bt = np.full(cache.block_tables.shape, -1, np.int32)
    for b, chain in enumerate(tables):
        bt[b, :len(chain)] = chain
    return dataclasses.replace(cache, block_tables=jnp.asarray(bt))


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_kv_write_prompt_quantizes_with_fresh_page_scales(quant):
    """`write_prompt` on a quantized pool: materialized values within the
    round-trip bound of the fp pool's, tail duplication copies codes AND
    scales bit-for-bit, and skip_pages wipes the skipped pages' scales."""
    Hkv, bs, Dh, W = 2, 8, 16, 13         # partial tail page (13 = 8 + 5)
    rng = np.random.default_rng(2)
    k = jnp.asarray(rng.normal(0, 0.5, (Hkv, W, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 0.5, (Hkv, W, Dh)), jnp.float32)
    pos = jnp.arange(W)
    kw = dict(blocks=jnp.asarray([1, 2]), tail_dst=jnp.asarray(3),
              duplicate_tail=True)
    cq = write_prompt(init_paged(2, Hkv, 5, bs, Dh, 2, 16, quant=quant),
                      k, v, pos, **kw)
    cf = write_prompt(init_paged(2, Hkv, 5, bs, Dh, 2, 16,
                                 dtype=jnp.float32),
                      k, v, pos, **kw)
    # tail page 2 duplicated into 3: same codes, same scales
    assert np.array_equal(np.asarray(cq.k_pool[2]), np.asarray(cq.k_pool[3]))
    assert np.array_equal(np.asarray(cq.k_scale[2]), np.asarray(cq.k_scale[3]))
    assert np.array_equal(np.asarray(cq.v_scale[2]), np.asarray(cq.v_scale[3]))
    # materialize through each row's chain and compare to the fp pool
    rows = [[1, 2], [1, 3]]
    mq = _alloc_rows(dataclasses.replace(cq, fill=jnp.full((2,), W)), rows)
    mf = _alloc_rows(dataclasses.replace(cf, fill=jnp.full((2,), W)), rows)
    kq, vq, pq = materialize(mq)
    kf, vf, pf = materialize(mf)
    assert np.array_equal(np.asarray(pq), np.asarray(pf))
    S = 16
    page_of_slot = np.asarray(rows)[:, np.arange(S) // bs]    # (B, S)
    for got, ref, sc in ((kq, kf, cq.k_scale), (vq, vf, cq.v_scale)):
        s = np.asarray(sc)[page_of_slot]                      # (B, S, Hkv)
        s = np.moveaxis(s, 2, 1)[..., None]                   # (B,Hkv,S,1)
        err = np.abs(np.asarray(got) - np.asarray(ref))
        bound = _roundtrip_bound(np.asarray(ref), s, quant)
        assert (err <= bound).all()
    # skip_pages: a short-bucketed prompt wipes the skipped pages' scales
    c2 = write_prompt(init_paged(2, Hkv, 5, bs, Dh, 2, 16, quant=quant),
                      k[:, bs:], v[:, bs:], pos[bs:],
                      blocks=jnp.asarray([4, 1]), tail_dst=jnp.asarray(2),
                      duplicate_tail=True, skip_pages=1)
    assert not np.asarray(c2.k_scale[4]).any()
    assert np.asarray(c2.pos_pool[4] == POS_EMPTY).all()


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_kv_append_single_token_tail(quant):
    """One appended token on a fresh page: the page scale is the token's
    own amax, the slot round-trips within bound, every other slot of the
    page stays exactly zero after materialize."""
    B, Hkv, bs, Dh = 2, 2, 4, 8
    cache = init_paged(B, Hkv, 6, bs, Dh, 2, 8, quant=quant)
    cache = _alloc_rows(cache, [[1, 2], [3, 4]])
    rng = np.random.default_rng(3)
    k1 = jnp.asarray(rng.normal(0, 0.5, (B, Hkv, Dh)), jnp.float32)
    v1 = jnp.asarray(rng.normal(0, 0.5, (B, Hkv, Dh)), jnp.float32)
    cache = paged_append(cache, k1, v1, jnp.zeros((B,), jnp.int32))
    _, qmax = quant_spec(quant)
    np.testing.assert_allclose(
        np.asarray(cache.k_scale[jnp.asarray([1, 3])]),
        np.asarray(jnp.max(jnp.abs(k1), axis=-1) / qmax), rtol=1e-6)
    k, v, pos = materialize(cache)
    err = np.abs(np.asarray(k[:, :, 0]) - np.asarray(k1))       # (B,Hkv,Dh)
    scale = np.asarray(cache.k_scale[jnp.asarray([1, 3])])       # (B, Hkv)
    bound = _roundtrip_bound(np.asarray(k1), scale[..., None], quant)
    assert (err <= bound).all()
    assert not np.asarray(k[:, :, 1:]).any()          # tail slots exact 0
    assert np.asarray(pos[:, :, 0] == 0).all()
    assert np.asarray(pos[:, :, 1:] == POS_EMPTY).all()


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_kv_append_monotone_scale_and_exact_requant(quant):
    """Page scales only grow; appends that do not grow the scale leave the
    resident codes bit-identical (`_rescale_page` is an exact identity at
    factor 1), and a genuinely larger token re-bounds earlier tokens under
    the new, larger scale."""
    B, Hkv, bs, Dh = 1, 2, 4, 8
    cache = init_paged(B, Hkv, 3, bs, Dh, 1, 4, quant=quant)
    cache = _alloc_rows(cache, [[1]])
    rng = np.random.default_rng(4)
    small = jnp.asarray(rng.normal(0, 0.05, (B, Hkv, Dh)), jnp.float32)
    small2 = jnp.asarray(rng.normal(0, 0.05, (B, Hkv, Dh)), jnp.float32)
    big = jnp.asarray(rng.normal(0, 5.0, (B, Hkv, Dh)), jnp.float32)
    cache = paged_append(cache, small, small, jnp.asarray([0]))
    s0 = np.asarray(cache.k_scale[1]).copy()
    codes0 = np.asarray(cache.k_pool[1]).copy()
    # same-magnitude append: scale unchanged -> resident codes unchanged
    cache = paged_append(cache, small2, small2, jnp.asarray([1]))
    scale_after_small = np.asarray(cache.k_scale[1])
    assert (scale_after_small >= s0 - 1e-12).all()
    same = scale_after_small <= s0 + 1e-12
    assert np.array_equal(
        np.asarray(cache.k_pool[1])[same][:, 0],
        codes0[same][:, 0]), "unchanged-scale requant must be bit-exact"
    # larger-magnitude append: scale grows, earlier token re-bounds
    cache = paged_append(cache, big, big, jnp.asarray([2]))
    s2 = np.asarray(cache.k_scale[1])
    assert (s2 >= scale_after_small - 1e-12).all()
    assert (s2 > scale_after_small).any()
    k, _, _ = materialize(cache)
    err0 = np.abs(np.asarray(k[0, :, 0]) - np.asarray(small[0]))  # (Hkv,Dh)
    bound = _roundtrip_bound(np.asarray(small[0]), s2[:, None], quant)
    assert (err0 <= bound).all()


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_kv_append_unmapped_rows_hit_garbage_sink_only(quant):
    """Retired rows (table all -1, fill 0 — `paged_reset_rows`) keep
    appending for static shapes: their writes clamp to page 0 and may
    scribble its scale, but no mapped page — codes or scales — moves, and
    the retired row materializes to exact zeros/POS_EMPTY regardless of
    what landed in the garbage sink."""
    from repro.kvcache.paged import paged_reset_rows

    B, Hkv, bs, Dh = 2, 2, 4, 8
    cache = init_paged(B, Hkv, 4, bs, Dh, 1, 4, quant=quant)
    cache = _alloc_rows(cache, [[1], [2]])
    rng = np.random.default_rng(5)
    k1 = jnp.asarray(rng.normal(0, 0.5, (B, Hkv, Dh)), jnp.float32)
    cache = paged_append(cache, k1, k1, jnp.zeros((B,), jnp.int32))
    cache = paged_reset_rows(cache, jnp.asarray([1]))    # retire row 1
    # freshly retired: materializes to exact zeros / POS_EMPTY (its old
    # page 2 content is unreachable junk the allocator will recycle)
    k, _, pos = materialize(cache)
    assert not np.asarray(k[1]).any()
    assert np.asarray(pos[1] == POS_EMPTY).all()
    live_k = np.asarray(cache.k_pool[1]).copy()
    live_s = np.asarray(cache.k_scale[1]).copy()
    junk = jnp.asarray(rng.normal(0, 50.0, (B, Hkv, Dh)), jnp.float32)
    junk = junk.at[0].set(jnp.asarray(
        rng.normal(0, 0.05, (Hkv, Dh)), jnp.float32))   # row 0 stays tame
    cache = paged_append(cache, junk, junk, jnp.ones((B,), jnp.int32))
    # row 0's page untouched by row 1's garbage write (slot 0 bits intact)
    assert np.array_equal(np.asarray(cache.k_pool[1])[:, 0], live_k[:, 0])
    # ...though its own append may have grown the scale monotonically
    assert (np.asarray(cache.k_scale[1]) >= live_s - 1e-12).all()
    # the junk landed where it should: page 0's scale grew, page 2 (row
    # 1's old, now-unmapped page) did not move a bit
    assert np.asarray(cache.k_scale[GARBAGE_BLOCK]).max() > 0.1


def test_kv_quant_none_keeps_fp_pool_bitwise():
    """quant="none" must be the historical fp pool exactly: no scales ever
    appear, dtypes are untouched, and the quant branch of append/write is
    never taken (bit-for-bit storage of the incoming values)."""
    B, Hkv, bs, Dh = 2, 2, 4, 8
    cache = init_paged(B, Hkv, 4, bs, Dh, 1, 4, dtype=jnp.float32)
    assert cache.k_scale is None and cache.v_scale is None
    cache = _alloc_rows(cache, [[1], [2]])
    rng = np.random.default_rng(6)
    k1 = jnp.asarray(rng.normal(0, 0.5, (B, Hkv, Dh)), jnp.float32)
    cache = paged_append(cache, k1, k1, jnp.zeros((B,), jnp.int32))
    assert cache.k_scale is None
    assert np.array_equal(np.asarray(cache.k_pool[jnp.asarray([1, 2]),
                                                  :, 0]), np.asarray(k1))
    # stacking layers (the engine's layout) keeps the None leaves None
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), cache)
    assert stacked.k_scale is None and stacked.quant == "none"


def test_kv_materialize_refuses_scale_confusion():
    """A quantized cache missing its scales, or an int8 pool claiming
    quant='none', must raise — never silently read codes as floats."""
    cache = init_paged(2, 2, 4, 8, 16, 2, 16, quant="int8")
    broken = dataclasses.replace(cache, k_scale=None, v_scale=None)
    with pytest.raises(ValueError, match="no k_scale"):
        materialize(broken)
    lying = dataclasses.replace(cache, quant="none", k_scale=None,
                                v_scale=None)
    with pytest.raises(ValueError, match="quantized bytes"):
        materialize(lying)


def test_kv_quant_pool_bytes_shrink():
    """The point of the exercise: int8 pool payload (codes + scales) is
    < 0.3x the f32 pool at equal block count (>= 1.8x capacity is the
    engine-level acceptance bar; at f32 it is ~3.9x)."""
    kw = dict(batch=2, kv_heads=2, num_blocks=32, block_size=16,
              head_dim=64, blocks_per_row=4, seq_len=64)
    fp = init_paged(*kw.values(), dtype=jnp.float32)
    q8 = init_paged(*kw.values(), quant="int8")
    nbytes = lambda c: (c.k_pool.nbytes + c.v_pool.nbytes
                        + (c.k_scale.nbytes + c.v_scale.nbytes
                           if c.k_scale is not None else 0))
    assert nbytes(q8) < 0.3 * nbytes(fp)
    assert nbytes(fp) / nbytes(q8) >= 1.8


def test_kv_roundtrip_property_fuzz():
    """Hypothesis fuzz over page contents: magnitudes spanning 2^-8..2^8,
    random zero fractions (all-zero pages included), both quant modes —
    the round-trip bound must hold pointwise."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1),
           quant=st.sampled_from(("int8", "fp8")),
           log_sigma=st.integers(-8, 8),
           zero_frac=st.floats(0.0, 1.0))
    def check(seed, quant, log_sigma, zero_frac):
        rng = np.random.default_rng(seed)
        x = rng.normal(0.0, 2.0 ** log_sigma, (3, 2, 8, 8)).astype(
            np.float32)
        x[rng.random(x.shape) < zero_frac] = 0.0
        _check_roundtrip(x, quant)

    check()
