"""KV-cache invariants (unit tests; the hypothesis-fuzzed properties live in
test_properties.py so these always run even without hypothesis installed)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SparseRLConfig
from repro.kvcache import (
    append,
    attend,
    compress_prefill,
    init_cache,
    update_scores,
)


def _scfg(**kw):
    base = dict(kv_budget=8, kv_buffer=4, obs_window=2, num_sinks=1,
                compression="rkv")
    base.update(kw)
    return SparseRLConfig(**base)


def _fill_cache(scfg, B=2, H=2, D=8, steps=20, seed=0):
    rng = np.random.default_rng(seed)
    cache = init_cache(B, H, scfg.cache_slots, D, jnp.float32)
    for t in range(steps):
        k = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
        cache = append(cache, k, v, jnp.full((B,), t, jnp.int32), scfg)
        q = jnp.asarray(rng.normal(size=(B, H * 2, D)), jnp.float32)
        _, probs = attend(q, cache)
        cache = update_scores(cache, probs, scfg)
    return cache


@pytest.mark.parametrize("policy", ["rkv", "h2o", "snapkv", "streaming"])
def test_slots_never_exceeded(policy):
    scfg = _scfg(compression=policy)
    cache = _fill_cache(scfg, steps=30)
    assert cache.k.shape[-2] == scfg.cache_slots
    assert (np.asarray(cache.fill) == scfg.cache_slots).all()
    # all slots hold real tokens once full
    assert bool(cache.valid_mask().all())


@pytest.mark.parametrize("policy", ["rkv", "h2o", "streaming"])
def test_protected_tokens_survive(policy):
    """Sinks + observation window are never evicted (cache.pos retains them)."""
    scfg = _scfg(compression=policy)
    steps = 30
    cache = _fill_cache(scfg, steps=steps)
    pos = np.asarray(cache.pos)
    for b in range(pos.shape[0]):
        for h in range(pos.shape[1]):
            kept = set(pos[b, h].tolist())
            for sink in range(scfg.num_sinks):
                assert sink in kept, f"sink {sink} evicted ({policy})"
            for recent in range(steps - scfg.obs_window + 1, steps):
                assert recent in kept, f"recent {recent} evicted ({policy})"


def test_streaming_evicts_oldest_unprotected():
    scfg = _scfg(compression="streaming")
    cache = _fill_cache(scfg, steps=13)  # slots=12 -> exactly one eviction
    pos = np.asarray(cache.pos)
    # oldest non-sink position (= num_sinks) must be gone
    assert scfg.num_sinks not in pos[0, 0].tolist()


def test_dense_cache_never_evicts():
    scfg = _scfg(compression="none")
    B, H, D = 1, 1, 4
    cache = init_cache(B, H, 16, D, jnp.float32)
    for t in range(10):
        k = jnp.ones((B, H, D)) * t
        cache = append(cache, k, k, jnp.full((B,), t, jnp.int32), scfg)
    pos = np.asarray(cache.pos[0, 0])
    assert sorted(p for p in pos.tolist() if p >= 0) == list(range(10))


def test_attend_masks_empty_slots():
    scfg = _scfg()
    B, H, D = 1, 1, 4
    cache = init_cache(B, H, 8, D, jnp.float32)
    cache = append(cache, jnp.ones((B, H, D)), jnp.ones((B, H, D)) * 7.0,
                   jnp.zeros((B,), jnp.int32), scfg)
    q = jnp.ones((B, H, D))
    out, probs = attend(q, cache)
    # single valid slot -> output == its value, probs one-hot
    np.testing.assert_allclose(out[0, 0], 7.0, rtol=1e-6)
    np.testing.assert_allclose(float(probs.sum()), 1.0, rtol=1e-6)


def test_compress_prefill_selects_topk_and_keeps_order():
    scfg = _scfg(num_sinks=1, obs_window=2)
    B, H, T, D = 1, 1, 10, 4
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    mask = jnp.ones((B, T), bool)
    positions = jnp.arange(T)[None, :]
    obs = jnp.asarray(rng.uniform(size=(B, H, T)), jnp.float32)
    slots = 6
    cache = compress_prefill(k, v, mask, obs, slots, scfg, positions)
    pos = np.asarray(cache.pos[0, 0])
    assert len(pos) == slots and (pos >= 0).all()
    # temporal order preserved
    assert (np.diff(pos) > 0).all()
    # sink 0 and the last obs_window-1 tokens kept
    assert 0 in pos and 9 in pos
    # selected = top scores among unprotected
    protected = {0, 9}
    sel = [p for p in pos.tolist() if p not in protected]
    scores = np.asarray(obs[0, 0])
    unprot = [i for i in range(T) if i not in protected]
    expected = sorted(sorted(unprot, key=lambda i: -scores[i])[:slots - 2])
    assert sel == expected


def test_compress_prefill_short_prompt_verbatim():
    scfg = _scfg()
    B, H, T, D = 2, 1, 4, 4
    k = jnp.ones((B, H, T, D))
    v = jnp.ones((B, H, T, D))
    mask = jnp.array([[True] * 4, [False, True, True, True]])
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    obs = jnp.zeros((B, H, T))
    cache = compress_prefill(k, v, mask, obs, 8, scfg, positions)
    assert cache.k.shape[-2] == 8
    assert (np.asarray(cache.fill) == 4).all()
    # padding marked empty
    assert np.asarray(cache.pos)[1, 0, 0] == -1


