"""Unit tests for the paper's core math (Eqs. 4-7)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SparseRLConfig
from repro.core import (
    group_advantages,
    grpo_loss,
    k3_kl,
    rejection_mask,
    sparse_rl_loss,
    sparsity_consistency_ratio,
)


def test_group_advantages_normalization():
    r = jnp.array([[1.0, 0.0, 1.0, 0.0], [1.0, 1.0, 1.0, 1.0]])
    adv = group_advantages(r)
    # zero-mean within groups
    np.testing.assert_allclose(adv.mean(axis=-1), 0.0, atol=1e-6)
    # degenerate (all-equal) group -> zero advantage, no NaN
    np.testing.assert_allclose(adv[1], 0.0, atol=1e-6)
    assert not jnp.isnan(adv).any()


def test_xi_ratio_eq5():
    lo = jnp.log(jnp.array([[0.5, 0.2]]))
    ls = jnp.log(jnp.array([[0.25, 0.2]]))
    xi = sparsity_consistency_ratio(lo, ls)
    np.testing.assert_allclose(xi, [[2.0, 1.0]], rtol=1e-6)


def test_xi_cap():
    lo = jnp.zeros((1, 1))
    ls = jnp.full((1, 1), -50.0)
    xi = sparsity_consistency_ratio(lo, ls, xi_clip_max=10.0)
    np.testing.assert_allclose(xi, 10.0, rtol=1e-6)


def test_rejection_mask_eq6():
    # token 2 of seq 0 is anomalous: pi_old << pi_sparse
    lo = jnp.log(jnp.array([[0.5, 1e-9, 0.5], [0.5, 0.5, 0.5]]))
    ls = jnp.log(jnp.full((2, 3), 0.5))
    mask = jnp.ones((2, 3), bool)
    m = rejection_mask(lo, ls, mask, eps=1e-4)
    np.testing.assert_allclose(m, [0.0, 1.0])
    # the anomalous token is ignored if masked out (e.g. after EOS)
    mask2 = mask.at[0, 1].set(False)
    m2 = rejection_mask(lo, ls, mask2, eps=1e-4)
    np.testing.assert_allclose(m2, [1.0, 1.0])


def _setup(B=4, T=6, seed=0):
    rng = np.random.default_rng(seed)
    lt = jnp.asarray(rng.normal(-1.5, 0.3, (B, T)), jnp.float32)
    lo = lt + jnp.asarray(rng.normal(0, 0.05, (B, T)), jnp.float32)
    ls = lo + jnp.asarray(rng.normal(0, 0.1, (B, T)), jnp.float32)
    adv = jnp.asarray(rng.normal(0, 1, (B,)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(B, T)) > 0.2)
    return lt, lo, ls, adv, mask


def test_sparse_rl_reduces_to_grpo_when_dense():
    """With pi_sparse == pi_old (no compression), Eq. 7 == Eq. 11."""
    lt, lo, _, adv, mask = _setup()
    scfg = SparseRLConfig()
    out = sparse_rl_loss(lt, lo, lo, adv, mask, scfg)
    g_loss, _ = grpo_loss(lt, lo, adv, mask, clip_eps=scfg.clip_eps)
    np.testing.assert_allclose(out.loss, g_loss, rtol=1e-5)
    assert float(out.metrics["rejection_rate"]) == 0.0
    np.testing.assert_allclose(out.metrics["mean_xi"], 1.0, rtol=1e-6)


def test_naive_config_ignores_corrections():
    lt, lo, ls, adv, mask = _setup()
    naive = SparseRLConfig().naive()
    out_naive = sparse_rl_loss(lt, lo, ls, adv, mask, naive)
    g_loss, _ = grpo_loss(lt, lo, adv, mask, clip_eps=naive.clip_eps)
    np.testing.assert_allclose(out_naive.loss, g_loss, rtol=1e-5)


def test_rejected_sequence_contributes_no_gradient():
    lt, lo, ls, adv, mask = _setup()
    # poison sequence 0 with an anomalous token
    ls = ls.at[0, 1].set(lo[0, 1] + 20.0)  # xi = e^-20 << eps
    scfg = SparseRLConfig()

    def loss(lt_):
        return sparse_rl_loss(lt_, lo, ls, adv, mask, scfg).loss

    g = jax.grad(loss)(lt)
    np.testing.assert_allclose(g[0], 0.0, atol=1e-9)
    assert float(jnp.abs(g[1:]).sum()) > 0


def test_reweighting_scales_token_gradient():
    """grad wrt logp_theta at theta=theta_old is -xi * A / |o| per token."""
    B, T = 2, 4
    lo = jnp.full((B, T), -1.0)
    ls = lo - jnp.log(jnp.array([[2.0, 1.0, 0.5, 1.0],
                                 [1.0, 1.0, 1.0, 1.0]]))  # xi = 2,1,.5,1 / 1s
    adv = jnp.array([1.0, -1.0])
    mask = jnp.ones((B, T), bool)
    scfg = SparseRLConfig(kl_coef=0.0)

    def loss(lt_):
        return sparse_rl_loss(lt_, lo, ls, adv, mask, scfg).loss

    g = jax.grad(loss)(lo)
    xi = jnp.exp(lo - ls)
    expected = -(xi * adv[:, None]) / T / B
    np.testing.assert_allclose(g, expected, rtol=1e-5)


def test_clip_restricted_to_staleness_ratio():
    """xi outside the clip: large xi passes through even when w is clipped."""
    B, T = 1, 1
    lo = jnp.zeros((B, T))
    ls = jnp.full((B, T), -jnp.log(5.0))   # xi = 5
    lt = jnp.full((B, T), jnp.log(2.0))    # w = 2 -> clipped to 1.2
    adv = jnp.array([1.0])
    mask = jnp.ones((B, T), bool)
    scfg = SparseRLConfig(clip_eps=0.2)
    out = sparse_rl_loss(lt, lo, ls, adv, mask, scfg)
    np.testing.assert_allclose(out.loss, -5.0 * 1.2, rtol=1e-5)
    assert float(out.metrics["clip_ratio"]) == 1.0


def test_k3_nonnegative():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(100,)))
    b = jnp.asarray(rng.normal(size=(100,)))
    assert float(k3_kl(a, b).min()) >= 0.0


def test_sequence_level_variant_runs():
    lt, lo, ls, adv, mask = _setup()
    scfg = SparseRLConfig(sequence_level=True)
    out = sparse_rl_loss(lt, lo, ls, adv, mask, scfg)
    assert jnp.isfinite(out.loss)
    g = jax.grad(lambda x: sparse_rl_loss(x, lo, ls, adv, mask, scfg).loss)(lt)
    assert jnp.isfinite(g).all()


def test_ref_kl_term():
    lt, lo, ls, adv, mask = _setup()
    scfg = SparseRLConfig(kl_coef=0.1)
    out_with = sparse_rl_loss(lt, lo, ls, adv, mask, scfg, logp_ref=lo)
    out_wo = sparse_rl_loss(lt, lo, ls, adv, mask, scfg)
    assert float(out_with.loss) > float(out_wo.loss) - 1e-6
    assert "ref_kl" in out_with.metrics


def test_min_log_xi_not_clamped_by_masked_fill():
    """min_log_xi must be the min over VALID tokens only.  Masked positions
    used to fill with 0.0 inside the min, clamping the metric at 0 whenever
    every valid log-ratio is positive (regression: fill is +inf now)."""
    B, T = 2, 4
    lt = jnp.zeros((B, T))
    lo = jnp.zeros((B, T))
    # every valid token: log xi = logp_old - logp_sparse = +0.3
    ls = jnp.full((B, T), -0.3)
    mask = jnp.ones((B, T), bool).at[0, 3].set(False)
    adv = jnp.ones((B,))
    out = sparse_rl_loss(lt, lo, ls, adv, mask, SparseRLConfig())
    np.testing.assert_allclose(float(out.metrics["min_log_xi"]), 0.3,
                               rtol=1e-6)
    # a genuinely negative log-ratio still wins the min
    ls2 = ls.at[1, 2].set(0.5)            # log xi = -0.5 there
    out2 = sparse_rl_loss(lt, lo, ls2, adv, mask, SparseRLConfig())
    np.testing.assert_allclose(float(out2.metrics["min_log_xi"]), -0.5,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# staleness-aware behavior correction (async pipeline; DESIGN.md
# §Async pipeline & staleness correction)
# ---------------------------------------------------------------------------
def test_staleness_rho_degenerates_bitwise_at_lag0():
    """logp_behave == logp_old (lag 0) must reproduce the sync loss
    EXACTLY: log rho = 0, rho = exp(0) = 1.0, and multiplying by the exact
    float 1.0 changes no bit."""
    rng = np.random.default_rng(0)
    B, T = 4, 6
    lo = jnp.asarray(rng.normal(-1.0, 0.5, (B, T)), jnp.float32)
    ls = jnp.asarray(rng.normal(-1.0, 0.5, (B, T)), jnp.float32)
    lt = lo + 0.03
    adv = jnp.asarray(rng.normal(size=(B,)), jnp.float32)
    mask = jnp.ones((B, T), bool)
    scfg = SparseRLConfig()
    out_sync = sparse_rl_loss(lt, lo, ls, adv, mask, scfg)
    out_lag0 = sparse_rl_loss(lt, lo, ls, adv, mask, scfg, logp_behave=lo)
    np.testing.assert_array_equal(np.asarray(out_sync.loss),
                                  np.asarray(out_lag0.loss))
    np.testing.assert_array_equal(np.asarray(out_lag0.metrics["mean_rho"]),
                                  1.0)
    np.testing.assert_array_equal(
        np.asarray(out_lag0.metrics["staleness_kl"]), 0.0)
    # gradients identical too (rho is stop-gradded and exactly 1)
    g0 = jax.grad(lambda x: sparse_rl_loss(
        x, lo, ls, adv, mask, scfg).loss)(lt)
    g1 = jax.grad(lambda x: sparse_rl_loss(
        x, lo, ls, adv, mask, scfg, logp_behave=lo).loss)(lt)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))


def test_staleness_rho_scales_and_caps():
    """rho = pi_old/pi_behave composes multiplicatively outside the clip
    and is capped at staleness_clip; xi and the rejection mask pair
    logp_sparse with the BEHAVIOR policy, not the proximal one."""
    B, T = 1, 1
    lo = jnp.zeros((B, T))                       # proximal (learner)
    lb = jnp.full((B, T), -jnp.log(1.5))         # behavior: rho = 1.5
    ls = lb                                       # xi = pi_behave/pi_sparse = 1
    lt = jnp.zeros((B, T))                       # w = pi_theta/pi_old = 1
    adv = jnp.array([1.0])
    mask = jnp.ones((B, T), bool)
    scfg = SparseRLConfig(clip_eps=0.2, staleness_clip=2.0)
    out = sparse_rl_loss(lt, lo, ls, adv, mask, scfg, logp_behave=lb)
    np.testing.assert_allclose(out.loss, -1.5, rtol=1e-5)
    np.testing.assert_allclose(out.metrics["mean_rho"], 1.5, rtol=1e-5)
    assert float(out.metrics["mean_xi"]) == 1.0   # paired with behavior
    assert float(out.metrics["rejection_rate"]) == 0.0
    # far-stale token: rho capped at staleness_clip
    lb2 = jnp.full((B, T), -jnp.log(100.0))
    out2 = sparse_rl_loss(lt, lo, lb2, adv, mask, scfg, logp_behave=lb2)
    np.testing.assert_allclose(out2.loss, -scfg.staleness_clip, rtol=1e-5)


def test_staleness_rejection_uses_behavior_policy():
    """A token whose BEHAVIOR dense policy disagrees with the sparse
    sampler by more than eps is rejected even if the proximal policy
    agrees — the veto must compare the policies that actually sampled."""
    B, T = 1, 2
    ls = jnp.zeros((B, T))
    lo = jnp.zeros((B, T))                        # proximal agrees
    lb = jnp.asarray([[0.0, np.log(1e-5)]])       # behavior: xi_1 = 1e-5
    adv = jnp.array([1.0])
    mask = jnp.ones((B, T), bool)
    scfg = SparseRLConfig(rejection_eps=1e-4)
    out = sparse_rl_loss(ls, lo, ls, adv, mask, scfg, logp_behave=lb)
    assert float(out.metrics["rejection_rate"]) == 1.0
    out_prox = sparse_rl_loss(ls, lo, ls, adv, mask, scfg)
    assert float(out_prox.metrics["rejection_rate"]) == 0.0


# ---------------------------------------------------------------------------
# End-to-end: the quantized paged pool is a *corrected sampler policy* —
# the engine records logp_sparse under the int8/fp8 cache, the dense
# rescore supplies pi_old, and the Eq. 5-7 machinery absorbs the mismatch
# (DESIGN.md §Quantized paged pool).
# ---------------------------------------------------------------------------
def _quant_phase(kv_quant, *, group=2, n_prompts=2, max_new=8, seed=3):
    """One paged rollout phase + dense rescore under ``kv_quant``.

    ``kv_quant=None`` omits the kwarg entirely (the historical call shape)
    so the "none" mode can be pinned bitwise against it."""
    from repro.configs import get_config
    from repro.data import TOKENIZER, encode_prompts, make_problems
    from repro.models import get_model
    from repro.rollout import (
        ContinuousEngine,
        Request,
        build_train_rollout,
        rescore,
    )
    P = 16
    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SparseRLConfig(group_size=group, compression="none")
    problems = make_problems(n_prompts, seed, "easy")
    ids, pmask, _ = encode_prompts(problems, P)
    np_tokens = np.repeat(np.asarray(ids, np.int32), group, axis=0)
    np_mask = np.repeat(np.asarray(pmask, bool), group, axis=0)
    kw = dict(batch_size=2, prompt_len=P, max_new_tokens=max_new,
              eos_id=TOKENIZER.eos_id, decode_chunk=2, seed=seed,
              cache_backend="paged", block_size=12)
    if kv_quant is not None:
        kw["kv_quant"] = kv_quant
    eng = ContinuousEngine(params, cfg, m, scfg, **kw)
    reqs = [Request(uid=u, prompt=np_tokens[u][np_mask[u]])
            for u in range(np_tokens.shape[0])]
    comps = eng.run(reqs, group_size=group)
    tr = build_train_rollout(comps, np_tokens, np_mask,
                             max_new_tokens=max_new, pad_id=eng.pad_id,
                             stats=eng.end_phase())
    logp_old = rescore(params, cfg, m, tr.rollout)
    return tr, logp_old, params, scfg


def test_quant_pool_mismatch_absorbed_by_correction():
    """int8 KV rollouts: the recorded logp_sparse genuinely differs from
    the dense rescore on sampled tokens, the mismatch KL is finite and
    pad-tail masked, and xi / the rejection veto activate on exactly that
    gap — quantization rides the existing correction, no new loss code."""
    from repro.rollout import mismatch_kl_estimate
    tr, logp_old, _, scfg = _quant_phase("int8")
    ro = tr.rollout
    mask = np.asarray(ro.resp_mask)
    gap = np.abs(np.asarray(ro.logp_sparse) - np.asarray(logp_old)) * mask
    assert mask.any()
    # the quantized cache is a different policy: the sampler's recorded
    # log-probs disagree with the dense teacher-forced rescore
    assert float(gap.max()) > 1e-6
    # mismatch KL (paper Fig. 3): finite, and the padded tail of
    # early-exited rows is masked out — a full-width mask with ``lengths``
    # must agree bitwise with the engine's own resp_mask
    kl = mismatch_kl_estimate(logp_old, ro.logp_sparse, ro.resp_mask,
                              lengths=ro.lengths)
    ones = jnp.ones_like(ro.resp_mask, bool)
    kl_len = mismatch_kl_estimate(logp_old, ro.logp_sparse, ones,
                                  lengths=ro.lengths)
    assert np.isfinite(float(kl))
    np.testing.assert_array_equal(np.asarray(kl), np.asarray(kl_len))
    # Eq. 5: xi = pi_old/pi_sparse deviates from 1 on the sampled tokens
    out = sparse_rl_loss(logp_old, logp_old, ro.logp_sparse,
                         jnp.ones((mask.shape[0],)), ro.resp_mask, scfg)
    assert np.isfinite(float(out.loss))
    assert abs(float(out.metrics["mean_xi"]) - 1.0) > 1e-6
    assert np.isfinite(float(out.metrics["mismatch_kl"]))
    assert 0.0 <= float(out.metrics["rejection_rate"]) <= 1.0
    # Eq. 6: the veto fires on the quantization gap once eps tightens to
    # sit inside it (any token with pi_old < eps * pi_sparse rejects) —
    # the machinery is live, its default eps just tolerates benign noise
    m_tight = rejection_mask(logp_old, ro.logp_sparse, ro.resp_mask,
                             eps=1.0 - 1e-9)
    assert float(np.asarray(m_tight).min()) == 0.0


def test_quant_none_is_bitwise_identical_to_paged_path():
    """kv_quant="none" must be a no-op: tokens, recorded logp_sparse, the
    dense rescore and the resulting Eq. 7 loss are bit-identical to the
    historical paged engine call that never mentions kv_quant."""
    tr_a, lo_a, _, scfg = _quant_phase(None)
    tr_b, lo_b, _, _ = _quant_phase("none")
    np.testing.assert_array_equal(np.asarray(tr_a.rollout.resp_tokens),
                                  np.asarray(tr_b.rollout.resp_tokens))
    np.testing.assert_array_equal(np.asarray(tr_a.rollout.logp_sparse),
                                  np.asarray(tr_b.rollout.logp_sparse))
    np.testing.assert_array_equal(np.asarray(tr_a.rollout.resp_mask),
                                  np.asarray(tr_b.rollout.resp_mask))
    np.testing.assert_array_equal(np.asarray(lo_a), np.asarray(lo_b))
    adv = jnp.ones((tr_a.keep.shape[0],))
    out_a = sparse_rl_loss(lo_a, lo_a, tr_a.rollout.logp_sparse, adv,
                           tr_a.rollout.resp_mask, scfg)
    out_b = sparse_rl_loss(lo_b, lo_b, tr_b.rollout.logp_sparse, adv,
                           tr_b.rollout.resp_mask, scfg)
    np.testing.assert_array_equal(np.asarray(out_a.loss),
                                  np.asarray(out_b.loss))
