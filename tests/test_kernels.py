"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes.

Kernels execute in Pallas interpret mode on CPU (same semantics as the
Mosaic TPU lowering, bit-for-bit kernel body).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.budget_attention import budget_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_decode import flash_decode
from repro.kernels.paged_decode import paged_flash_decode
from repro.kernels.rkv_scores import rkv_scores

TOL = dict(rtol=2e-2, atol=2e-2)   # bf16 paths
TOL32 = dict(rtol=2e-5, atol=2e-5)


def _mk(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


@pytest.mark.parametrize("B,Hq,Hkv,S,Dh", [
    (1, 4, 4, 16, 16),     # MHA
    (2, 8, 2, 64, 32),     # GQA 4:1
    (1, 16, 1, 40, 8),     # MQA, ragged S
    (3, 6, 3, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_budget_attention_sweep(B, Hq, Hkv, S, Dh, dtype):
    rng = np.random.default_rng(B * 7 + S)
    q = _mk(rng, (B, Hq, Dh), dtype)
    k = _mk(rng, (B, Hkv, S, Dh), dtype)
    v = _mk(rng, (B, Hkv, S, Dh), dtype)
    pos = jnp.asarray(rng.integers(-1, 50, (B, Hkv, S)), jnp.int32)
    # ensure at least one valid slot per row
    pos = pos.at[:, :, 0].set(0)
    o, p = budget_attention(q, k, v, pos, interpret=True)
    o_ref, p_ref = ref.budget_attention_ref(q, k, v, pos)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(o, jnp.float32),
                               np.asarray(o_ref, jnp.float32), **tol)
    np.testing.assert_allclose(p, p_ref, **tol)


@pytest.mark.parametrize("S,block_s", [(16, 8), (64, 16), (100, 32), (128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(S, block_s, dtype):
    B, Hq, Hkv, Dh = 2, 4, 2, 16
    rng = np.random.default_rng(S)
    q = _mk(rng, (B, Hq, Dh), dtype)
    k = _mk(rng, (B, Hkv, S, Dh), dtype)
    v = _mk(rng, (B, Hkv, S, Dh), dtype)
    pos = jnp.asarray(rng.integers(-1, 99, (B, Hkv, S)), jnp.int32)
    pos = pos.at[:, :, 0].set(0)
    o = flash_decode(q, k, v, pos, block_s=block_s, interpret=True)
    o_ref = ref.flash_decode_ref(q, k, v, pos)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(o, jnp.float32),
                               np.asarray(o_ref, jnp.float32), **tol)


@pytest.mark.parametrize("bs,nb", [(8, 2), (16, 4), (8, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_sweep(bs, nb, dtype):
    """Block-table gather kernel vs the jnp oracle: shared pages, partially
    filled rows, and unmapped (-1) table tails."""
    B, Hq, Hkv, Dh = 3, 4, 2, 16
    N = B * nb + 2
    rng = np.random.default_rng(bs * nb)
    q = _mk(rng, (B, Hq, Dh), dtype)
    k_pool = _mk(rng, (N, Hkv, bs, Dh), dtype)
    v_pool = _mk(rng, (N, Hkv, bs, Dh), dtype)
    pos_pool = jnp.asarray(rng.integers(-1, 99, (N, bs)), jnp.int32)
    pos_pool = pos_pool.at[:, 0].set(0)
    bt = np.asarray(rng.permutation(np.arange(1, N))[:B * nb],
                    np.int32).reshape(B, nb)
    bt[0, 0] = bt[1, 0]                    # rows 0/1 share a prompt page
    bt[2, nb - 1] = -1                     # short row: unmapped tail
    fill = jnp.asarray([nb * bs, nb * bs - bs // 2, (nb - 1) * bs], jnp.int32)
    o = paged_flash_decode(q, k_pool, v_pool, pos_pool, jnp.asarray(bt),
                           fill, interpret=True)
    o_ref = ref.paged_decode_ref(q, k_pool, v_pool, pos_pool,
                                 jnp.asarray(bt), fill)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(o, jnp.float32),
                               np.asarray(o_ref, jnp.float32), **tol)


@pytest.mark.parametrize("bs,nb", [(8, 4), (16, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_ragged_fills(bs, nb, dtype):
    """Fill-aware early exit vs the oracle on ragged fills: a fully-mapped
    table (generation head-room, the serving state) with per-row fills of
    one page, a partial single page, a mid-chain partial page, the full
    chain, and an empty unmapped row.  The clamped index maps + pl.when
    skip must be invisible in the output."""
    B, Hq, Hkv, Dh = 5, 4, 2, 16
    N = B * nb + 1
    rng = np.random.default_rng(bs + nb)
    q = _mk(rng, (B, Hq, Dh), dtype)
    k_pool = _mk(rng, (N, Hkv, bs, Dh), dtype)
    v_pool = _mk(rng, (N, Hkv, bs, Dh), dtype)
    pos_pool = jnp.asarray(rng.integers(0, 99, (N, bs)), jnp.int32)
    bt = np.arange(1, B * nb + 1, dtype=np.int32).reshape(B, nb)
    bt[4, :] = -1                          # empty row: nothing mapped
    fill = jnp.asarray([bs,                # exactly one live page
                        bs // 2,           # partial single page
                        (nb - 1) * bs + 1,  # partial page mid/end of chain
                        nb * bs,           # every page live
                        0], jnp.int32)     # nothing written
    o = paged_flash_decode(q, k_pool, v_pool, pos_pool, jnp.asarray(bt),
                           fill, interpret=True)
    o_ref = ref.paged_decode_ref(q, k_pool, v_pool, pos_pool,
                                 jnp.asarray(bt), fill)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(o, jnp.float32),
                               np.asarray(o_ref, jnp.float32), **tol)
    # the empty row attends nothing: exact zeros on both paths
    assert not np.asarray(o_ref[4], np.float32).any()
    np.testing.assert_array_equal(np.asarray(o[4], jnp.float32), 0.0)


def test_paged_decode_matches_paged_attend():
    """Kernel contract == production jnp paged decode path: attending a
    materialized PagedKVCache equals streaming its pages in the kernel."""
    from repro.kvcache.paged import PagedKVCache, init_paged, paged_append

    B, Hkv, Dh, bs, nb = 2, 2, 16, 8, 3
    rng = np.random.default_rng(5)
    c = init_paged(B, Hkv, num_blocks=B * nb + 1, block_size=bs, head_dim=Dh,
                   blocks_per_row=nb, seq_len=nb * bs, dtype=jnp.float32)
    tables = jnp.arange(1, B * nb + 1, dtype=jnp.int32).reshape(B, nb)
    c = PagedKVCache(c.k_pool, c.v_pool, c.pos_pool, tables, c.fill,
                     seq_len=nb * bs)
    for t in range(13):
        kx = jnp.asarray(rng.normal(size=(B, Hkv, Dh)), jnp.float32)
        c = paged_append(c, kx, kx * 0.5, jnp.full((B,), t, jnp.int32))
    q = jnp.asarray(rng.normal(size=(B, 4, Dh)), jnp.float32)
    from repro.kvcache.paged import paged_attend
    o_prod = paged_attend(q, c)
    o_kern = paged_flash_decode(q, c.k_pool, c.v_pool, c.pos_pool,
                                c.block_tables, c.fill, interpret=True)
    np.testing.assert_allclose(o_prod, o_kern, **TOL32)


def test_ops_paged_decode_fallback():
    """use_kernels(False) routes paged decode to its oracle; paths agree."""
    B, Hq, Hkv, Dh, bs, nb, N = 2, 4, 2, 16, 8, 2, 6
    rng = np.random.default_rng(9)
    q = _mk(rng, (B, Hq, Dh), jnp.float32)
    kp = _mk(rng, (N, Hkv, bs, Dh), jnp.float32)
    vp = _mk(rng, (N, Hkv, bs, Dh), jnp.float32)
    posp = jnp.asarray(rng.integers(0, 20, (N, bs)), jnp.int32)
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    fill = jnp.asarray([12, 9], jnp.int32)
    try:
        ops.use_kernels(False)
        o_ref = ops.paged_flash_decode(q, kp, vp, posp, bt, fill)
    finally:
        ops.use_kernels(True)
    o_k = ops.paged_flash_decode(q, kp, vp, posp, bt, fill)
    np.testing.assert_allclose(o_k, o_ref, **TOL32)


def _mk_quant_pool(rng, N, Hkv, bs, Dh, quant):
    """A random quantized pool + per-(page, head) scales: int8 draws raw
    codes, fp8 casts normals (both exactly representable states a real
    write would produce)."""
    if quant == "int8":
        pool = jnp.asarray(rng.integers(-127, 128, (N, Hkv, bs, Dh)),
                           jnp.int8)
    else:
        pool = jnp.asarray(rng.normal(0, 8.0, (N, Hkv, bs, Dh)),
                           jnp.float8_e4m3fn)
    scale = jnp.asarray(rng.uniform(1e-3, 0.1, (N, Hkv)), jnp.float32)
    return pool, scale


@pytest.mark.parametrize("quant", ["int8", "fp8"])
@pytest.mark.parametrize("bs,nb", [(8, 2), (16, 4)])
def test_paged_decode_quant_sweep(quant, bs, nb):
    """Dequantizing kernel path vs `paged_decode_quant_ref`: the scales ride
    the same clamped block-table prefetch as the pages, so shared pages,
    partial fills and unmapped (-1) tails must all dequantize identically.
    Both paths do the same f32 math after dequant -> f32-tight tolerance."""
    B, Hq, Hkv, Dh = 3, 4, 2, 16
    N = B * nb + 2
    rng = np.random.default_rng(bs * nb + 17)
    q = _mk(rng, (B, Hq, Dh), jnp.float32)
    k_pool, k_scale = _mk_quant_pool(rng, N, Hkv, bs, Dh, quant)
    v_pool, v_scale = _mk_quant_pool(rng, N, Hkv, bs, Dh, quant)
    pos_pool = jnp.asarray(rng.integers(-1, 99, (N, bs)), jnp.int32)
    pos_pool = pos_pool.at[:, 0].set(0)
    bt = np.asarray(rng.permutation(np.arange(1, N))[:B * nb],
                    np.int32).reshape(B, nb)
    bt[0, 0] = bt[1, 0]                    # rows 0/1 share a prompt page
    bt[2, nb - 1] = -1                     # short row: unmapped tail
    fill = jnp.asarray([nb * bs, nb * bs - bs // 2, (nb - 1) * bs],
                       jnp.int32)
    o = paged_flash_decode(q, k_pool, v_pool, pos_pool, jnp.asarray(bt),
                           fill, k_scale, v_scale, interpret=True)
    o_ref = ref.paged_decode_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                                       pos_pool, jnp.asarray(bt), fill)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), **TOL32)


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_paged_decode_quant_ragged_fills(quant):
    """Quant path under the fill-aware early exit: one live page, partial
    pages, full chains, and an empty unmapped row (exact zeros) — the
    clamped scale index map must skip exactly the pages the K/V maps skip."""
    B, Hq, Hkv, Dh, bs, nb = 5, 4, 2, 16, 8, 4
    N = B * nb + 1
    rng = np.random.default_rng(bs + nb + 29)
    q = _mk(rng, (B, Hq, Dh), jnp.float32)
    k_pool, k_scale = _mk_quant_pool(rng, N, Hkv, bs, Dh, quant)
    v_pool, v_scale = _mk_quant_pool(rng, N, Hkv, bs, Dh, quant)
    pos_pool = jnp.asarray(rng.integers(0, 99, (N, bs)), jnp.int32)
    bt = np.arange(1, B * nb + 1, dtype=np.int32).reshape(B, nb)
    bt[4, :] = -1                          # empty row: nothing mapped
    fill = jnp.asarray([bs, bs // 2, (nb - 1) * bs + 1, nb * bs, 0],
                       jnp.int32)
    o = paged_flash_decode(q, k_pool, v_pool, pos_pool, jnp.asarray(bt),
                           fill, k_scale, v_scale, interpret=True)
    o_ref = ref.paged_decode_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                                       pos_pool, jnp.asarray(bt), fill)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), **TOL32)
    assert not np.asarray(o_ref[4]).any()
    np.testing.assert_array_equal(np.asarray(o[4]), 0.0)


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_paged_decode_quant_matches_paged_attend(quant):
    """Kernel contract == production jnp quantized decode: a pool built the
    way the engine builds it — `write_prompt` (partial tail page), COW tail
    duplication via `copy_block`, then `paged_append` steps — must stream
    through the dequantizing kernel exactly as `paged_attend` dequantizes
    it via materialize."""
    from repro.kvcache.paged import (
        copy_block,
        init_paged,
        paged_append,
        paged_attend,
        write_prompt,
    )
    import dataclasses

    B, Hkv, Dh, bs, nb, W = 2, 2, 16, 8, 3, 13    # 13 = full page + tail 5
    rng = np.random.default_rng(7)
    c = init_paged(B, Hkv, num_blocks=2 * nb + 2, block_size=bs,
                   head_dim=Dh, blocks_per_row=nb, seq_len=nb * bs,
                   quant=quant)
    kp = jnp.asarray(rng.normal(size=(Hkv, W, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(Hkv, W, Dh)), jnp.float32)
    c = write_prompt(c, kp, vp, jnp.arange(W), blocks=jnp.asarray([1, 2]),
                     tail_dst=jnp.asarray(3), duplicate_tail=True)
    # row 0 owns the original tail, row 1 a COW copy of it (copy_block is
    # the group-member admission path: codes AND scales must travel)
    c = copy_block(c, jnp.asarray(3), jnp.asarray(4))
    tables = jnp.asarray([[1, 2, 5], [1, 4, 6]], jnp.int32)
    c = dataclasses.replace(c, block_tables=tables,
                            fill=jnp.full((B,), W, jnp.int32))
    for t in range(W, W + 7):                      # crosses into page 3
        kx = jnp.asarray(rng.normal(size=(B, Hkv, Dh)), jnp.float32)
        c = paged_append(c, kx, kx * 0.5, jnp.full((B,), t, jnp.int32))
    q = jnp.asarray(rng.normal(size=(B, 4, Dh)), jnp.float32)
    o_prod = paged_attend(q, c)
    o_kern = paged_flash_decode(q, c.k_pool, c.v_pool, c.pos_pool,
                                c.block_tables, c.fill, c.k_scale,
                                c.v_scale, interpret=True)
    np.testing.assert_allclose(np.asarray(o_prod), np.asarray(o_kern),
                               **TOL32)


def test_paged_decode_quant_none_is_bitwise_unchanged():
    """Passing no scales must leave the fp kernel path untouched — same
    operands, same specs, bitwise-identical output to the historical call."""
    B, Hq, Hkv, Dh, bs, nb, N = 2, 4, 2, 16, 8, 2, 6
    rng = np.random.default_rng(11)
    q = _mk(rng, (B, Hq, Dh), jnp.float32)
    kp = _mk(rng, (N, Hkv, bs, Dh), jnp.float32)
    vp = _mk(rng, (N, Hkv, bs, Dh), jnp.float32)
    posp = jnp.asarray(rng.integers(0, 20, (N, bs)), jnp.int32)
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    fill = jnp.asarray([12, 9], jnp.int32)
    o_old = paged_flash_decode(q, kp, vp, posp, bt, fill, interpret=True)
    o_new = paged_flash_decode(q, kp, vp, posp, bt, fill, None, None,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(o_old), np.asarray(o_new))


def test_ops_paged_decode_quant_fallback():
    """use_kernels(False) routes the quantized call to its dequant oracle;
    kernel and oracle paths agree."""
    B, Hq, Hkv, Dh, bs, nb, N = 2, 4, 2, 16, 8, 2, 6
    rng = np.random.default_rng(13)
    q = _mk(rng, (B, Hq, Dh), jnp.float32)
    kp, ks = _mk_quant_pool(rng, N, Hkv, bs, Dh, "int8")
    vp, vs = _mk_quant_pool(rng, N, Hkv, bs, Dh, "int8")
    posp = jnp.asarray(rng.integers(0, 20, (N, bs)), jnp.int32)
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    fill = jnp.asarray([12, 9], jnp.int32)
    try:
        ops.use_kernels(False)
        o_ref = ops.paged_flash_decode(q, kp, vp, posp, bt, fill, ks, vs)
    finally:
        ops.use_kernels(True)
    o_k = ops.paged_flash_decode(q, kp, vp, posp, bt, fill, ks, vs)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref), **TOL32)


@pytest.mark.parametrize("Sq,Sk,bq,bk,causal", [
    (16, 16, 8, 8, True),
    (24, 24, 8, 16, True),      # ragged vs blocks
    (32, 32, 16, 16, False),    # non-causal (whisper encoder)
    (17, 33, 8, 8, True),       # prime-ish padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(Sq, Sk, bq, bk, causal, dtype):
    B, Hq, Hkv, Dh = 2, 4, 2, 16
    rng = np.random.default_rng(Sq * Sk)
    q = _mk(rng, (B, Sq, Hq, Dh), dtype)
    k = _mk(rng, (B, Sk, Hkv, Dh), dtype)
    v = _mk(rng, (B, Sk, Hkv, Dh), dtype)
    qp = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq)).astype(jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk)).astype(jnp.int32)
    o = flash_attention_fwd(q, k, v, qp, kp, block_q=bq, block_k=bk,
                            causal=causal, interpret=True)
    o_ref = ref.flash_attention_ref(q, k, v, qp, kp, causal=causal)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(o, jnp.float32),
                               np.asarray(o_ref, jnp.float32), **tol)


def test_flash_attention_left_padded_positions():
    """left-padded prompts: padding has position -1 and must get no mass."""
    B, S, Hq, Hkv, Dh = 1, 12, 2, 2, 8
    rng = np.random.default_rng(0)
    q = _mk(rng, (B, S, Hq, Dh), jnp.float32)
    k = _mk(rng, (B, S, Hkv, Dh), jnp.float32)
    v = _mk(rng, (B, S, Hkv, Dh), jnp.float32)
    pad = 4
    posr = np.full((B, S), -1, np.int32)
    posr[0, pad:] = np.arange(S - pad)
    pos = jnp.asarray(posr)
    o = flash_attention_fwd(q, k, v, pos, pos, block_q=4, block_k=4,
                            interpret=True)
    o_ref = ref.flash_attention_ref(q, k, v, pos, pos)
    np.testing.assert_allclose(o[0, pad:], o_ref[0, pad:], **TOL32)


@pytest.mark.parametrize("S", [8, 24, 64])
@pytest.mark.parametrize("lam", [0.0, 0.1, 1.0])
def test_rkv_scores_sweep(S, lam):
    B, Hkv, Dh = 2, 2, 16
    rng = np.random.default_rng(S)
    k = _mk(rng, (B, Hkv, S, Dh), jnp.float32)
    kn = _mk(rng, (B, Hkv, Dh), jnp.float32)
    imp = jnp.asarray(rng.uniform(0, 2, (B, Hkv, S)), jnp.float32)
    pos = jnp.asarray(rng.integers(-1, 40, (B, Hkv, S)), jnp.int32)
    cur = jnp.asarray(rng.integers(30, 45, (B,)), jnp.int32)
    s = rkv_scores(k, kn, imp, pos, cur, lam=lam, interpret=True)
    s_ref = ref.rkv_scores_ref(k, kn, imp, pos, cur, lam=lam)
    np.testing.assert_allclose(s, s_ref, **TOL32)


def test_ops_fallback_matches_kernel():
    """use_kernels(False) routes to oracles; both paths agree."""
    rng = np.random.default_rng(1)
    B, Hq, Hkv, S, Dh = 1, 4, 2, 32, 16
    q = _mk(rng, (B, Hq, Dh), jnp.float32)
    k = _mk(rng, (B, Hkv, S, Dh), jnp.float32)
    v = _mk(rng, (B, Hkv, S, Dh), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 30, (B, Hkv, S)), jnp.int32)
    try:
        ops.use_kernels(False)
        o_ref, p_ref = ops.budget_attention(q, k, v, pos)
    finally:
        ops.use_kernels(True)
    o_k, p_k = ops.budget_attention(q, k, v, pos)
    np.testing.assert_allclose(o_k, o_ref, **TOL32)
    np.testing.assert_allclose(p_k, p_ref, **TOL32)


def test_budget_attention_matches_cache_attend():
    """kernel contract == production jnp decode path (kvcache.attend)."""
    from repro.kvcache import attend, init_cache, append
    from repro.configs import SparseRLConfig
    scfg = SparseRLConfig(kv_budget=12, kv_buffer=4, obs_window=2, num_sinks=1)
    B, H, D = 2, 2, 16
    rng = np.random.default_rng(3)
    cache = init_cache(B, H, scfg.cache_slots, D, jnp.float32)
    for t in range(10):
        kx = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
        cache = append(cache, kx, kx * 0.5, jnp.full((B,), t, jnp.int32), scfg)
    q = jnp.asarray(rng.normal(size=(B, 4, D)), jnp.float32)
    o_prod, p_prod = attend(q, cache)
    o_kern, p_kern = budget_attention(q, cache.k, cache.v, cache.pos,
                                      interpret=True)
    np.testing.assert_allclose(o_prod, o_kern, **TOL32)
    np.testing.assert_allclose(p_prod, p_kern, **TOL32)


@pytest.mark.parametrize("Dh", [4, 8, 32, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_budget_attention_head_dim_sweep(Dh, dtype):
    """head_dim axis of the sweep: tiny (4) through flash-width (128) lanes
    must all match the oracle — the VMEM tile is (S, Dh) so odd widths
    exercise the non-128 padding path."""
    B, Hq, Hkv, S = 2, 4, 2, 24
    rng = np.random.default_rng(Dh)
    q = _mk(rng, (B, Hq, Dh), dtype)
    k = _mk(rng, (B, Hkv, S, Dh), dtype)
    v = _mk(rng, (B, Hkv, S, Dh), dtype)
    pos = jnp.asarray(rng.integers(-1, 50, (B, Hkv, S)), jnp.int32)
    pos = pos.at[:, :, 0].set(0)
    o, p = budget_attention(q, k, v, pos, interpret=True)
    o_ref, p_ref = ref.budget_attention_ref(q, k, v, pos)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(o, jnp.float32),
                               np.asarray(o_ref, jnp.float32), **tol)
    np.testing.assert_allclose(p, p_ref, **tol)


@pytest.mark.parametrize("budgets", [(1, 24), (3, 7), (24, 24), (1, 1)])
def test_budget_attention_ragged_per_head_budgets(budgets):
    """Per-kv-head budget raggedness (the per_head policy's live regime):
    head 0 keeps ``budgets[0]`` valid slots, head 1 keeps ``budgets[1]`` —
    down to a single survivor.  Invalid (pos < 0) slots must contribute
    exactly zero attention mass and zero pooled probability."""
    B, Hq, Hkv, S, Dh = 2, 4, 2, 24, 16
    rng = np.random.default_rng(sum(budgets))
    q = _mk(rng, (B, Hq, Dh), jnp.float32)
    k = _mk(rng, (B, Hkv, S, Dh), jnp.float32)
    v = _mk(rng, (B, Hkv, S, Dh), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 50, (B, Hkv, S)), jnp.int32)
    for h, budget in enumerate(budgets):
        pos = pos.at[:, h, budget:].set(-1)      # slots past the budget die
    o, p = budget_attention(q, k, v, pos, interpret=True)
    o_ref, p_ref = ref.budget_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(o, o_ref, **TOL32)
    np.testing.assert_allclose(p, p_ref, **TOL32)
    p = np.asarray(p)
    for h, budget in enumerate(budgets):
        assert np.all(p[:, h, budget:] == 0.0)          # no mass on dead slots
        np.testing.assert_allclose(p[:, h, :budget].sum(-1),
                                   np.full(B, float(Hq // Hkv)), **TOL32)


def test_budget_attention_after_enforce_budget():
    """End-to-end with the budget-enforcement pass (per_head policy): the
    kernel on an ``enforce_budget``-invalidated cache must equal the oracle
    on the same cache, and compressed heads must only draw mass from their
    surviving slots."""
    from repro.configs import SparseRLConfig
    from repro.kvcache import append, decode_budgets, enforce_budget, init_cache

    scfg = SparseRLConfig(kv_budget=4, kv_buffer=2, obs_window=2,
                          num_sinks=1, compression="per_head",
                          reasoning_head_frac=0.5)
    B, H, D = 2, 4, 16
    rng = np.random.default_rng(9)
    cache = init_cache(B, H, 24, D, jnp.float32)
    for t in range(20):
        kx = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
        cache = append(cache, kx, kx * 0.5, jnp.full((B,), t, jnp.int32),
                       scfg)
    cache = enforce_budget(cache, scfg, jnp.full((B,), 20, jnp.int32))
    budgets = np.asarray(decode_budgets(scfg, H, 24,
                                        jnp.full((B,), 20, jnp.int32)))
    valid = (np.asarray(cache.pos) >= 0).sum(-1)         # (B, H) live slots
    np.testing.assert_array_equal(valid, np.minimum(budgets, valid.max()))
    q = jnp.asarray(rng.normal(size=(B, 4, D)), jnp.float32)
    o, p = budget_attention(q, cache.k, cache.v, cache.pos, interpret=True)
    o_ref, p_ref = ref.budget_attention_ref(q, cache.k, cache.v, cache.pos)
    np.testing.assert_allclose(o, o_ref, **TOL32)
    np.testing.assert_allclose(p, p_ref, **TOL32)
    assert np.all(np.asarray(p)[np.asarray(cache.pos) < 0] == 0.0)
