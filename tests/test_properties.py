"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import SparseRLConfig
from repro.core import (
    group_advantages,
    masked_mean,
    rejection_mask,
    sparse_rl_loss,
    sparsity_consistency_ratio,
)
from repro.data.tokenizer import TOKENIZER


@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(st.sampled_from([0.0, 1.0]), min_size=2, max_size=8),
                min_size=1, max_size=6).filter(
                    lambda g: len({len(r) for r in g}) == 1))
def test_group_advantages_properties(groups):
    r = jnp.asarray(groups, jnp.float32)
    adv = group_advantages(r)
    # zero mean per group; zero iff group constant; sign matches centering
    np.testing.assert_allclose(np.asarray(adv.mean(-1)), 0.0, atol=1e-5)
    for i, row in enumerate(groups):
        if len(set(row)) == 1:
            np.testing.assert_allclose(np.asarray(adv[i]), 0.0, atol=1e-5)
        else:
            m = sum(row) / len(row)
            signs = np.sign(np.asarray(row) - m)
            np.testing.assert_array_equal(np.sign(np.asarray(adv[i])), signs)


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_rejection_monotone_in_eps(data):
    """larger eps never accepts a sequence a smaller eps rejected."""
    B, T = 3, 5
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    lo = jnp.asarray(rng.normal(-2, 1, (B, T)), jnp.float32)
    ls = jnp.asarray(rng.normal(-2, 1, (B, T)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(B, T)) > 0.2)
    e1 = data.draw(st.floats(1e-6, 1e-1))
    e2 = data.draw(st.floats(1e-6, 1e-1))
    lo_, hi_ = min(e1, e2), max(e1, e2)
    m_small = rejection_mask(lo, ls, mask, lo_)
    m_big = rejection_mask(lo, ls, mask, hi_)
    # smaller eps is more permissive: m_small >= m_big
    assert bool(jnp.all(m_small >= m_big))


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_loss_finite_under_extremes(data):
    """the objective never produces NaN/inf for any bounded log-prob inputs
    (stability claim: reweighting is capped, ratios in log space)."""
    B, T = 2, 6
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    scale = data.draw(st.floats(0.1, 30.0))
    lt = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    lo = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    ls = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    adv = jnp.asarray(rng.normal(0, 2, (B,)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(B, T)) > 0.3)
    scfg = SparseRLConfig()
    out = sparse_rl_loss(lt, lo, ls, adv, mask, scfg)
    assert bool(jnp.isfinite(out.loss))
    g = jax.grad(lambda x: sparse_rl_loss(x, lo, ls, adv, mask, scfg).loss)(lt)
    assert bool(jnp.isfinite(g).all())


@settings(max_examples=50, deadline=None)
@given(st.floats(1.001, 100.0), st.integers(0, 10**6))
def test_xi_cap_bounds(cap, seed):
    rng = np.random.default_rng(seed)
    lo = jnp.asarray(rng.normal(0, 5, (4, 4)), jnp.float32)
    ls = jnp.asarray(rng.normal(0, 5, (4, 4)), jnp.float32)
    xi = sparsity_consistency_ratio(lo, ls, xi_clip_max=cap)
    assert float(xi.max()) <= cap * (1 + 1e-5)
    assert float(xi.min()) >= 0.0


@settings(max_examples=50, deadline=None)
@given(st.text(alphabet="0123456789+-*/=?().,: QA#", max_size=60))
def test_tokenizer_fuzz_roundtrip(s):
    ids = TOKENIZER.encode(s)
    assert TOKENIZER.decode(ids) == s
    assert all(0 <= i < TOKENIZER.vocab_size for i in ids)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_masked_mean_bounds(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    x = jnp.asarray(rng.normal(size=(3, 7)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(3, 7)) > 0.4)
    if not bool(mask.any()):
        return
    mm = masked_mean(x, mask)
    sel = np.asarray(x)[np.asarray(mask)]
    assert sel.min() - 1e-5 <= float(mm) <= sel.max() + 1e-5
