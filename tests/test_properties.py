"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import SparseRLConfig
from repro.core import (
    group_advantages,
    masked_mean,
    rejection_mask,
    sparse_rl_loss,
    sparsity_consistency_ratio,
)
from repro.data.tokenizer import TOKENIZER
from repro.kvcache import KVCache, append, attend, init_cache


@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(st.sampled_from([0.0, 1.0]), min_size=2, max_size=8),
                min_size=1, max_size=6).filter(
                    lambda g: len({len(r) for r in g}) == 1))
def test_group_advantages_properties(groups):
    r = jnp.asarray(groups, jnp.float32)
    adv = group_advantages(r)
    # zero mean per group; zero iff group constant; sign matches centering
    np.testing.assert_allclose(np.asarray(adv.mean(-1)), 0.0, atol=1e-5)
    for i, row in enumerate(groups):
        if len(set(row)) == 1:
            np.testing.assert_allclose(np.asarray(adv[i]), 0.0, atol=1e-5)
        else:
            m = sum(row) / len(row)
            signs = np.sign(np.asarray(row) - m)
            np.testing.assert_array_equal(np.sign(np.asarray(adv[i])), signs)


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_rejection_monotone_in_eps(data):
    """larger eps never accepts a sequence a smaller eps rejected."""
    B, T = 3, 5
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    lo = jnp.asarray(rng.normal(-2, 1, (B, T)), jnp.float32)
    ls = jnp.asarray(rng.normal(-2, 1, (B, T)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(B, T)) > 0.2)
    e1 = data.draw(st.floats(1e-6, 1e-1))
    e2 = data.draw(st.floats(1e-6, 1e-1))
    lo_, hi_ = min(e1, e2), max(e1, e2)
    m_small = rejection_mask(lo, ls, mask, lo_)
    m_big = rejection_mask(lo, ls, mask, hi_)
    # smaller eps is more permissive: m_small >= m_big
    assert bool(jnp.all(m_small >= m_big))


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_loss_finite_under_extremes(data):
    """the objective never produces NaN/inf for any bounded log-prob inputs
    (stability claim: reweighting is capped, ratios in log space)."""
    B, T = 2, 6
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    scale = data.draw(st.floats(0.1, 30.0))
    lt = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    lo = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    ls = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    adv = jnp.asarray(rng.normal(0, 2, (B,)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(B, T)) > 0.3)
    scfg = SparseRLConfig()
    out = sparse_rl_loss(lt, lo, ls, adv, mask, scfg)
    assert bool(jnp.isfinite(out.loss))
    g = jax.grad(lambda x: sparse_rl_loss(x, lo, ls, adv, mask, scfg).loss)(lt)
    assert bool(jnp.isfinite(g).all())


@settings(max_examples=50, deadline=None)
@given(st.floats(1.001, 100.0), st.integers(0, 10**6))
def test_xi_cap_bounds(cap, seed):
    rng = np.random.default_rng(seed)
    lo = jnp.asarray(rng.normal(0, 5, (4, 4)), jnp.float32)
    ls = jnp.asarray(rng.normal(0, 5, (4, 4)), jnp.float32)
    xi = sparsity_consistency_ratio(lo, ls, xi_clip_max=cap)
    assert float(xi.max()) <= cap * (1 + 1e-5)
    assert float(xi.min()) >= 0.0


@settings(max_examples=50, deadline=None)
@given(st.text(alphabet="0123456789+-*/=?().,: QA#", max_size=60))
def test_tokenizer_fuzz_roundtrip(s):
    ids = TOKENIZER.encode(s)
    assert TOKENIZER.decode(ids) == s
    assert all(0 <= i < TOKENIZER.vocab_size for i in ids)


@settings(max_examples=30, deadline=None)
@given(
    slots=st.integers(4, 16),
    steps=st.integers(1, 40),
    policy=st.sampled_from(["rkv", "h2o", "streaming", "snapkv"]),
)
def test_property_cache_bounded_and_valid(slots, steps, policy):
    """Memory bound + validity: the paper's core claim, fuzzed."""
    scfg = SparseRLConfig(kv_budget=slots, kv_buffer=0, obs_window=2,
                          num_sinks=1, compression=policy)
    B, H, D = 1, 2, 4
    cache = init_cache(B, H, slots, D, jnp.float32)
    rng = np.random.default_rng(slots * 101 + steps)
    for t in range(steps):
        k = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
        cache = append(cache, k, k, jnp.full((B,), t, jnp.int32), scfg)
    pos = np.asarray(cache.pos)
    assert pos.shape[-1] == slots                      # static bound
    assert (np.asarray(cache.fill) == min(steps, slots)).all()
    for b in range(pos.shape[0]):
        for h in range(pos.shape[1]):                  # caches are per-head
            valid = pos[b, h][pos[b, h] >= 0]
            assert len(set(valid.tolist())) == len(valid)  # no dup tokens
            assert valid.max(initial=-1) <= steps - 1
            # newest token always present in every head's cache
            if steps > 0:
                assert (pos[b, h] == steps - 1).any()


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_property_attend_is_convex_combination(data):
    """attention output lies in the convex hull of values; pooled probs sum
    to group size over valid slots."""
    B, H, S, D = 1, 1, data.draw(st.integers(2, 12)), 4
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.uniform(-1, 1, (B, H, S, D)), jnp.float32)
    n_valid = data.draw(st.integers(1, S))
    pos = jnp.asarray([[np.concatenate([np.arange(n_valid),
                                        -np.ones(S - n_valid)])]], jnp.int32)
    cache = KVCache(k=k, v=v, pos=pos,
                    score=jnp.zeros((B, H, S)), fill=jnp.full((B,), S))
    q = jnp.asarray(rng.normal(size=(B, 2, D)), jnp.float32)
    out, probs = attend(q, cache)
    assert float(out.max()) <= float(v.max()) + 1e-5
    assert float(out.min()) >= float(v.min()) - 1e-5
    np.testing.assert_allclose(float(probs.sum()), 2.0, rtol=1e-5)
    # no attention mass on empty slots
    np.testing.assert_allclose(np.asarray(probs)[0, 0, n_valid:], 0.0, atol=1e-7)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_masked_mean_bounds(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    x = jnp.asarray(rng.normal(size=(3, 7)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(3, 7)) > 0.4)
    if not bool(mask.any()):
        return
    mm = masked_mean(x, mask)
    sel = np.asarray(x)[np.asarray(mask)]
    assert sel.min() - 1e-5 <= float(mm) <= sel.max() + 1e-5


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_property_lag0_reduces_to_sync_for_every_policy(data):
    """At staleness lag 0 (logp_behave == logp_old bitwise) the async loss
    must reduce to the sync objective EXACTLY — rho = exp(0) = 1.0 and
    multiplying by the float 1.0 is exact in IEEE arithmetic — for every
    registered sampler policy's resolved config (the loss is
    policy-agnostic; this pins that no policy's config knobs break it)."""
    from repro.rollout import policy_names, resolve_policy

    name = data.draw(st.sampled_from(sorted(policy_names())))
    scfg = resolve_policy(name).apply(SparseRLConfig())
    B, T = 3, 5
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    lt = jnp.asarray(rng.normal(-2, 3, (B, T)), jnp.float32)
    lo = jnp.asarray(rng.normal(-2, 3, (B, T)), jnp.float32)
    ls = jnp.asarray(rng.normal(-2, 3, (B, T)), jnp.float32)
    adv = jnp.asarray(rng.normal(0, 2, (B,)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(B, T)) > 0.3)
    sync = sparse_rl_loss(lt, lo, ls, adv, mask, scfg)
    lag0 = sparse_rl_loss(lt, lo, ls, adv, mask, scfg, logp_behave=lo)
    assert float(sync.loss) == float(lag0.loss)          # bitwise
    g_sync = jax.grad(lambda x: sparse_rl_loss(
        x, lo, ls, adv, mask, scfg).loss)(lt)
    g_lag0 = jax.grad(lambda x: sparse_rl_loss(
        x, lo, ls, adv, mask, scfg, logp_behave=lo).loss)(lt)
    np.testing.assert_array_equal(np.asarray(g_sync), np.asarray(g_lag0))
    assert float(lag0.metrics["mean_rho"]) == 1.0
    assert float(lag0.metrics["staleness_kl"]) == 0.0


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_property_rho_clip_never_breaks_gradients(data):
    """Staleness correction under arbitrary drift: rho is capped at
    staleness_clip, so neither the loss nor its gradient may ever go
    non-finite, no matter how far logp_behave drifts from logp_old."""
    B, T = 2, 6
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    scale = data.draw(st.floats(0.1, 50.0))
    clip = data.draw(st.floats(1.001, 10.0))
    lt = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    lo = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    ls = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    lb = jnp.asarray(rng.normal(-2, scale, (B, T)), jnp.float32)
    adv = jnp.asarray(rng.normal(0, 2, (B,)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(B, T)) > 0.3)
    scfg = SparseRLConfig(staleness_clip=clip)
    out = sparse_rl_loss(lt, lo, ls, adv, mask, scfg, logp_behave=lb)
    assert bool(jnp.isfinite(out.loss))
    assert float(out.metrics["mean_rho"]) <= clip * (1 + 1e-5)
    g = jax.grad(lambda x: sparse_rl_loss(
        x, lo, ls, adv, mask, scfg, logp_behave=lb).loss)(lt)
    assert bool(jnp.isfinite(g).all())


@settings(max_examples=30, deadline=None)
@given(
    slots=st.integers(4, 12),
    steps=st.integers(1, 40),
    policy=st.sampled_from(["per_head", "adaptive"]),
)
def test_property_enforce_budget_invariants(policy, slots, steps):
    """The scheduled/per-head budget pass, fuzzed: after ``enforce_budget``
    every kv-head's live slots respect its ``decode_budgets`` bound,
    survivors are a subset of the pre-enforcement entries, the protected
    slots (sinks + newest) survive, k/v payloads and fill are untouched,
    and the pass is idempotent."""
    from repro.kvcache import decode_budgets, enforce_budget

    S = 2 * slots            # dense-ish geometry, budget << slots
    scfg = SparseRLConfig(kv_budget=slots, kv_buffer=0, obs_window=2,
                          num_sinks=1, compression=policy,
                          reasoning_head_frac=0.5,
                          adaptive_min_frac=0.3, adaptive_decay_tokens=16)
    B, H, D = 1, 2, 4
    cache = init_cache(B, H, S, D, jnp.float32)
    rng = np.random.default_rng(slots * 101 + steps)
    for t in range(steps):
        k = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
        cache = append(cache, k, k, jnp.full((B,), t, jnp.int32), scfg)
    before = np.asarray(cache.pos)
    cur = jnp.full((B,), steps, jnp.int32)
    out = enforce_budget(cache, scfg, cur)
    pos = np.asarray(out.pos)
    budgets = np.asarray(decode_budgets(scfg, H, S, cur))
    for b in range(B):
        for h in range(H):
            live = pos[b, h][pos[b, h] >= 0]
            assert len(live) <= budgets[b, h]
            # survivors existed before; no entry was invented
            assert set(live.tolist()) <= set(
                before[b, h][before[b, h] >= 0].tolist())
            if len(live):
                assert (pos[b, h] == steps - 1).any()    # newest protected
                if steps > scfg.num_sinks:
                    assert (live < scfg.num_sinks).sum() == min(
                        scfg.num_sinks, len(live))       # sinks protected
    np.testing.assert_array_equal(np.asarray(out.k), np.asarray(cache.k))
    np.testing.assert_array_equal(np.asarray(out.v), np.asarray(cache.v))
    np.testing.assert_array_equal(np.asarray(out.fill),
                                  np.asarray(cache.fill))
    again = enforce_budget(out, scfg, cur)
    np.testing.assert_array_equal(np.asarray(again.pos), pos)  # idempotent
