"""The scenario matrix: per-cell conformance contracts.

Cells: {dense, rkv, per_head, adaptive} x {transformer, hybrid, ssm}
x {fixed, mixed} prompt-length dists (24) plus the quantized-pool policies
{quant-int8, quant-fp8} on the transformer (pool) family x both dists (4)
— 28 cells, each one parametrized test.  Contracts per cell class are
documented in conftest.py; every cell runs through ``end_phase`` so the
paged allocator leak check is armed everywhere it exists.

A separate per-policy smoke-trainer sweep asserts reward non-degradation
under each genuinely sparse policy (the paper's stability claim at matrix
scale), driven through ``TrainerOptions.sampler_policy`` — i.e. the
registry path the CLIs use, not the legacy field pair.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _harness import (
    ARCHS,
    MAIN_POLICIES,
    MAX_NEW,
    PLEN_DISTS,
    QUANT_POLICIES,
    TOTAL,
    base_scfg,
    identity_class,
    run_cell,
    tight_scfg,
)

MAIN_CELLS = [(p, a, d) for p in MAIN_POLICIES for a in ARCHS
              for d in PLEN_DISTS]
QUANT_CELLS = [(p, "transformer", d) for p in QUANT_POLICIES
               for d in PLEN_DISTS]


def _loss_inputs(cell):
    ro = cell["tr"].rollout
    logp_old = jnp.asarray(cell["logp_old"])
    logp_sparse = jnp.asarray(ro.logp_sparse)
    mask = jnp.asarray(ro.resp_mask).astype(bool)
    adv = jnp.asarray([1.0, -1.0] * (TOTAL // 2))
    return logp_old, logp_sparse, mask, adv


@pytest.mark.parametrize("policy,arch,plen_dist", MAIN_CELLS + QUANT_CELLS)
def test_matrix_cell(policy, arch, plen_dist, record_cell):
    from repro.core import rejection_mask, sparse_rl_loss

    cell = run_cell(arch, policy, plen_dist)
    pol, cfg = cell["policy"], cell["cfg"]
    logp_old, logp_sparse, mask, adv = _loss_inputs(cell)
    gap = float(jnp.max(jnp.where(mask, jnp.abs(logp_old - logp_sparse),
                                  0.0)))
    m_rs = np.asarray(rejection_mask(logp_old, logp_sparse, mask,
                                     eps=0.999))
    out = sparse_rl_loss(logp_old, logp_old, logp_sparse, adv, mask,
                         tight_scfg(cell["scfg"]))
    ident = identity_class(pol, cfg)
    record_cell(policy=policy, arch=arch, plen_dist=plen_dist,
                family=cfg.family, identity_class=ident,
                mismatch_kl=cell["mismatch_kl"], max_logp_gap=gap,
                tight_eps_rejections=int(TOTAL - m_rs.sum()),
                loss=float(out.loss),
                tokens=int(np.sum(np.asarray(cell["tr"].rollout.lengths))))

    # universal contracts: the phase completed, the pool drained
    # (end_phase inside run_cell raises on a leak), KL finite, loss finite
    assert len(cell["cont"]) == TOTAL
    assert np.isfinite(cell["mismatch_kl"])
    assert np.isfinite(float(out.loss))

    # scheduler contract (non-quant): continuous == same-scfg lockstep,
    # token for token, under any policy — row placement is invisible
    if cell["lock"] is not None:
        for c, l in zip(cell["cont"], cell["lock"]):
            assert c.uid == l.uid
            np.testing.assert_array_equal(c.tokens, l.tokens)
            np.testing.assert_allclose(c.logps, l.logps, atol=1e-6)

    if ident:
        # identity class: the sampler IS the dense policy (or the family
        # has no KV cache to compress) — xi == 1 up to numerics
        assert abs(cell["mismatch_kl"]) < 1e-4
        assert gap < 1e-4
        assert int(TOTAL - m_rs.sum()) == 0
    else:
        # sparse class: a real policy gap the correction must absorb —
        # and a tight eps must actually veto some sequence (if nothing
        # ever trips rejection the cell isn't exercising Eq. 6)
        assert gap > 1e-6
        assert m_rs.sum() < TOTAL
        assert float(out.metrics["rejection_rate"]) > 0.0


@pytest.mark.parametrize("policy", QUANT_POLICIES)
def test_quant_cells_capacity(policy):
    """The quantized pool must actually shrink bytes/token (int8 meets the
    paper-level 1.8x acceptance bar; fp8 carries the same 1-byte codes)."""
    cell = run_cell("transformer", policy, "fixed")
    ratio = float(cell["stats"]["kv_capacity_ratio"])
    assert ratio >= (1.8 if policy == "quant-int8" else 1.5)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_dense_oracle_shared_across_policies(arch):
    """All non-quant cells of one arch share the dense cell's lockstep
    oracle: identity-class cells match it bitwise, sparse cells must NOT
    (otherwise the policy is silently a no-op on that family)."""
    from repro.configs.base import SSM

    dense = run_cell(arch, "dense", "fixed")
    for policy in ("rkv", "per_head", "adaptive"):
        cell = run_cell(arch, policy, "fixed")
        same_tokens = all(np.array_equal(c.tokens, d.tokens)
                          for c, d in zip(cell["cont"], dense["cont"]))
        same_logps = all(np.allclose(np.asarray(c.logps),
                                     np.asarray(d.logps), atol=1e-6)
                         for c, d in zip(cell["cont"], dense["cont"]))
        if identity_class(cell["policy"], cell["cfg"]):
            assert same_tokens, (f"{policy} on {arch} must match the dense "
                                 f"oracle")
            assert cell["cfg"].family == SSM or cell["policy"].is_dense
        else:
            # a sparse policy must leave a measurable footprint: either the
            # token trajectory diverges, or (at smoke scale, where a short
            # rollout may ride out a small logit shift) at least the
            # recorded sampler log-probs do
            assert not (same_tokens and same_logps), \
                f"{policy} on {arch} is a silent no-op"


@pytest.mark.parametrize("policy", ("rkv", "per_head", "adaptive"))
def test_matrix_reward_nondegrading(policy, tmp_path, record_cell):
    """Smoke trainer per sparse policy on the continuous-paged backend via
    ``TrainerOptions.sampler_policy`` (the registry path): the corrected
    objective must keep reward non-degrading across the short run — the
    matrix-scale version of the paper's stability claim."""
    from repro.configs import TrainConfig, get_config
    from repro.runtime import Trainer, TrainerOptions

    cfg = get_config(ARCHS["transformer"]).smoke()
    scfg = dataclasses.replace(base_scfg(), group_size=4,
                               learning_rate=2e-3, kl_coef=0.0)
    tcfg = TrainConfig(update_batch=16, total_steps=10, warmup_steps=2,
                       checkpoint_every=0, checkpoint_dir=str(tmp_path))
    opts = TrainerOptions(num_prompts=4, prompt_len=12,
                          max_new_tokens=MAX_NEW, level="trivial",
                          rollout_backend="continuous",
                          cache_backend="paged", decode_chunk=2,
                          sampler_policy=policy)
    tr = Trainer(cfg, scfg, tcfg, opts)
    assert tr.scfg.compression == {"rkv": "rkv", "per_head": "per_head",
                                   "adaptive": "adaptive"}[policy]
    hist = tr.train(10, log_every=0)
    rewards = [m["reward"] for m in hist]
    half = len(rewards) // 2
    r_first = float(np.mean(rewards[:half]))
    r_second = float(np.mean(rewards[half:]))
    slack = max(0.02, 0.5 * r_first)   # scale-aware: collapse fails,
    nondeg = r_second >= r_first - slack   # noise-floor rewards don't
    record_cell(policy=policy, arch="transformer", plen_dist="train",
                reward_first_half=r_first, reward_second_half=r_second,
                reward_nondegrading=bool(nondeg))
    assert nondeg, (policy, r_first, r_second)
    assert all(np.isfinite(m["loss"]) for m in hist)
