"""Sampler-policy registry contracts: resolution, geometry round-trips,
legacy-flag bitwise identity, schedules, and the single engine-config
validator (every illegal combination raises)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SparseRLConfig, get_config
from repro.configs.base import DENSE, HYBRID, SSM
from repro.rollout import (
    POLICIES,
    SamplerPolicy,
    legacy_policy_name,
    policy_for_scfg,
    resolve_policy,
    validate_engine_config,
)
from repro.rollout.engine import paged_rollout_geometry, rollout_slots
from repro.rollout.policies import policy_names, register, resolve_cli_policy

from _harness import base_scfg

P, T = 12, 6


# -- resolution ---------------------------------------------------------
def test_registry_resolves_at_least_six_policies():
    names = policy_names()
    assert len(names) >= 6
    for expect in ("dense", "rkv", "snapkv", "h2o", "streaming", "per_head",
                   "adaptive", "quant-int8", "quant-fp8"):
        assert expect in names
        assert resolve_policy(expect) is POLICIES[expect]


def test_unknown_policy_and_duplicate_register_raise():
    with pytest.raises(KeyError, match="unknown sampler policy"):
        resolve_policy("nope")
    with pytest.raises(ValueError, match="duplicate"):
        register(SamplerPolicy("dense", compression="none"))


def test_identity_flags():
    assert resolve_policy("dense").is_dense
    for name in policy_names():
        if name != "dense":
            assert not resolve_policy(name).is_dense


# -- geometry round-trip (satellite: no magic slot constants) -----------
@pytest.mark.parametrize("name", sorted(policy_names()))
def test_geometry_roundtrips_through_rollout_slots(name):
    pol = resolve_policy(name)
    scfg = pol.apply(base_scfg())
    slots = rollout_slots(scfg, P, T)
    assert slots == pol.geometry(scfg, P, T, 0)
    if pol.kv_quant == "none":      # reverse map needs the kv_quant hint
        assert policy_for_scfg(scfg).geometry is pol.geometry
    seq, blocks = paged_rollout_geometry(scfg, P, T, block_size=4)
    assert seq == slots and blocks == -(-slots // 4)
    if name in ("dense", "per_head", "quant-int8", "quant-fp8"):
        # dense-sized: prompt + new + headroom, workload-dependent
        assert slots == P + T + 8
        assert rollout_slots(scfg, P, T, prefix_len=5) == slots + 5
    else:
        # budget-sized: workload-independent fixed budget
        assert slots == scfg.cache_slots
        assert rollout_slots(scfg, 2 * P, 2 * T) == slots


# -- budget schedules ---------------------------------------------------
def test_adaptive_schedule_monotone_and_floored():
    pol = resolve_policy("adaptive")
    scfg = base_scfg()      # decay_tokens=8, min_frac=0.3, floor=2+4
    budgets = [int(pol.budget_schedule(scfg, p)) for p in range(0, 24)]
    assert budgets[0] == scfg.cache_slots
    assert all(a >= b for a, b in zip(budgets, budgets[1:]))
    floor = scfg.num_sinks + scfg.obs_window
    assert budgets[-1] >= floor
    assert budgets[-1] < budgets[0]
    # past the decay horizon the schedule is flat at its terminal value
    assert budgets[scfg.adaptive_decay_tokens] == budgets[-1]


def test_flat_and_per_head_schedules():
    scfg = base_scfg()
    assert resolve_policy("rkv").budget_schedule(scfg, 0) == scfg.cache_slots
    assert (resolve_policy("rkv").budget_schedule(scfg, 10 ** 6)
            == scfg.cache_slots)
    # per_head reports the compressed-head (worst-case) budget
    ph = resolve_policy("per_head").budget_schedule(scfg, 0)
    assert ph == max(scfg.kv_budget, scfg.num_sinks + scfg.obs_window)


# -- legacy-flag deprecation shim --------------------------------------
def test_legacy_policy_name_mapping():
    assert legacy_policy_name("none") == "dense"
    assert legacy_policy_name("rkv") == "rkv"
    assert legacy_policy_name("per_head") == "per_head"
    assert legacy_policy_name("none", "int8") == "quant-int8"
    assert legacy_policy_name("none", "fp8") == "quant-fp8"
    with pytest.raises(ValueError, match="unknown compression"):
        legacy_policy_name("zip")
    with pytest.raises(ValueError, match="unknown kv_quant"):
        legacy_policy_name("none", "int4")
    with pytest.raises(ValueError, match="composes only"):
        legacy_policy_name("rkv", "int8")


def test_resolve_cli_policy_shim(capsys):
    # --sampler-policy wins; mixing with legacy flags is a config error
    assert resolve_cli_policy("per_head", None, None,
                              default_compression="rkv").name == "per_head"
    with pytest.raises(ValueError, match="cannot be combined"):
        resolve_cli_policy("dense", "rkv", None, default_compression="rkv")
    with pytest.raises(ValueError, match="cannot be combined"):
        resolve_cli_policy("dense", None, "int8", default_compression="rkv")
    # no flags at all -> the launcher's historical default, no warning
    assert resolve_cli_policy(None, None, None,
                              default_compression="rkv").name == "rkv"
    assert "deprecated" not in capsys.readouterr().err
    # legacy flags alias through the registry, with a deprecation note
    assert resolve_cli_policy(None, "none", None,
                              default_compression="rkv").name == "dense"
    assert resolve_cli_policy(None, None, "int8",
                              default_compression="none"
                              ).name == "quant-int8"
    assert "deprecated" in capsys.readouterr().err


def test_legacy_flags_bitwise_identical_rollouts():
    """The pin the deprecation shim advertises: a legacy
    ``compression=...`` config and the registry policy it aliases to must
    produce the SAME rollout, token for token and logp-bit for logp-bit."""
    from repro.data import TOKENIZER, encode_prompts, make_problems
    from repro.rollout import ContinuousEngine, Request

    cfg = get_config("qwen2.5-14b").smoke()
    from repro.models import get_model

    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    problems = make_problems(3, 5, "easy")
    ids, mask, _ = encode_prompts(problems, P)
    reqs = [Request(uid=i, prompt=ids[i][mask[i]], max_new_tokens=T)
            for i in range(3)]

    for compression, kv_quant in (("rkv", "none"), ("none", "int8")):
        legacy_scfg = dataclasses.replace(base_scfg(),
                                          compression=compression)
        pol = resolve_policy(legacy_policy_name(compression, kv_quant))
        pol_scfg = pol.apply(base_scfg())
        assert pol_scfg == legacy_scfg          # identical resolved fields
        assert pol.kv_quant == kv_quant
        outs = []
        for scfg, q in ((legacy_scfg, kv_quant), (pol_scfg, pol.kv_quant)):
            eng = ContinuousEngine(params, cfg, m, scfg, batch_size=3,
                                   prompt_len=P, max_new_tokens=T,
                                   eos_id=TOKENIZER.eos_id, decode_chunk=2,
                                   seed=9, cache_backend="paged",
                                   block_size=4, kv_quant=q)
            outs.append(eng.run(reqs))
            eng.end_phase()
        for a, b in zip(*outs):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(np.asarray(a.logps),
                                          np.asarray(b.logps))   # bitwise


# -- the single engine-config validator (satellite: dedup) --------------
def test_validate_accepts_every_registered_policy_somewhere():
    for name in policy_names():
        pol = resolve_policy(name)
        pol.validate(cache_backend="paged",
                     family=DENSE)              # must not raise


ILLEGAL = [
    # (scfg-compression, kv_quant, backend, family, match)
    ("zip", "none", "contiguous", DENSE, "unknown compression"),
    ("none", "int4", "paged", DENSE, "unknown kv_quant"),
    ("none", "none", "ring", DENSE, "unknown cache_backend"),
    ("rkv", "int8", "paged", DENSE, "requires the paged pool"),
    ("none", "int8", "contiguous", DENSE, "requires the paged pool"),
    ("none", "fp8", "paged", SSM, "requires the paged pool"),
    ("none", "int8", "paged", HYBRID, "requires the paged pool"),
]


@pytest.mark.parametrize("compression,kv_quant,backend,family,match",
                         ILLEGAL)
def test_validate_rejects_illegal_combination(compression, kv_quant,
                                              backend, family, match):
    scfg = dataclasses.replace(SparseRLConfig(), compression=compression)
    with pytest.raises(ValueError, match=match):
        validate_engine_config(scfg, kv_quant=kv_quant,
                               cache_backend=backend, family=family)


def test_engine_and_trainer_reject_through_the_same_validator():
    """ContinuousEngine.__init__ and Trainer.__init__ both route through
    validate_engine_config — the same message for the same illegal combo."""
    from repro.data import TOKENIZER
    from repro.models import get_model

    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SparseRLConfig(compression="rkv")
    from repro.rollout import ContinuousEngine

    with pytest.raises(ValueError, match="requires the paged pool"):
        ContinuousEngine(params, cfg, m, scfg, batch_size=2, prompt_len=P,
                         max_new_tokens=T, eos_id=TOKENIZER.eos_id,
                         cache_backend="paged", kv_quant="int8")

    from repro.configs import TrainConfig
    from repro.runtime import Trainer, TrainerOptions

    with pytest.raises(ValueError, match="requires the paged pool"):
        Trainer(cfg, scfg, TrainConfig(checkpoint_every=0),
                TrainerOptions(rollout_backend="continuous",
                               cache_backend="contiguous",
                               kv_quant="int8"))
