"""Fixtures for the scenario-matrix harness (cell table -> CI artifact).

The heavy lifting (per-arch contexts, cached cell runs, contracts) lives in
tests/matrix/_harness.py; this conftest only collects per-cell result rows
and writes ``reports/matrix_cells.json`` at session end so CI can upload a
machine-readable table of every cell that ran.
"""
import json
import os

import pytest

from _harness import REPO

_CELLS = []


@pytest.fixture
def record_cell():
    def _rec(**row):
        _CELLS.append(row)
    return _rec


def pytest_sessionfinish(session, exitstatus):
    if not _CELLS:
        return
    path = os.path.join(REPO, "reports", "matrix_cells.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(_CELLS, f, indent=1)
