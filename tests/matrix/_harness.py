"""Scenario-matrix conformance harness: sampler policy x arch x length-dist.

Every cell pushes one RL rollout phase through the continuous engine (paged
backend, so the allocator leak check is armed at ``end_phase``) and asserts
that cell's contract (tests/matrix/test_matrix.py):

  * identity-class cells — ``policy.is_dense``, or an SSM family whose
    recurrent state has no KV cache to compress — are pinned token-identical
    to the dense lockstep oracle, with mismatch KL at numerical noise;
  * sparse cells assert the Sparse-RL correction invariants instead: finite
    length-masked mismatch KL, a genuinely nonzero policy gap, the rejection
    mask firing under a tight eps, and a finite loss;
  * quantized-pool cells additionally check the capacity payoff.

Cells share compiled programs aggressively: model params are cached per
arch, engine + lockstep server per (arch, policy), and the phase run per
(arch, policy, plen_dist) — the two length-dist cells of a policy reuse one
engine.  Per-cell results are collected and written to
``reports/matrix_cells.json`` at session end (the CI artifact).
"""
from __future__ import annotations

import os
from dataclasses import replace

import jax
import numpy as np

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))

# matrix axes -----------------------------------------------------------
ARCHS = {"transformer": "qwen2.5-14b",   # dense family, paged pool applies
         "hybrid": "zamba2-1.2b",        # attn every 2 layers + ssm blocks
         "ssm": "mamba2-370m"}           # no KV cache: compression == noop
MAIN_POLICIES = ("dense", "rkv", "per_head", "adaptive")
QUANT_POLICIES = ("quant-int8", "quant-fp8")   # pool families only
PLEN_DISTS = ("fixed", "mixed")

# workload: small enough for CPU CI, large enough that the sparse budget
# (cache_slots = 8 + 4 = 12 < prompt 12 + new 6) genuinely evicts
N_PROMPTS, GROUP, PROMPT_LEN, MAX_NEW = 2, 2, 12, 6
BATCH, DECODE_CHUNK, BLOCK_SIZE = 2, 2, 4
TOTAL = N_PROMPTS * GROUP


def base_scfg():
    from repro.configs import SparseRLConfig

    return SparseRLConfig(kv_budget=8, kv_buffer=4, obs_window=4,
                          num_sinks=2, group_size=GROUP,
                          max_new_tokens=MAX_NEW,
                          reasoning_head_frac=0.5,
                          adaptive_min_frac=0.3, adaptive_decay_tokens=8)


_CTX, _SRV, _RUNS = {}, {}, {}


def arch_ctx(arch_key: str):
    """(cfg, mfns, params) per arch — params init is the slow part."""
    if arch_key not in _CTX:
        from repro.configs import get_config
        from repro.models import get_model

        cfg = get_config(ARCHS[arch_key]).smoke()
        m = get_model(cfg)
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        _CTX[arch_key] = (cfg, m, params)
    return _CTX[arch_key]


def phase_requests(plen_dist: str, seed: int = 7):
    """Group-major phase workload; "mixed" spreads prompt lengths exactly
    like the serve CLI / rollout bench (full / half / quarter)."""
    from repro.data import encode_prompts, make_problems
    from repro.launch.serve import mix_prompt_lengths
    from repro.rollout import Request

    problems = make_problems(N_PROMPTS, seed, "easy")
    ids, mask, _ = encode_prompts(problems, PROMPT_LEN)
    prompts = mix_prompt_lengths(
        [ids[i][mask[i]] for i in range(N_PROMPTS)], seed, plen_dist)
    rng = np.random.default_rng(seed + 1)
    caps = rng.choice([2, MAX_NEW // 2, MAX_NEW], size=TOTAL, p=[0.3, 0.3, 0.4])
    return [Request(uid=u, prompt=prompts[u // GROUP],
                    max_new_tokens=int(caps[u]))
            for u in range(TOTAL)]


def cell_policy(name: str):
    from repro.rollout import resolve_policy

    return resolve_policy(name)


def run_cell(arch_key: str, policy_name: str, plen_dist: str):
    """One matrix cell: continuous-paged phase + same-scfg lockstep oracle +
    dense rescore.  Cached per (arch, policy, plen_dist); the engine and the
    lockstep server are reused across the two length-dist cells."""
    key = (arch_key, policy_name, plen_dist)
    if key in _RUNS:
        return _RUNS[key]
    from repro.data import TOKENIZER
    from repro.rollout import (
        ContinuousEngine,
        LockstepServer,
        build_train_rollout,
        mismatch_kl_estimate,
        rescore,
    )

    cfg, m, params = arch_ctx(arch_key)
    pol = cell_policy(policy_name)
    scfg = pol.apply(base_scfg())
    skey = (arch_key, policy_name)
    if skey not in _SRV:
        eng = ContinuousEngine(params, cfg, m, scfg, batch_size=BATCH,
                               prompt_len=PROMPT_LEN, max_new_tokens=MAX_NEW,
                               eos_id=TOKENIZER.eos_id,
                               decode_chunk=DECODE_CHUNK, seed=11,
                               cache_backend="paged", block_size=BLOCK_SIZE,
                               kv_quant=pol.kv_quant)
        lock = (None if pol.kv_quant != "none" else
                LockstepServer(params, cfg, m, scfg, batch_size=TOTAL,
                               prompt_len=PROMPT_LEN, max_new_tokens=MAX_NEW,
                               eos_id=TOKENIZER.eos_id, seed=11))
        _SRV[skey] = (eng, lock)
    eng, srv = _SRV[skey]

    reqs = phase_requests(plen_dist)
    eng.begin_phase()
    cont = eng.run(reqs, group_size=GROUP)
    stats = eng.end_phase()            # leak check armed in every cell
    lock = srv.run(reqs) if srv is not None else None

    ids = np.zeros((TOTAL, PROMPT_LEN), np.int32)
    pmask = np.zeros((TOTAL, PROMPT_LEN), bool)
    for r in reqs:
        p = np.asarray(r.prompt, np.int32)
        ids[r.uid, PROMPT_LEN - len(p):] = p
        pmask[r.uid, PROMPT_LEN - len(p):] = True
    tr = build_train_rollout(cont, ids, pmask, max_new_tokens=MAX_NEW,
                             pad_id=eng.pad_id, stats=stats)
    logp_old = rescore(params, cfg, m, tr.rollout)
    kl = float(mismatch_kl_estimate(logp_old, tr.rollout.logp_sparse,
                                    tr.rollout.resp_mask,
                                    lengths=tr.rollout.lengths))
    out = dict(cfg=cfg, params=params, mfns=m, scfg=scfg, policy=pol,
               cont=cont, lock=lock, tr=tr, logp_old=np.asarray(logp_old),
               stats=stats, mismatch_kl=kl)
    _RUNS[key] = out
    return out


def identity_class(policy, cfg) -> bool:
    """True when the cell must be token-identical to the dense oracle:
    the dense/quant-geometry identity policies, or an SSM family whose
    decode state is recurrent (no KV cache for any policy to touch)."""
    from repro.configs.base import SSM

    return bool(policy.is_dense) or cfg.family == SSM


def tight_scfg(scfg, eps: float = 0.999):
    return replace(scfg, rejection_eps=eps, reject=True)
