"""Autotune config resolution + sweep harness (kernels/autotune.py).

Covers the ISSUE-8 contract: tuned-entry hit, fallback-to-default that is
bitwise-identical to the historical kernels, malformed/stale tuned JSON as
a loud TunedConfigError (never a silent fallback), ref-oracle rejection of
wrong winners, the roofline sanity bound rejecting impossible timings, the
persist round-trip, and the tools/autotune.py dry-run CLI.
"""
import importlib.util
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at
from repro.kernels import ops, ref
from repro.kernels.budget_attention import budget_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.paged_decode import paged_flash_decode


@pytest.fixture
def tuned_dir(tmp_path, monkeypatch):
    """Point the registry at an isolated temp dir (empty = pure defaults)."""
    monkeypatch.setenv(at.TUNED_DIR_ENV, str(tmp_path))
    at.reset_cache()
    yield tmp_path
    at.reset_cache()


def _write_tuned(path, kind, entries, schema=at.SCHEMA_VERSION):
    with open(os.path.join(str(path), f"{kind}.json"), "w") as f:
        json.dump(dict(schema=schema, device_kind=kind, entries=entries), f)


def _tuned_entry(config, us=123.0):
    return dict(config=config, source="tuned", us=us, oracle_ok=True,
                roofline_ok=True)


def _paged_operands(seed=0, B=2, Hq=4, Hkv=2, Dh=16, bs=8, nb=2):
    rng = np.random.default_rng(seed)
    N = B * nb + 2
    q = jnp.asarray(rng.normal(size=(B, Hq, Dh)), jnp.float32)
    k_pool = jnp.asarray(rng.normal(size=(N, Hkv, bs, Dh)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(N, Hkv, bs, Dh)), jnp.float32)
    pos_pool = jnp.asarray(rng.integers(0, 99, (N, bs)), jnp.int32)
    bt = jnp.asarray(
        rng.permutation(np.arange(1, N))[:B * nb].reshape(B, nb), jnp.int32)
    fill = jnp.asarray([bs + 3, bs // 2], jnp.int32)
    return q, k_pool, v_pool, pos_pool, bt, fill


# ------------------------------------------------------------- resolution --

def test_tuned_entry_hit(tuned_dir):
    key = at.tune_key("budget_attention", head_dim=16)
    _write_tuned(tuned_dir, at.device_kind(),
                 {key.s: _tuned_entry({"bh_tile": 2})})
    cfg, src = at.get_tuned_config("budget_attention", key)
    assert cfg == {"bh_tile": 2}
    assert src == "tuned"


def test_missing_entry_falls_back_to_default(tuned_dir):
    key = at.tune_key("paged_decode", head_dim=16, page_size=8)
    cfg, src = at.get_tuned_config("paged_decode", key)
    assert cfg == {"page_tile": 8}
    assert src == "default"
    cfg, src = at.get_tuned_config(
        "flash_attention", at.tune_key("flash_attention", head_dim=16))
    assert (cfg, src) == ({"block_q": 512, "block_k": 512}, "default")


def test_default_fallback_is_bitwise_identical(tuned_dir):
    """With no tuned entry, the ops wrappers must produce bit-for-bit the
    outputs of the historical hand-picked constants — the acceptance pin."""
    operands = _paged_operands()
    ops.reset_config_sources()
    via_ops = ops.paged_flash_decode(*operands)
    direct = paged_flash_decode(*operands, page_tile=8, interpret=True)
    assert np.array_equal(np.asarray(via_ops), np.asarray(direct))
    assert ops.config_provenance() == "default"

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 24, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 24, 16)), jnp.float32)
    pos = jnp.asarray(rng.integers(-1, 50, (2, 2, 24)), jnp.int32)
    pos = pos.at[:, :, 0].set(0)
    o1, p1 = ops.budget_attention(q, k, v, pos)
    o2, p2 = budget_attention(q, k, v, pos, bh_tile=1, interpret=True)
    assert np.array_equal(np.asarray(o1), np.asarray(o2))
    assert np.array_equal(np.asarray(p1), np.asarray(p2))

    qa = jnp.asarray(rng.normal(size=(1, 24, 4, 16)), jnp.float32)
    ka = jnp.asarray(rng.normal(size=(1, 24, 2, 16)), jnp.float32)
    va = jnp.asarray(rng.normal(size=(1, 24, 2, 16)), jnp.float32)
    posa = jnp.broadcast_to(jnp.arange(24)[None], (1, 24)).astype(jnp.int32)
    f1 = ops.flash_attention(qa, ka, va, posa, posa)
    f2 = flash_attention_fwd(qa, ka, va, posa, posa, block_q=512,
                             block_k=512, interpret=True)
    assert np.array_equal(np.asarray(f1), np.asarray(f2))


def test_tuned_entry_drives_kernel_and_provenance(tuned_dir):
    key = at.tune_key("paged_decode", head_dim=16, page_size=8)
    _write_tuned(tuned_dir, at.device_kind(),
                 {key.s: _tuned_entry({"page_tile": 8})})
    operands = _paged_operands()
    ops.reset_config_sources()
    out = ops.paged_flash_decode(*operands)
    assert ops.config_sources()["paged_decode"] == "tuned"
    assert ops.config_provenance() == "tuned"
    oracle = ref.paged_decode_ref(*operands)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)
    ops.reset_config_sources()
    assert ops.config_provenance() == "default"


# --------------------------------------------------- loud schema failures --

@pytest.mark.parametrize("mutate,match", [
    (lambda d: d.update(schema=99), "schema"),
    (lambda d: d["entries"].update({"nonsense": {"config": {"x": 1},
                                                 "source": "default"}}),
     "unparseable"),
    (lambda d: d["entries"]["budget_attention/any/hd16/ps0"].update(
        config={"old_param": 4}), "stale"),
    (lambda d: d["entries"]["budget_attention/any/hd16/ps0"].update(
        config={"bh_tile": -2}), "positive"),
    (lambda d: d["entries"]["budget_attention/any/hd16/ps0"].update(
        source="guessed"), "source"),
    (lambda d: d["entries"]["budget_attention/any/hd16/ps0"].update(
        source="tuned"), "us"),
])
def test_malformed_tuned_json_is_loud(tuned_dir, mutate, match):
    """A broken tuned file must raise, never silently fall back to the
    defaults (an invisible perf regression)."""
    key = at.tune_key("budget_attention", head_dim=16)
    doc = dict(schema=at.SCHEMA_VERSION, device_kind=at.device_kind(),
               entries={key.s: dict(config={"bh_tile": 1},
                                    source="default")})
    mutate(doc)
    with open(os.path.join(str(tuned_dir),
                           f"{at.device_kind()}.json"), "w") as f:
        json.dump(doc, f)
    with pytest.raises(at.TunedConfigError, match=match):
        at.get_tuned_config("budget_attention", key)


def test_stale_page_tile_not_dividing_page_size_is_loud(tuned_dir):
    key = at.tune_key("paged_decode", head_dim=16, page_size=8)
    _write_tuned(tuned_dir, at.device_kind(),
                 {key.s: _tuned_entry({"page_tile": 3})})
    with pytest.raises(at.TunedConfigError, match="divide"):
        at.get_tuned_config("paged_decode", key)


def test_invalid_json_text_is_loud(tuned_dir):
    with open(os.path.join(str(tuned_dir),
                           f"{at.device_kind()}.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(at.TunedConfigError, match="invalid JSON"):
        at.load_tuned()


def test_device_kind_mismatch_is_loud(tuned_dir):
    _write_tuned(tuned_dir, at.device_kind(), {})
    path = os.path.join(str(tuned_dir), f"{at.device_kind()}.json")
    with open(path) as f:
        doc = json.load(f)
    doc["device_kind"] = "tpu_v5e"
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(at.TunedConfigError, match="device_kind"):
        at.load_tuned()


# -------------------------------------------------- sweep winner checking --

def _smoke_case():
    key = at.tune_key("budget_attention", head_dim=16)
    return key, at.make_case(key, workload=at.Workload(B=2, Hq=4, Hkv=2,
                                                       S=16))


def test_winner_rejected_on_oracle_failure():
    key, case = _smoke_case()

    def wrong_runner(config):
        o, p = case.run(config)
        return o + 1.0, p           # subtly wrong kernel output

    cand = at.evaluate_candidate(case, {"bh_tile": 1}, kind="interpret",
                                 runner=wrong_runner)
    assert cand.oracle_ok is False
    assert not cand.accepted
    assert "oracle" in cand.reject_reason
    # the wrong config can never be persisted: a sweep built from it has no
    # winner, so persist() writes nothing for the key
    res = at.SweepResult(key=key, kind="interpret", workload=case.workload,
                         candidates=[cand], winner=None, default_us=None)
    assert all(not c.accepted for c in res.candidates)


def test_roofline_rejects_too_fast_timing():
    _, case = _smoke_case()

    def impossible_timer(thunk, *, warmup, repeats):
        return 1e-6                 # "measured" 1 picosecond: a bug

    cand = at.evaluate_candidate(case, {"bh_tile": 1}, kind="interpret",
                                 timer=impossible_timer)
    assert cand.oracle_ok is True
    assert not cand.accepted
    assert "roofline" in cand.reject_reason
    assert cand.us < cand.bound_us


def test_accepted_candidate_passes_both_gates():
    _, case = _smoke_case()

    def slow_timer(thunk, *, warmup, repeats):
        return 1e6                  # one second: far above any bound

    cand = at.evaluate_candidate(case, {"bh_tile": 2}, kind="interpret",
                                 timer=slow_timer)
    assert cand.accepted
    assert cand.oracle_ok is True
    assert cand.us >= cand.bound_us


def test_persist_round_trip(tuned_dir):
    key, _ = _smoke_case()

    def slow_timer(thunk, *, warmup, repeats):
        return 1e6

    res = at.sweep(key, kind=at.device_kind(),
                   workload=at.Workload(B=2, Hq=4, Hkv=2, S=16),
                   timer=slow_timer)
    assert res.winner is not None
    path = at.persist([res], kind=at.device_kind(),
                      directory=str(tuned_dir))
    with open(path) as f:
        entries = at.validate_tuned(json.load(f), kind=at.device_kind())
    assert key.s in entries
    assert entries[key.s]["source"] == "tuned"
    cfg, src = at.get_tuned_config("budget_attention", key)
    assert src == "tuned"
    assert cfg == res.winner.config
    rows = res.report_rows()
    assert any(r["winner"] for r in rows)
    assert all(r["roofline_bound_us"] is not None for r in rows)


def test_candidate_space_contains_default():
    for kernel in at.KERNELS:
        key = at.tune_key(kernel, head_dim=128,
                          page_size=32 if kernel == "paged_decode" else 0)
        assert at.default_config(key) in at.candidate_space(key)


# ---------------------------------------------------------------- the CLI --

def _load_cli():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                        "autotune.py")
    spec = importlib.util.spec_from_file_location("autotune_cli", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_cli_dry_run_validates_grids_and_schemas(tmp_path):
    cli = _load_cli()
    out = tmp_path / "autotune.json"
    assert cli.main(["--dry-run", "--all", "--out", str(out)]) == 0
    report = json.loads(out.read_text())
    assert report["mode"] == "dry_run"
    kernels = {r["kernel"] for r in report["rows"]}
    assert kernels == set(at.KERNELS)
    for row in report["rows"]:
        assert row["default"] in row["candidates"]
    # the checked-in interpret.json schema-validated as part of the dry run
    assert any(t["kind"] == "interpret" for t in report["tuned_files"])


def test_cli_refuses_interpret_persist_without_force(tmp_path, monkeypatch):
    cli = _load_cli()
    monkeypatch.setenv(at.TUNED_DIR_ENV, str(tmp_path))
    at.reset_cache()
    out = tmp_path / "autotune.json"
    assert cli.main(["--kernel", "budget_attention", "--smoke",
                     "--repeats", "1", "--out", str(out)]) == 0
    # no tuned file written for the interpret device kind without --force
    assert not (tmp_path / "interpret.json").exists()
    report = json.loads(out.read_text())
    assert report["mode"] == "sweep" and report["rows"]
    at.reset_cache()
