"""Async actor-learner pipeline (DESIGN.md §Async pipeline & staleness
correction): WeightStore ring semantics, engine weight hot-swap +
per-token version accounting, group streaming, the lag-0 sync-equivalence
guarantee, lag>=1 stability, and checkpoint round-trips that include the
optimizer state and the weight-version counter."""

import jax
import numpy as np
import pytest

from repro.configs import SparseRLConfig, TrainConfig, get_config
from repro.data import TOKENIZER
from repro.models import get_model
from repro.rollout import ContinuousEngine, Request
from repro.runtime import Trainer, TrainerOptions, WeightStore


# ---------------------------------------------------------------------------
# WeightStore
# ---------------------------------------------------------------------------
def test_weight_store_versions_and_ring_eviction():
    ws = WeightStore(capacity=2, start_version=5)
    assert ws.publish({"w": 0}) == 5
    assert ws.publish({"w": 1}) == 6
    assert ws.publish({"w": 2}) == 7
    assert len(ws) == 2 and 5 not in ws          # oldest unreferenced dropped
    v, params = ws.acquire()
    assert v == 7 and params == {"w": 2}
    ws.release(7)
    with pytest.raises(KeyError):
        ws.acquire(5)                            # evicted version = hard error


def test_weight_store_refcount_pins_across_eviction():
    ws = WeightStore(capacity=2, start_version=0)
    ws.publish({"w": 0})
    v0, _ = ws.acquire(0)                        # pin the oldest
    ws.publish({"w": 1})
    ws.publish({"w": 2})
    ws.publish({"w": 3})
    assert 0 in ws                               # referenced: never evicted
    assert ws.refs(0) == 1
    ws.release(0)
    ws.publish({"w": 4})                         # next publish collects it
    assert 0 not in ws
    with pytest.raises(ValueError):
        ws.release(0)                            # unheld release = bug signal


# ---------------------------------------------------------------------------
# Engine: hot-swap at sweep boundaries + per-token version accounting
# ---------------------------------------------------------------------------
def _smoke_engine(decode_chunk=2, batch_size=4, max_new=8):
    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SparseRLConfig(compression="none", group_size=4)
    eng = ContinuousEngine(params, cfg, m, scfg, batch_size=batch_size,
                           prompt_len=8, max_new_tokens=max_new,
                           eos_id=TOKENIZER.eos_id, decode_chunk=decode_chunk,
                           seed=0, cache_backend="paged", block_size=4)
    return eng, params, cfg, m


def test_set_params_swaps_at_sweep_boundary_and_tags_versions():
    """A hot-swap staged mid-run applies at the next admission sweep: rows
    admitted later carry the new version, the first post-swap token of an
    in-flight row is still attributed to the old params (the carried
    logits), and the prefix cache is invalidated with the swap."""
    eng, params, cfg, m = _smoke_engine(decode_chunk=2, batch_size=2)
    eng.begin_phase(params=params, base_key=jax.random.PRNGKey(3),
                    weight_version=7)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, 60, size=6) for _ in range(4)]
    params2 = jax.tree.map(lambda x: x * 1.01, params)
    seen = []

    def on_group(gid, comps):
        seen.append((gid, [c.uid for c in comps]))
        if gid == 0:
            eng.set_params(params2, 8)           # staged, not yet applied

    comps = eng.run([Request(uid=u, prompt=prompts[u // 2],
                             max_new_tokens=4 + 2 * (u // 2))
                     for u in range(4)],
                    group_size=2, on_group=on_group)
    eng.end_phase()
    assert seen[0][0] == 0 and seen[0][1] == [0, 1]   # uid-sorted streaming
    by_uid = {c.uid: c for c in comps}
    assert by_uid[0].weight_version == 7
    # the group admitted after the swap carries the new version everywhere
    assert by_uid[2].weight_version == by_uid[3].weight_version == 8
    assert all((c.tok_versions == 8).all() for c in (by_uid[2], by_uid[3]))
    assert eng.stats["weight_swaps"] == 1


def test_inflight_row_first_post_swap_token_keeps_old_version():
    """Per-token accounting across a swap: the chunk dispatched right after
    the swap samples its first token from logits the OLD params produced."""
    eng, params, cfg, m = _smoke_engine(decode_chunk=2, batch_size=3,
                                        max_new=8)
    eng.begin_phase(params=params, base_key=jax.random.PRNGKey(5),
                    weight_version=1)
    prompt = np.arange(3, 9)
    fired = []

    def on_group(gid, comps):
        fired.append(gid)
        if gid == 0:
            # uid 2 is co-resident and mid-decode (cap 8 > uid0/1's cap 2)
            eng.set_params(jax.tree.map(lambda x: x * 1.01, params), 2)

    comps = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=2),
                     Request(uid=1, prompt=prompt, max_new_tokens=2),
                     Request(uid=2, prompt=np.arange(10, 16),
                             max_new_tokens=8)],
                    group_size=2, on_group=on_group)
    eng.end_phase()
    long = {c.uid: c for c in comps}[2]
    tv = long.tok_versions
    assert long.weight_version == 1        # co-admitted with group 0
    assert tv[0] == 1
    assert (tv == 2).any()                 # swap landed while it decoded
    first_new = int(np.argmax(tv == 2))
    # the chunk dispatched right after the swap samples its first token
    # from logits the OLD params produced — boundary token stays v1
    assert first_new >= 3                  # 2 pre-swap + 1 boundary token
    assert tv[first_new - 1] == 1
    assert (tv[first_new:] == 2).all()


def test_end_phase_reports_pool_and_queue_telemetry():
    eng, params, cfg, m = _smoke_engine()
    eng.begin_phase(params=params, base_key=jax.random.PRNGKey(1))
    prompt = np.arange(3, 9)
    eng.run([Request(uid=u, prompt=prompt) for u in range(8)], group_size=4)
    stats = eng.end_phase()
    assert stats["staged_peak"] >= 1
    assert stats["blocks_in_use_peak"] > 0
    assert 0 < stats["pool_peak_frac"] <= 1.0
    assert stats["pool_blocks"] == eng.pool_blocks
    for k in ("admit_wait_p50", "admit_wait_p99", "latency_p50",
              "latency_p99"):
        assert k in stats and stats[k] >= 0.0
    # admission waits are populated (later groups waited for free rows)
    assert stats["admit_wait_p99"] >= stats["admit_wait_p50"]
    assert stats["latency_p99"] > 0.0


# ---------------------------------------------------------------------------
# Trainer e2e: lag-0 equivalence, lag>=1 stability
# ---------------------------------------------------------------------------
def _mk_trainer(tmp, pipeline="sync", max_lag=1, **kw):
    cfg = get_config("qwen2.5-14b").smoke()
    scfg = SparseRLConfig(kv_budget=12, kv_buffer=4, obs_window=2,
                          num_sinks=1, group_size=4, max_new_tokens=10,
                          learning_rate=3e-4, kl_coef=0.0)
    tcfg = TrainConfig(update_batch=16, total_steps=10, warmup_steps=1,
                       checkpoint_every=kw.pop("checkpoint_every", 0),
                       checkpoint_dir=str(tmp))
    opts = TrainerOptions(num_prompts=4, prompt_len=16, max_new_tokens=10,
                          rollout_backend="continuous",
                          cache_backend="paged", decode_chunk=2,
                          pipeline=pipeline, max_lag=max_lag, **kw)
    return Trainer(cfg, scfg, tcfg, opts)


def test_async_lag0_token_logp_and_param_identical_to_sync(tmp_path):
    """The acceptance bound: pipeline="async", max_lag=0 serializes the
    actor-learner handoff and must reproduce the sync trainer exactly —
    per-step rollout tokens and logp_sparse, rewards, and the final
    params/opt state, bit for bit."""
    rolls = {"sync": [], "async": []}

    def cap(name):
        def cb(step, metrics):
            tr = trainers[name]
            rolls[name].append((
                np.asarray(jax.device_get(tr.last_rollout.resp_tokens)),
                np.asarray(jax.device_get(tr.last_rollout.logp_sparse)),
                metrics["reward"]))
        return cb

    trainers = {"sync": _mk_trainer(tmp_path / "s", "sync")}
    trainers["sync"].train(3, log_every=0, callback=cap("sync"))
    trainers["async"] = _mk_trainer(tmp_path / "a", "async", max_lag=0)
    trainers["async"].train(3, log_every=0, callback=cap("async"))

    for (ts, ls, rs), (ta, la, ra) in zip(rolls["sync"], rolls["async"]):
        np.testing.assert_array_equal(ts, ta)
        np.testing.assert_array_equal(ls, la)   # bitwise: same sampler pass
        assert rs == ra
    for a, b in zip(jax.tree.leaves(trainers["sync"].params),
                    jax.tree.leaves(trainers["async"].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(trainers["sync"].opt_state),
                    jax.tree.leaves(trainers["async"].opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert trainers["async"].weight_version == 3


def test_async_lag1_trains_stably_and_measures_staleness(tmp_path):
    """max_lag=1 smoke run: finite metrics every step, the staleness
    telemetry actually measures lag (> 0 once the pipeline fills), the
    correction stays active (mean_rho finite, capped), and the page pool
    drains at every phase end (no leak across overlapped phases)."""
    tr = _mk_trainer(tmp_path / "l1", "async", max_lag=1)
    hist = tr.train(4, log_every=0)
    assert len(hist) == 4
    for m in hist:
        for k, v in m.items():
            assert np.isfinite(v), (k, v)
    assert max(m["staleness_lag"] for m in hist) > 0
    assert all(m.get("mean_rho", 1.0) <= tr.scfg.staleness_clip + 1e-6
               for m in hist)
    # reward must not degrade over the smoke horizon
    half = len(hist) // 2
    first = np.mean([m["reward"] for m in hist[:half]])
    second = np.mean([m["reward"] for m in hist[half:]])
    assert second >= first - 0.25
    # nothing leaks across overlapped phases: the (rkv-compressed) paged
    # backend shares prefills by state splice — its prefix cache must be
    # bulk-released at every phase end; a pool allocator, when present,
    # must have drained
    assert len(tr.engine.prefix) == 0
    if tr.engine.allocator is not None:
        assert tr.engine.allocator.blocks_in_use == 0
    assert tr.step == 4 and tr.weight_version == 4


def test_async_requires_continuous_backend(tmp_path):
    cfg = get_config("qwen2.5-14b").smoke()
    scfg = SparseRLConfig(group_size=4, max_new_tokens=10)
    tcfg = TrainConfig(checkpoint_dir=str(tmp_path / "x"))
    with pytest.raises(ValueError, match="continuous"):
        Trainer(cfg, scfg, tcfg,
                TrainerOptions(num_prompts=4, prompt_len=16,
                               max_new_tokens=10, pipeline="async"))


# ---------------------------------------------------------------------------
# Checkpointer round-trip: optimizer state + weight-version counter
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_includes_opt_state_and_weight_version(tmp_path):
    tr = _mk_trainer(tmp_path / "ck", "sync", checkpoint_every=2)
    for _ in range(2):
        tr.train_step()
    assert tr.weight_version == 2
    saved_opt = jax.device_get(tr.opt_state)
    saved_params = jax.device_get(tr.params)
    del tr
    tr2 = _mk_trainer(tmp_path / "ck", "sync", checkpoint_every=2)
    assert tr2.step == 2 and tr2.weight_version == 2
    for a, b in zip(jax.tree.leaves(saved_params),
                    jax.tree.leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(saved_opt),
                    jax.tree.leaves(tr2.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_resume_lands_on_consistent_snapshot(tmp_path):
    """Kill an async run mid-flight; the restart resumes from the last
    checkpoint with step == weight_version (a consistent snapshot: the
    producer's run-ahead rollouts are simply regenerated — phase keys are
    a pure function of (seed, step)) and training continues."""
    tr = _mk_trainer(tmp_path / "ar", "async", max_lag=1, checkpoint_every=2)
    tr.train(3, log_every=0)
    del tr  # crash after the step-2 checkpoint (step 3 never saved)
    tr2 = _mk_trainer(tmp_path / "ar", "async", max_lag=1,
                      checkpoint_every=2)
    assert tr2.step == 2 and tr2.weight_version == 2
    hist = tr2.train(2, log_every=0)
    assert len(hist) == 2 and tr2.step == 4 and tr2.weight_version == 4
    for m in hist:
        assert np.isfinite(m["loss"])
