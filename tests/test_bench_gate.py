"""tools/bench_gate.py: the CI benchmark regression gate (pure JSON logic,
no jax) — hard bounds (identity, rollout speedup >= 1) and the tolerance
band against committed baselines."""
import importlib.util
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
spec = importlib.util.spec_from_file_location(
    "bench_gate", ROOT / "tools" / "bench_gate.py")
bench_gate = importlib.util.module_from_spec(spec)
sys.modules["bench_gate"] = bench_gate
spec.loader.exec_module(bench_gate)


def _row(speedup=1.2, identical=True, policy="none", batch=4, group_size=4,
         n_prompts=4, **kw):
    return dict(policy=policy, batch=batch, group_size=group_size,
                n_prompts=n_prompts, speedup=speedup, identical=identical,
                **kw)


def _write(d: Path, serving, rollout):
    d.mkdir(parents=True, exist_ok=True)
    (d / "BENCH_serving.json").write_text(json.dumps(serving))
    (d / "BENCH_rollout.json").write_text(json.dumps(rollout))


def _arow(speedup=0.9, max_lag=1, nondegrading=True, **kw):
    """Async-pipeline cell: no lockstep-floor, reward-stability hard bound."""
    return dict(policy="rkv", max_lag=max_lag, speedup=speedup,
                reward_nondegrading=nondegrading, **kw)


def _qrow(kv_quant="int8", speedup=0.9, capacity_ratio=3.9,
          nondegrading=True, **kw):
    """Quantized-pool cell: no identity bound, no lockstep floor (default
    speedup < 1 encodes that), capacity_ratio >= 1.8 hard bound."""
    return dict(kv_quant=kv_quant, group_size=4, speedup=speedup,
                capacity_ratio=capacity_ratio,
                reward_nondegrading=nondegrading, **kw)


def _quant_rows():
    return [_qrow("none", capacity_ratio=1.0), _qrow("int8"), _qrow("fp8")]


def _mrow(policy="per_head", plen_dist="fixed", speedup=0.9, **kw):
    """Sampler-matrix cell: sparse cells carry NO 'identical' field (their
    tokens legitimately diverge from the dense oracle) and no lockstep
    speedup floor (default speedup < 1 encodes both)."""
    return dict(policy=policy, arch="qwen2.5-14b", plen_dist=plen_dist,
                group_size=4, speedup=speedup, **kw)


def _matrix_rows():
    return [_mrow("per_head", "fixed", identical=True),
            _mrow("per_head", "mixed", identical=True),
            _mrow("adaptive", "fixed", identical=True),
            _mrow("adaptive", "mixed", identical=True),
            _mrow("quant-int8", "mixed", kv_quant="int8",
                  capacity_ratio=3.9),
            _mrow("rkv", "train", reward_nondegrading=True),
            _mrow("per_head", "train", reward_nondegrading=True),
            _mrow("adaptive", "train", reward_nondegrading=True)]


def _full(speedups=(1.2, 1.2, 1.2), identical=True, async_rows=None,
          quant_rows=None, matrix_rows=None):
    s_cl, s_pp, s_rp = speedups
    qr = quant_rows if quant_rows is not None else _quant_rows()
    mr = matrix_rows if matrix_rows is not None else _matrix_rows()
    serving = {"continuous_vs_lockstep_smoke": [_row(s_cl)],
               "paged_prefix_smoke": [_row(s_pp)],
               "paged_quant_smoke": qr}
    # the full-scale section rides along unchanged in CI (only the smoke
    # bench re-runs) but its hard bounds are still vetted
    rollout = {"rollout_phase_smoke": [_row(s_rp, identical=identical)],
               "rollout_phase": [_row(1.4)],
               "rollout_async_smoke": async_rows if async_rows is not None
               else [_arow(max_lag=0, identical=True), _arow(max_lag=1)],
               "rollout_async": [_arow(max_lag=0, identical=True),
                                 _arow(max_lag=1)],
               "rollout_quant_smoke": qr,
               "rollout_quant": _quant_rows(),
               "rollout_matrix_smoke": mr,
               "rollout_matrix": _matrix_rows()}
    return serving, rollout


def test_gate_passes_within_tolerance(tmp_path):
    _write(tmp_path / "committed", *_full((1.5, 1.5, 1.5)))
    _write(tmp_path / "fresh", *_full((1.2, 1.2, 1.2)))  # -20% < 35% band
    assert bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                           0.35) == []


def test_gate_fails_on_regression_beyond_tolerance(tmp_path):
    _write(tmp_path / "committed", *_full((2.0, 1.5, 1.5)))
    _write(tmp_path / "fresh", *_full((1.0, 1.5, 1.5)))  # -50% > 35% band
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert len(problems) == 1 and "regressed" in problems[0]


def test_gate_hard_floor_on_rollout_speedup(tmp_path):
    """rollout_phase speedup < 1.0 fails even with no committed baseline:
    the ISSUE-3 bound (continuous-paged phase <= lockstep) is absolute."""
    _write(tmp_path / "fresh", *_full((1.2, 1.2, 0.9)))
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("slower than lockstep" in p for p in problems)


def test_gate_fails_on_lost_token_identity(tmp_path):
    _write(tmp_path / "committed", *_full())
    _write(tmp_path / "fresh", *_full(identical=False))
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert any("token-identical" in p for p in problems)


def test_gate_fails_on_missing_fresh_section(tmp_path):
    serving, rollout = _full()
    _write(tmp_path / "committed", serving, rollout)
    _write(tmp_path / "fresh", serving, {})   # rollout bench didn't run
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert any("missing from fresh" in p for p in problems)


def test_gate_matches_rows_by_key_not_order(tmp_path):
    """Reordered rows pair with their committed counterparts by identity
    fields, so a section shuffle cannot hide (or fake) a regression."""
    serving = {"continuous_vs_lockstep_smoke": [
        _row(2.0, policy="rkv", batch=4), _row(1.1, policy="none", batch=4)],
        "paged_prefix_smoke": [_row(1.2)],
        "paged_quant_smoke": _quant_rows()}
    rollout = _full()[1]
    _write(tmp_path / "committed", serving, rollout)
    shuffled = {"continuous_vs_lockstep_smoke": [
        _row(1.1, policy="none", batch=4), _row(2.0, policy="rkv", batch=4)],
        "paged_prefix_smoke": [_row(1.2)],
        "paged_quant_smoke": _quant_rows()}
    _write(tmp_path / "fresh", shuffled, rollout)
    assert bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                           0.35) == []


def test_gate_cli_exit_codes(tmp_path):
    _write(tmp_path / "committed", *_full())
    _write(tmp_path / "fresh", *_full())
    assert bench_gate.main(["--committed", str(tmp_path / "committed"),
                            "--fresh", str(tmp_path / "fresh")]) == 0
    _write(tmp_path / "fresh2", *_full((1.2, 1.2, 0.5)))
    assert bench_gate.main(["--committed", str(tmp_path / "committed"),
                            "--fresh", str(tmp_path / "fresh2")]) == 1


def test_gate_ignores_key_fields_unknown_to_old_baselines(tmp_path):
    """A fresh row may carry identity fields the committed baseline predates
    (e.g. ``plen_dist``): matching restricts the key to fields the baseline
    knows, so the regression check still pairs the rows instead of silently
    skipping them."""
    serving = {"continuous_vs_lockstep_smoke": [_row(1.2)],
               "paged_prefix_smoke": [_row(1.2)],
               "paged_quant_smoke": _quant_rows()}
    async_rows = _full()[1]["rollout_async_smoke"]
    async_full = _full()[1]["rollout_async"]
    quant = dict((k, _quant_rows()) for k in ("rollout_quant_smoke",
                                              "rollout_quant"))
    matrix = dict((k, _matrix_rows()) for k in ("rollout_matrix_smoke",
                                                "rollout_matrix"))
    old_rollout = {"rollout_phase_smoke": [_row(2.0)],       # no plen_dist
                   "rollout_phase": [_row(1.4)],
                   "rollout_async_smoke": async_rows,
                   "rollout_async": async_full, **quant, **matrix}
    _write(tmp_path / "committed", serving, old_rollout)
    fresh_row = dict(_row(1.0), plen_dist="mixed")           # -50% regression
    new_rollout = {"rollout_phase_smoke": [fresh_row],
                   "rollout_phase": [dict(_row(1.4), plen_dist="mixed")],
                   "rollout_async_smoke": async_rows,
                   "rollout_async": async_full, **quant, **matrix}
    _write(tmp_path / "fresh", serving, new_rollout)
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert len(problems) == 1 and "regressed" in problems[0]
    # once the baseline itself carries the field, it participates in the key
    new_base = {"rollout_phase_smoke": [dict(_row(2.0), plen_dist="fixed"),
                                        dict(_row(1.1), plen_dist="mixed")],
                "rollout_phase": [dict(_row(1.4), plen_dist="mixed")],
                "rollout_async_smoke": async_rows,
                "rollout_async": async_full, **quant, **matrix}
    _write(tmp_path / "committed2", serving, new_base)
    assert bench_gate.gate(tmp_path / "committed2", tmp_path / "fresh",
                           0.35) == []


def test_gate_async_reward_degradation_is_hard_bound(tmp_path):
    """An async row with reward_nondegrading=false fails even with no
    committed baseline: pipeline speed may never cost training stability."""
    bad = [_arow(max_lag=0, identical=True),
           _arow(max_lag=1, nondegrading=False,
                 reward_first_half=0.2, reward_second_half=0.05)]
    _write(tmp_path / "fresh", *_full(async_rows=bad))
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("reward degraded" in p for p in problems)


def test_gate_async_rows_have_no_lockstep_speedup_floor(tmp_path):
    """speedup < 1.0 is allowed for rollout_async rows (overlap gains are
    hardware-dependent) — only the rollout_phase sections carry the hard
    lockstep floor."""
    slow = [_arow(max_lag=0, speedup=0.8, identical=True),
            _arow(max_lag=1, speedup=0.85)]
    _write(tmp_path / "committed", *_full())
    _write(tmp_path / "fresh", *_full(async_rows=slow))
    assert bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                           0.35) == []


def test_gate_old_baseline_without_async_rows_still_gates(tmp_path):
    """A committed baseline that predates the async sections must not
    disable gating: fresh async rows still hit the hard bounds (identity,
    reward stability) and the other sections still tolerance-band."""
    serving, rollout = _full()
    old_rollout = {k: v for k, v in rollout.items()
                   if not k.startswith("rollout_async")}
    _write(tmp_path / "committed", serving, old_rollout)
    bad = [_arow(max_lag=0, identical=False),
           _arow(max_lag=1, nondegrading=False)]
    _write(tmp_path / "fresh", *_full(async_rows=bad))
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert any("token-identical" in p for p in problems)
    assert any("reward degraded" in p for p in problems)
    # and a clean fresh run passes against the same old baseline
    _write(tmp_path / "fresh2", *_full())
    assert bench_gate.gate(tmp_path / "committed", tmp_path / "fresh2",
                           0.35) == []


def test_gate_quant_capacity_floor_is_hard_bound(tmp_path):
    """A quantized row below the 1.8x effective-capacity bound fails even
    with no committed baseline — a quantization scheme that doesn't buy
    capacity is pure policy mismatch for nothing."""
    bad = [_qrow("none", capacity_ratio=1.0),
           _qrow("int8", capacity_ratio=1.5), _qrow("fp8")]
    _write(tmp_path / "fresh", *_full(quant_rows=bad))
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("effective-KV-capacity" in p for p in problems)
    # the int8 row fails in BOTH files' quant sections, nothing else does
    assert all("capacity" in p for p in problems)


def test_gate_quant_none_row_exempt_from_capacity_floor(tmp_path):
    """The kv_quant="none" sanity row reports capacity_ratio 1.0 by
    construction (it IS the fp pool) — the floor only binds quantized
    rows, and quant rows carry no lockstep speedup floor or identity
    bound (default _qrow speedup is < 1.0, and it has no 'identical')."""
    _write(tmp_path / "fresh", *_full())
    assert bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                           0.35) == []


def test_gate_quant_row_missing_capacity_field_flagged(tmp_path):
    rows = [_qrow("none", capacity_ratio=1.0), _qrow("fp8")]
    broken = dict(_qrow("int8"))
    del broken["capacity_ratio"]
    _write(tmp_path / "fresh", *_full(quant_rows=rows + [broken]))
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("no 'capacity_ratio'" in p for p in problems)


def test_gate_quant_reward_degradation_is_hard_bound(tmp_path):
    """Quantized rollouts that lose reward over the smoke horizon fail
    regardless of history: the corrected-sampler-policy claim is exactly
    that training stays stable under the quantization mismatch."""
    bad = [_qrow("none", capacity_ratio=1.0),
           _qrow("int8", nondegrading=False,
                 reward_first_half=0.3, reward_second_half=0.05),
           _qrow("fp8")]
    _write(tmp_path / "fresh", *_full(quant_rows=bad))
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("reward degraded" in p for p in problems)


def test_gate_old_baseline_without_quant_rows_still_gates(tmp_path):
    """A committed baseline that predates the quant sections must not
    disable gating: fresh quant rows still hit the hard bounds, and a
    clean fresh run passes against the same old baseline."""
    serving, rollout = _full()
    old_serving = {k: v for k, v in serving.items()
                   if not k.startswith("paged_quant")}
    old_rollout = {k: v for k, v in rollout.items()
                   if not k.startswith("rollout_quant")}
    _write(tmp_path / "committed", old_serving, old_rollout)
    bad = [_qrow("none", capacity_ratio=1.0),
           _qrow("int8", capacity_ratio=1.2), _qrow("fp8")]
    _write(tmp_path / "fresh", *_full(quant_rows=bad))
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert any("effective-KV-capacity" in p for p in problems)
    _write(tmp_path / "fresh2", *_full())
    assert bench_gate.gate(tmp_path / "committed", tmp_path / "fresh2",
                           0.35) == []


def test_gate_quant_speedup_tolerance_bands_once_baseline_exists(tmp_path):
    """Quant rows tolerance-band their speedup against a baseline that has
    quant rows (matched on (kv_quant, group_size))."""
    _write(tmp_path / "committed",
           *_full(quant_rows=[_qrow("none", capacity_ratio=1.0),
                              _qrow("int8", speedup=1.0), _qrow("fp8")]))
    _write(tmp_path / "fresh",
           *_full(quant_rows=[_qrow("none", capacity_ratio=1.0),
                              _qrow("int8", speedup=0.4), _qrow("fp8")]))
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    # the int8 collapse is flagged in both the serving and rollout sections
    assert problems and all("regressed" in p for p in problems)
    assert any("paged_quant" in p for p in problems)
    assert any("rollout_quant" in p for p in problems)


def test_gate_async_speedup_tolerance_bands_once_baseline_exists(tmp_path):
    """Once a committed baseline carries async rows, a steps/s collapse
    beyond the tolerance band is flagged (matched on (policy, max_lag))."""
    _write(tmp_path / "committed",
           *_full(async_rows=[_arow(max_lag=0, speedup=1.0, identical=True),
                              _arow(max_lag=1, speedup=1.0)]))
    _write(tmp_path / "fresh",
           *_full(async_rows=[_arow(max_lag=0, speedup=0.95, identical=True),
                              _arow(max_lag=1, speedup=0.4)]))
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert len(problems) == 1 and "regressed" in problems[0] \
        and "rollout_async" in problems[0]


def test_gate_matrix_sparse_cells_carry_no_identity_bound(tmp_path):
    """Sparse matrix cells (per_head/adaptive, the quant cell) carry no
    'identical' field and speedup < 1.0 — neither may trip the gate: the
    hard identity bound only bites where a row opts in, and matrix cells
    have no lockstep floor (they trade FLOPs for memory by design)."""
    rows = [_mrow("per_head", "fixed", speedup=0.7),
            _mrow("adaptive", "mixed", speedup=0.6),
            _mrow("quant-int8", "mixed", kv_quant="int8",
                  capacity_ratio=3.9, speedup=0.5),
            _mrow("per_head", "train", reward_nondegrading=True)]
    _write(tmp_path / "fresh", *_full(matrix_rows=rows))
    assert bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                           0.35) == []


def test_gate_matrix_reward_degradation_is_hard_bound(tmp_path):
    """A matrix trainer cell with reward_nondegrading=false fails even with
    no committed baseline — a sparse sampler policy that destabilizes
    training is a bug regardless of its memory win."""
    rows = _matrix_rows()[:-1] + [
        _mrow("adaptive", "train", reward_nondegrading=False,
              reward_first_half=0.3, reward_second_half=0.02)]
    _write(tmp_path / "fresh", *_full(matrix_rows=rows))
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("reward degraded" in p and "rollout_matrix" in p
               for p in problems)


def test_gate_matrix_identity_cells_still_pin(tmp_path):
    """A matrix cell that DOES declare identical (the scheduler contract on
    non-quant cells) is hard-gated like every other identity row."""
    rows = [_mrow("per_head", "fixed", identical=False)] + _matrix_rows()[1:]
    _write(tmp_path / "fresh", *_full(matrix_rows=rows))
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("token-identical" in p and "rollout_matrix" in p
               for p in problems)


def test_gate_matrix_quant_cell_capacity_floor(tmp_path):
    rows = _matrix_rows()
    rows[4] = _mrow("quant-int8", "mixed", kv_quant="int8",
                    capacity_ratio=1.2)
    _write(tmp_path / "fresh", *_full(matrix_rows=rows))
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("effective-KV-capacity" in p and "rollout_matrix" in p
               for p in problems)


def test_gate_matrix_speedup_tolerance_bands_once_baseline_exists(tmp_path):
    """Matrix cells pair on (policy, arch, plen_dist); a steps/s collapse
    beyond the band is flagged once a baseline carries the rows."""
    base = _matrix_rows()
    _write(tmp_path / "committed", *_full(matrix_rows=base))
    fresh = [dict(r) for r in base]
    fresh[0] = dict(base[0], speedup=base[0]["speedup"] * 0.4)
    _write(tmp_path / "fresh", *_full(matrix_rows=fresh))
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert len(problems) == 1 and "regressed" in problems[0] \
        and "rollout_matrix" in problems[0]


def test_gate_pairs_rows_by_config_provenance(tmp_path):
    """A tuned fresh row must not band-compare against a default baseline
    (its tuned-config speedup would mask a regression) and vice versa: the
    pairing key includes config_source, so rows of unlike provenance simply
    have no baseline and only the hard bounds apply."""
    committed = _full((2.0, 1.2, 1.2))   # fast default-config baseline
    _write(tmp_path / "committed", *committed)
    # fresh row ran under a tuned config and is slower than the committed
    # default row by more than the band — but it pairs with nothing, so
    # only the hard bounds gate it
    serving, rollout = _full((1.0, 1.2, 1.2))
    serving["continuous_vs_lockstep_smoke"][0]["config_source"] = "tuned"
    _write(tmp_path / "fresh", serving, rollout)
    assert bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                           0.35) == []
    # same-provenance rows DO band: a tuned baseline catches the tuned
    # fresh row's collapse
    serving_c, rollout_c = _full((2.0, 1.2, 1.2))
    serving_c["continuous_vs_lockstep_smoke"][0]["config_source"] = "tuned"
    _write(tmp_path / "committed2", serving_c, rollout_c)
    problems = bench_gate.gate(tmp_path / "committed2", tmp_path / "fresh",
                               0.35)
    assert len(problems) == 1 and "regressed" in problems[0]


def test_gate_missing_config_source_counts_as_default(tmp_path):
    """Baselines committed before autotuning existed carry no config_source:
    they pair with fresh default rows (explicit "default" or absent field),
    so the regression band keeps gating across the transition."""
    _write(tmp_path / "committed", *_full((2.0, 1.2, 1.2)))  # no field
    serving, rollout = _full((1.0, 1.2, 1.2))                # -50% > band
    serving["continuous_vs_lockstep_smoke"][0]["config_source"] = "default"
    _write(tmp_path / "fresh", serving, rollout)
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert len(problems) == 1 and "regressed" in problems[0]


def test_gate_tuned_rows_still_hit_hard_bounds(tmp_path):
    """Provenance pairing never relaxes the hard bounds: a tuned row that
    loses token identity fails even with no tuned baseline to pair with."""
    serving, rollout = _full()
    row = dict(_row(1.2, identical=False), config_source="tuned")
    rollout["rollout_phase_smoke"] = [row]
    _write(tmp_path / "fresh", serving, rollout)
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("token-identical" in p for p in problems)


def test_gate_old_baseline_without_matrix_rows_still_gates(tmp_path):
    """A committed baseline predating the matrix sections must not disable
    gating: bad fresh matrix rows still hit the hard bounds, and a clean
    fresh run passes against the same old baseline."""
    serving, rollout = _full()
    old_rollout = {k: v for k, v in rollout.items()
                   if not k.startswith("rollout_matrix")}
    _write(tmp_path / "committed", serving, old_rollout)
    bad = _matrix_rows()[:-1] + [
        _mrow("adaptive", "train", reward_nondegrading=False)]
    _write(tmp_path / "fresh", *_full(matrix_rows=bad))
    problems = bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                               0.35)
    assert any("reward degraded" in p for p in problems)
    _write(tmp_path / "fresh2", *_full())
    assert bench_gate.gate(tmp_path / "committed", tmp_path / "fresh2",
                           0.35) == []


def test_gate_skipped_update_frac_is_hard_bound(tmp_path):
    """A trainer row whose anomaly guard dropped more than 5% of updates
    fails even with no committed baseline — a bench that trained on a
    poisoned batch stream is not a valid perf or reward sample (DESIGN.md
    §Fault tolerance & degraded modes)."""
    bad = [_arow(max_lag=0, identical=True, skipped_update_frac=0.0),
           _arow(max_lag=1, skipped_update_frac=0.25)]
    _write(tmp_path / "fresh", *_full(async_rows=bad))
    problems = bench_gate.gate(tmp_path / "missing", tmp_path / "fresh",
                               0.35)
    assert any("skipped_update_frac" in p for p in problems)


def test_gate_rows_without_skipped_update_field_pass(tmp_path):
    """Baselines (and fresh rows) committed before the resilience telemetry
    existed carry no skipped_update_frac — the bound must skip, not fail,
    and a healthy in-bound value must also pass."""
    ok = [_arow(max_lag=0, identical=True),                    # no field
          _arow(max_lag=1, skipped_update_frac=0.0)]           # in bound
    _write(tmp_path / "committed", *_full())
    _write(tmp_path / "fresh", *_full(async_rows=ok))
    assert bench_gate.gate(tmp_path / "committed", tmp_path / "fresh",
                           0.35) == []


def test_gate_telemetry_overhead_is_hard_bound_on_phase_rows(tmp_path):
    """telemetry=metrics may cost at most 3% of the continuous phase
    wall-clock (DESIGN.md §Observability & telemetry) — a rollout_phase row
    over the bound fails with no baseline needed; an in-bound or
    field-less row passes (pre-telemetry baselines skip the check)."""
    over = [_row(telemetry_overhead_frac=0.07)]
    problems = bench_gate.gate_section(
        "rollout_phase_smoke", over, None,
        ("policy", "group_size", "n_prompts"), 0.35)
    assert any("telemetry_overhead_frac" in p for p in problems)
    ok = [_row(telemetry_overhead_frac=0.01), _row()]
    assert bench_gate.gate_section(
        "rollout_phase_smoke", ok, None,
        ("policy", "group_size", "n_prompts"), 0.35) == []


def test_gate_telemetry_overhead_only_gates_phase_sections(tmp_path):
    """Matrix cells stamp the same field informationally, but only the
    rollout_phase sections hard-gate it: slow compression-policy cells
    jitter past 3% on shared runners without being a telemetry bug."""
    rows = [dict(policy="per_head", arch="qwen2.5-14b", plen_dist="mixed",
                 speedup=2.4, identical=True,
                 telemetry_overhead_frac=0.08)]
    assert bench_gate.gate_section(
        "rollout_matrix_smoke", rows, None,
        ("policy", "arch", "plen_dist"), 0.35) == []
