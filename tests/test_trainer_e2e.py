"""End-to-end trainer behaviour: full RL loop, fault tolerance, straggler
mitigation, and the paper's stability claim at smoke scale."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SparseRLConfig, TrainConfig, get_config
from repro.runtime import Trainer, TrainerOptions


def _mk(tmp, **scfg_kw):
    cfg = get_config("qwen2.5-14b").smoke()
    base = dict(kv_budget=12, kv_buffer=4, obs_window=2, num_sinks=1,
                group_size=4, max_new_tokens=10, learning_rate=3e-4,
                kl_coef=0.0)
    base.update(scfg_kw)
    scfg = SparseRLConfig(**base)
    tcfg = TrainConfig(update_batch=16, total_steps=10, warmup_steps=1,
                       checkpoint_every=2, checkpoint_dir=str(tmp))
    opts = TrainerOptions(num_prompts=4, prompt_len=16, max_new_tokens=10)
    return cfg, scfg, tcfg, opts


def test_full_rl_step_metrics(tmp_path):
    cfg, scfg, tcfg, opts = _mk(tmp_path / "a")
    tr = Trainer(cfg, scfg, tcfg, opts)
    m = tr.train_step()
    for key in ("reward", "loss", "grad_norm", "rejection_rate", "mean_xi",
                "mismatch_kl", "resp_len", "entropy"):
        assert key in m, key
        assert np.isfinite(m[key]), (key, m[key])
    assert 0.0 <= m["rejection_rate"] <= 1.0


def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Kill-and-restart: the restarted trainer continues from the saved step
    with identical parameters (elastic restart story)."""
    d = tmp_path / "b"
    cfg, scfg, tcfg, opts = _mk(d)
    tr = Trainer(cfg, scfg, tcfg, opts)
    for _ in range(4):
        tr.train_step()
    saved_params = jax.device_get(tr.params)
    del tr  # simulated crash after step-4 checkpoint
    tr2 = Trainer(cfg, scfg, tcfg, opts)
    assert tr2.step == 4
    for a, b in zip(jax.tree.leaves(saved_params), jax.tree.leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tr2.train_step()  # continues without error
    assert tr2.step == 5


def test_group_overprovision_straggler_drop(tmp_path):
    """group_slack: G+k sampled, G kept, preferring finished rows."""
    cfg, scfg, tcfg, opts = _mk(tmp_path / "c")
    opts.group_slack = 2
    tr = Trainer(cfg, scfg, tcfg, opts)
    m = tr.train_step()
    assert np.isfinite(m["loss"])


def test_sparse_vs_naive_differ(tmp_path):
    """The corrections must actually change the update: naive (no reject, no
    reweight) and Sparse-RL gradients diverge under an aggressive budget."""
    cfg, scfg, tcfg, opts = _mk(tmp_path / "d", kv_budget=6, kv_buffer=2)
    tr = Trainer(cfg, scfg, tcfg, opts)
    prompts, pmask, answers = tr.loader.get(0)
    G = scfg.group_size
    tokens = jnp.asarray(np.repeat(prompts, G, axis=0))
    mask = jnp.asarray(np.repeat(pmask, G, axis=0))
    ro = tr._rollout_fn(tr.params, tokens, mask, jax.random.PRNGKey(3),
                        max_new=opts.max_new_tokens)
    logp_old = tr._rescore_fn(tr.params, ro)
    from repro.core import sparse_rl_loss
    adv = jnp.asarray(np.random.default_rng(0).normal(size=(ro.resp_tokens.shape[0],)),
                      jnp.float32)
    lt = logp_old + 0.01  # slightly stale learner

    out_srl = sparse_rl_loss(lt, logp_old, ro.logp_sparse, adv, ro.resp_mask, scfg)
    out_naive = sparse_rl_loss(lt, logp_old, ro.logp_sparse, adv, ro.resp_mask,
                               scfg.naive())
    # mean_xi != 1 under compression -> reweighted loss differs
    assert abs(float(out_srl.metrics["mean_xi"]) - 1.0) > 1e-4
    assert abs(float(out_srl.loss) - float(out_naive.loss)) > 1e-7


def test_dense_config_zero_mismatch(tmp_path):
    cfg, scfg, tcfg, opts = _mk(tmp_path / "e", compression="none")
    tr = Trainer(cfg, scfg, tcfg, opts)
    m = tr.train_step()
    assert abs(m["mismatch_kl"]) < 1e-4
    assert m["rejection_rate"] == 0.0
