"""End-to-end trainer behaviour: full RL loop, fault tolerance, straggler
mitigation, and the paper's stability claim at smoke scale."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SparseRLConfig, TrainConfig, get_config
from repro.runtime import Trainer, TrainerOptions


def _mk(tmp, opts_kw=None, **scfg_kw):
    cfg = get_config("qwen2.5-14b").smoke()
    base = dict(kv_budget=12, kv_buffer=4, obs_window=2, num_sinks=1,
                group_size=4, max_new_tokens=10, learning_rate=3e-4,
                kl_coef=0.0)
    base.update(scfg_kw)
    scfg = SparseRLConfig(**base)
    tcfg = TrainConfig(update_batch=16, total_steps=10, warmup_steps=1,
                       checkpoint_every=2, checkpoint_dir=str(tmp))
    opts_defaults = dict(num_prompts=4, prompt_len=16, max_new_tokens=10)
    opts_defaults.update(opts_kw or {})
    opts = TrainerOptions(**opts_defaults)
    return cfg, scfg, tcfg, opts


def test_full_rl_step_metrics(tmp_path):
    cfg, scfg, tcfg, opts = _mk(tmp_path / "a")
    tr = Trainer(cfg, scfg, tcfg, opts)
    m = tr.train_step()
    for key in ("reward", "loss", "grad_norm", "rejection_rate", "mean_xi",
                "mismatch_kl", "resp_len", "entropy"):
        assert key in m, key
        assert np.isfinite(m[key]), (key, m[key])
    assert 0.0 <= m["rejection_rate"] <= 1.0


def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Kill-and-restart: the restarted trainer continues from the saved step
    with identical parameters (elastic restart story)."""
    d = tmp_path / "b"
    cfg, scfg, tcfg, opts = _mk(d)
    tr = Trainer(cfg, scfg, tcfg, opts)
    for _ in range(4):
        tr.train_step()
    saved_params = jax.device_get(tr.params)
    del tr  # simulated crash after step-4 checkpoint
    tr2 = Trainer(cfg, scfg, tcfg, opts)
    assert tr2.step == 4
    for a, b in zip(jax.tree.leaves(saved_params), jax.tree.leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tr2.train_step()  # continues without error
    assert tr2.step == 5


def test_group_overprovision_straggler_drop(tmp_path):
    """group_slack: G+k sampled, G kept, preferring finished rows."""
    cfg, scfg, tcfg, opts = _mk(tmp_path / "c")
    opts.group_slack = 2
    tr = Trainer(cfg, scfg, tcfg, opts)
    m = tr.train_step()
    assert np.isfinite(m["loss"])


def test_sparse_vs_naive_differ(tmp_path):
    """The corrections must actually change the update: naive (no reject, no
    reweight) and Sparse-RL gradients diverge under an aggressive budget."""
    cfg, scfg, tcfg, opts = _mk(tmp_path / "d", kv_budget=6, kv_buffer=2)
    tr = Trainer(cfg, scfg, tcfg, opts)
    prompts, pmask, answers = tr.loader.get(0)
    G = scfg.group_size
    tokens = jnp.asarray(np.repeat(prompts, G, axis=0))
    mask = jnp.asarray(np.repeat(pmask, G, axis=0))
    ro = tr._rollout_fn(tr.params, tokens, mask, jax.random.PRNGKey(3),
                        max_new=opts.max_new_tokens)
    logp_old = tr._rescore_fn(tr.params, ro)
    from repro.core import sparse_rl_loss
    adv = jnp.asarray(np.random.default_rng(0).normal(size=(ro.resp_tokens.shape[0],)),
                      jnp.float32)
    lt = logp_old + 0.01  # slightly stale learner

    out_srl = sparse_rl_loss(lt, logp_old, ro.logp_sparse, adv, ro.resp_mask, scfg)
    out_naive = sparse_rl_loss(lt, logp_old, ro.logp_sparse, adv, ro.resp_mask,
                               scfg.naive())
    # mean_xi != 1 under compression -> reweighted loss differs
    assert abs(float(out_srl.metrics["mean_xi"]) - 1.0) > 1e-4
    assert abs(float(out_srl.loss) - float(out_naive.loss)) > 1e-7


def test_dense_config_zero_mismatch(tmp_path):
    cfg, scfg, tcfg, opts = _mk(tmp_path / "e", compression="none")
    tr = Trainer(cfg, scfg, tcfg, opts)
    m = tr.train_step()
    assert abs(m["mismatch_kl"]) < 1e-4
    assert m["rejection_rate"] == 0.0


# ---------------------------------------------------------------------------
# rollout_backend="continuous" (DESIGN.md §Training on the continuous engine)
# ---------------------------------------------------------------------------
def test_continuous_paged_rollout_identical_to_lockstep(tmp_path):
    """Fixed-length setting: the continuous-paged rollout phase must produce
    token- and logp_sparse-identical batches to the lockstep backend for the
    same phase key — the engine is a pure scheduling change."""
    cfg, scfg, tcfg, opts_l = _mk(tmp_path / "cl", compression="none")
    _, _, tcfg_c, opts_c = _mk(
        tmp_path / "cc", compression="none",
        opts_kw=dict(rollout_backend="continuous", cache_backend="paged",
                     decode_chunk=2))
    tr_l = Trainer(cfg, scfg, tcfg, opts_l)
    tr_c = Trainer(cfg, scfg, tcfg_c, opts_c)
    prompts, pmask, _ = tr_l.loader.get(0)
    G = scfg.group_size
    np_tokens = np.repeat(np.asarray(prompts, np.int32), G, axis=0)
    np_mask = np.repeat(np.asarray(pmask, bool), G, axis=0)
    r1 = jax.random.PRNGKey(11)
    ro_l, keep_l, _ = tr_l._rollout_phase(np_tokens, np_mask, r1)
    ro_c, keep_c, stats = tr_c._rollout_phase(np_tokens, np_mask, r1)
    np.testing.assert_array_equal(keep_l, keep_c)
    np.testing.assert_array_equal(np.asarray(ro_l.resp_tokens),
                                  np.asarray(ro_c.resp_tokens))
    np.testing.assert_array_equal(np.asarray(ro_l.resp_mask),
                                  np.asarray(ro_c.resp_mask))
    np.testing.assert_allclose(np.asarray(ro_l.logp_sparse),
                               np.asarray(ro_c.logp_sparse), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ro_l.prompt_tokens),
                                  np.asarray(ro_c.prompt_tokens))
    # group prompt pages were prefilled once: (G-1)/G of admissions hit
    assert stats["prefix_hits"] / stats["admissions"] >= (G - 1) / G - 1e-9


def test_continuous_paged_trains_and_releases_all_pages(tmp_path):
    """Variable-length run (EOS early-exits recycle slots mid-phase) trains
    without NaNs and the page pool drains at every phase end (the allocator
    leak check `end_phase` enforces — and we re-assert here)."""
    cfg, scfg, tcfg, opts = _mk(
        tmp_path / "cv", compression="none", max_new_tokens=16,
        opts_kw=dict(rollout_backend="continuous", cache_backend="paged",
                     max_new_tokens=16, decode_chunk=2))
    tr = Trainer(cfg, scfg, tcfg, opts)
    for _ in range(2):
        m = tr.train_step()
        for k, v in m.items():
            assert np.isfinite(v), (k, v)
        assert tr.engine.allocator is not None
        assert tr.engine.allocator.blocks_in_use == 0   # nothing leaked
        assert len(tr.engine.prefix) == 0               # pins bulk-released


def test_continuous_group_slack_first_g_finished(tmp_path):
    """Over-provisioned groups on the continuous backend: exactly G of G+k
    survive per prompt and the stragglers are cancelled (freeing their
    slots), never assembled into the update batch."""
    cfg, scfg, tcfg, opts = _mk(
        tmp_path / "cs",
        opts_kw=dict(rollout_backend="continuous", cache_backend="paged",
                     group_slack=2, decode_chunk=2))
    tr = Trainer(cfg, scfg, tcfg, opts)
    m = tr.train_step()
    assert np.isfinite(m["loss"])
    assert m["rollout_cancelled"] == opts.num_prompts * opts.group_slack
    # kept batch is exactly num_prompts * G (reward averaged over it)
    assert tr.engine.stats["admissions"] <= opts.num_prompts * (
        scfg.group_size + opts.group_slack)
