"""One benchmark per paper table/figure (smoke scale, CPU).

The paper's absolute numbers need pretrained 1.5B-7B checkpoints; offline we
reproduce each artifact MECHANISTICALLY: same conditions, same metrics, same
comparisons, on same-family reduced models trained from scratch on the
synthetic verifiable-math task.
"""
from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from benchmarks.common import (
    make_trainer,
    run_condition,
    toks_saving,
    window_mean,
)

OUT = "reports/benchmarks"


def _dump(name: str, obj):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1, default=float)


def table1_main(fast: bool = False) -> List[str]:
    """Table 1: dense vs naive-sparse vs Sparse-RL (R-KV & SnapKV)."""
    steps = 8 if fast else 120
    conds = ["dense", "naive_rkv", "sparse_rl_rkv"]
    if not fast:
        conds += ["naive_snapkv", "sparse_rl_snapkv"]
    rows, out = [], []
    for cond in conds:
        hist = run_condition(cond, steps)
        rew = window_mean(hist, "reward")
        sav = 0.0 if cond == "dense" else toks_saving(hist, 10)
        rej = window_mean(hist, "rejection_rate")
        rows.append(dict(condition=cond, reward_final=rew, toks_saving=sav,
                         rejection_rate=rej,
                         grad_norm=window_mean(hist, "grad_norm"),
                         history=[{k: h[k] for k in
                                   ("reward", "grad_norm", "resp_len",
                                    "entropy", "mismatch_kl",
                                    "rejection_rate", "clip_ratio")}
                                  for h in hist]))
        out.append(f"table1/{cond},{0.0},reward={rew:.3f};toks_saving={sav:.2%}")
    _dump("table1_main", rows)
    return out


def fig2_dynamics(fast: bool = False) -> List[str]:
    """Fig 2: reward / response length / entropy curves, dense vs Sparse-RL."""
    rows = json_path = os.path.join(OUT, "table1_main.json")
    if not os.path.exists(json_path):
        table1_main(fast)
    with open(json_path) as f:
        rows = json.load(f)
    out = []
    for r in rows:
        if r["condition"] not in ("dense", "sparse_rl_rkv", "naive_rkv"):
            continue
        h = r["history"]
        out.append(
            f"fig2/{r['condition']},0,"
            f"reward_first={h[0]['reward']:.3f};reward_last={h[-1]['reward']:.3f};"
            f"entropy_last={h[-1]['entropy']:.3f};len_last={h[-1]['resp_len']:.1f}")
    return out


def fig3_mismatch_kl(fast: bool = False) -> List[str]:
    """Fig 3: mismatch KL magnitude, sparse vs dense; should be ~0 dense and
    finite positive-ish under compression, shrinking as training adapts."""
    json_path = os.path.join(OUT, "table1_main.json")
    if not os.path.exists(json_path):
        table1_main(fast)
    with open(json_path) as f:
        rows = json.load(f)
    out = []
    for r in rows:
        kls = [abs(h["mismatch_kl"]) for h in r["history"]]
        out.append(f"fig3/{r['condition']},0,"
                   f"kl_first={kls[0]:.2e};kl_last={kls[-1]:.2e}")
    return out


def fig4_budget_ablation(fast: bool = False) -> List[str]:
    """Fig 4: KV budget sweep.  Rewards should degrade at tiny budgets and
    approach dense at larger ones."""
    steps = 6 if fast else 40
    budgets = [2, 4] if fast else [2, 4, 8, 16]
    rows, out = [], []
    dense_hist = run_condition("dense", steps)
    dense_rew = window_mean(dense_hist, "reward")
    for b in budgets:
        hist = run_condition("sparse_rl_rkv", steps, budget=b)
        rew = window_mean(hist, "reward")
        rows.append(dict(budget=b, reward=rew,
                         mismatch_kl=window_mean(hist, "mismatch_kl"),
                         rejection=window_mean(hist, "rejection_rate")))
        out.append(f"fig4/budget{b},0,reward={rew:.3f};dense_ref={dense_rew:.3f}")
    rows.append(dict(budget="dense", reward=dense_rew))
    _dump("fig4_budget", rows)
    return out


def table2_sparse_inference(fast: bool = False) -> List[str]:
    """Table 2: models trained dense vs Sparse-RL, both EVALUATED under
    sparse (budget) inference — sparsity-aware training robustness."""
    import jax
    import jax.numpy as jnp
    from repro.data import TOKENIZER
    from repro.rewards import binary_rewards
    from repro.rollout import generate

    steps = 8 if fast else 120
    trained = {}
    for cond in ("dense", "sparse_rl_rkv"):
        tr = make_trainer(cond, steps=steps)
        tr.train(steps, log_every=0)
        trained[cond] = tr

    out, rows = [], []
    for cond, tr in trained.items():
        # evaluate under the SAME sparse config used in sparse training
        eval_scfg = make_trainer("sparse_rl_rkv", steps=1).scfg
        prompts, pmask, answers = tr.loader.get(99991)
        batch = {"tokens": jnp.asarray(prompts), "valid_mask": jnp.asarray(pmask)}
        accs = []
        for seed in range(2 if fast else 4):
            ro = generate(tr.params, tr.cfg, tr.m, batch, eval_scfg,
                          jax.random.PRNGKey(seed), max_new_tokens=6,
                          eos_id=TOKENIZER.eos_id)
            r = binary_rewards(np.asarray(jax.device_get(ro.resp_tokens)),
                               answers)
            accs.append(float(r.mean()))
        acc = float(np.mean(accs))
        rows.append(dict(trained=cond, sparse_eval_acc=acc))
        out.append(f"table2/{cond}_under_sparse_eval,0,acc={acc:.3f}")
    _dump("table2_sparse_inference", rows)
    return out


def appc_ratios(fast: bool = False) -> List[str]:
    """App. C: rejection-rate and clip-ratio dynamics under Sparse-RL."""
    json_path = os.path.join(OUT, "table1_main.json")
    if not os.path.exists(json_path):
        table1_main(fast)
    with open(json_path) as f:
        rows = json.load(f)
    out = []
    for r in rows:
        if not r["condition"].startswith("sparse_rl"):
            continue
        rej = [h["rejection_rate"] for h in r["history"]]
        clip = [h["clip_ratio"] for h in r["history"]]
        out.append(f"appc/{r['condition']},0,"
                   f"rej_mean={np.mean(rej):.4f};rej_max={np.max(rej):.4f};"
                   f"clip_mean={np.mean(clip):.2e}")
    return out


def fig1_collapse(fast: bool = False) -> List[str]:
    """Fig 1: naive sparse rollouts destabilize training (grad spikes /
    reward collapse) while Sparse-RL stays stable.  At smoke scale we use an
    AGGRESSIVE budget to force the mismatch and compare gradient-norm tails
    and rejection incidence."""
    steps = 8 if fast else 100
    out, rows = [], []
    for cond in ("naive_rkv", "sparse_rl_rkv"):
        hist = run_condition(cond, steps, budget=4, lr=2e-3, max_new=8)
        gn = [h["grad_norm"] for h in hist]
        xi_min = [h.get("min_log_xi", 0.0) for h in hist]
        rows.append(dict(condition=cond, grad_norm_p95=float(np.percentile(gn, 95)),
                         grad_norm_max=float(np.max(gn)),
                         min_log_xi=float(np.min(xi_min)),
                         reward_last=window_mean(hist, "reward")))
        out.append(f"fig1/{cond},0,grad_p95={np.percentile(gn,95):.3f};"
                   f"grad_max={np.max(gn):.3f}")
    _dump("fig1_collapse", rows)
    return out
