"""Performance benchmarks: memory-wall accounting, rollout throughput,
kernel microbench (CPU numbers are for the jnp execution paths; Pallas
kernels run in interpret mode here and compile to Mosaic on the TPU target —
their roofline story lives in reports/roofline_*.md)."""
from __future__ import annotations

import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit

OUT = "reports/benchmarks"


def memory_wall(fast: bool = False) -> List[str]:
    """The paper's motivation, exactly: per-sequence KV bytes vs context
    length, dense vs fixed-budget cache (Qwen2.5-7B geometry, bf16)."""
    from repro.configs import SparseRLConfig, get_config

    cfg = get_config("paper-qwen2.5-7b")
    scfg = SparseRLConfig()  # budget 512 + buffer 128
    per_tok = cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2 * 2  # k+v bf16
    out, rows = [], []
    for ctx in (1024, 4096, 16384, 131072, 524288):
        dense = ctx * per_tok
        sparse = min(ctx, scfg.cache_slots) * per_tok
        rows.append(dict(ctx=ctx, dense_gb=dense / 1e9, sparse_gb=sparse / 1e9,
                         saving=1 - sparse / dense))
        out.append(f"memory_wall/ctx{ctx},0,"
                   f"dense_gb={dense/1e9:.3f};sparse_gb={sparse/1e9:.4f};"
                   f"saving={1-sparse/dense:.1%}")
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "memory_wall.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return out


def rollout_throughput(fast: bool = False) -> List[str]:
    """Decode tokens/s, sparse budget cache vs dense cache (smoke model,
    CPU).  The ratio demonstrates the bounded-cache win even at toy scale;
    absolute numbers are CPU-bound."""
    from repro.configs import SparseRLConfig, get_config
    from repro.data import TOKENIZER, encode_prompts, make_problems
    from repro.models import get_model
    from repro.rollout import generate

    cfg = get_config("qwen2.5-14b").smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    B = 4 if fast else 16
    T = 16 if fast else 48
    ids, mask, _ = encode_prompts(make_problems(B, 0), 24)
    batch = {"tokens": jnp.asarray(ids), "valid_mask": jnp.asarray(mask)}
    out = []
    for name, scfg in (
        ("sparse_rkv", SparseRLConfig(kv_budget=16, kv_buffer=4, obs_window=2,
                                      num_sinks=1)),
        ("dense", SparseRLConfig(compression="none")),
    ):
        fn = jax.jit(lambda p, b, r, s=scfg: generate(
            p, cfg, m, b, s, r, max_new_tokens=T, eos_id=TOKENIZER.eos_id))
        us = timeit(fn, params, batch, jax.random.PRNGKey(1),
                    warmup=1, iters=2)
        tps = B * T / (us / 1e6)
        slots = scfg.cache_slots if scfg.compression != "none" else 24 + T
        out.append(f"rollout/{name},{us:.0f},tok_s={tps:.1f};slots={slots}")
    return out


def kernel_bench(fast: bool = False) -> List[str]:
    """Per-kernel call latency: jnp oracle (the CPU production path) and the
    Pallas kernel in interpret mode (semantics check; Mosaic on TPU)."""
    from repro.kernels import ref
    from repro.kernels.budget_attention import budget_attention

    rng = np.random.default_rng(0)
    B, Hq, Hkv, S, Dh = 4, 8, 2, 640, 64
    if fast:
        B, S = 2, 128
    q = jnp.asarray(rng.normal(size=(B, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 500, (B, Hkv, S)), jnp.int32)
    out = []
    oracle = jax.jit(ref.budget_attention_ref)
    us = timeit(oracle, q, k, v, pos, iters=5)
    out.append(f"kernel/budget_attention_jnp,{us:.0f},B{B}xH{Hq}xS{S}xD{Dh}")
    us_k = timeit(lambda *a: budget_attention(*a, interpret=True),
                  q, k, v, pos, iters=1, warmup=1)
    out.append(f"kernel/budget_attention_pallas_interp,{us_k:.0f},"
               f"interpret_mode=CPU_semantics_only")

    from repro.kernels import ref as R
    Sq = 128 if fast else 256
    qf = jnp.asarray(rng.normal(size=(1, Sq, 4, 32)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(1, Sq, 2, 32)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(1, Sq, 2, 32)), jnp.float32)
    pp = jnp.broadcast_to(jnp.arange(Sq)[None], (1, Sq)).astype(jnp.int32)
    us = timeit(jax.jit(lambda *a: R.flash_attention_ref(*a)), qf, kf, vf,
                pp, pp, iters=3)
    out.append(f"kernel/flash_attention_jnp,{us:.0f},Sq{Sq}")

    from repro.kernels.paged_decode import paged_flash_decode
    bs, nb = (64, 4) if fast else (128, 8)
    N = B * nb + 1
    kp = jnp.asarray(rng.normal(size=(N, Hkv, bs, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, Hkv, bs, Dh)), jnp.float32)
    posp = jnp.asarray(rng.integers(0, 500, (N, bs)), jnp.int32)
    bt = jnp.arange(1, B * nb + 1, dtype=jnp.int32).reshape(B, nb)
    fill = jnp.full((B,), nb * bs - bs // 2, jnp.int32)
    us = timeit(jax.jit(R.paged_decode_ref), q, kp, vp, posp, bt, fill,
                iters=5)
    out.append(f"kernel/paged_decode_jnp,{us:.0f},B{B}xH{Hq}xS{nb*bs}"
               f"(bs{bs})xD{Dh}")
    us_k = timeit(lambda *a: paged_flash_decode(*a, interpret=True),
                  q, kp, vp, posp, bt, fill, iters=1, warmup=1)
    out.append(f"kernel/paged_decode_pallas_interp,{us_k:.0f},"
               f"interpret_mode=CPU_semantics_only")
    return out


def sharding_fallback_bench(fast: bool = False) -> List[str]:
    """Rule-engine micro-bench: resolving 1e3 shapes (launcher-path cost)."""
    from repro.distributed.sharding import DEFAULT_RULES, _resolve

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        class devices:
            shape = (2, 16, 16)

    import time
    t0 = time.time()
    n = 200 if fast else 2000
    for i in range(n):
        _resolve(FakeMesh, DEFAULT_RULES, (256, 4096, 5120),
                 ("batch", "seq", "embed"))
        _resolve(FakeMesh, DEFAULT_RULES, (5120, 27392), ("embed", "ffn"))
    us = (time.time() - t0) / n * 1e6
    return [f"sharding/resolve,{us:.1f},per_2_shapes"]
