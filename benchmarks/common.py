"""Shared benchmark scaffolding: smoke-scale training runs for the paper's
tables/figures, with one function per experimental condition, plus the
machine-readable BENCH_*.json writers the CI regression gate
(tools/bench_gate.py) compares against."""
from __future__ import annotations

import json
import os
import shutil
import time
from dataclasses import replace
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import SparseRLConfig, TrainConfig, get_config
from repro.runtime import Trainer, TrainerOptions

ARCH = "qwen2.5-14b"          # qwen-family backbone (paper: Qwen2.5 series)
ARCH_SMALL = "qwen1.5-32b"    # second family for cross-arch rows

REPO_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))


def update_bench_json(path: str, section: str, payload) -> str:
    """Merge one section into a machine-readable BENCH_*.json at the repo
    root — the cross-PR perf trajectory record, and the committed baseline
    the CI smoke regression gate (tools/bench_gate.py) diffs fresh runs
    against.  Unknown/corrupt existing content is replaced, other sections
    are preserved."""
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {}
    data[section] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")
    return path


def make_trainer(condition: str, *, steps: int, seed: int = 0,
                 arch: str = ARCH, budget: int = 8, ckpt: Optional[str] = None,
                 lr: float = 2e-3, level: str = "trivial",
                 max_new: int = 6) -> Trainer:
    """condition: dense | naive_<policy> | sparse_rl_<policy>

    Defaults are the smoke-scale curriculum where the reduced model shows
    real reward growth within ~100 steps (reward 0.07 -> 0.22 measured),
    with a budget that compresses the prompt+response context ~45%."""
    cfg = get_config(arch).smoke()
    scfg = SparseRLConfig(kv_budget=budget, kv_buffer=2, obs_window=2,
                          num_sinks=1, group_size=8, max_new_tokens=max_new,
                          learning_rate=lr, kl_coef=0.0)
    if condition == "dense":
        scfg = scfg.dense()
    elif condition.startswith("naive_"):
        scfg = replace(scfg.naive(), compression=condition.split("_", 1)[1])
    elif condition.startswith("sparse_rl_"):
        scfg = replace(scfg, compression=condition.split("_", 2)[2])
    else:
        raise ValueError(condition)
    tcfg = TrainConfig(update_batch=64, total_steps=steps, warmup_steps=5,
                       checkpoint_every=0,
                       checkpoint_dir=ckpt or f"/tmp/srl_bench_{condition}_{seed}",
                       seed=seed)
    if ckpt is None:
        shutil.rmtree(tcfg.checkpoint_dir, ignore_errors=True)
    opts = TrainerOptions(num_prompts=16, prompt_len=12, max_new_tokens=max_new,
                          level=level)
    return Trainer(cfg, scfg, tcfg, opts)


def run_condition(condition: str, steps: int, seed: int = 0, **kw
                  ) -> List[Dict[str, float]]:
    tr = make_trainer(condition, steps=steps, seed=seed, **kw)
    return tr.train(steps, log_every=0)


def window_mean(history: List[Dict], key: str, frac: float = 0.25) -> float:
    vals = [h[key] for h in history if key in h]
    n = max(1, int(len(vals) * frac))
    return float(np.mean(vals[-n:]))


def toks_saving(history: List[Dict], budget_slots: int) -> float:
    """Paper's "Toks. saving": stored-KV reduction vs dense rollout.
    Dense stores prompt+response tokens; sparse stores min(len, slots)."""
    lens = np.array([h["resp_len"] for h in history]) + 12  # + prompt
    dense = lens.mean()
    sparse = np.minimum(lens, budget_slots).mean()
    return float(1.0 - sparse / dense)


def config_source() -> str:
    """Kernel-config provenance ("tuned" when any kernel resolved an
    autotuned entry, else "default") — recorded as ``config_source`` on
    every BENCH row so tools/bench_gate.py pairs rows of like provenance
    (PERFORMANCE.md §Benchmark attribution)."""
    from repro.kernels import ops
    return ops.config_provenance()


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6  # us
