"""Benchmark harness entry point — one function per paper table/figure plus
the perf benches.  Prints ``name,us_per_call,derived`` CSV; the serving and
training-rollout benches additionally update the machine-readable
``BENCH_serving.json`` / ``BENCH_rollout.json`` at the repo root
(throughput, p50/p99 latency, prefix-hit rate, phase wall-clock) so the
perf trajectory is tracked across PRs and regression-gated in CI
(tools/bench_gate.py).

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only serving,kernels]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="few-step smoke variants (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark name filter")
    args = ap.parse_args()

    from benchmarks import paper_tables as P
    from benchmarks import perf as F
    from benchmarks import rollout as R
    from benchmarks import serving as S

    benches = [
        ("table1", P.table1_main),
        ("fig1", P.fig1_collapse),
        ("fig2", P.fig2_dynamics),
        ("fig3", P.fig3_mismatch_kl),
        ("fig4", P.fig4_budget_ablation),
        ("table2", P.table2_sparse_inference),
        ("appc", P.appc_ratios),
        ("memory_wall", F.memory_wall),
        ("rollout", F.rollout_throughput),
        ("kernels", F.kernel_bench),
        ("sharding", F.sharding_fallback_bench),
        ("serving", S.serving_bench),
        ("serving_paged", S.paged_prefix_bench),
        ("rollout_train", R.rollout_train_bench),
    ]
    if args.only:
        keep = set(args.only.split(","))
        benches = [(n, f) for n, f in benches if n in keep]

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        t0 = time.time()
        try:
            rows = fn(fast=args.fast)
            for r in rows:
                print(r, flush=True)
            print(f"_timing/{name},{(time.time()-t0)*1e6:.0f},wall", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
