"""Training-rollout benchmark: continuous-paged vs lockstep on one RL phase.

This is the training-side counterpart of benchmarks/serving.py — the
workload is a rollout *phase* exactly as the Trainer issues it
(``num_prompts`` prompts x G group rollouts, group-major uids), not open
serving traffic.  The lockstep baseline is the Trainer's historical backend:
one full-width batch decoded for the global ``max_new`` — every row pays the
pad-to-max tail.  The continuous-paged backend streams the same requests
through `ContinuousEngine.run(group_size=G)` with ``cache_backend="paged"``:
shared prompt pages prefilled once per group (cold prefix-hit rate
(G-1)/G), per-request early exit freeing slots for the next group.  Both
paths use identical per-request sampling-key chains, so outputs are
token-identical and the comparison is pure scheduling + caching (DESIGN.md
§Training on the continuous engine).

Mixed response lengths come from per-request new-token caps with the serve
CLI's long-tailed spread — the regime the paper's RL rollouts live in (most
responses EOS early, a few run to the cap) and where the lockstep tail
bleeds: its useful-token fraction is mean(len)/max_new.

Also demonstrated: ``mismatch_kl_estimate`` masked to true response lengths
(early-exited rows are right-padded; averaging the pad tail in would dilute
and bias the Fig. 3 statistic).

  PYTHONPATH=src python -m benchmarks.rollout --smoke

Row format matches benchmarks.run (``name,us_per_call,derived``);
machine-readable results land in reports/benchmarks/rollout.json and — the
cross-PR perf trajectory + the CI smoke regression-gate baseline
(tools/bench_gate.py) — BENCH_rollout.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

import jax
import numpy as np

from benchmarks.common import REPO_ROOT, config_source, update_bench_json

OUT = "reports/benchmarks"
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_rollout.json")


def _resilience_fields(hist) -> dict:
    """Resilience telemetry stamped onto every trainer bench row (DESIGN.md
    §Fault tolerance & degraded modes): the mean anomaly-guard skip
    fraction over the run — bench_gate hard-bounds it, a bench that trained
    on non-finite updates is not a valid perf sample — plus the cumulative
    recovery counters (restarts / storm rerolls / checkpoint rollbacks),
    all expected to be 0 in a healthy bench environment."""
    return dict(
        skipped_update_frac=float(np.mean(
            [m.get("skipped_update_frac", 0.0) for m in hist])),
        producer_restarts=float(max(
            (m.get("producer_restarts", 0.0) for m in hist), default=0.0)),
        storm_rerolls=float(hist[-1].get("resilience_storm_rerolls", 0.0)),
        checkpoint_rollbacks=float(
            hist[-1].get("checkpoint_rollbacks", 0.0)),
    )


def _phase_requests(n_prompts: int, group_size: int, prompt_len: int,
                    max_new: int, seed: int, plen_dist: str = "fixed"):
    """Group-major phase workload with mixed-length caps: prompt p's group
    occupies uids [p*G, (p+1)*G), every member shares the prompt (the prefix
    the paged backend deduplicates) but draws its own response cap.

    ``plen_dist="mixed"`` additionally spreads PROMPT lengths (full / half /
    quarter per prompt, shared across the group) — the workload where
    chunked batched prefill stops short prompts paying for engine-wide
    padding at admission."""
    from repro.data import encode_prompts, make_problems
    from repro.launch.serve import mix_prompt_lengths
    from repro.rollout import Request

    problems = make_problems(n_prompts, seed, "easy")
    ids, mask, _ = encode_prompts(problems, prompt_len)
    prompts = mix_prompt_lengths(
        [ids[i][mask[i]] for i in range(n_prompts)], seed, plen_dist)
    total = n_prompts * group_size
    rng = np.random.default_rng(seed + 1)
    lo = max(2, max_new // 16)
    spread = [lo, max(lo, max_new // 4), max(lo, max_new // 2), max_new]
    caps = rng.choice(spread, size=total, p=[0.4, 0.3, 0.2, 0.1])
    return [Request(uid=u, prompt=prompts[u // group_size],
                    max_new_tokens=int(caps[u]))
            for u in range(total)]


def _bench_phase(arch: str, policy: str, group_size: int, n_prompts: int,
                 batch: int, prompt_len: int, max_new: int, block_size: int,
                 decode_chunk: int, seed: int, plen_dist: str = "fixed"):
    """One phase cell: lockstep full-width batch vs continuous-paged engine
    on the identical request set.  Returns the measured row dict.

    ``policy`` is a registry sampler-policy name (rollout.policies); the
    legacy compression spelling "none" still aliases to "dense" so the
    historical ``rollout_phase`` cells keep their committed row identity."""
    from repro.configs import SparseRLConfig, get_config
    from repro.data import TOKENIZER
    from repro.models import get_model
    from repro.rollout import (
        ContinuousEngine,
        LockstepServer,
        build_train_rollout,
        mismatch_kl_estimate,
        rescore,
        resolve_policy,
    )
    from dataclasses import replace

    cfg = get_config(arch).smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(seed))
    pol = resolve_policy("dense" if policy == "none" else policy)
    scfg = pol.apply(SparseRLConfig())
    if not pol.is_dense:
        scfg = replace(scfg, kv_budget=16, kv_buffer=8, obs_window=4,
                       num_sinks=2, reasoning_head_frac=0.5,
                       adaptive_min_frac=0.3,
                       adaptive_decay_tokens=max(max_new // 2, 8))
    total = n_prompts * group_size
    reqs = _phase_requests(n_prompts, group_size, prompt_len, max_new, seed,
                           plen_dist)

    # the Trainer's lockstep shape: ONE batch as wide as the whole phase,
    # decoded to the global max_new (LockstepServer with batch_size=total)
    srv = LockstepServer(params, cfg, m, scfg, batch_size=total,
                         prompt_len=prompt_len, max_new_tokens=max_new,
                         eos_id=TOKENIZER.eos_id, seed=seed)
    eng = ContinuousEngine(params, cfg, m, scfg, batch_size=batch,
                           prompt_len=prompt_len, max_new_tokens=max_new,
                           eos_id=TOKENIZER.eos_id, decode_chunk=decode_chunk,
                           seed=seed, cache_backend="paged",
                           block_size=block_size, kv_quant=pol.kv_quant)
    # cold run compiles both + measures the sharing behaviour.  The engine
    # runs the phase under LPT admission ("longest"): per-request caps are
    # known up front in an RL phase, so long-cap members start first and
    # overlap everyone else instead of draining near-alone at phase end
    # (token-identical either way: per-request key chains)
    lock, cont = srv.run(reqs), eng.run(reqs, group_size=group_size,
                                        schedule="longest")
    identical = all(np.array_equal(a.tokens, b.tokens)
                    for a, b in zip(cont, lock))
    hit_rate = eng.prefix_hit_rate
    prefills = int(eng.stats["prefills"])
    eng.end_phase()      # bulk release + allocator leak check, phase-style
    # warm best-of-N phase wall-clock (what the Trainer pays every step).
    # Each round also re-runs the SAME warm engine with a metrics-mode
    # Telemetry attached (interleaved, so load spikes hit both variants):
    # telemetry_overhead_frac is the bench-gated <= 3% bound and the
    # registry's duration histograms supply the phase-breakdown fractions
    # (DESIGN.md §Observability & telemetry)
    from repro.telemetry import Telemetry

    tel = Telemetry("metrics", console_level=None)
    t_lock = t_cont = t_tel = float("inf")
    tel_wall = 0.0
    for _ in range(3):
        t0 = time.perf_counter()
        lock = srv.run(reqs)
        t_lock = min(t_lock, time.perf_counter() - t0)
        eng.reset_clock()
        t0 = time.perf_counter()
        cont = eng.run(reqs, group_size=group_size, schedule="longest")
        t_last = time.perf_counter() - t0
        t_cont = min(t_cont, t_last)
        run_stats = dict(eng.stats)        # per-run counters (clock reset)
        phase_stats = eng.end_phase()
        eng.set_telemetry(tel)
        eng.reset_clock()
        t0 = time.perf_counter()
        eng.run(reqs, group_size=group_size, schedule="longest")
        dt = time.perf_counter() - t0
        t_tel = min(t_tel, dt)
        tel_wall += dt
        eng.end_phase()
        eng.set_telemetry(None)
    snap = tel.metrics.snapshot()

    def _frac(hist_name: str) -> float:
        return snap.get(hist_name, {}).get("sum", 0.0) / max(tel_wall, 1e-12)

    # trainer-ready assembly + the masked mismatch-KL statistic
    ids = np.zeros((total, prompt_len), np.int32)
    pmask = np.zeros((total, prompt_len), bool)
    for r in reqs:
        p = np.asarray(r.prompt, np.int32)
        ids[r.uid, prompt_len - len(p):] = p
        pmask[r.uid, prompt_len - len(p):] = True
    tr = build_train_rollout(cont, ids, pmask, max_new_tokens=max_new,
                             pad_id=eng.pad_id, stats=eng.stats)
    logp_old = rescore(params, cfg, m, tr.rollout)
    kl = float(mismatch_kl_estimate(logp_old, tr.rollout.logp_sparse,
                                    tr.rollout.resp_mask,
                                    lengths=tr.rollout.lengths))
    toks = int(np.sum(np.asarray(tr.rollout.lengths)))
    # quant rows legitimately diverge from the fp lockstep oracle (the
    # quantized cache IS the policy gap) — they carry the pool-capacity
    # bound instead of an ``identical`` pin
    extra = (dict(identical=identical) if pol.kv_quant == "none" else
             dict(kv_quant=pol.kv_quant,
                  capacity_ratio=float(phase_stats["kv_capacity_ratio"])))
    return dict(arch=arch, policy=policy, group_size=group_size,
                n_prompts=n_prompts, batch=batch, max_new=max_new,
                plen_dist=plen_dist, config_source=config_source(),
                tokens=toks,
                lockstep_s=t_lock, continuous_s=t_cont,
                lockstep_tps=toks / t_lock, continuous_tps=toks / t_cont,
                speedup=t_lock / t_cont, **extra,
                prefix_hit_rate=hit_rate,
                target_hit_rate=(group_size - 1) / group_size,
                prefills=prefills, admissions=int(eng.stats["admissions"]),
                lockstep_decode_steps=max_new,
                useful_token_frac=toks / (total * max_new),
                # host-side admission-dispatch share of the last warm run
                # (the chunked-prefill cost the decode batch never stalls on)
                prefill_s=float(run_stats["prefill_s"]),
                prefill_s_frac=float(run_stats["prefill_s"]) / max(t_last,
                                                                   1e-12),
                prefill_dispatches=int(run_stats["prefill_dispatches"]),
                prefill_tokens=int(run_stats["prefill_tokens"]),
                wasted_row_frac=(float(run_stats["wasted_row_steps"])
                                 / max(run_stats["decode_steps"] * batch, 1)),
                # telemetry=metrics cost + the breakdown it buys (fractions
                # of the instrumented runs' wall-clock; gate bound <= 3%)
                telemetry_overhead_frac=t_tel / t_cont - 1.0,
                telemetry_s=t_tel,
                prefill_frac=_frac("admit_sweep_s"),
                decode_frac=_frac("decode_chunk_s"),
                harvest_frac=_frac("harvest_s"),
                mismatch_kl=kl)


def rollout_train_bench(fast: bool = False, *, arch: str = "qwen2.5-14b",
                        seed: int = 0) -> List[str]:
    """Continuous-paged rollout phase vs lockstep; writes the
    ``rollout_phase`` section of BENCH_rollout.json.  The acceptance bound
    (continuous phase wall-clock <= lockstep on mixed-length groups) is
    enforced here and re-enforced by the CI gate on the smoke section.

    Cells sweep the PROMPT-length distribution too (``plen_dist``): "fixed"
    keeps every prompt at its natural encoded length (the historical cell);
    "mixed" spreads full/half/quarter prompt lengths — where the
    length-aware hot loop (chunked batched prefill + fill-aware decode +
    async harvest) actually earns its win, because lockstep pads every
    prompt to P while the engine buckets them."""
    cells = ((("none", 4, 4, "fixed"), ("none", 4, 4, "mixed")) if fast else
             (("none", 8, 4, "fixed"), ("none", 8, 4, "mixed"),
              ("rkv", 8, 4, "mixed")))
    max_new = 32 if fast else 64
    # full phases harvest every 16 steps (mean response ~17 tokens: fewer
    # host syncs, recycling still fine-grained); the short smoke phases
    # (max_new 32) keep 8 so slots still turn over a few times per phase
    decode_chunk = 8 if fast else 16
    rows, out = [], []
    for policy, group_size, n_prompts, plen_dist in cells:
        # engine rows = half the phase: slots recycle across groups but each
        # decode step stays wide enough to amortize dispatch (the Trainer's
        # decode_batch auto-default makes the same choice)
        batch = n_prompts * group_size // 2
        # block_size 8 gives the pool TWO admission buckets (16 and 8) at
        # prompt_len 16, so the mixed-plen cells actually exercise the
        # short bucket (pool bucket widths are P - j*block_size)
        r = _bench_phase(arch, policy, group_size, n_prompts, batch=batch,
                         prompt_len=16, max_new=max_new, block_size=8,
                         decode_chunk=decode_chunk, seed=seed,
                         plen_dist=plen_dist)
        rows.append(r)
        base = f"rollout_phase/{policy}/g{group_size}/{plen_dist}"
        out.append(f"{base}/lockstep,{r['lockstep_s']*1e6:.0f},"
                   f"toks_per_s={r['lockstep_tps']:.1f};"
                   f"useful_frac={r['useful_token_frac']:.2f}")
        out.append(f"{base}/continuous_paged,{r['continuous_s']*1e6:.0f},"
                   f"toks_per_s={r['continuous_tps']:.1f};"
                   f"speedup={r['speedup']:.2f};"
                   f"identical={r['identical']};"
                   f"prefix_hit_rate={r['prefix_hit_rate']:.2f};"
                   f"prefill_s={r['prefill_s_frac']:.2f};"
                   f"wasted_row_frac={r['wasted_row_frac']:.2f};"
                   f"tel_overhead={r['telemetry_overhead_frac']:+.3f};"
                   f"mismatch_kl={r['mismatch_kl']:.4f}")
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "rollout.json"), "w") as f:
        json.dump(rows, f, indent=1)
    update_bench_json(BENCH_JSON,
                      "rollout_phase" + ("_smoke" if fast else ""), rows)
    return out


def rollout_matrix_bench(fast: bool = False, *, arch: str = "qwen2.5-14b",
                         seed: int = 0) -> List[str]:
    """Sampler-policy matrix cells (DESIGN.md §Sampler policy registry):
    writes the ``rollout_matrix(_smoke)`` section of BENCH_rollout.json.

    Phase cells run the NEW registry policies (per_head, adaptive, and the
    quantized pool) through the same lockstep-vs-continuous phase harness as
    ``rollout_phase`` across both prompt-length dists; non-quant rows keep
    the ``identical`` scheduler pin, quant rows carry ``capacity_ratio``
    instead.  Trainer cells then run a short smoke-curriculum Trainer per
    sparse policy via ``TrainerOptions.sampler_policy`` — the registry path
    the CLIs use — recording the reward trajectory; ``reward_nondegrading``
    is a hard gate bound (the paper's stability claim, matrix-scale).
    ``speedup`` on trainer rows is steps/s vs the rkv trainer (banded, not
    floored: the per-head fused kernel and the adaptive re-ranking both
    trade FLOPs for memory)."""
    import shutil
    from repro.configs import SparseRLConfig, TrainConfig, get_config
    from repro.runtime import Trainer, TrainerOptions

    group_size, n_prompts = (4, 4) if fast else (8, 4)
    max_new = 32 if fast else 64
    rows, out = [], []
    cells = [("per_head", "fixed"), ("per_head", "mixed"),
             ("adaptive", "fixed"), ("adaptive", "mixed"),
             ("quant-int8", "mixed")]
    for policy, plen_dist in cells:
        r = _bench_phase(arch, policy, group_size, n_prompts,
                         batch=n_prompts * group_size // 2, prompt_len=16,
                         max_new=max_new, block_size=8,
                         decode_chunk=8 if fast else 16, seed=seed,
                         plen_dist=plen_dist)
        rows.append(r)
        out.append(f"rollout_matrix/{policy}/{plen_dist},"
                   f"{r['continuous_s']*1e6:.0f},"
                   f"toks_per_s={r['continuous_tps']:.1f};"
                   f"speedup={r['speedup']:.2f};"
                   + (f"identical={r['identical']};" if "identical" in r
                      else f"capacity={r['capacity_ratio']:.2f}x;")
                   + f"mismatch_kl={r['mismatch_kl']:.4f}")

    # trainer stability cells: one short run per sparse policy
    steps = 12 if fast else 24
    warmup = 3
    sps_by_p = {}
    for policy in ("rkv", "per_head", "adaptive"):
        cfg = get_config(arch).smoke()
        scfg = SparseRLConfig(kv_budget=8, kv_buffer=4, obs_window=4,
                              num_sinks=2, group_size=4,
                              max_new_tokens=8, learning_rate=2e-3,
                              kl_coef=0.0, reasoning_head_frac=0.5,
                              adaptive_min_frac=0.3,
                              adaptive_decay_tokens=8)
        ckpt = f"/tmp/srl_bench_matrix_{policy}_{seed}"
        shutil.rmtree(ckpt, ignore_errors=True)
        tcfg = TrainConfig(update_batch=16, total_steps=steps + warmup,
                           warmup_steps=2, checkpoint_every=0,
                           checkpoint_dir=ckpt, seed=seed)
        opts = TrainerOptions(num_prompts=4, prompt_len=12,
                              max_new_tokens=8, level="trivial",
                              rollout_backend="continuous",
                              cache_backend="paged", decode_chunk=2,
                              sampler_policy=policy)
        tr = Trainer(cfg, scfg, tcfg, opts)
        hist = tr.train(warmup, log_every=0)
        t0 = time.perf_counter()
        hist += tr.train(steps, log_every=0)
        sps = steps / (time.perf_counter() - t0)
        sps_by_p[policy] = sps
        rewards = [m["reward"] for m in hist]
        half = len(rewards) // 2
        r_first = float(np.mean(rewards[:half]))
        r_second = float(np.mean(rewards[half:]))
        slack = max(0.02, 0.5 * r_first)   # scale-aware stability bound
        rows.append(dict(
            arch=arch, policy=policy, plen_dist="train",
            config_source=config_source(),
            group_size=4, n_prompts=4, steps=steps + warmup,
            steps_s=sps, speedup=sps / sps_by_p["rkv"],
            mismatch_kl=float(np.mean([m["mismatch_kl"]
                                       for m in hist[warmup:]])),
            rejection_rate=float(np.mean([m["rejection_rate"]
                                          for m in hist[warmup:]])),
            reward_first_half=r_first, reward_second_half=r_second,
            reward_nondegrading=bool(r_second >= r_first - slack),
            **_resilience_fields(hist)))
        r = rows[-1]
        out.append(f"rollout_matrix/{policy}/train,{1e6 / r['steps_s']:.0f},"
                   f"steps_per_s={r['steps_s']:.3f};"
                   f"speedup={r['speedup']:.2f};"
                   f"mismatch_kl={r['mismatch_kl']:.4f};"
                   f"reward={r['reward_first_half']:.3f}->"
                   f"{r['reward_second_half']:.3f}")
        del tr
    update_bench_json(BENCH_JSON,
                      "rollout_matrix" + ("_smoke" if fast else ""), rows)
    return out


def _async_trainer(arch: str, *, pipeline: str, max_lag: int, seed: int,
                   n_prompts: int, group_size: int, max_new: int):
    """Smoke-curriculum Trainer on the continuous-paged backend — the
    setting where the reduced model shows real reward movement — built
    sync or async for the steps/s and stability cells."""
    import shutil
    from repro.configs import SparseRLConfig, TrainConfig, get_config
    from repro.runtime import Trainer, TrainerOptions

    cfg = get_config(arch).smoke()
    scfg = SparseRLConfig(kv_budget=8, kv_buffer=2, obs_window=2,
                          num_sinks=1, group_size=group_size,
                          max_new_tokens=max_new, learning_rate=2e-3,
                          kl_coef=0.0, compression="rkv")
    ckpt = f"/tmp/srl_bench_async_{pipeline}{max_lag}_{seed}"
    shutil.rmtree(ckpt, ignore_errors=True)
    tcfg = TrainConfig(update_batch=64, total_steps=64, warmup_steps=5,
                       checkpoint_every=0, checkpoint_dir=ckpt, seed=seed)
    opts = TrainerOptions(num_prompts=n_prompts, prompt_len=12,
                          max_new_tokens=max_new, level="trivial",
                          rollout_backend="continuous",
                          cache_backend="paged", decode_chunk=2,
                          pipeline=pipeline, max_lag=max_lag)
    return Trainer(cfg, scfg, tcfg, opts)


def rollout_async_bench(fast: bool = False, *, arch: str = "qwen2.5-14b",
                        seed: int = 0) -> List[str]:
    """Async actor-learner pipeline vs the sync trainer
    (DESIGN.md §Async pipeline & staleness correction): writes the
    ``rollout_async(_smoke)`` section of BENCH_rollout.json.

    Two cells: ``max_lag=0`` re-checks the hard identity bound (the
    serialized pipeline must reproduce the sync trainer's rollouts
    token-for-token — cheap insurance that CI re-verifies on every push
    next to the e2e test), and ``max_lag=1`` records the overlapped
    steps/s against the sync trainer plus the reward trajectory, whose
    non-degradation the bench gate enforces as a hard bound."""
    n_prompts, G = (4, 4) if fast else (8, 8)
    max_new = 8
    steps = 24 if fast else 48
    warmup = 4          # covers the lag>=1 stale-update/behavior-rescore
                        # compiles (staleness appears from step 2 on)
    kw = dict(arch=arch, seed=seed, n_prompts=n_prompts, group_size=G,
              max_new=max_new)

    def timed_run(pipeline, max_lag, n):
        tr = _async_trainer(pipeline=pipeline, max_lag=max_lag, **kw)
        rolls = []

        def cap(step, metrics):
            rolls.append(np.asarray(
                jax.device_get(tr.last_rollout.resp_tokens)))

        hist = tr.train(warmup, log_every=0, callback=cap)
        t0 = time.perf_counter()
        hist += tr.train(n, log_every=0, callback=cap)
        return tr, hist, rolls, n / (time.perf_counter() - t0)

    _, h_sync, rolls_sync, sync_sps = timed_run("sync", 0, steps)
    _, h_lag0, rolls_lag0, lag0_sps = timed_run("async", 0, steps)
    tr1, h_lag1, _, lag1_sps = timed_run("async", 1, steps)

    identical = all(np.array_equal(a, b)
                    for a, b in zip(rolls_sync, rolls_lag0))
    rewards = [m["reward"] for m in h_lag1]
    half = len(rewards) // 2
    r_first, r_second = float(np.mean(rewards[:half])), float(
        np.mean(rewards[half:]))
    # stability bound sized to the reward scale, not an absolute slack: a
    # collapse to zero from any measurable reward level must fail, while
    # sub-noise-floor rewards (< ~0.02 at smoke scale) stay un-gateable
    slack = max(0.02, 0.5 * r_first)
    rows = [
        dict(arch=arch, policy="rkv", max_lag=0, steps=steps + warmup,
             group_size=G, n_prompts=n_prompts,
             config_source=config_source(),
             sync_steps_s=sync_sps, async_steps_s=lag0_sps,
             speedup=lag0_sps / sync_sps, identical=identical,
             reward_nondegrading=True, **_resilience_fields(h_lag0)),
        dict(arch=arch, policy="rkv", max_lag=1, steps=steps + warmup,
             group_size=G, n_prompts=n_prompts,
             config_source=config_source(),
             sync_steps_s=sync_sps, async_steps_s=lag1_sps,
             speedup=lag1_sps / sync_sps,
             reward_first_half=r_first, reward_second_half=r_second,
             reward_nondegrading=bool(r_second >= r_first - slack),
             staleness_lag_mean=float(np.mean(
                 [m["staleness_lag"] for m in h_lag1])),
             weight_swaps=int(sum(
                 m["rollout_weight_swaps"] for m in h_lag1)),
             **_resilience_fields(h_lag1)),
    ]
    del tr1
    update_bench_json(BENCH_JSON,
                      "rollout_async" + ("_smoke" if fast else ""), rows)
    out = []
    for r in rows:
        out.append(
            f"rollout_async/lag{r['max_lag']},"
            f"{1e6 / r['async_steps_s']:.0f},"
            f"steps_per_s={r['async_steps_s']:.3f};"
            f"sync_steps_per_s={r['sync_steps_s']:.3f};"
            f"speedup={r['speedup']:.2f};"
            + (f"identical={r['identical']}" if "identical" in r else
               f"reward={r['reward_first_half']:.3f}->"
               f"{r['reward_second_half']:.3f};"
               f"staleness_lag={r['staleness_lag_mean']:.2f};"
               f"swaps={r['weight_swaps']}"))
    return out


def _quant_trainer(arch: str, *, kv_quant: str, seed: int, n_prompts: int,
                   group_size: int, max_new: int):
    """Smoke-curriculum Trainer on the continuous-paged backend with a
    ``kv_quant`` pool.  ``compression="none"`` on purpose: the quantized
    pool is then the ONLY behavior/trainer policy gap, so the xi/rejection
    correction measured here is pure quantization mismatch."""
    import shutil
    from repro.configs import SparseRLConfig, TrainConfig, get_config
    from repro.runtime import Trainer, TrainerOptions

    cfg = get_config(arch).smoke()
    scfg = SparseRLConfig(group_size=group_size, max_new_tokens=max_new,
                          learning_rate=2e-3, kl_coef=0.0,
                          compression="none")
    ckpt = f"/tmp/srl_bench_quant_{kv_quant}_{seed}"
    shutil.rmtree(ckpt, ignore_errors=True)
    tcfg = TrainConfig(update_batch=64, total_steps=64, warmup_steps=5,
                       checkpoint_every=0, checkpoint_dir=ckpt, seed=seed)
    opts = TrainerOptions(num_prompts=n_prompts, prompt_len=12,
                          max_new_tokens=max_new, level="trivial",
                          rollout_backend="continuous",
                          cache_backend="paged", kv_quant=kv_quant,
                          decode_chunk=2)
    return Trainer(cfg, scfg, tcfg, opts)


def rollout_quant_bench(fast: bool = False, *, arch: str = "qwen2.5-14b",
                        seed: int = 0) -> List[str]:
    """Quantized-pool RL rollouts as a corrected sampler policy
    (DESIGN.md §Quantized paged pool): writes the ``rollout_quant(_smoke)``
    section of BENCH_rollout.json.

    One short training run per ``kv_quant`` in (none, int8, fp8).  The
    quantized engine's recorded log-probs become ``logp_sparse`` while the
    dense rescore supplies pi_old, so the existing xi/rejection/reweighting
    machinery absorbs the quantization mismatch — the cells record the
    reward trajectory (non-degradation is a hard gate bound), the masked
    mismatch-KL it induces, and the pool-capacity payoff (bytes per
    resident token; int8 >= 1.8x fp is the acceptance bar).  ``speedup``
    (quant vs fp steps/s) is banded by the gate, not floored: on CPU the
    dequant work can outweigh the bandwidth it saves."""
    n_prompts, G = (4, 4) if fast else (8, 8)
    max_new = 8
    steps = 16 if fast else 32
    warmup = 3
    rows, out, sps_by_q = [], [], {}
    for kv_quant in ("none", "int8", "fp8"):
        tr = _quant_trainer(arch, kv_quant=kv_quant, seed=seed,
                            n_prompts=n_prompts, group_size=G,
                            max_new=max_new)
        hist = tr.train(warmup, log_every=0)
        t0 = time.perf_counter()
        hist += tr.train(steps, log_every=0)
        sps = steps / (time.perf_counter() - t0)
        sps_by_q[kv_quant] = sps
        rewards = [m["reward"] for m in hist]
        half = len(rewards) // 2
        r_first = float(np.mean(rewards[:half]))
        r_second = float(np.mean(rewards[half:]))
        # same scale-aware stability bound as the async bench: collapse
        # from a measurable reward level fails, noise-floor rewards don't
        slack = max(0.02, 0.5 * r_first)
        last = hist[-1]
        rows.append(dict(
            arch=arch, policy="none", kv_quant=kv_quant,
            config_source=config_source(),
            steps=steps + warmup, group_size=G, n_prompts=n_prompts,
            steps_s=sps, speedup=sps / sps_by_q["none"],
            kv_bytes_per_token=float(last["rollout_kv_bytes_per_token"]),
            capacity_ratio=float(last["rollout_kv_capacity_ratio"]),
            mismatch_kl=float(np.mean([m["mismatch_kl"]
                                       for m in hist[warmup:]])),
            rejection_rate=float(np.mean([m["rejection_rate"]
                                          for m in hist[warmup:]])),
            reward_first_half=r_first, reward_second_half=r_second,
            reward_nondegrading=bool(r_second >= r_first - slack),
            **_resilience_fields(hist)))
        r = rows[-1]
        out.append(f"rollout_quant/{kv_quant},{1e6 / r['steps_s']:.0f},"
                   f"steps_per_s={r['steps_s']:.3f};"
                   f"speedup={r['speedup']:.2f};"
                   f"capacity={r['capacity_ratio']:.2f}x;"
                   f"bytes_per_token={r['kv_bytes_per_token']:.1f};"
                   f"mismatch_kl={r['mismatch_kl']:.4f};"
                   f"reward={r['reward_first_half']:.3f}->"
                   f"{r['reward_second_half']:.3f}")
        del tr
    update_bench_json(BENCH_JSON,
                      "rollout_quant" + ("_smoke" if fast else ""), rows)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast workload (CPU CI)")
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for r in rollout_train_bench(fast=args.smoke, arch=args.arch,
                                 seed=args.seed):
        print(r, flush=True)
    for r in rollout_async_bench(fast=args.smoke, arch=args.arch,
                                 seed=args.seed):
        print(r, flush=True)
    for r in rollout_quant_bench(fast=args.smoke, arch=args.arch,
                                 seed=args.seed):
        print(r, flush=True)
    for r in rollout_matrix_bench(fast=args.smoke, arch=args.arch,
                                  seed=args.seed):
        print(r, flush=True)
    # acceptance bar: the continuous-paged phase must not be slower than the
    # lockstep phase, token-identically (the ISSUE-3 bound; the CI smoke
    # gate re-checks the committed JSON so it cannot silently regress)
    with open(os.path.join(OUT, "rollout.json")) as f:
        rows = json.load(f)
    worst = min(r["speedup"] for r in rows)
    ok = worst >= 1.0 and all(r["identical"] for r in rows)
    print(f"continuous_paged<=lockstep phase wall-clock: worst speedup "
          f"{worst:.2f}x, identical={all(r['identical'] for r in rows)} "
          f"({'PASS' if ok else 'FAIL'}) -> {BENCH_JSON}")
    # async acceptance: lag-0 token identity + lag>=1 reward stability
    # (ISSUE-5 bound; steps/s is recorded, not floored — overlap gains are
    # hardware-dependent and the regression gate bands them instead)
    with open(BENCH_JSON) as f:
        arows = json.load(f)["rollout_async" + ("_smoke" if args.smoke
                                                else "")]
    aok = (all(r.get("identical", True) for r in arows)
           and all(r["reward_nondegrading"] for r in arows))
    print(f"async pipeline: lag0 identical="
          f"{all(r.get('identical', True) for r in arows)}, reward "
          f"nondegrading={all(r['reward_nondegrading'] for r in arows)} "
          f"({'PASS' if aok else 'FAIL'})")
    # quant acceptance: int8 pool >= 1.8x effective capacity and reward
    # nondegrading under quantized rollouts (the ISSUE-6 bounds; the gate
    # re-enforces both on the committed smoke rows)
    with open(BENCH_JSON) as f:
        qrows = json.load(f)["rollout_quant" + ("_smoke" if args.smoke
                                                else "")]
    by_q = {r["kv_quant"]: r for r in qrows}
    qok = (by_q["int8"]["capacity_ratio"] >= 1.8
           and all(r["reward_nondegrading"] for r in qrows))
    print(f"quantized rollouts: int8 capacity "
          f"{by_q['int8']['capacity_ratio']:.2f}x>=1.8x, reward "
          f"nondegrading={all(r['reward_nondegrading'] for r in qrows)} "
          f"({'PASS' if qok else 'FAIL'})")
    # matrix acceptance: non-quant phase cells keep the scheduler identity
    # pin, quant cells the capacity bound, trainer cells reward stability
    with open(BENCH_JSON) as f:
        mrows = json.load(f)["rollout_matrix" + ("_smoke" if args.smoke
                                                 else "")]
    mok = (all(r.get("identical", True) for r in mrows)
           and all(r.get("capacity_ratio", 1.8) >= 1.8 for r in mrows)
           and all(r.get("reward_nondegrading", True) for r in mrows))
    print(f"sampler-policy matrix: identical="
          f"{all(r.get('identical', True) for r in mrows)}, reward "
          f"nondegrading={all(r.get('reward_nondegrading', True) for r in mrows)} "
          f"({'PASS' if mok else 'FAIL'})")
    return 0 if (ok and aok and qok and mok) else 1


if __name__ == "__main__":
    raise SystemExit(main())
