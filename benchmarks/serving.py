"""Serving benchmarks: (1) continuous batching vs lockstep across
compression policies and batch sizes, (2) paged cache backend (block-table
pool + prefix sharing) vs contiguous on GRPO group-sampling workloads.

The (1) workload has mixed response lengths (per-request new-token caps
drawn from a fixed spread), which is exactly where lockstep decoding bleeds:
every batch runs to the global ``max_new`` while finished rows feed padding,
so its useful-token fraction is mean(cap)/max_new.  Continuous batching
recycles a finished row's fixed-size slot block into the next queued prompt
and keeps the decode batch full.  The (2) workload repeats each prompt G
times (group sampling): the paged backend must prefill each prompt once
(cold prefix-hit rate (G-1)/G) and store its full prompt pages once,
refcount-shared (DESIGN.md §Paged cache & prefix sharing).  Every engine
pair is token-identical per request (same per-request key chains), so the
comparisons are pure scheduling/caching.

  PYTHONPATH=src python -m benchmarks.serving --smoke
  PYTHONPATH=src python -m benchmarks.serving --smoke --policies rkv,none

Row format matches benchmarks.run: ``name,us_per_call,derived``.  Machine-
readable results land in reports/benchmarks/serving.json and — the
cross-PR perf trajectory — BENCH_serving.json at the repo root (throughput,
p50/p99 latency, prefix-hit rate).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Optional

import jax
import numpy as np

from benchmarks.common import REPO_ROOT, config_source
from benchmarks.common import update_bench_json as _update_json

OUT = "reports/benchmarks"
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_serving.json")


def _pct(completions, q):
    from repro.launch.serve import _pct as pct

    return pct([c.latency for c in completions], q)


def update_bench_json(section: str, payload) -> str:
    """Merge one section into BENCH_serving.json (see common.py helper)."""
    return _update_json(BENCH_JSON, section, payload)


def _make_requests(n: int, prompt_len: int, max_new: int, seed: int,
                   plen_dist: str = "fixed"):
    """n burst-arrival requests with the serve CLI's long-tailed spread of
    per-request response caps (most responses short, a few near ``max_new``
    — the shape real serving traffic has, and the regime where lockstep
    decoding pays ``max_new`` steps for every row).  ``plen_dist="mixed"``
    additionally spreads prompt lengths — where the chunked-prefill length
    buckets stop short prompts paying engine-wide padding at admission."""
    from repro.launch.serve import make_workload

    reqs, _, _ = make_workload(n, prompt_len, max_new, rate=0.0,
                               resp_dist="mixed", seed=seed,
                               plen_dist=plen_dist)
    return reqs


def _bench_one(arch: str, policy: str, batch: int, n_requests: int,
               prompt_len: int, max_new: int, decode_chunk: int, seed: int,
               plen_dist: str = "fixed"):
    """Returns a dict of measured numbers for one (policy, batch) cell."""
    from dataclasses import replace

    from repro.configs import SparseRLConfig, get_config
    from repro.data import TOKENIZER
    from repro.models import get_model
    from repro.rollout import ContinuousEngine, LockstepServer

    cfg = get_config(arch).smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(seed))
    scfg = SparseRLConfig(compression=policy)
    if policy != "none":
        scfg = replace(scfg, kv_budget=16, kv_buffer=8, obs_window=4,
                       num_sinks=2)
    reqs = _make_requests(n_requests, prompt_len, max_new, seed, plen_dist)

    srv = LockstepServer(params, cfg, m, scfg, batch_size=batch,
                         prompt_len=prompt_len, max_new_tokens=max_new,
                         eos_id=TOKENIZER.eos_id, seed=seed)
    eng = ContinuousEngine(params, cfg, m, scfg, batch_size=batch,
                           prompt_len=prompt_len, max_new_tokens=max_new,
                           eos_id=TOKENIZER.eos_id, decode_chunk=decode_chunk,
                           seed=seed)
    # warm both (compile), then interleave best-of-N so machine-load drift
    # hits both schedulers alike; best-of filters the noise floor.  The
    # engine clock/stats reset each repeat so reported counters are per-run.
    lock, cont = srv.run(reqs), eng.run(reqs)
    t_lock = t_cont = float("inf")
    for _ in range(4):
        t0 = time.perf_counter()
        lock = srv.run(reqs)
        t_lock = min(t_lock, time.perf_counter() - t0)
        eng.reset_clock()
        t0 = time.perf_counter()
        cont = eng.run(reqs)
        t_cont = min(t_cont, time.perf_counter() - t0)

    toks_lock = sum(len(c.tokens) for c in lock)
    toks_cont = sum(len(c.tokens) for c in cont)
    identical = all(np.array_equal(a.tokens, b.tokens)
                    for a, b in zip(cont, lock))
    return dict(policy=policy, batch=batch, n_requests=n_requests,
                max_new=max_new, plen_dist=plen_dist,
                config_source=config_source(), tokens=toks_cont,
                lockstep_s=t_lock, continuous_s=t_cont,
                lockstep_tps=toks_lock / t_lock,
                continuous_tps=toks_cont / t_cont,
                speedup=t_lock / t_cont, identical=identical,
                latency_p50_s=_pct(cont, 50), latency_p99_s=_pct(cont, 99),
                decode_steps=int(eng.stats["decode_steps"]),
                wasted_row_steps=int(eng.stats["wasted_row_steps"]),
                prefill_s=float(eng.stats["prefill_s"]),
                prefill_dispatches=int(eng.stats["prefill_dispatches"]),
                prefill_tokens=int(eng.stats["prefill_tokens"]))


def serving_bench(fast: bool = False, *, arch: str = "qwen2.5-14b",
                  policies=("rkv", "none"), batches: Optional[tuple] = None,
                  seed: int = 0) -> List[str]:
    if batches is None:
        batches = (4,) if fast else (4, 8)
    n_requests = 12 if fast else 32
    max_new = 64 if fast else 96
    prompt_len = 16
    decode_chunk = 8
    rows, out = [], []
    for policy in policies:
        for batch in batches:
            # mixed prompt lengths only on the first batch size: the sweep
            # that shows the chunked-prefill win without doubling runtime
            plens = ("fixed", "mixed") if batch == batches[0] else ("fixed",)
            for plen_dist in plens:
                r = _bench_one(arch, policy, batch, n_requests, prompt_len,
                               max_new, decode_chunk, seed,
                               plen_dist=plen_dist)
                rows.append(r)
                base = f"serving/{policy}/b{batch}/{plen_dist}"
                out.append(f"{base}/lockstep,{r['lockstep_s']*1e6:.0f},"
                           f"toks_per_s={r['lockstep_tps']:.1f}")
                out.append(f"{base}/continuous,{r['continuous_s']*1e6:.0f},"
                           f"toks_per_s={r['continuous_tps']:.1f};"
                           f"speedup={r['speedup']:.2f};"
                           f"identical={r['identical']};"
                           f"prefill_dispatches={r['prefill_dispatches']}")
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "serving.json"), "w") as f:
        json.dump(rows, f, indent=1)
    # smoke-scale numbers get their own section so the cross-PR trajectory
    # never mixes non-comparable workloads
    update_bench_json("continuous_vs_lockstep" + ("_smoke" if fast else ""),
                      rows)
    return out


def _bench_paged_one(arch: str, group_size: int, n_prompts: int, batch: int,
                     prompt_len: int, max_new: int, block_size: int,
                     decode_chunk: int, seed: int):
    """One paged-vs-contiguous cell on a GRPO group-sampling workload:
    ``n_prompts`` prompts, each rolled out ``group_size`` times.  The paged
    backend must (a) produce token-identical outputs and (b) prefill every
    prompt exactly once — cold prefix-hit rate (G-1)/G."""
    from repro.configs import SparseRLConfig, get_config
    from repro.data import TOKENIZER
    from repro.launch.serve import make_workload
    from repro.models import get_model
    from repro.rollout import ContinuousEngine

    cfg = get_config(arch).smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(seed))
    scfg = SparseRLConfig(compression="none")   # the pool backend is dense
    reqs, _, _ = make_workload(n_prompts, prompt_len, max_new, rate=0.0,
                               resp_dist="mixed", seed=seed,
                               group_size=group_size)
    kw = dict(batch_size=batch, prompt_len=prompt_len,
              max_new_tokens=max_new, eos_id=TOKENIZER.eos_id,
              decode_chunk=decode_chunk, seed=seed)
    base = ContinuousEngine(params, cfg, m, scfg, **kw)
    eng = ContinuousEngine(params, cfg, m, scfg, cache_backend="paged",
                           block_size=block_size, **kw)
    # cold run: compiles + measures sharing (every hit skips one prefill)
    cont, paged = base.run(reqs), eng.run(reqs)
    identical = all(np.array_equal(a.tokens, b.tokens)
                    for a, b in zip(cont, paged))
    hit_rate = eng.prefix_hit_rate
    prefills = int(eng.stats["prefills"])
    blocks_peak = int(eng.stats["blocks_in_use_peak"])
    # warm best-of-N: scheduling + admission cost with a hot prefix cache
    t_base = t_paged = float("inf")
    for _ in range(3):
        base.reset_clock()
        t0 = time.perf_counter()
        cont = base.run(reqs)
        t_base = min(t_base, time.perf_counter() - t0)
        eng.reset_clock()
        t0 = time.perf_counter()
        paged = eng.run(reqs)
        t_paged = min(t_paged, time.perf_counter() - t0)
    toks = sum(len(c.tokens) for c in paged)
    return dict(arch=arch, group_size=group_size, n_prompts=n_prompts,
                batch=batch, block_size=block_size,
                config_source=config_source(), tokens=toks,
                contiguous_s=t_base, paged_s=t_paged,
                contiguous_tps=sum(len(c.tokens) for c in cont) / t_base,
                paged_tps=toks / t_paged,
                speedup=t_base / t_paged, identical=identical,
                prefix_hit_rate=hit_rate,
                target_hit_rate=(group_size - 1) / group_size,
                prefills=prefills, admissions=int(eng.stats["admissions"]),
                latency_p50_s=_pct(paged, 50), latency_p99_s=_pct(paged, 99),
                blocks_in_use_peak=blocks_peak,
                pool_blocks=eng.pool_blocks - 1)


def paged_prefix_bench(fast: bool = False, *, arch: str = "qwen2.5-14b",
                       seed: int = 0) -> List[str]:
    """Paged backend vs contiguous on group-sampling workloads; writes the
    ``paged_prefix`` section of BENCH_serving.json."""
    cells = ((4, 2),) if fast else ((4, 3), (8, 3))   # (G, n_prompts)
    max_new = 16 if fast else 48
    rows, out = [], []
    for group_size, n_prompts in cells:
        r = _bench_paged_one(arch, group_size, n_prompts, batch=4,
                             prompt_len=16, max_new=max_new, block_size=16,
                             decode_chunk=4, seed=seed)
        rows.append(r)
        base = f"serving/paged/g{group_size}"
        out.append(f"{base}/contiguous,{r['contiguous_s']*1e6:.0f},"
                   f"toks_per_s={r['contiguous_tps']:.1f}")
        out.append(f"{base}/paged,{r['paged_s']*1e6:.0f},"
                   f"toks_per_s={r['paged_tps']:.1f};"
                   f"identical={r['identical']};"
                   f"prefix_hit_rate={r['prefix_hit_rate']:.2f};"
                   f"prefills={r['prefills']}/{r['admissions']};"
                   f"blocks_peak={r['blocks_in_use_peak']}/{r['pool_blocks']}")
    update_bench_json("paged_prefix" + ("_smoke" if fast else ""), rows)
    return out


def _bench_quant_one(arch: str, kv_quant: str, group_size: int,
                     n_prompts: int, batch: int, prompt_len: int,
                     max_new: int, block_size: int, decode_chunk: int,
                     seed: int):
    """One quantized-pool cell: fp paged engine vs ``kv_quant`` paged engine
    on the same group-sampling workload.  Tokens may legitimately diverge —
    the quantized pool IS a different sampler policy (DESIGN.md §Quantized
    paged pool) — so there is no ``identical`` bound here; what the cell
    pins is effective pool capacity (bytes per resident token vs an fp pool
    at equal block count) and the size of the policy gap (mean |delta
    logp| over each request pair's shared prefix).  ``kv_quant="none"``
    doubles as the identity sanity row: same path, logp_mad exactly 0."""
    from repro.configs import SparseRLConfig, get_config
    from repro.data import TOKENIZER
    from repro.launch.serve import make_workload
    from repro.models import get_model
    from repro.rollout import ContinuousEngine

    cfg = get_config(arch).smoke()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(seed))
    scfg = SparseRLConfig(compression="none")   # the pool backend is dense
    reqs, _, _ = make_workload(n_prompts, prompt_len, max_new, rate=0.0,
                               resp_dist="mixed", seed=seed,
                               group_size=group_size)
    kw = dict(batch_size=batch, prompt_len=prompt_len,
              max_new_tokens=max_new, eos_id=TOKENIZER.eos_id,
              decode_chunk=decode_chunk, seed=seed, cache_backend="paged",
              block_size=block_size)
    base = ContinuousEngine(params, cfg, m, scfg, **kw)
    eng = ContinuousEngine(params, cfg, m, scfg, kv_quant=kv_quant, **kw)
    fp, qt = base.run(reqs), eng.run(reqs)
    # sampler-policy gap: |logp_fp - logp_quant| over each pair's shared
    # prefix (identical per-request key chains, so positions align until
    # the first token the policies disagree on)
    diffs = []
    for a, b in zip(fp, qt):
        n = min(len(a.logps), len(b.logps))
        if n:
            diffs.append(np.abs(np.asarray(a.logps[:n], np.float64)
                                - np.asarray(b.logps[:n], np.float64)))
    logp_mad = float(np.mean(np.concatenate(diffs))) if diffs else 0.0
    hit_rate = eng.prefix_hit_rate
    ps = eng.kv_pool_stats()
    t_fp = t_q = float("inf")
    for _ in range(3):
        base.reset_clock()
        t0 = time.perf_counter()
        fp = base.run(reqs)
        t_fp = min(t_fp, time.perf_counter() - t0)
        eng.reset_clock()
        t0 = time.perf_counter()
        qt = eng.run(reqs)
        t_q = min(t_q, time.perf_counter() - t0)
    toks = sum(len(c.tokens) for c in qt)
    return dict(arch=arch, kv_quant=kv_quant, group_size=group_size,
                n_prompts=n_prompts, batch=batch, block_size=block_size,
                config_source=config_source(),
                tokens=toks, fp_s=t_fp, quant_s=t_q,
                fp_tps=sum(len(c.tokens) for c in fp) / t_fp,
                quant_tps=toks / t_q, speedup=t_fp / t_q,
                logp_mad=logp_mad, prefix_hit_rate=hit_rate,
                target_hit_rate=(group_size - 1) / group_size,
                kv_pool_bytes_per_layer=ps["kv_pool_bytes_per_layer"],
                kv_bytes_per_token=ps["kv_bytes_per_token"],
                capacity_ratio=ps["kv_capacity_ratio"])


def paged_quant_bench(fast: bool = False, *, arch: str = "qwen2.5-14b",
                      seed: int = 0) -> List[str]:
    """Quantized paged pool vs fp paged pool; writes the ``paged_quant``
    section of BENCH_serving.json.  Acceptance (re-enforced by
    tools/bench_gate.py on the committed smoke rows): int8 capacity ratio
    >= 1.8x, and the ``none`` row's logp_mad identically 0."""
    group_size, n_prompts = (4, 2) if fast else (4, 3)
    max_new = 16 if fast else 48
    rows, out = [], []
    for kv_quant in ("none", "int8", "fp8"):
        r = _bench_quant_one(arch, kv_quant, group_size, n_prompts, batch=4,
                             prompt_len=16, max_new=max_new, block_size=16,
                             decode_chunk=4, seed=seed)
        rows.append(r)
        out.append(f"serving/paged_quant/{kv_quant},{r['quant_s']*1e6:.0f},"
                   f"toks_per_s={r['quant_tps']:.1f};"
                   f"speedup={r['speedup']:.2f};"
                   f"capacity={r['capacity_ratio']:.2f}x;"
                   f"bytes_per_token={r['kv_bytes_per_token']:.1f};"
                   f"logp_mad={r['logp_mad']:.4f};"
                   f"prefix_hit_rate={r['prefix_hit_rate']:.2f}")
    update_bench_json("paged_quant" + ("_smoke" if fast else ""), rows)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast workload (CPU CI)")
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--policies", default="rkv,none",
                    help="comma-separated compression policies to compare")
    ap.add_argument("--batches", default=None,
                    help="comma-separated decode batch sizes")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    batches = (tuple(int(b) for b in args.batches.split(","))
               if args.batches else None)
    print("name,us_per_call,derived")
    rows = serving_bench(fast=args.smoke, arch=args.arch,
                         policies=tuple(args.policies.split(",")),
                         batches=batches, seed=args.seed)
    rows += paged_prefix_bench(fast=args.smoke, arch=args.arch,
                               seed=args.seed)
    rows += paged_quant_bench(fast=args.smoke, arch=args.arch,
                              seed=args.seed)
    for r in rows:
        print(r, flush=True)
    # acceptance bar 1: continuous must not serve slower than lockstep
    with open(os.path.join(OUT, "serving.json")) as f:
        results = json.load(f)
    worst = min(r["speedup"] for r in results)
    ok = worst >= 1.0 and all(r["identical"] for r in results)
    print(f"continuous>=lockstep: {worst:.2f}x worst-case speedup "
          f"({'PASS' if ok else 'FAIL'})")
    # acceptance bar 2: the paged backend must be token-identical and
    # prefill a G-way shared prompt once (cold hit rate >= (G-1)/G)
    with open(BENCH_JSON) as f:
        paged = json.load(f)[
            "paged_prefix" + ("_smoke" if args.smoke else "")]
    ok2 = all(r["identical"] and
              r["prefix_hit_rate"] >= r["target_hit_rate"] - 1e-9
              for r in paged)
    print(f"paged: identical={all(r['identical'] for r in paged)}, "
          f"hit rates " +
          ",".join(f"{r['prefix_hit_rate']:.2f}>={r['target_hit_rate']:.2f}"
                   for r in paged) +
          f" ({'PASS' if ok2 else 'FAIL'}) -> {BENCH_JSON}")
    # acceptance bar 3: int8 pool capacity >= 1.8x fp at equal block count,
    # and the kv_quant="none" cell must be the exact fp path (logp_mad 0)
    with open(BENCH_JSON) as f:
        quant = json.load(f)["paged_quant" + ("_smoke" if args.smoke
                                              else "")]
    by_q = {r["kv_quant"]: r for r in quant}
    ok3 = (by_q["int8"]["capacity_ratio"] >= 1.8
           and by_q["none"]["logp_mad"] == 0.0)
    print(f"paged_quant: int8 capacity "
          f"{by_q['int8']['capacity_ratio']:.2f}x>=1.8x, none logp_mad="
          f"{by_q['none']['logp_mad']:.4f} "
          f"({'PASS' if ok3 else 'FAIL'})")
    return 0 if ok and ok2 and ok3 else 1


if __name__ == "__main__":
    raise SystemExit(main())
